//! BENCH — §Fault injection (PR 8): degraded fleets, retry-with-backoff
//! pricing, and SLO-aware graceful degradation, emitted as `BENCH_PR8.json`.
//!
//! All rows are **modeled virtual-time** outputs of the deterministic
//! fault subsystem except the scale smoke (host time). Units per row:
//!
//! - `faults_healthy_replay` — 1.0 iff a config carrying an empty
//!   (all-healthy) fault plan replays the no-faults serving run bit for
//!   bit (the zero-perturbation contract); prints a greppable
//!   `faults: healthy-replay OK` line.
//! - `chat_slo_aware_vs_blind_2n` — chat-class SLO attainment percent
//!   under a single-node NIC derate (`nic=1:0.05`) at 2 nodes: before =
//!   degradation-blind baseline, after = degradation-aware policy
//!   (re-select + drain + shed + preempt). The bench asserts the aware
//!   policy is strictly higher — the PR's acceptance gate.
//! - `selector_flip_degraded_2n` — 2 MB all-gather latency (ns) on the
//!   derated topology: before = the healthy selector's (stale) schedule,
//!   after = the degradation-aware re-pick (Sequential → Pipelined flip).
//! - `retry_backoff_latency_4n` — 4-node all-reduce latency (ns): before
//!   = healthy links, after = every NIC link flapping at p=0.9 with the
//!   retry-with-backoff model priced in (asserts retries > 0).
//! - `serve_scale_smoke_1n` — host ns to simulate a thousands-of-requests
//!   serving run (wall-clock sanity bound, not a virtual-time claim).
//!
//! JSON lands at `../BENCH_PR8.json` (repo root when run via cargo),
//! overridable with `DMA_LATTE_BENCH_JSON=path` (`=0` disables).

use dma_latte::cluster::{
    run_hier, run_hier_ar, select_allreduce, select_cluster, select_cluster_degraded, ClusterKind,
    ClusterTopology, FaultPlan, FaultSpec, HierRunOptions, LinkHealth,
};
use dma_latte::coordinator::config::DegradePolicy;
use dma_latte::coordinator::workload::{default_tenants, drive, ArrivalProcess, WorkloadSpec};
use dma_latte::figures::faults as ff;
use dma_latte::figures::serving_load as sl;
use dma_latte::models::zoo::QWEN25_0_5B;
use dma_latte::util::bytes::MB;
use dma_latte::util::timer::{bench_json, BenchComparison, BenchResult};

const SEED: u64 = 7;

/// Wrap one deterministic modeled value as a BenchResult (no spread).
fn modeled(name: &str, value: f64) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        iters: 1,
        mean_ns: value,
        median_ns: value,
        p95_ns: value,
        p99_ns: value,
        min_ns: value,
    }
}

/// Single-value row.
fn value_row(path: &str, name: &str, value: f64) -> BenchComparison {
    BenchComparison {
        path: path.to_string(),
        before: None,
        after: modeled(name, value),
    }
}

fn report(row: &BenchComparison, unit: &str) {
    match &row.before {
        Some(b) => println!(
            "row {:<28} before {:>14.1} after {:>14.1} {unit}",
            row.path, b.median_ns, row.after.median_ns
        ),
        None => println!(
            "row {:<28} value {:>14.1} {unit}",
            row.path, row.after.median_ns
        ),
    }
}

fn main() {
    let smoke = dma_latte::util::bench_smoke();
    println!("== fault injection: degraded fleets, retries, SLO shedding (BENCH_PR8) ==\n");
    let classes = default_tenants();
    let mut rows: Vec<BenchComparison> = Vec::new();

    // 1) Zero-perturbation contract: an empty fault plan replays the
    //    no-faults serving run bit for bit.
    let n_replay = if smoke { 48 } else { 128 };
    let replay_ok = ff::healthy_replay_ok(&QWEN25_0_5B, 2, n_replay, SEED);
    assert!(replay_ok, "empty fault plan perturbed the healthy run");
    println!("faults: healthy-replay OK ({n_replay} requests, 2 nodes)");
    rows.push(value_row(
        "faults_healthy_replay",
        "empty plan replays healthy run (1.0 = bit-identical)",
        1.0,
    ));
    report(rows.last().unwrap(), "bool");
    println!();

    // 2) The acceptance gate: under a single-node NIC derate the aware
    //    policy must keep strictly more of the chat class inside its SLO
    //    than the blind baseline. Blind keeps both nodes and pays 20x
    //    slower inter-node all-reduces on every step; aware drains the
    //    sick node (flat intra-node comm, 2x compute) and sheds/preempts
    //    best-effort work under SLO pressure.
    let n_cap = if smoke { 96 } else { 256 };
    let cfg2 = sl::serve_config(&QWEN25_0_5B, 2, true);
    let cap2 = sl::estimate_capacity_rps(&cfg2, &classes, n_cap, SEED);
    let spec = FaultSpec::parse("nic=1:0.05").expect("literal spec");
    let n_slo = if smoke { 160 } else { 448 };
    let wl = WorkloadSpec {
        process: ArrivalProcess::Poisson {
            rate_rps: 0.4 * cap2,
        },
        classes: classes.clone(),
        requests: n_slo,
        seed: SEED,
    };
    let blind_cfg = cfg2
        .clone()
        .with_faults(spec.clone())
        .with_degrade(DegradePolicy::blind());
    let aware_cfg = cfg2
        .clone()
        .with_faults(spec)
        .with_degrade(DegradePolicy::aware());
    let mb = drive(&blind_cfg, &wl);
    let ma = drive(&aware_cfg, &wl);
    let chat_blind = ff::chat_attainment(&mb) * 100.0;
    let chat_aware = ff::chat_attainment(&ma) * 100.0;
    println!(
        "2n nic=1:0.05 @ {:.0} req/s: chat slo {chat_blind:.1}% blind -> {chat_aware:.1}% aware \
         (aware drained {}, shed {}, preempted {})",
        0.4 * cap2,
        ma.drained_nodes,
        ma.shed,
        ma.preemptions
    );
    assert!(
        chat_aware > chat_blind,
        "degradation-aware policy must beat blind on chat SLO attainment \
         ({chat_aware:.1}% vs {chat_blind:.1}%)"
    );
    rows.push(BenchComparison {
        path: "chat_slo_aware_vs_blind_2n".to_string(),
        before: Some(modeled("chat slo %, degradation-blind", chat_blind)),
        after: modeled("chat slo %, degradation-aware", chat_aware),
    });
    report(rows.last().unwrap(), "%");
    println!();

    // 3) Degradation-aware re-selection: at 2 MB the healthy AG schedule
    //    (Sequential) is stale on a 4x-derated NIC; the aware re-pick
    //    (Pipelined) must not lose on the derated topology it was picked
    //    for.
    let c2 = ClusterTopology::mi300x(2);
    let flip_spec = FaultSpec::parse("nic=1:0.25").expect("literal spec");
    let flip_plan = FaultPlan::generate(&flip_spec, 2, SEED);
    let derated = flip_plan.derate_cluster(&c2, None);
    let flip_size = derated.pad_size(2 * MB);
    let stale = select_cluster(ClusterKind::AllGather, &c2, flip_size);
    let repick = select_cluster_degraded(ClusterKind::AllGather, &c2, flip_size, &flip_plan);
    assert_ne!(stale.inter, repick.inter, "2 MB AG must flip under nic=1:0.25");
    let opts = HierRunOptions::default();
    let kind = ClusterKind::AllGather;
    let stale_run = run_hier(kind.transport(), stale, &derated, flip_size, &opts);
    let repick_run = run_hier(kind.transport(), repick, &derated, flip_size, &opts);
    assert!(
        repick_run.latency_ns <= stale_run.latency_ns,
        "re-picked schedule lost on the topology it was picked for"
    );
    println!(
        "selector flip 2n/2MB AG: {:?} -> {:?}, {} -> {} ns on derated links",
        stale.inter, repick.inter, stale_run.latency_ns, repick_run.latency_ns
    );
    rows.push(BenchComparison {
        path: "selector_flip_degraded_2n".to_string(),
        before: Some(modeled("2MB AG, stale healthy schedule", stale_run.latency_ns as f64)),
        after: modeled("2MB AG, degradation-aware re-pick", repick_run.latency_ns as f64),
    });
    report(rows.last().unwrap(), "ns");
    println!();

    // 4) Retry-with-backoff pricing: flapping every NIC link makes the
    //    4-node all-reduce strictly slower and counts retries; the
    //    healthy run never enters the fault path.
    let c4 = ClusterTopology::mi300x(4);
    let ar_size = c4.pad_size(8 * MB);
    let (rs, ag) = select_allreduce(&c4, ar_size);
    let healthy_run = run_hier_ar(rs, ag, &c4, ar_size, &HierRunOptions::default());
    let flappy = HierRunOptions {
        link_faults: Some(LinkHealth::uniform(4, 0.9, SEED)),
        ..HierRunOptions::default()
    };
    let (rs2, ag2) = select_allreduce(&c4, ar_size);
    let flapped_run = run_hier_ar(rs2, ag2, &c4, ar_size, &flappy);
    assert_eq!(healthy_run.faults.retries, 0);
    assert!(flapped_run.faults.retries > 0, "p=0.9 flaps must retry");
    assert!(
        flapped_run.latency_ns > healthy_run.latency_ns,
        "retries must be priced into the critical path"
    );
    println!(
        "retry backoff 4n/8MB AR: {} -> {} ns ({} retries, {} timeouts)",
        healthy_run.latency_ns,
        flapped_run.latency_ns,
        flapped_run.faults.retries,
        flapped_run.faults.timeouts
    );
    rows.push(BenchComparison {
        path: "retry_backoff_latency_4n".to_string(),
        before: Some(modeled("8MB AR, healthy links", healthy_run.latency_ns as f64)),
        after: modeled("8MB AR, p=0.9 flaps + retries", flapped_run.latency_ns as f64),
    });
    report(rows.last().unwrap(), "ns");
    println!();

    // 5) Scale smoke: a thousands-of-requests serving run must stay cheap
    //    in host time (the DES is event-driven, not token-stepped).
    let n_scale = if smoke { 2048 } else { 8192 };
    let cfg1 = sl::serve_config(&QWEN25_0_5B, 1, true);
    let cap1 = sl::estimate_capacity_rps(&cfg1, &classes, n_cap, SEED);
    let t0 = std::time::Instant::now();
    let p = sl::measure(&cfg1, &classes, "poisson", cap1 * 0.8, n_scale, SEED);
    let host_s = t0.elapsed().as_secs_f64();
    assert_eq!(p.finished, n_scale, "scale smoke: all requests must finish");
    assert!(
        host_s < 120.0,
        "scale smoke too slow: {n_scale} requests took {host_s:.1}s host time"
    );
    println!("scale smoke 1n: {n_scale} requests in {host_s:.2}s host time");
    rows.push(value_row(
        "serve_scale_smoke_1n",
        "host ns to simulate the scale run",
        host_s * 1e9,
    ));
    report(rows.last().unwrap(), "ns");
    println!();

    // Machine-readable trajectory file.
    let dest = std::env::var("DMA_LATTE_BENCH_JSON")
        .unwrap_or_else(|_| "../BENCH_PR8.json".to_string());
    if dest != "0" {
        let meta = [
            ("pr", "PR8".to_string()),
            ("mode", if smoke { "smoke" } else { "full" }.to_string()),
            (
                "note",
                "modeled virtual-time fault subsystem; latency rows are ns, \
                 chat_slo row is percent, healthy-replay row is a boolean, \
                 scale-smoke row is host ns (all stored in the ns-named fields)"
                    .to_string(),
            ),
        ];
        let doc = bench_json("faults", &meta, &rows);
        if let Err(e) = std::fs::write(&dest, doc) {
            // Fatal: CI asserts the file was regenerated; a silent miss
            // would let a stale checked-in copy masquerade as fresh.
            eprintln!("could not write {dest}: {e}");
            std::process::exit(1);
        }
        println!("wrote {dest}");
    }
}
