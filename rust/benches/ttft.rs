//! BENCH — Fig. 16: TTFT speedups with optimized DMA KV fetch across the
//! model zoo (Qwen2.5 0.5B–32B, Llama 3.1/3.2) at prefill 4096 and 8192,
//! 100% CPU-cache hit.

use dma_latte::figures::serving;
use dma_latte::models::ALL_MODELS;
use dma_latte::util::stats;

fn main() {
    // Smoke runs cover two models at one prefill length.
    let rows = if dma_latte::util::bench_smoke() {
        serving::fig16(&ALL_MODELS[..2], &[4096])
    } else {
        serving::fig16_default()
    };
    print!("{}", serving::render_fig16(&rows));

    let gpu: Vec<f64> = rows.iter().map(|r| r.speedup_gpu).collect();
    let total: Vec<f64> = rows.iter().map(|r| r.speedup_total).collect();
    println!("\n-- paper-vs-measured --");
    println!(
        "max TTFT_GPU speedup  : paper 2.29x  measured {:.2}x",
        stats::max(&gpu)
    );
    println!(
        "max TTFT_total speedup: paper 1.5x   measured {:.2}x",
        stats::max(&total)
    );
    // Kernel vs DMA TTFT (§5.3.3: kernel ~11% lower on average).
    let kern_vs_dma: Vec<f64> = rows
        .iter()
        .map(|r| r.b2b_total_ms / r.kernel_total_ms)
        .collect();
    println!(
        "kernel TTFT advantage : paper ~11%   measured {:.0}%",
        (stats::mean(&kern_vs_dma) - 1.0) * 100.0
    );
    println!(
        "smaller models gain more: first row {:.2}x vs last row {:.2}x",
        rows.first().unwrap().speedup_gpu,
        rows.last().unwrap().speedup_gpu
    );
    serving::fig16_csv(&rows).write("results/fig16_ttft.csv").unwrap();
}
