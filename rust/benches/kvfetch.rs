//! BENCH — §5.3.3 "DMA versus kernel KV fetch" at operator level: fetch
//! cost of a 4096/8192-token cached context per model and fetch impl,
//! plus host-CPU occupancy (the quantity continuous batching cares about).

use dma_latte::kvcache::fetch::{run_fetch, FetchImpl};
use dma_latte::kvcache::BlockLayout;
use dma_latte::models::ALL_MODELS;
use dma_latte::sim::{Sim, SimConfig};
use dma_latte::util::bytes::fmt_size;
use dma_latte::util::csv::Csv;
use dma_latte::util::table::Table;

fn main() {
    let mut t = Table::new(vec![
        "model", "tokens", "block", "blocks", "impl", "host_us", "total_us", "cu_us", "api",
    ]);
    let mut csv = Csv::new(vec![
        "model", "tokens", "block_bytes", "impl", "host_ns", "total_ns", "gpu_cu_ns",
    ]);
    // Smoke runs cover two models at one context length.
    let smoke = dma_latte::util::bench_smoke();
    let models = if smoke { &ALL_MODELS[..2] } else { ALL_MODELS };
    let token_counts: &[u64] = if smoke { &[4096] } else { &[4096, 8192] };
    for &m in models {
        for &tokens in token_counts {
            let layout = BlockLayout::new(m, 16);
            let blocks = layout.blocks_for(tokens);
            let copies: Vec<_> = (0..blocks)
                .map(|i| {
                    (
                        layout.cpu_block_addr(i),
                        layout.gpu_block_addr(0, i),
                        layout.block_bytes,
                    )
                })
                .collect();
            for imp in [FetchImpl::DmaBaseline, FetchImpl::DmaB2b, FetchImpl::Kernel] {
                let mut sim = Sim::new(SimConfig::mi300x());
                let o = run_fetch(&mut sim, imp, &copies);
                t.row(vec![
                    m.name.to_string(),
                    tokens.to_string(),
                    fmt_size(layout.block_bytes),
                    blocks.to_string(),
                    imp.name().to_string(),
                    format!("{:.0}", o.host_ns as f64 / 1e3),
                    format!("{:.0}", o.total_ns as f64 / 1e3),
                    format!("{:.0}", o.gpu_cu_ns as f64 / 1e3),
                    o.api_calls.to_string(),
                ]);
                csv.row(vec![
                    m.name.to_string(),
                    tokens.to_string(),
                    layout.block_bytes.to_string(),
                    imp.name().to_string(),
                    o.host_ns.to_string(),
                    o.total_ns.to_string(),
                    o.gpu_cu_ns.to_string(),
                ]);
            }
        }
    }
    t.print();
    println!("\nb2b: ~10-30x less host CPU than per-copy hipMemcpyAsync; kernel:");
    println!("cheapest host-side but burns CU time that contends with decode.");
    csv.write("results/kvfetch.csv").unwrap();
}
