//! BENCH — Fig. 7: latency breakdown of a single DMA copy (control /
//! schedule / copy / sync) for 4KB–2MB, via the traced DES.

use dma_latte::figures::breakdown;

fn main() {
    let rows = breakdown::fig7();
    print!("{}", breakdown::render(&rows));
    let r4k = rows[0];
    let r2m = *rows.last().unwrap();
    println!("\n-- paper-vs-measured --");
    println!(
        "non-copy share @4KB : paper ~60%  measured {:.0}%",
        r4k.non_copy_fraction() * 100.0
    );
    println!(
        "non-copy share @2MB : paper <20%  measured {:.0}%",
        r2m.non_copy_fraction() * 100.0
    );
    println!(
        "phase order @4KB    : copy({}) > schedule({}) ~ sync({}) >> control({})  [ns]",
        r4k.copy_ns, r4k.schedule_ns, r4k.sync_ns, r4k.control_ns
    );
    breakdown::to_csv(&rows).write("results/fig7_breakdown.csv").unwrap();
    println!("CSV → results/fig7_breakdown.csv");
}
