//! BENCH — Fig. 15: total GPU power, best DMA collective vs RCCL (AG),
//! 16KB–1GB, via the component power model over DES activity.

use dma_latte::figures::power;
use dma_latte::util::bytes::{fmt_size, KB, MB};

fn main() {
    // Smoke runs keep one size per summary band (16-64KB and ≥64MB).
    let sizes = dma_latte::util::bench_smoke()
        .then(|| vec![16 * KB, 64 * KB, MB, 64 * MB]);
    let rows = power::fig15(sizes);
    print!("{}", power::render(&rows));

    let small: Vec<&power::PowerRow> = rows
        .iter()
        .filter(|r| (16 * KB..=64 * KB).contains(&r.size))
        .collect();
    let large: Vec<&power::PowerRow> = rows.iter().filter(|r| r.size >= 64 * MB).collect();
    let avg =
        |v: &[&power::PowerRow]| v.iter().map(|r| r.saving()).sum::<f64>() / v.len() as f64;
    println!("\n-- paper-vs-measured --");
    println!("saving ≥64MB    : paper ~32%   measured {:.0}%", avg(&large) * 100.0);
    println!("saving 16-64KB  : paper 3-10%  measured {:.0}%", avg(&small) * 100.0);
    let xcd_ratio = large.iter().map(|r| r.rccl.xcd_w / r.dma.xcd_w).sum::<f64>()
        / large.len() as f64;
    println!("XCD power ratio : paper 3.7x   measured {xcd_ratio:.1}x");
    for r in &rows {
        if r.dma_variant.contains("bcst") {
            println!(
                "bcst region {:>5}: saving {:.0}% (paper: bcst adds 5-10% >1MB via 1-read-2-write)",
                fmt_size(r.size),
                r.saving() * 100.0
            );
        }
    }
    power::to_csv(&rows).write("results/fig15_power.csv").unwrap();
}
