//! BENCH — §Overlap (PR 4): what the chunk-granular overlap scheduler
//! buys, as before/after rows of the `BENCH_PR4.json` trajectory file.
//!
//! Unlike `perf_hotpath` (wall clock of the simulator), these rows compare
//! **modeled nanoseconds** — deterministic DES outputs, identical on every
//! machine:
//!
//! - `ar_modeled_seq_vs_ovl_*` — hierarchical all-reduce latency, the
//!   barriered sequential composition (before) vs the fused chunk-granular
//!   schedule (after), selector-chosen intra variants.
//! - `serving_wall_overlap_2n` — 2-node virtual serving wall time with the
//!   engine charging full collectives on the critical path (before) vs
//!   only the exposed remainder (after).
//! - `serving_comm_exposed_2n` — the same run's total collective time
//!   (before) vs its exposed part (after): the gap is what rides behind
//!   compute.
//!
//! The sweep section additionally asserts, for every (size × nodes) cell
//! of the figure sweep, that the overlapped schedule is never slower than
//! the best of the sequential/pipelined compositions — the PR 4
//! acceptance bound. Row names are stable and grep-asserted by CI; the
//! JSON lands at `../BENCH_PR4.json` (repo root when run via cargo),
//! overridable with `DMA_LATTE_BENCH_JSON=path` (`=0` disables).

use dma_latte::cluster::{
    overlap_report, run_hier_ar, select_allreduce, ClusterChoice, ClusterTopology,
    HierRunOptions, InterSchedule,
};
use dma_latte::coordinator::request::Request;
use dma_latte::coordinator::{ServeConfig, VirtualEngine};
use dma_latte::kvcache::fetch::FetchImpl;
use dma_latte::models::zoo::QWEN25_0_5B;
use dma_latte::util::bytes::{fmt_ns, fmt_size, size_sweep, KB, MB};
use dma_latte::util::timer::{bench_json, BenchComparison, BenchResult};

/// A deterministic modeled-latency "measurement": every stat is the same
/// modeled nanosecond count (there is no run-to-run spread to report).
fn modeled(name: &str, ns: u64) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        iters: 1,
        mean_ns: ns as f64,
        median_ns: ns as f64,
        p95_ns: ns as f64,
        p99_ns: ns as f64,
        min_ns: ns as f64,
    }
}

fn report(row: &BenchComparison) {
    if let Some(b) = &row.before {
        println!("  before: {}", b.summary());
    }
    println!("  after:  {}", row.after.summary());
    match row.speedup() {
        Some(sp) => println!(
            "row {:<36} before {:>10} after {:>10} speedup {:.2}x\n",
            row.path,
            fmt_ns(row.before.as_ref().unwrap().median_ns),
            fmt_ns(row.after.median_ns),
            sp
        ),
        None => println!(
            "row {:<36} after {:>10}\n",
            row.path,
            fmt_ns(row.after.median_ns)
        ),
    }
}

fn with_inter(mut c: ClusterChoice, inter: InterSchedule) -> ClusterChoice {
    c.inter = inter;
    c
}

/// One modeled AR row: sequential barriered composition vs fused schedule.
fn ar_row(path: &str, nodes: usize, size: u64) -> BenchComparison {
    let cluster = ClusterTopology::mi300x(nodes);
    let size = cluster.pad_size(size);
    let opts = HierRunOptions::default();
    let (rs, ag) = select_allreduce(&cluster, size);
    let seq = run_hier_ar(
        with_inter(rs, InterSchedule::Sequential),
        with_inter(ag, InterSchedule::Sequential),
        &cluster,
        size,
        &opts,
    );
    let rep = overlap_report(rs, ag, &cluster, size, &opts);
    println!(
        "  {} on {nodes} nodes: seq {:.1} us, pipe {:.1} us, ovl {:.1} us (saved {:.1} us vs pipe)",
        fmt_size(size),
        seq.latency_ns as f64 / 1e3,
        rep.barrier.latency_ns as f64 / 1e3,
        rep.overlapped.latency_ns as f64 / 1e3,
        rep.saved_ns as f64 / 1e3,
    );
    let after = modeled("allreduce overlapped", rep.overlapped.latency_ns);
    BenchComparison {
        path: path.to_string(),
        before: Some(modeled("allreduce sequential", seq.latency_ns)),
        after,
    }
}

fn serve(overlap: bool, requests: u64) -> dma_latte::coordinator::metrics::ServeMetrics {
    let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b)
        .with_nodes(2)
        .with_comm_overlap(overlap);
    cfg.gpu_blocks = 1 << 18;
    let mut eng = VirtualEngine::new(cfg);
    for i in 0..requests {
        eng.submit(Request::new(i, 1024, 8, 0), true);
    }
    eng.run_to_completion().clone()
}

fn main() {
    let smoke = dma_latte::util::bench_smoke();
    println!("== overlap scheduler: modeled before/after (BENCH_PR4) ==\n");
    let mut rows: Vec<BenchComparison> = Vec::new();

    // 1) Modeled hierarchical all-reduce: sequential composition vs the
    //    fused chunk-granular schedule.
    let size_2n = if smoke { MB } else { 4 * MB };
    let size_4n = if smoke { 4 * MB } else { 64 * MB };
    rows.push(ar_row("ar_modeled_seq_vs_ovl_2n", 2, size_2n));
    report(rows.last().unwrap());
    rows.push(ar_row("ar_modeled_seq_vs_ovl_4n", 4, size_4n));
    report(rows.last().unwrap());

    // 2) Acceptance bound over the figure sweep: the overlapped schedule
    //    must not lose to EITHER barriered composition on any cell.
    let max = if smoke { 8 * MB } else { 256 * MB };
    let opts = HierRunOptions::default();
    let mut cells = 0usize;
    let mut total_saved_us = 0f64;
    for &nodes in &[1usize, 2, 4] {
        let cluster = ClusterTopology::mi300x(nodes);
        for size in size_sweep(KB, max, 4) {
            let size = cluster.pad_size(size);
            // Force the fused schedule on both phases (the 1-node selector
            // would pick Sequential and leave the pipe bound untested):
            // overlap_report's barrier baseline is then the Pipelined
            // composition on every cell, and seq is run explicitly.
            let (rs, ag) = select_allreduce(&cluster, size);
            let rs = with_inter(rs, InterSchedule::Overlapped);
            let ag = with_inter(ag, InterSchedule::Overlapped);
            let rep = overlap_report(rs, ag, &cluster, size, &opts);
            let seq = run_hier_ar(
                with_inter(rs, InterSchedule::Sequential),
                with_inter(ag, InterSchedule::Sequential),
                &cluster,
                size,
                &opts,
            );
            let best = seq.latency_ns.min(rep.barrier.latency_ns);
            assert!(
                rep.overlapped.latency_ns <= best,
                "overlap lost at {} on {nodes} nodes: {} vs {best}",
                fmt_size(size),
                rep.overlapped.latency_ns
            );
            cells += 1;
            total_saved_us += rep.saved_ns as f64 / 1e3;
        }
    }
    println!(
        "sweep bound: overlapped <= min(seq, pipe) on all {cells} cells \
         ({total_saved_us:.1} us saved vs pipelined in total)\n"
    );

    // 3) Serving: the 2-node virtual engine with full collectives charged
    //    on the critical path vs only the exposed remainder.
    let requests = if smoke { 16 } else { 64 };
    let serial = serve(false, requests);
    let fused = serve(true, requests);
    assert_eq!(serial.finished, requests);
    assert_eq!(fused.finished, requests);
    assert_eq!(fused.comm_exposed_ns + fused.comm_hidden_ns, fused.comm_ns);
    assert!(fused.comm_hidden_ns > 0 && fused.wall_ns < serial.wall_ns);
    rows.push(BenchComparison {
        path: "serving_wall_overlap_2n".to_string(),
        before: Some(modeled("2n serving wall, serialized comm", serial.wall_ns)),
        after: modeled("2n serving wall, overlapped comm", fused.wall_ns),
    });
    report(rows.last().unwrap());
    rows.push(BenchComparison {
        path: "serving_comm_exposed_2n".to_string(),
        before: Some(modeled("2n serving comm total", fused.comm_ns)),
        after: modeled("2n serving comm exposed", fused.comm_exposed_ns),
    });
    report(rows.last().unwrap());
    println!(
        "2n serving: {:.1}% of comm hidden behind compute ({} -> {} tok/s)\n",
        fused.comm_hidden_frac() * 100.0,
        serial.tps() as u64,
        fused.tps() as u64,
    );

    // Machine-readable trajectory file.
    let dest = std::env::var("DMA_LATTE_BENCH_JSON")
        .unwrap_or_else(|_| "../BENCH_PR4.json".to_string());
    if dest != "0" {
        let meta = [
            ("pr", "PR4".to_string()),
            ("mode", if smoke { "smoke" } else { "full" }.to_string()),
            (
                "note",
                "modeled (deterministic DES) nanoseconds, not wall clock: before = \
                 barriered/serialized composition, after = chunk-granular overlap"
                    .to_string(),
            ),
        ];
        let doc = bench_json("overlap", &meta, &rows);
        if let Err(e) = std::fs::write(&dest, doc) {
            // Fatal: CI asserts the file was regenerated; a silent miss
            // would let a stale checked-in copy masquerade as fresh.
            eprintln!("could not write {dest}: {e}");
            std::process::exit(1);
        }
        println!("wrote {dest}");
    }
}
