//! BENCH — cluster reduction scaling: hierarchical reduce-scatter and
//! all-reduce over 1, 2 and 4 MI300X nodes (8 GPUs each, 400 Gb/s RoCE NIC
//! model), 1KB to 1GB, selector-chosen configuration per cell. RS is the
//! paper-faithful split (DMA/NIC move chunks, CUs reduce); AR composes RS
//! with the hierarchical all-gather. The 1-node column is the flat
//! single-node cost; the other columns are the scale-out cost on top.
//!
//! `DMA_LATTE_BENCH_SMOKE=1` shrinks the sweep for CI smoke runs.

use dma_latte::cluster::{
    run_hier, run_hier_ar, run_hier_rs, select_allreduce, ClusterKind, ClusterTopology,
    HierRunOptions, InterSchedule,
};
use dma_latte::collectives::CollectiveKind;
use dma_latte::figures::cluster as fig;
use dma_latte::util::bytes::{fmt_size, size_sweep, GB, KB, MB};

fn main() {
    let smoke = dma_latte::util::bench_smoke();
    let max = if smoke { 16 * MB } else { GB };
    let nodes = [1usize, 2, 4];
    let t0 = std::time::Instant::now();
    for kind in [ClusterKind::ReduceScatter, ClusterKind::AllReduce] {
        let rows = fig::scaling(kind, &nodes, Some(size_sweep(KB, max, 2)));
        print!("{}", fig::render(kind, &rows));
        fig::to_csv(&rows)
            .write(format!("results/cluster_{}.csv", kind.name()))
            .unwrap();
        println!();
    }

    // Decomposition sanity at one bandwidth-bound size: the selector's AR
    // is the chunk-granular fused schedule (PR 4), so it must cost no
    // more than its RS phase plus its AG phase — and at least as much as
    // either phase alone. Pipelining the RS partial exchange must not
    // lose to the sequential barrier.
    let size = if smoke { 8 * MB } else { 64 * MB };
    let cluster = ClusterTopology::mi300x(4);
    let opts = HierRunOptions::default();
    let (rs_c, ag_c) = select_allreduce(&cluster, size);
    let rs = run_hier_rs(rs_c, &cluster, size, &opts);
    let ag = run_hier(CollectiveKind::AllGather, ag_c, &cluster, size, &opts);
    let ar = run_hier_ar(rs_c, ag_c, &cluster, size, &opts);
    assert!(ar.latency_ns <= rs.latency_ns + ag.latency_ns);
    assert!(ar.latency_ns >= rs.latency_ns.max(ag.latency_ns));
    println!(
        "allreduce {} on 4 nodes: {:.1} us (fused, {:.1} us under rs {:.1} us ({}) + ag {:.1} us ({}))",
        fmt_size(size),
        ar.latency_ns as f64 / 1e3,
        (rs.latency_ns + ag.latency_ns - ar.latency_ns) as f64 / 1e3,
        rs.latency_ns as f64 / 1e3,
        rs_c.name(),
        ag.latency_ns as f64 / 1e3,
        ag_c.name(),
    );

    let mut seq_c = rs_c;
    seq_c.inter = InterSchedule::Sequential;
    let mut pipe_c = rs_c;
    pipe_c.inter = InterSchedule::Pipelined;
    let seq = run_hier_rs(seq_c, &cluster, size, &opts);
    let pipe = run_hier_rs(pipe_c, &cluster, size, &opts);
    assert!(pipe.latency_ns <= seq.latency_ns);
    println!(
        "reduce_scatter {} on 4 nodes: pipelined {:.1} us vs sequential {:.1} us",
        fmt_size(size),
        pipe.latency_ns as f64 / 1e3,
        seq.latency_ns as f64 / 1e3,
    );
    println!("\nbench wall time: {:.2?}", t0.elapsed());
}
