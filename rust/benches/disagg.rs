//! BENCH — §Disaggregated serving (PR 10): prefill/decode node pools with
//! cross-node KV migration over the DMA/NIC path, emitted as
//! `BENCH_PR10.json`.
//!
//! All rows are **modeled virtual-time** outputs of the deterministic
//! serving simulator. The sweep covers model size (Qwen2.5-0.5B,
//! Llama-3.1-8B) × P:D ratio (1:1, 3:1) × workload shape (prefill-heavy:
//! 4096-token prompts / 8-token generations; decode-heavy: 512/128). Per
//! cell:
//!
//! - `disagg_ttft_<cell>` — mean TTFT (ms): before = blocking bulk KV
//!   transfer, after = layer-pipelined streaming. The bench asserts the
//!   pipelined schedule is never slower, per cell, at both the modeled
//!   migration level (total_ns) and the serving level (mean TTFT) — the
//!   PR's acceptance bound, grep-gated in CI via `disagg check: OK`.
//! - `disagg_tps_<cell>` — tokens/s: before = colocated serving on P+D
//!   tensor-parallel nodes, after = disaggregated layer-pipelined.
//!
//! The bench also asserts the second acceptance clause: on at least one
//! prefill-heavy cell, disaggregated pipelined serving beats colocated
//! mean TTFT (the decode pool pays no per-step all-reduce and prefill
//! bursts stop stalling decode).
//!
//! JSON lands at `../BENCH_PR10.json` (repo root when run via cargo),
//! overridable with `DMA_LATTE_BENCH_JSON=path` (`=0` disables).

use dma_latte::cluster::topology::NicModel;
use dma_latte::figures::disagg as figd;
use dma_latte::kvcache::fetch::FetchImpl;
use dma_latte::kvcache::{BlockLayout, MigrateSchedule, Migrator};
use dma_latte::util::timer::{bench_json, BenchComparison, BenchResult};

/// Wrap one deterministic modeled value as a BenchResult (no spread).
fn modeled(name: &str, value: f64) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        iters: 1,
        mean_ns: value,
        median_ns: value,
        p95_ns: value,
        p99_ns: value,
        min_ns: value,
    }
}

fn report(row: &BenchComparison, unit: &str) {
    match &row.before {
        Some(b) => println!(
            "row {:<34} before {:>12.2} after {:>12.2} {unit}",
            row.path, b.median_ns, row.after.median_ns
        ),
        None => println!(
            "row {:<34} value {:>12.2} {unit}",
            row.path, row.after.median_ns
        ),
    }
}

/// Short stable row key for a cell.
fn cell_key(c: &figd::DisaggCell) -> String {
    let model = if c.model.name.starts_with("Qwen2.5-0.5B") {
        "qwen05b"
    } else {
        "llama8b"
    };
    let wl = if c.workload == "prefill_heavy" { "pf" } else { "dec" };
    format!("{model}_{}x{}_{wl}", c.prefill_nodes, c.decode_nodes)
}

fn main() {
    let smoke = dma_latte::util::bench_smoke();
    println!("== disaggregated prefill/decode: layer-pipelined KV migration (BENCH_PR10) ==\n");
    let mut cells = figd::default_cells();
    if smoke {
        for c in &mut cells {
            c.requests = 8;
        }
    }
    let nic = NicModel::default();
    let mut mig = Migrator::new();
    let mut rows: Vec<BenchComparison> = Vec::new();
    let mut colocated_beaten = false;

    for cell in &cells {
        let key = cell_key(cell);

        // Modeled migration level: the streamed schedule must never be
        // slower than the bulk transfer for this cell's KV footprint.
        let layout = BlockLayout::new(cell.model, 16);
        let n_blocks = layout.blocks_for(cell.prompt_tokens);
        let b = mig.cost(
            &layout,
            cell.model.layers,
            FetchImpl::DmaB2b,
            &nic,
            n_blocks,
            MigrateSchedule::Blocking,
        );
        let p = mig.cost(
            &layout,
            cell.model.layers,
            FetchImpl::DmaB2b,
            &nic,
            n_blocks,
            MigrateSchedule::LayerPipelined,
        );
        assert!(
            p.total_ns <= b.total_ns,
            "{key}: pipelined migration slower than blocking ({} > {} ns)",
            p.total_ns,
            b.total_ns
        );
        assert!(p.first_ready_ns <= b.first_ready_ns);

        // Serving level: identical burst through colocated / blocking /
        // pipelined deployments.
        let pts = figd::measure_cell(cell);
        let (colo, blocking, pipelined) = (&pts[0], &pts[1], &pts[2]);
        assert!(
            pipelined.ttft_mean_ms <= blocking.ttft_mean_ms + 1e-9,
            "{key}: pipelined serving TTFT worse than blocking \
             ({:.3} > {:.3} ms)",
            pipelined.ttft_mean_ms,
            blocking.ttft_mean_ms
        );
        if cell.workload == "prefill_heavy" && pipelined.ttft_mean_ms < colo.ttft_mean_ms {
            colocated_beaten = true;
        }
        println!(
            "{key}: ttft colo {:.1} / blocking {:.1} / pipelined {:.1} ms · \
             migration first-ready {:.0} vs bulk {:.0} us ({} chunks)",
            colo.ttft_mean_ms,
            blocking.ttft_mean_ms,
            pipelined.ttft_mean_ms,
            p.first_ready_ns as f64 / 1e3,
            b.total_ns as f64 / 1e3,
            p.chunks
        );
        rows.push(BenchComparison {
            path: format!("disagg_ttft_{key}"),
            before: Some(modeled("mean TTFT ms, blocking migration", blocking.ttft_mean_ms)),
            after: modeled("mean TTFT ms, layer-pipelined", pipelined.ttft_mean_ms),
        });
        report(rows.last().unwrap(), "ms");
        rows.push(BenchComparison {
            path: format!("disagg_tps_{key}"),
            before: Some(modeled("tok/s, colocated", colo.tps)),
            after: modeled("tok/s, disagg layer-pipelined", pipelined.tps),
        });
        report(rows.last().unwrap(), "tok/s");
        println!();
    }

    assert!(
        colocated_beaten,
        "no prefill-heavy cell beat colocated TTFT — acceptance clause 2 failed"
    );
    println!(
        "disagg check: OK (pipelined <= blocking on all {} cells; \
         beats colocated TTFT on a prefill-heavy cell)",
        cells.len()
    );

    // Machine-readable trajectory file.
    let dest = std::env::var("DMA_LATTE_BENCH_JSON")
        .unwrap_or_else(|_| "../BENCH_PR10.json".to_string());
    if dest != "0" {
        let meta = [
            ("pr", "PR10".to_string()),
            ("mode", if smoke { "smoke" } else { "full" }.to_string()),
            (
                "note",
                "modeled virtual-time disaggregated serving sweep; ttft rows \
                 are ms (blocking -> layer-pipelined migration), tps rows are \
                 tok/s (colocated -> disaggregated), all stored in the \
                 ns-named fields"
                    .to_string(),
            ),
        ];
        let doc = bench_json("disagg", &meta, &rows);
        if let Err(e) = std::fs::write(&dest, doc) {
            // Fatal: CI asserts the file was regenerated; a silent miss
            // would let a stale checked-in copy masquerade as fresh.
            eprintln!("could not write {dest}: {e}");
            std::process::exit(1);
        }
        println!("wrote {dest}");
    }
}
