//! BENCH — ablations of the design choices DESIGN.md calls out:
//!
//! 1. **engines-vs-b2b tradeoff** (§4.4: "interesting tradeoff between
//!    harnessing parallelism (more #engines) and benefiting from b2b…
//!    we leave exploring heuristics as future work"): split one rank's
//!    7 peer copies over E ∈ {1,2,4,7} engines across sizes.
//! 2. **batch fan-out threshold** (§5.3.1's empirical 4MB): sweep the
//!    threshold for a KV-fetch batch and report the best.
//! 3. **MoE top-k dispatch** (§4.2): bcst-based vs copy-based token
//!    dispatch across token counts.
//! 4. **prelaunch trigger sensitivity**: poll-wake latency sweep.

use dma_latte::collectives::moe;
use dma_latte::sim::command::{Addr, AtomicOp, Command};
use dma_latte::sim::host::{ApiKind, HostOp};
use dma_latte::sim::topology::NodeId;
use dma_latte::sim::{EngineId, Sim, SimConfig};
use dma_latte::util::bytes::{fmt_ns, fmt_size, KB, MB};
use dma_latte::util::rng::Rng;
use dma_latte::util::table::Table;

/// One rank's AG-like send (7 peers) split over E engines; returns ns.
fn chain_split(size_per_peer: u64, engines: usize) -> u64 {
    let mut sim = Sim::new(SimConfig::mi300x());
    let sig = sim.alloc_signal(0);
    let mut chains: Vec<Vec<Command>> = vec![Vec::new(); engines];
    for (k, peer) in (1u8..8).enumerate() {
        chains[k % engines].push(Command::Copy {
            src: Addr::new(NodeId::Gpu(0), (k as u64) << 32),
            dst: Addr::new(NodeId::Gpu(peer), 0),
            len: size_per_peer,
        });
    }
    let mut script = vec![HostOp::Mark { name: "s" }];
    for (e, chain) in chains.into_iter().enumerate() {
        if chain.is_empty() {
            continue;
        }
        let engine = EngineId {
            gpu: 0,
            idx: e as u8,
        };
        let mut cmds = chain;
        cmds.push(Command::Atomic {
            signal: sig,
            op: AtomicOp::Add(1),
        });
        script.push(HostOp::CreateCommands {
            engine,
            cmds,
            api: ApiKind::RawBatched,
        });
        script.push(HostOp::RingDoorbell { engine });
    }
    script.push(HostOp::WaitSignal {
        signal: sig,
        at_least: engines.min(7) as i64,
    });
    script.push(HostOp::Mark { name: "e" });
    sim.add_host(script, 0);
    sim.run();
    let h = sim.host(dma_latte::sim::HostId(0));
    h.mark("e").unwrap() - h.mark("s").unwrap()
}

fn ablation_engines_vs_b2b() {
    println!("## 1. engines-vs-b2b: one rank's 7 sends over E engines");
    let mut t = Table::new(vec!["size/peer", "E=1(b2b)", "E=2", "E=4", "E=7(pcpy)", "best"]);
    // Smoke runs keep one size per regime (latency-bound / crossover /
    // bandwidth-bound).
    let sizes: &[u64] = if dma_latte::util::bench_smoke() {
        &[4 * KB, 256 * KB, 4 * MB]
    } else {
        &[4 * KB, 64 * KB, 256 * KB, MB, 4 * MB, 16 * MB]
    };
    for &size in sizes {
        let vals: Vec<u64> = [1usize, 2, 4, 7].iter().map(|&e| chain_split(size, e)).collect();
        let best = [1, 2, 4, 7][vals
            .iter()
            .enumerate()
            .min_by_key(|(_, v)| **v)
            .unwrap()
            .0];
        t.row(vec![
            fmt_size(size),
            fmt_ns(vals[0] as f64),
            fmt_ns(vals[1] as f64),
            fmt_ns(vals[2] as f64),
            fmt_ns(vals[3] as f64),
            format!("E={best}"),
        ]);
    }
    t.print();
    println!("→ crossover from 1 engine (b2b) to full fan-out tracks the");
    println!("  paper's <1MB b2b window; intermediate E wins in between.\n");
}

fn ablation_fanout_threshold() {
    println!("## 2. batch fan-out threshold (paper picked 4MB empirically)");
    let copies: Vec<_> = (0..256u64)
        .map(|i| {
            (
                Addr::new(NodeId::Cpu, i * 196_608),
                Addr::new(NodeId::Gpu(0), i * 196_608),
                196_608u64,
            )
        })
        .collect();
    let mut t = Table::new(vec!["threshold", "chains", "total"]);
    for thresh_mb in [1u64, 2, 4, 8, 16, 64] {
        // Re-plan with a custom threshold by chunking manually.
        let total: u64 = copies.iter().map(|c| c.2).sum();
        let chains_wanted =
            ((total / (thresh_mb * MB)) as usize + 1).min(8).max(1);
        let per = copies.len().div_ceil(chains_wanted);
        let mut sim = Sim::new(SimConfig::mi300x());
        let sig = sim.alloc_signal(0);
        let mut script = vec![HostOp::Mark { name: "s" }];
        let chunks: Vec<_> = copies.chunks(per).collect();
        for (ci, chunk) in chunks.iter().enumerate() {
            let engine = EngineId {
                gpu: 0,
                idx: ci as u8,
            };
            let mut cmds: Vec<Command> = chunk
                .iter()
                .map(|&(s, d, l)| Command::Copy { src: s, dst: d, len: l })
                .collect();
            cmds.push(Command::Atomic {
                signal: sig,
                op: AtomicOp::Add(1),
            });
            script.push(HostOp::CreateCommands {
                engine,
                cmds,
                api: ApiKind::HipBatched,
            });
            script.push(HostOp::RingDoorbell { engine });
        }
        script.push(HostOp::WaitSignal {
            signal: sig,
            at_least: chunks.len() as i64,
        });
        script.push(HostOp::Mark { name: "e" });
        sim.add_host(script, 0);
        sim.run();
        let h = sim.host(dma_latte::sim::HostId(0));
        let ns = h.mark("e").unwrap() - h.mark("s").unwrap();
        t.row(vec![
            format!("{thresh_mb}M"),
            chunks.len().to_string(),
            fmt_ns(ns as f64),
        ]);
    }
    t.print();
    println!("→ near-flat above ~4MB: the PCIe link is the floor; below it,\n  per-chain sync overheads surface (supports the paper's choice).\n");
}

fn ablation_moe() {
    println!("## 3. MoE top-k dispatch: bcst vs copy (k=2, 4KB tokens)");
    let mut t = Table::new(vec!["tokens", "copy_cmds", "bcst_cmds", "copy", "bcst", "speedup"]);
    let token_counts: &[u32] = if dma_latte::util::bench_smoke() {
        &[16, 256]
    } else {
        &[16, 64, 256, 1024]
    };
    for &tokens in token_counts {
        let mut rng = Rng::new(7);
        let run = |mode| {
            let mut sim = Sim::new(SimConfig::mi300x());
            let mut rng2 = Rng::new(7);
            let routes =
                moe::random_routing(&mut rng2, &sim.cfg.topology, 0, tokens, 2);
            moe::run_dispatch(&mut sim, 0, &routes, tokens, 4096, mode)
        };
        let c = run(moe::DispatchMode::CopyPerExpert);
        let b = run(moe::DispatchMode::Broadcast);
        t.row(vec![
            tokens.to_string(),
            c.commands.to_string(),
            b.commands.to_string(),
            fmt_ns(c.latency_ns as f64),
            fmt_ns(b.latency_ns as f64),
            format!("{:.2}x", c.latency_ns as f64 / b.latency_ns as f64),
        ]);
        let _ = &mut rng;
    }
    t.print();
    println!("→ halved command count compounds with chain length (§4.2).\n");
}

fn ablation_prelaunch_sensitivity() {
    println!("## 4. prelaunch sensitivity to poll-wake latency");
    use dma_latte::collectives::{run_collective, CollectiveKind, RunOptions, Strategy, Variant};
    let mut t = Table::new(vec!["poll_wake", "prelaunch_b2b 64K", "direct_b2b 64K"]);
    for wake in [200.0, 400.0, 1600.0, 6400.0] {
        let mut opts = RunOptions {
            sim: SimConfig::mi300x(),
            verify: false,
        };
        opts.sim.latency.t_poll_wake = wake;
        let pre = run_collective(
            CollectiveKind::AllGather,
            Variant::new(Strategy::B2b, true),
            64 * KB,
            &opts,
        );
        let dir = run_collective(
            CollectiveKind::AllGather,
            Variant::new(Strategy::B2b, false),
            64 * KB,
            &opts,
        );
        t.row(vec![
            fmt_ns(wake),
            fmt_ns(pre.latency_ns as f64),
            fmt_ns(dir.latency_ns as f64),
        ]);
    }
    t.print();
    println!("→ prelaunch stays profitable until poll wake approaches the\n  full doorbell+wake path it replaces (§4.5 robustness).");
}

fn main() {
    ablation_engines_vs_b2b();
    ablation_fanout_threshold();
    ablation_moe();
    ablation_prelaunch_sensitivity();
}
