//! BENCH — §Perf: wall-clock micro-benchmarks of the L3 hot paths, run
//! BOTH through the legacy (pre-optimization) code paths and the
//! optimized ones, in one process on one machine — the before/after rows
//! of the `BENCH_*.json` trajectory (this PR: `BENCH_PR3.json`).
//!
//! - Collective episode: fresh-Sim + fresh-plan per call (legacy) vs one
//!   reset-reused simulator + cross-episode plan cache.
//! - Fetch plan + episode: fresh `Sim::new` per admission (legacy) vs
//!   `Sim::reset` reuse.
//! - Virtual serving engine step rate and raw DES event rate (optimized
//!   path only — their legacy substrate no longer exists in-tree).
//!
//! Row names are stable and grep-asserted by the CI bench-smoke job. The
//! JSON lands at `../BENCH_PR3.json` (the repo root when run via cargo);
//! override with `DMA_LATTE_BENCH_JSON=path` or disable with `=0`.
//! See `rust/benches/README.md` for the methodology.

use dma_latte::collectives::exec::run_collective_uncached;
use dma_latte::collectives::{
    cache, CollectiveKind, CollectiveRunner, RunOptions, Strategy, Variant,
};
use dma_latte::coordinator::request::Request;
use dma_latte::coordinator::{ServeConfig, VirtualEngine};
use dma_latte::kvcache::fetch::{run_fetch, CopySpec, FetchImpl};
use dma_latte::models::zoo::QWEN25_0_5B;
use dma_latte::sim::topology::NodeId;
use dma_latte::sim::{Addr, Sim, SimConfig};
use dma_latte::util::bytes::{fmt_ns, KB, MB};
use dma_latte::util::timer::{bench, bench_json, black_box, BenchComparison};

fn report(row: &BenchComparison) {
    if let Some(b) = &row.before {
        println!("  before: {}", b.summary());
    }
    println!("  after:  {}", row.after.summary());
    match row.speedup() {
        Some(sp) => println!(
            "row {:<36} before {:>10} after {:>10} speedup {:.2}x\n",
            row.path,
            fmt_ns(row.before.as_ref().unwrap().median_ns),
            fmt_ns(row.after.median_ns),
            sp
        ),
        None => println!(
            "row {:<36} after {:>10}\n",
            row.path,
            fmt_ns(row.after.median_ns)
        ),
    }
}

fn collective_row(
    path: &str,
    kind: CollectiveKind,
    v: Variant,
    size: u64,
    warm: usize,
    iters: usize,
) -> BenchComparison {
    let opts = RunOptions {
        sim: SimConfig::mi300x(),
        verify: false,
    };
    let before = bench(&format!("{path} (legacy fresh-sim)"), warm, iters, || {
        black_box(run_collective_uncached(kind, v, size, &opts));
    });
    let mut runner = CollectiveRunner::new(&opts);
    let after = bench(&format!("{path} (reset+plan-cache)"), warm, iters, || {
        black_box(runner.run(kind, v, size));
    });
    BenchComparison {
        path: path.to_string(),
        before: Some(before),
        after,
    }
}

fn main() {
    println!("== L3 hot-path microbenchmarks (before/after, BENCH_PR3) ==\n");
    // Smoke runs trade measurement stability for wall time.
    let smoke = dma_latte::util::bench_smoke();
    let (warm, iters) = if smoke { (1, 5) } else { (3, 50) };
    let mut rows: Vec<BenchComparison> = Vec::new();

    // 1) Collective episodes: the substrate under every sweep figure and
    //    the cluster selector. One bandwidth-bound point, one
    //    latency-bound point (higher episode rate ⇒ setup dominates more).
    rows.push(collective_row(
        "collective_episode_pcpy_ag_1mb",
        CollectiveKind::AllGather,
        Variant::new(Strategy::Pcpy, false),
        MB,
        warm,
        iters,
    ));
    report(rows.last().unwrap());
    rows.push(collective_row(
        "collective_episode_prelaunch_b2b_64kb",
        CollectiveKind::AllGather,
        Variant::new(Strategy::B2b, true),
        64 * KB,
        warm,
        iters,
    ));
    report(rows.last().unwrap());

    // 2) Fetch plan + episode (the serving scheduler's per-admission
    //    inner call): fresh Sim per admission vs reset reuse.
    let copies: Vec<CopySpec> = (0..256u64)
        .map(|i| {
            (
                Addr::new(NodeId::Cpu, i * 4096),
                Addr::new(NodeId::Gpu(0), i * 4096),
                4096u64,
            )
        })
        .collect();
    let fetch_iters = if smoke { 10 } else { 100 };
    let before = bench("fetch episode (legacy fresh-sim)", warm, fetch_iters, || {
        let mut sim = Sim::new(SimConfig::mi300x());
        black_box(run_fetch(&mut sim, FetchImpl::DmaB2b, &copies));
    });
    let mut fetch_sim = Sim::new(SimConfig::mi300x());
    let after = bench("fetch episode (reset reuse)", warm, fetch_iters, || {
        fetch_sim.reset();
        black_box(run_fetch(&mut fetch_sim, FetchImpl::DmaB2b, &copies));
    });
    rows.push(BenchComparison {
        path: "fetch_episode_b2b_256".to_string(),
        before: Some(before),
        after,
    });
    report(rows.last().unwrap());

    // 3) Virtual serving engine: requests/s of the simulator itself
    //    (optimized substrate only — no legacy toggle survives in-tree).
    let after = bench(
        "virtual engine (64 reqs, b2b)",
        1,
        if smoke { 3 } else { 10 },
        || {
            let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b);
            cfg.gpu_blocks = 1 << 18;
            let mut eng = VirtualEngine::new(cfg);
            for i in 0..64 {
                eng.submit(Request::new(i, 1024, 8, 0), true);
            }
            black_box(eng.run_to_completion().finished);
        },
    );
    rows.push(BenchComparison {
        path: "virtual_engine_64req".to_string(),
        before: None,
        after,
    });
    report(rows.last().unwrap());

    // 4) Raw DES event rate over one long fetch episode.
    let big_copies: Vec<CopySpec> = (0..2048u64)
        .map(|i| {
            (
                Addr::new(NodeId::Cpu, i * 4096),
                Addr::new(NodeId::Gpu(0), i * 4096),
                4096u64,
            )
        })
        .collect();
    let mut sim = Sim::new(SimConfig::mi300x());
    let t0 = std::time::Instant::now();
    black_box(run_fetch(&mut sim, FetchImpl::DmaBaseline, &big_copies));
    let events = 2048 * 4; // ≈ events per copy
    println!(
        "DES rate ≈ {:.2}M events/s (2048-copy fetch episode in {:.1}ms)\n",
        events as f64 / t0.elapsed().as_secs_f64() / 1e6,
        t0.elapsed().as_secs_f64() * 1e3
    );

    let (hits, misses) = cache::stats();
    println!("plan cache: {hits} hits / {misses} misses");

    // Machine-readable trajectory file.
    let dest = std::env::var("DMA_LATTE_BENCH_JSON")
        .unwrap_or_else(|_| "../BENCH_PR3.json".to_string());
    if dest != "0" {
        let meta = [
            ("pr", "PR3".to_string()),
            ("mode", if smoke { "smoke" } else { "full" }.to_string()),
            (
                "note",
                "before = legacy fresh-sim/fresh-plan path, after = Sim::reset + \
                 cross-episode plan cache; same process, same machine"
                    .to_string(),
            ),
        ];
        let doc = bench_json("perf_hotpath", &meta, &rows);
        if let Err(e) = std::fs::write(&dest, doc) {
            // Fatal: CI asserts the file was regenerated; a silent miss
            // would let a stale checked-in copy masquerade as fresh.
            eprintln!("could not write {dest}: {e}");
            std::process::exit(1);
        }
        println!("wrote {dest}");
    }

    println!("\nTargets (DESIGN.md §7): DES ≥ 1M events/s; serving loop");
    println!(">10x faster than the workload it models.");
}
