//! BENCH — §Perf: wall-clock micro-benchmarks of the L3 hot paths
//! (EXPERIMENTS.md §Perf records before/after for the optimization pass).
//!
//! - DES event throughput (events/s) — the substrate under every figure.
//! - Collective sweep point (end-to-end DES episode).
//! - Fetch planning + DES episode (the serving scheduler's inner call).
//! - Virtual serving engine step rate (requests/s).

use dma_latte::collectives::{run_collective, CollectiveKind, RunOptions, Strategy, Variant};
use dma_latte::coordinator::request::Request;
use dma_latte::coordinator::{ServeConfig, VirtualEngine};
use dma_latte::kvcache::fetch::{run_fetch, FetchImpl};
use dma_latte::models::zoo::QWEN25_0_5B;
use dma_latte::sim::topology::NodeId;
use dma_latte::sim::{Addr, Sim, SimConfig};
use dma_latte::util::bytes::MB;
use dma_latte::util::timer::{bench, black_box};

fn main() {
    println!("== L3 hot-path microbenchmarks ==\n");
    // Smoke runs trade measurement stability for wall time.
    let smoke = dma_latte::util::bench_smoke();
    let (warm, iters) = if smoke { (1, 5) } else { (3, 50) };

    // 1) DES throughput: one pcpy collective episode = ~500 events.
    let opts = RunOptions {
        sim: SimConfig::mi300x(),
        verify: false,
    };
    let r = bench("collective episode (pcpy AG 1MB)", warm, iters, || {
        black_box(run_collective(
            CollectiveKind::AllGather,
            Variant::new(Strategy::Pcpy, false),
            MB,
            &opts,
        ));
    });
    println!("{}", r.summary());

    // Events/s measurement.
    let mut sim = Sim::new(SimConfig::mi300x());
    let sig = sim.alloc_signal(0);
    let copies: Vec<_> = (0..2048u64)
        .map(|i| {
            (
                Addr::new(NodeId::Cpu, i * 4096),
                Addr::new(NodeId::Gpu(0), i * 4096),
                4096u64,
            )
        })
        .collect();
    let t0 = std::time::Instant::now();
    let out = run_fetch(&mut sim, FetchImpl::DmaBaseline, &copies);
    let outcome = { black_box(out); sim };
    let _ = sig;
    let events = 2048 * 4; // ≈ events per copy
    println!(
        "DES rate ≈ {:.2}M events/s (2048-copy fetch episode in {:.1}ms)",
        events as f64 / t0.elapsed().as_secs_f64() / 1e6,
        t0.elapsed().as_secs_f64() * 1e3
    );
    drop(outcome);

    // 2) Fetch episode (the serving loop's per-admission cost).
    let copies_small: Vec<_> = copies[..256].to_vec();
    let r = bench("fetch episode (b2b, 256 blocks)", warm, if smoke { 10 } else { 100 }, || {
        let mut sim = Sim::new(SimConfig::mi300x());
        black_box(run_fetch(&mut sim, FetchImpl::DmaB2b, &copies_small));
    });
    println!("{}", r.summary());

    // 3) Virtual serving engine: requests/s of the simulator itself.
    let r = bench("virtual engine (64 reqs, b2b)", 1, if smoke { 3 } else { 10 }, || {
        let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b);
        cfg.gpu_blocks = 1 << 18;
        let mut eng = VirtualEngine::new(cfg);
        for i in 0..64 {
            eng.submit(Request::new(i, 1024, 8, 0), true);
        }
        black_box(eng.run_to_completion().finished);
    });
    println!("{}", r.summary());

    println!("\nTargets (DESIGN.md §7): DES ≥ 1M events/s; serving loop");
    println!(">10x faster than the workload it models.");
}
