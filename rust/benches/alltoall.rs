//! BENCH — Fig. 14 + Table 3: all-to-all DMA variants vs RCCL.

use dma_latte::collectives::{CollectiveKind, Strategy, Variant};
use dma_latte::figures::collectives as fig;
use dma_latte::util::bytes::{size_sweep, GB, KB, MB};
use dma_latte::util::stats::geomean;

fn main() {
    let kind = CollectiveKind::AllToAll;
    // Smoke runs stop at 64MB (keeps the ≥32MB summary band non-empty).
    let sizes = dma_latte::util::bench_smoke().then(|| size_sweep(KB, 64 * MB, 2));
    let rows = fig::sweep(kind, sizes);
    print!("{}", fig::render(kind, &rows));

    println!("\n-- Table 3 (derived from this sweep) --");
    for (lo, hi, v) in fig::best_table(&rows) {
        println!(
            "  {:>6} ..= {:>6}  {}",
            dma_latte::util::bytes::fmt_size(lo),
            dma_latte::util::bytes::fmt_size(hi),
            v.name()
        );
    }

    let below = fig::LATENCY_BOUND_CEILING;
    let pcpy = fig::geomean_speedup(&rows, Variant::new(Strategy::Pcpy, false), below);
    let best = fig::geomean_best(&rows, below);
    let large: Vec<f64> = rows
        .iter()
        .filter(|r| (32 * MB..=GB).contains(&r.size))
        .map(|r| r.best().1)
        .collect();
    println!("\n-- paper-vs-measured (geomean, <32MB unless noted) --");
    println!("pcpy slowdown       : paper 2.5x        measured {:.2}x", 1.0 / pcpy);
    println!("best-DMA vs RCCL    : paper 1.2x faster measured {:.2}x", best);
    println!("32MB-1GB speedup    : paper ~1.2x       measured {:.2}x", geomean(&large));
    let sw = fig::geomean_speedup(&rows, Variant::new(Strategy::Swap, false), 4 * MB);
    let pc = fig::geomean_speedup(&rows, Variant::new(Strategy::Pcpy, false), 4 * MB);
    println!("swap over pcpy <4MB : paper 1.7x        measured {:.2}x", sw / pc);
    let b_small = fig::geomean_speedup(&rows, Variant::new(Strategy::B2b, false), MB);
    println!(
        "b2b over pcpy <1MB  : paper 2.5x        measured {:.2}x",
        b_small / fig::geomean_speedup(&rows, Variant::new(Strategy::Pcpy, false), MB)
    );

    fig::to_csv(kind, &rows).write("results/fig14_alltoall.csv").unwrap();
}
