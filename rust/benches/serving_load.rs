//! BENCH — §Serving load (PR 7): trace-driven production-traffic serving
//! under seeded arrival processes, emitted as `BENCH_PR7.json`.
//!
//! All rows are **modeled virtual-time** outputs of the deterministic
//! serving engine — identical on every machine. Units vary per row and
//! are documented in the JSON `note` field:
//!
//! - `{poisson,bursty,trace}_ttft_p{50,95,99}_1n` — TTFT percentiles (ns)
//!   for each workload shape at 0.6× the measured closed-loop capacity,
//!   default two-tenant mix (chat SLO'd + bulk best-effort).
//! - `{poisson,bursty,trace}_slo_attainment_1n` — SLO attainment percent
//!   at the same operating point (stored in the ns-named fields).
//! - `sustained_rps_slo_{1,2}n` — highest probed offered rate (req/s)
//!   holding ≥ 90% SLO attainment.
//! - `p99_ttft_knee_{1,2}n` — before = p99 TTFT (ns) at 0.4× capacity,
//!   after = p99 TTFT at 4× capacity: the saturation knee. The bench
//!   asserts super-linear growth and prints a greppable
//!   `knee check Nn: OK` line per node count.
//! - `serving_load_overlap_2n` — overloaded 2-node bursty wall time with
//!   comm overlap off (before) vs on (after).
//!
//! JSON lands at `../BENCH_PR7.json` (repo root when run via cargo),
//! overridable with `DMA_LATTE_BENCH_JSON=path` (`=0` disables).

use dma_latte::coordinator::workload::default_tenants;
use dma_latte::figures::serving_load as sl;
use dma_latte::models::zoo::QWEN25_0_5B;
use dma_latte::util::timer::{bench_json, BenchComparison, BenchResult};

const SEED: u64 = 7;

/// Wrap one deterministic modeled value as a BenchResult (no spread).
fn modeled(name: &str, value: f64) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        iters: 1,
        mean_ns: value,
        median_ns: value,
        p95_ns: value,
        p99_ns: value,
        min_ns: value,
    }
}

/// Single-value row.
fn value_row(path: &str, name: &str, value: f64) -> BenchComparison {
    BenchComparison {
        path: path.to_string(),
        before: None,
        after: modeled(name, value),
    }
}

fn report(row: &BenchComparison, unit: &str) {
    match &row.before {
        Some(b) => println!(
            "row {:<28} before {:>14.1} after {:>14.1} {unit}",
            row.path, b.median_ns, row.after.median_ns
        ),
        None => println!(
            "row {:<28} value {:>14.1} {unit}",
            row.path, row.after.median_ns
        ),
    }
}

fn main() {
    let smoke = dma_latte::util::bench_smoke();
    println!("== serving load: arrival processes, SLOs, saturation (BENCH_PR7) ==\n");
    let classes = default_tenants();
    let mut rows: Vec<BenchComparison> = Vec::new();

    // Closed-loop service capacity per node count — the yardstick every
    // offered rate below is expressed against.
    let n_cap = if smoke { 96 } else { 256 };
    let cfg1 = sl::serve_config(&QWEN25_0_5B, 1, true);
    let cfg2 = sl::serve_config(&QWEN25_0_5B, 2, true);
    let cap1 = sl::estimate_capacity_rps(&cfg1, &classes, n_cap, SEED);
    let cap2 = sl::estimate_capacity_rps(&cfg2, &classes, n_cap, SEED);
    println!("closed-loop capacity: {cap1:.0} req/s at 1n, {cap2:.0} req/s at 2n\n");

    // 1) Per-workload-shape latency distributions at a moderate operating
    //    point (0.6x capacity), 1 node.
    let n_pct = if smoke { 128 } else { 512 };
    for kind in ["poisson", "bursty", "trace"] {
        let p = sl::measure(&cfg1, &classes, kind, cap1 * 0.6, n_pct, SEED);
        assert_eq!(p.finished, n_pct, "{kind}: all requests must finish");
        assert!(p.attainment.is_finite());
        println!(
            "{kind} @ {:.0} req/s: ttft p50 {:.1}ms p95 {:.1}ms p99 {:.1}ms, slo {:.1}%",
            p.rate_rps,
            p.ttft_p50_ms,
            p.ttft_p95_ms,
            p.ttft_p99_ms,
            p.attainment * 100.0
        );
        for (pct, ms) in [
            ("p50", p.ttft_p50_ms),
            ("p95", p.ttft_p95_ms),
            ("p99", p.ttft_p99_ms),
        ] {
            rows.push(value_row(
                &format!("{kind}_ttft_{pct}_1n"),
                &format!("{kind} ttft {pct}, 0.6x cap"),
                ms * 1e6,
            ));
            report(rows.last().unwrap(), "ns");
        }
        rows.push(value_row(
            &format!("{kind}_slo_attainment_1n"),
            &format!("{kind} slo attainment, 0.6x cap"),
            p.attainment * 100.0,
        ));
        report(rows.last().unwrap(), "%");
        println!();
    }

    // 2) Sustained rate at >= 90% SLO attainment: probe a fixed grid of
    //    capacity fractions, keep the highest passing rate.
    let n_probe = if smoke { 128 } else { 384 };
    for (nodes, cfg, cap) in [(1usize, &cfg1, cap1), (2, &cfg2, cap2)] {
        let mut sustained = 0.0f64;
        for frac in [0.3, 0.5, 0.7, 0.9, 1.1] {
            let p = sl::measure(cfg, &classes, "poisson", cap * frac, n_probe, SEED);
            let ok = p.attainment >= 0.9;
            println!(
                "  {nodes}n @ {:.2}x cap ({:.0} req/s): slo {:.1}% {}",
                frac,
                p.rate_rps,
                p.attainment * 100.0,
                if ok { "PASS" } else { "fail" }
            );
            if ok && p.rate_rps > sustained {
                sustained = p.rate_rps;
            }
        }
        assert!(sustained > 0.0, "{nodes}n: no probed rate met the SLO");
        rows.push(value_row(
            &format!("sustained_rps_slo_{nodes}n"),
            &format!("{nodes}n sustained req/s at >=90% slo"),
            sustained,
        ));
        report(rows.last().unwrap(), "req/s");
        println!();
    }

    // 3) Saturation knee: p99 TTFT far under vs far over capacity. The
    //    overload point is sized so the terminal backlog dominates p99 —
    //    super-linear growth is the acceptance bound (10x the rate must
    //    cost much more than 10x... at minimum >3x the p99).
    for (nodes, cfg, cap) in [(1usize, &cfg1, cap1), (2, &cfg2, cap2)] {
        let scale = if smoke { 0.25 } else { 0.5 };
        let n_knee = ((cap * scale) as u64).clamp(96, 4096);
        let sust = sl::measure(cfg, &classes, "poisson", cap * 0.4, n_knee, SEED);
        let over = sl::measure(cfg, &classes, "poisson", cap * 4.0, n_knee, SEED);
        let ratio = over.ttft_p99_ms / sust.ttft_p99_ms;
        assert!(
            ratio > 3.0,
            "{nodes}n knee too soft: p99 {:.1}ms -> {:.1}ms ({ratio:.1}x)",
            sust.ttft_p99_ms,
            over.ttft_p99_ms
        );
        println!(
            "knee check {nodes}n: OK (p99 ttft {:.1}ms -> {:.1}ms, {ratio:.1}x for 10x rate)",
            sust.ttft_p99_ms, over.ttft_p99_ms
        );
        rows.push(BenchComparison {
            path: format!("p99_ttft_knee_{nodes}n"),
            before: Some(modeled(
                &format!("{nodes}n p99 ttft at 0.4x cap"),
                sust.ttft_p99_ms * 1e6,
            )),
            after: modeled(
                &format!("{nodes}n p99 ttft at 4x cap"),
                over.ttft_p99_ms * 1e6,
            ),
        });
        report(rows.last().unwrap(), "ns");
        println!();
    }

    // 4) Comm overlap under overloaded 2-node bursty traffic: charging
    //    only the exposed collective remainder must not lose wall time.
    let n_ovl = if smoke { 96 } else { 256 };
    let cfg2_serial = sl::serve_config(&QWEN25_0_5B, 2, false);
    let fused = sl::measure(&cfg2, &classes, "bursty", cap2 * 1.5, n_ovl, SEED);
    let serial = sl::measure(&cfg2_serial, &classes, "bursty", cap2 * 1.5, n_ovl, SEED);
    assert_eq!(fused.finished, n_ovl);
    assert_eq!(serial.finished, n_ovl);
    assert!(
        fused.wall_s <= serial.wall_s,
        "overlap lost wall time: {} vs {}",
        fused.wall_s,
        serial.wall_s
    );
    println!(
        "2n bursty overload: wall {:.2}s serialized -> {:.2}s overlapped",
        serial.wall_s, fused.wall_s
    );
    rows.push(BenchComparison {
        path: "serving_load_overlap_2n".to_string(),
        before: Some(modeled("2n bursty wall, serialized comm", serial.wall_s * 1e9)),
        after: modeled("2n bursty wall, overlapped comm", fused.wall_s * 1e9),
    });
    report(rows.last().unwrap(), "ns");
    println!();

    // Machine-readable trajectory file.
    let dest = std::env::var("DMA_LATTE_BENCH_JSON")
        .unwrap_or_else(|_| "../BENCH_PR7.json".to_string());
    if dest != "0" {
        let meta = [
            ("pr", "PR7".to_string()),
            ("mode", if smoke { "smoke" } else { "full" }.to_string()),
            (
                "note",
                "modeled virtual-time serving under seeded arrival processes; \
                 ttft/knee/overlap rows are ns, slo_attainment rows are percent, \
                 sustained rows are req/s (stored in the ns-named fields)"
                    .to_string(),
            ),
        ];
        let doc = bench_json("serving_load", &meta, &rows);
        if let Err(e) = std::fs::write(&dest, doc) {
            // Fatal: CI asserts the file was regenerated; a silent miss
            // would let a stale checked-in copy masquerade as fresh.
            eprintln!("could not write {dest}: {e}");
            std::process::exit(1);
        }
        println!("wrote {dest}");
    }
}
