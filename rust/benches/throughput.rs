//! BENCH — Fig. 17 + §5.3.3 KV-hit sweep: serving throughput (tokens/s)
//! with b2b DMA vs baseline DMA vs kernel KV fetch, continuous batching.
//!
//! The paper uses 2000 simultaneous requests; pass `--full` for that scale
//! (several minutes), default is 400 which preserves all ratios.

use dma_latte::figures::serving;
use dma_latte::models::ALL_MODELS;
use dma_latte::util::stats;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let sweep_hit = std::env::args().any(|a| a == "--sweep-hit");
    let smoke = dma_latte::util::bench_smoke();
    let n: u64 = if smoke {
        64
    } else if full {
        2000
    } else {
        400
    };
    let decode = 32;
    let models = if smoke { &ALL_MODELS[..2] } else { ALL_MODELS };

    println!("# Fig 17 — {} requests, prefill 4096, 100% hit", n);
    let mut rows = Vec::new();
    for &m in models {
        let r = serving::throughput(m, 4096, n, decode, 1.0);
        rows.push(r);
    }
    print!("{}", serving::render_fig17(&rows));

    let gains: Vec<f64> = rows.iter().map(|r| r.gain).collect();
    let vs_kernel: Vec<f64> = rows.iter().map(|r| r.gain_vs_kernel).collect();
    println!("\n-- paper-vs-measured --");
    println!(
        "max tput gain (b2b/base)  : paper 1.9x  measured {:.2}x",
        stats::max(&gains)
    );
    println!(
        "tput gain vs kernel fetch : paper 1.3x  measured {:.2}x",
        stats::max(&vs_kernel)
    );

    if sweep_hit {
        println!("\n# §5.3.3 hit-rate sweep (Qwen2.5-0.5B)");
        let mut hit_rows = Vec::new();
        for hit in [1.0, 0.7, 0.5] {
            hit_rows.push(serving::throughput(ALL_MODELS[0], 4096, n / 2, decode, hit));
        }
        print!("{}", serving::render_fig17(&hit_rows));
        println!("(gains shrink as misses add prefill GPU time — paper §5.3.3)");
        rows.extend(hit_rows);
    }
    serving::fig17_csv(&rows).write("results/fig17_throughput.csv").unwrap();
}
