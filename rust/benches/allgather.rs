//! BENCH — Fig. 1 + Fig. 13 + Table 2: all-gather DMA variants vs RCCL
//! across 1KB–4GB. Prints the paper's rows (speedup of each DMA variant
//! over RCCL), the derived best-implementation table, and the paper-vs-
//! measured summary statistics recorded in EXPERIMENTS.md.

use dma_latte::collectives::{CollectiveKind, Strategy, Variant};
use dma_latte::figures::collectives as fig;
use dma_latte::util::bytes::{size_sweep, GB, KB, MB};
use dma_latte::util::stats::geomean;

fn main() {
    let kind = CollectiveKind::AllGather;
    let t0 = std::time::Instant::now();
    // Smoke runs stop at 64MB — large enough to keep the ≥32MB summary
    // band non-empty, small enough for CI.
    let sizes = dma_latte::util::bench_smoke().then(|| size_sweep(KB, 64 * MB, 2));
    let rows = fig::sweep(kind, sizes);
    let wall = t0.elapsed();
    print!("{}", fig::render(kind, &rows));

    println!("\n-- Table 2 (derived from this sweep) --");
    for (lo, hi, v) in fig::best_table(&rows) {
        println!(
            "  {:>6} ..= {:>6}  {}",
            dma_latte::util::bytes::fmt_size(lo),
            dma_latte::util::bytes::fmt_size(hi),
            v.name()
        );
    }

    let below = fig::LATENCY_BOUND_CEILING;
    let pcpy = fig::geomean_speedup(&rows, Variant::new(Strategy::Pcpy, false), below);
    let best = fig::geomean_best(&rows, below);
    let large: Vec<f64> = rows
        .iter()
        .filter(|r| (32 * MB..=GB).contains(&r.size))
        .map(|r| r.best().1)
        .collect();
    println!("\n-- paper-vs-measured (geomean, <32MB unless noted) --");
    println!("pcpy slowdown      : paper 4.5x   measured {:.2}x", 1.0 / pcpy);
    println!("best-DMA slowdown  : paper 1.30x  measured {:.2}x", 1.0 / best);
    println!("32MB-1GB speedup   : paper ~1.2x  measured {:.2}x", geomean(&large));
    let b_small = fig::geomean_speedup(&rows, Variant::new(Strategy::B2b, false), MB);
    let p_small = fig::geomean_speedup(&rows, Variant::new(Strategy::Pcpy, false), MB);
    println!("b2b over pcpy <1MB : paper 2.7x   measured {:.2}x", b_small / p_small);
    let bc = fig::geomean_speedup(&rows, Variant::new(Strategy::Bcst, false), 4 * MB);
    let pc = fig::geomean_speedup(&rows, Variant::new(Strategy::Pcpy, false), 4 * MB);
    println!("bcst over pcpy <4MB: paper 1.7x   measured {:.2}x", bc / pc);

    fig::to_csv(kind, &rows).write("results/fig13_allgather.csv").unwrap();
    println!(
        "\nsweep wall time: {:.2}s ({} sizes × 6 variants; CSV → results/)",
        wall.as_secs_f64(),
        rows.len()
    );
}
