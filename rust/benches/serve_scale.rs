//! BENCH — §Serving at scale (PR 9): streaming arrival generation and
//! bounded-memory serving at 10k / 100k / 1M requests, emitted as
//! `BENCH_PR9.json`.
//!
//! Unlike the virtual-time serving benches, the headline rows here are
//! **host-side** measurements (they time the generator / engine process
//! itself, so absolute values vary by machine; the asserted *ratios* do
//! not):
//!
//! - `arrivals_sec_{10k,100k,1m}` — host arrival throughput of a full
//!   [`WorkloadSpec::stream`] drain (arrivals/second, stored in the
//!   ns-named fields).
//! - `first_arrivals_1m` — host ns until the first 10k schedulable
//!   arrivals exist, from a 1M-request spec: before = the legacy
//!   materialize-then-sort `generate()` path (which must draw all 1M
//!   events first), after = the lazy stream. The bench asserts the
//!   stream is ≥ 10× faster and prints a greppable `scale check: OK`
//!   line.
//! - `resident_arrivals_{10k,100k,1m}` — peak arrival events resident in
//!   memory: before = the materialized vector (= N), after = the
//!   stream's session heap (O(active sessions)); the 1M peak must stay
//!   within 10× of the 10k peak (sublinear growth).
//! - `engine_stream_drive` — host wall ns for a full
//!   `drive()` episode fed by the stream (smoke: 5k requests; full:
//!   100k, which also pushes the TTFT/TPOT series past the exact-phase
//!   cap and exercises the sketch).
//!
//! JSON lands at `../BENCH_PR9.json` (repo root when run via cargo),
//! overridable with `DMA_LATTE_BENCH_JSON=path` (`=0` disables).

use dma_latte::coordinator::workload::{drive, WorkloadSpec};
use dma_latte::figures::serving_load as sl;
use dma_latte::models::zoo::QWEN25_0_5B;
use dma_latte::util::timer::{bench, bench_json, black_box, BenchComparison, BenchResult};

const SEED: u64 = 9;
/// Offered rate for every spec below (the arrival horizon scales with the
/// request count; the active-session population does not).
const RATE_RPS: f64 = 4000.0;
/// Arrival prefix the first-arrivals gate times — the events a serving
/// process actually waits on before it can schedule anything.
const FIRST_K: usize = 10_000;

/// Wrap one deterministic value as a BenchResult (no spread).
fn modeled(name: &str, value: f64) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        iters: 1,
        mean_ns: value,
        median_ns: value,
        p95_ns: value,
        p99_ns: value,
        min_ns: value,
    }
}

/// Single-value row.
fn value_row(path: &str, name: &str, value: f64) -> BenchComparison {
    BenchComparison {
        path: path.to_string(),
        before: None,
        after: modeled(name, value),
    }
}

fn report(row: &BenchComparison, unit: &str) {
    match &row.before {
        Some(b) => println!(
            "row {:<24} before {:>14.1} after {:>14.1} {unit}",
            row.path, b.median_ns, row.after.median_ns
        ),
        None => println!(
            "row {:<24} value {:>14.1} {unit}",
            row.path, row.after.median_ns
        ),
    }
}

fn spec(requests: u64) -> WorkloadSpec {
    WorkloadSpec::poisson(RATE_RPS, requests, SEED)
}

const SIZES: [(&str, u64); 3] = [("10k", 10_000), ("100k", 100_000), ("1m", 1_000_000)];

fn main() {
    let smoke = dma_latte::util::bench_smoke();
    let (warmup, iters) = if smoke { (0, 1) } else { (1, 3) };
    println!("== serve scale: streaming arrivals, bounded memory (BENCH_PR9) ==\n");
    let mut rows: Vec<BenchComparison> = Vec::new();

    // Equality oracle on the bench's own spec (the property tests cover
    // random specs): the stream is the materialized reference, lazily.
    let s10k = spec(10_000);
    assert_eq!(s10k.stream().collect::<Vec<_>>(), s10k.generate());

    // 1) Full-drain host arrival throughput at every size.
    for (label, n) in SIZES {
        let sp = spec(n);
        let r = bench(&format!("stream drain {label}"), warmup, iters, || {
            let mut count = 0u64;
            for e in sp.stream() {
                count += 1;
                black_box(e.at_ns);
            }
            assert_eq!(count, n);
        });
        let per_sec = n as f64 / (r.median_ns / 1e9);
        rows.push(value_row(
            &format!("arrivals_sec_{label}"),
            &format!("streamed arrivals/sec, {label} requests"),
            per_sec,
        ));
        report(rows.last().unwrap(), "arrivals/s");
    }
    println!();

    // 2) The scale gate: host time until the first FIRST_K schedulable
    //    arrivals from a 1M-request spec. The legacy path draws and sorts
    //    all 1M events before the engine can see event #1; the stream
    //    hands events over as sessions start.
    let big = spec(1_000_000);
    let legacy = bench(
        "first 10k arrivals, materialize+sort 1M",
        warmup,
        iters,
        || {
            let events = big.generate();
            black_box(events[FIRST_K - 1].at_ns);
        },
    );
    let streaming = bench("first 10k arrivals, streamed", warmup, iters, || {
        let mut st = big.stream();
        let mut last = 0;
        for _ in 0..FIRST_K {
            last = st.next().expect("1M-request stream").at_ns;
        }
        black_box(last);
    });
    let speedup = legacy.median_ns / streaming.median_ns;
    assert!(
        speedup >= 10.0,
        "streaming must reach the first arrivals >=10x sooner: {speedup:.1}x"
    );
    println!(
        "scale check: OK (first {FIRST_K} arrivals from a 1M-request spec: {speedup:.0}x faster streamed)"
    );
    rows.push(BenchComparison {
        path: "first_arrivals_1m".to_string(),
        before: Some(legacy),
        after: streaming,
    });
    report(rows.last().unwrap(), "ns");
    println!();

    // 3) Peak resident arrival events: materialized = N, streamed =
    //    session heap. Growth across 100x more requests must stay within
    //    10x (the population tracks active sessions, not episode length).
    let mut peaks = Vec::new();
    for (label, n) in SIZES {
        let sp = spec(n);
        let mut st = sp.stream();
        let mut count = 0u64;
        while st.next().is_some() {
            count += 1;
        }
        assert_eq!(count, n);
        let peak = st.peak_resident() as f64;
        peaks.push(peak);
        rows.push(BenchComparison {
            path: format!("resident_arrivals_{label}"),
            before: Some(modeled(&format!("materialized events, {label}"), n as f64)),
            after: modeled(&format!("peak resident streamed events, {label}"), peak),
        });
        report(rows.last().unwrap(), "events");
    }
    assert!(
        peaks[2] <= 10.0 * peaks[0].max(1.0),
        "resident arrivals must grow sublinearly: {peaks:?}"
    );
    println!("peak resident events across 10k/100k/1m: {peaks:?} (sublinear)\n");

    // 4) End-to-end: one engine episode fed by the stream. The full run
    //    pushes 100k samples into the TTFT/TPOT series — past the exact
    //    phase — so bounded-memory percentiles are exercised, not just
    //    unit-tested.
    let n_drive: u64 = if smoke { 5_000 } else { 100_000 };
    let cfg = sl::serve_config(&QWEN25_0_5B, 1, true);
    let sp = spec(n_drive);
    let t0 = std::time::Instant::now();
    let m = drive(&cfg, &sp);
    let host_ns = t0.elapsed().as_nanos() as f64;
    assert_eq!(m.finished, n_drive, "every streamed request must finish");
    assert!(m.queue_depth.len() <= cfg.queue_sample_cap);
    assert!(m.ttft_pct_ms(99.0).is_finite() && m.tpot_pct_ms(99.0).is_finite());
    println!(
        "engine drive: {} streamed requests in {:.2}s host wall ({:.1}s virtual, ttft p99 {:.1}ms)",
        m.finished,
        host_ns / 1e9,
        m.wall_ns as f64 / 1e9,
        m.ttft_pct_ms(99.0)
    );
    rows.push(value_row(
        "engine_stream_drive",
        &format!("drive() over {n_drive} streamed requests, host wall"),
        host_ns,
    ));
    report(rows.last().unwrap(), "ns");
    println!();

    // Machine-readable trajectory file.
    let dest = std::env::var("DMA_LATTE_BENCH_JSON")
        .unwrap_or_else(|_| "../BENCH_PR9.json".to_string());
    if dest != "0" {
        let meta = [
            ("pr", "PR9".to_string()),
            ("mode", if smoke { "smoke" } else { "full" }.to_string()),
            (
                "note",
                "host-side scale measurements (machine-dependent absolutes, \
                 asserted ratios): arrivals_sec rows are arrivals/s, \
                 resident rows are event counts (both stored in the \
                 ns-named fields), first_arrivals/engine_stream_drive rows \
                 are host ns"
                    .to_string(),
            ),
        ];
        let doc = bench_json("serve_scale", &meta, &rows);
        if let Err(e) = std::fs::write(&dest, doc) {
            // Fatal: CI asserts the file was regenerated; a silent miss
            // would let a stale checked-in copy masquerade as fresh.
            eprintln!("could not write {dest}: {e}");
            std::process::exit(1);
        }
        println!("wrote {dest}");
    }
}
