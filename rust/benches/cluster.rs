//! BENCH — cluster scaling: hierarchical DMA all-gather / all-to-all over
//! 1, 2 and 4 MI300X nodes (8 GPUs each, 400 Gb/s RoCE NIC model), 1KB to
//! 1GB, with the cluster-aware selector picking the (intra variant, inter
//! schedule) per cell. The 1-node column reproduces the paper's flat
//! collectives; the other columns are the scale-out cost on top.

use dma_latte::cluster::{run_hier, select_cluster, ClusterTopology, HierRunOptions};
use dma_latte::collectives::CollectiveKind;
use dma_latte::figures::cluster as fig;
use dma_latte::util::bytes::{fmt_size, size_sweep, GB, KB};

fn main() {
    let smoke = dma_latte::util::bench_smoke();
    let max = if smoke { 16 * 1024 * 1024 } else { GB };
    let nodes = [1usize, 2, 4];
    let t0 = std::time::Instant::now();
    for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
        let rows = fig::scaling(kind, &nodes, Some(size_sweep(KB, max, 2)));
        print!("{}", fig::render(kind, &rows));
        println!();
    }

    // Spot-check the schedule axis at one bandwidth-bound size: pipelining
    // must not lose to the sequential barrier.
    let size = if smoke { 8 * 1024 * 1024 } else { 64 * 1024 * 1024 };
    for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
        let cluster = ClusterTopology::mi300x(4);
        let mut choice = select_cluster(kind, &cluster, size);
        let auto = run_hier(kind, choice, &cluster, size, &HierRunOptions::default());
        choice.inter = dma_latte::cluster::InterSchedule::Sequential;
        let seq = run_hier(kind, choice, &cluster, size, &HierRunOptions::default());
        println!(
            "{} {} on 4 nodes: selector {:.1} us (inter {:.1} us) vs sequential {:.1} us",
            kind.name(),
            fmt_size(size),
            auto.latency_ns as f64 / 1e3,
            auto.inter_ns as f64 / 1e3,
            seq.latency_ns as f64 / 1e3,
        );
        assert!(auto.latency_ns <= seq.latency_ns);
    }
    println!("\nbench wall time: {:.2?}", t0.elapsed());
}
