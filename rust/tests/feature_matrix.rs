//! Table 1 as executable properties: each DMA feature delivers exactly the
//! benefits the paper's feature matrix lists.

use dma_latte::collectives::{run_collective, CollectiveKind, RunOptions, Strategy, Variant};
use dma_latte::sim::SimConfig;
use dma_latte::util::bytes::KB;

fn run(kind: CollectiveKind, s: Strategy, pre: bool, size: u64) -> dma_latte::collectives::CollectiveResult {
    run_collective(
        kind,
        Variant::new(s, pre),
        size,
        &RunOptions {
            sim: SimConfig::mi300x(),
            verify: true,
        },
    )
}

/// Row "broadcast": lowers #copy commands, #engines, sync commands;
/// improves link utilization (1 read / 2 writes); lowers memory traffic.
#[test]
fn broadcast_row() {
    let size = 256 * KB;
    let p = run(CollectiveKind::AllGather, Strategy::Pcpy, false, size);
    let b = run(CollectiveKind::AllGather, Strategy::Bcst, false, size);
    assert!(b.data_cmds < p.data_cmds, "fewer commands");
    assert!(b.engines_used < p.engines_used, "fewer engines");
    // Memory traffic: bcst reads each source once per pair (1 read, 2
    // writes) — less HBM traffic than pcpy's per-peer reads.
    assert!(b.activity.hbm_bytes < p.activity.hbm_bytes, "less memory traffic");
    // Same wire bytes delivered in spite of fewer engines.
    assert!((b.activity.link_bytes - p.activity.link_bytes).abs() < 1.0);
    assert_eq!(b.verified, Some(true));
}

/// Row "swap": lowers #copies/#engines/syncs; in-place (no extra memory).
#[test]
fn swap_row() {
    let size = 256 * KB;
    let p = run(CollectiveKind::AllToAll, Strategy::Pcpy, false, size);
    let s = run(CollectiveKind::AllToAll, Strategy::Swap, false, size);
    assert!(s.data_cmds < p.data_cmds);
    assert!(s.engines_used < p.engines_used);
    // In-place: out-of-place AA must WRITE to a separate output region;
    // swap writes only the input buffers. Traffic equal or lower, and the
    // verifier checked the transpose happened in place.
    assert!(s.activity.hbm_bytes <= p.activity.hbm_bytes + 1.0);
    assert_eq!(s.verified, Some(true));
}

/// Row "back-to-back": lowers #engines and sync commands; improves link
/// utilization at small sizes (copies overlap).
#[test]
fn b2b_row() {
    let size = 32 * KB;
    let p = run(CollectiveKind::AllGather, Strategy::Pcpy, false, size);
    let b = run(CollectiveKind::AllGather, Strategy::B2b, false, size);
    assert_eq!(b.engines_used, 8, "one engine per GPU");
    assert!(b.engines_used < p.engines_used);
    assert!(b.latency_ns < p.latency_ns, "latency-bound sizes improve");
    assert_eq!(b.verified, Some(true));
}

/// Row "prelaunch": off-critical-path DMA launch via poll.
#[test]
fn prelaunch_row() {
    for s in [Strategy::Pcpy, Strategy::Bcst, Strategy::B2b] {
        let size = 128 * KB;
        let d = run(CollectiveKind::AllGather, s, false, size);
        let pre = run(CollectiveKind::AllGather, s, true, size);
        assert!(
            pre.latency_ns < d.latency_ns,
            "{}: prelaunch must shorten the critical path",
            s.name()
        );
        assert_eq!(pre.verified, Some(true));
    }
}
