//! Property tests over the collectives layer: functional correctness for
//! random sizes / GPU counts / variants, and structural invariants.

use dma_latte::collectives::{
    run_collective, CollectiveKind, RunOptions, Strategy, Variant,
};
use dma_latte::sim::{SimConfig, Topology};
use dma_latte::util::proptest::{run as prop_run, Config};
use dma_latte::util::rng::Rng;

/// AG = concatenation and AA = transpose for random (n, size, variant).
#[test]
fn prop_collectives_verify_random() {
    prop_run(
        "collectives-verify",
        Config {
            cases: 40,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let n = *rng.pick(&[2u8, 3, 4, 8]);
            let kind = if rng.chance(0.5) {
                CollectiveKind::AllGather
            } else {
                CollectiveKind::AllToAll
            };
            let variants = Variant::all_for(kind);
            let v = *rng.pick(&variants);
            // size divisible by n, 1-256 KB per chunk
            let chunk = 1024 * rng.range(1, 256) as u64;
            let size = chunk * n as u64;
            let mut opts = RunOptions {
                sim: SimConfig::mi300x(),
                verify: true,
            };
            opts.sim.topology = Topology::custom(n, 16, 64.0, 64.0);
            let r = run_collective(kind, v, size, &opts);
            assert_eq!(
                r.verified,
                Some(true),
                "{} {} n={n} size={size}",
                kind.name(),
                v.name()
            );
        },
    );
}

/// Plans cover every (src, dst) pair exactly once, for every variant.
#[test]
fn prop_plan_coverage() {
    prop_run(
        "plan-coverage",
        Config {
            cases: 48,
            ..Default::default()
        },
        |rng: &mut Rng| {
            use dma_latte::collectives::exec::build_plan;
            use dma_latte::sim::command::Command;
            let n = rng.range(2, 8) as u8;
            let topo = Topology::custom(n, 16, 64.0, 64.0);
            let kind = if rng.chance(0.5) {
                CollectiveKind::AllGather
            } else {
                CollectiveKind::AllToAll
            };
            let variants = Variant::all_for(kind);
            let v = *rng.pick(&variants);
            let size = n as u64 * 4096;
            let plan = build_plan(kind, v, &topo, size);
            // Count transfer coverage: (src_gpu, dst_gpu) pairs.
            let mut pairs = std::collections::HashMap::new();
            for r in &plan.ranks {
                for e in &r.engines {
                    for c in &e.cmds {
                        match *c {
                            Command::Copy { src, dst, .. } => {
                                *pairs.entry((src.node, dst.node)).or_insert(0) += 1;
                            }
                            Command::Bcst {
                                src, dst0, dst1, ..
                            } => {
                                *pairs.entry((src.node, dst0.node)).or_insert(0) += 1;
                                *pairs.entry((src.node, dst1.node)).or_insert(0) += 1;
                            }
                            Command::Swap { a, b, .. } => {
                                *pairs.entry((a.node, b.node)).or_insert(0) += 1;
                                *pairs.entry((b.node, a.node)).or_insert(0) += 1;
                            }
                            _ => {}
                        }
                    }
                }
            }
            // Every ordered pair of distinct GPUs appears exactly once.
            let mut want = 0;
            for i in 0..n {
                for j in 0..n {
                    if i != j {
                        want += 1;
                        let k = (
                            dma_latte::sim::NodeId::Gpu(i),
                            dma_latte::sim::NodeId::Gpu(j),
                        );
                        assert_eq!(
                            pairs.get(&k).copied().unwrap_or(0),
                            1,
                            "{kind:?} {} pair {i}->{j}",
                            v.name()
                        );
                    }
                }
            }
            assert_eq!(pairs.len(), want);
        },
    );
}

/// Latency monotonicity: for any variant, bigger payload is never faster.
#[test]
fn prop_latency_monotone_in_size() {
    prop_run(
        "latency-monotone",
        Config {
            cases: 16,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let kind = if rng.chance(0.5) {
                CollectiveKind::AllGather
            } else {
                CollectiveKind::AllToAll
            };
            let variants = Variant::all_for(kind);
            let v = *rng.pick(&variants);
            let opts = RunOptions {
                sim: SimConfig::mi300x(),
                verify: false,
            };
            let base = 8 * 1024 * rng.range(1, 64) as u64;
            let small = run_collective(kind, v, base, &opts);
            let big = run_collective(kind, v, base * 4, &opts);
            assert!(
                big.latency_ns >= small.latency_ns,
                "{} {}: {} vs {}",
                kind.name(),
                v.name(),
                small.latency_ns,
                big.latency_ns
            );
        },
    );
}

/// The selector never picks an inapplicable strategy and is total.
#[test]
fn prop_selector_total_and_applicable() {
    use dma_latte::collectives::select_variant;
    prop_run(
        "selector",
        Config {
            cases: 200,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let size = 1 + rng.below(8 << 30);
            for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
                let v = select_variant(kind, size);
                assert!(v.strategy.applicable(kind));
                // Very large sizes never use b2b (serialization) and very
                // small sizes never use bare pcpy.
                if size >= 1 << 30 {
                    assert_ne!(v.strategy, Strategy::B2b, "size {size}");
                }
                if size <= 16 * 1024 {
                    assert!(
                        !(v.strategy == Strategy::Pcpy && !v.prelaunch),
                        "size {size}"
                    );
                }
            }
        },
    );
}
