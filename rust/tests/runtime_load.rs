//! AOT bridge integration: load the HLO artifacts compiled by
//! `python/compile/aot.py`, execute via PJRT, and match the golden vectors
//! the JAX side recorded — proving the three layers compose numerically.
//!
//! Requires `make artifacts`; tests self-skip when artifacts are absent.

use dma_latte::runtime::{ArtifactMeta, Executor};
use dma_latte::util::json::Json;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

/// Rebuild the deterministic example inputs of `aot.example_inputs`
/// (numpy default_rng(7) is not reproducible here, so goldens carry the
/// checksums; we only need the *param* path to be cross-language — inputs
/// for golden checks are re-derived in python and compared by checksum).
/// For the runtime test we check: (a) artifacts compile and execute with
/// correct shapes; (b) params regenerate bit-identically (param_probe).
#[test]
fn params_regenerate_bit_identical() {
    let Some(dir) = artifacts() else { return };
    let meta = ArtifactMeta::load(&dir).unwrap();
    let gold = meta.goldens().unwrap();
    let probe = gold.get("param_probe").unwrap();
    let seed = meta.dims.param_seed;

    let embed = &meta.params[0];
    let got: Vec<f32> = (0..4)
        .map(|i| dma_latte::runtime::params::counter_uniform(seed, embed.offset, i) * embed.scale)
        .collect();
    let want = probe.get("embed_first4").unwrap().arr().unwrap();
    for (g, w) in got.iter().zip(want) {
        let w = w.num().unwrap() as f32;
        assert!((g - w).abs() < 1e-7, "embed probe: {g} vs {w}");
    }

    let unembed = meta.params.last().unwrap();
    let got: Vec<f32> = (0..4)
        .map(|i| {
            dma_latte::runtime::params::counter_uniform(seed, unembed.offset, i) * unembed.scale
        })
        .collect();
    let want = probe.get("unembed_first4").unwrap().arr().unwrap();
    for (g, w) in got.iter().zip(want) {
        let w = w.num().unwrap() as f32;
        assert!((g - w).abs() < 1e-7, "unembed probe: {g} vs {w}");
    }
}

#[test]
fn kv_gather_executes_and_is_exact() {
    let Some(dir) = artifacts() else { return };
    let exe = Executor::load(&dir).unwrap();
    let d = exe.meta.dims.clone();
    // Identity check: gather row i == pool row idx[i], bit-exact.
    let pool: Vec<f32> = (0..d.num_blocks * 256).map(|i| (i % 97) as f32 * 0.25).collect();
    let idx: Vec<i32> = (0..d.max_blocks as i32).rev().collect();
    let out = exe.kv_gather(&pool, &idx).unwrap();
    assert_eq!(out.len(), d.max_blocks * 256);
    for (k, &i) in idx.iter().enumerate() {
        let got = &out[k * 256..(k + 1) * 256];
        let want = &pool[i as usize * 256..(i as usize + 1) * 256];
        assert_eq!(got, want, "row {k}");
    }
}

#[test]
fn decode_step_shapes_and_finite() {
    let Some(dir) = artifacts() else { return };
    let exe = Executor::load(&dir).unwrap();
    let d = exe.meta.dims.clone();
    let token = vec![1i32; d.batch];
    let pos = vec![0i32; d.batch];
    let pool =
        vec![0f32; d.num_blocks * d.block_size * d.layers * 2 * d.kv_heads * d.head_dim];
    let tables = vec![0i32; d.batch * d.max_blocks];
    let (logits, new_kv) = exe.decode_step(&token, &pos, &pool, &tables).unwrap();
    assert_eq!(logits.len(), d.batch * d.vocab);
    assert_eq!(new_kv.len(), d.batch * d.layers * 2 * d.kv_heads * d.head_dim);
    assert!(logits.iter().all(|x| x.is_finite()));
    // Same token + same empty context ⇒ identical logits across the batch.
    let (a, b) = (&logits[..d.vocab], &logits[d.vocab..2 * d.vocab]);
    assert_eq!(a, b);
}

#[test]
fn prefill_then_decode_consistency() {
    let Some(dir) = artifacts() else { return };
    let exe = Executor::load(&dir).unwrap();
    let d = exe.meta.dims.clone();
    let tokens: Vec<i32> = (0..d.prefill_len as i32).map(|i| (i * 37) % 512).collect();
    let (logits, kv) = exe.prefill(&tokens).unwrap();
    assert_eq!(logits.len(), d.vocab);
    let kv_row = d.layers * 2 * d.kv_heads * d.head_dim;
    assert_eq!(kv.len(), d.prefill_len * kv_row);
    assert!(logits.iter().all(|x| x.is_finite()));
    assert!(kv.iter().any(|&x| x != 0.0));

    // Page prefill KV into a pool (identity table) and decode the argmax
    // token; logits must be finite and context-dependent (differ from the
    // empty-context decode).
    let mut pool =
        vec![0f32; d.num_blocks * d.block_size * d.layers * 2 * d.kv_heads * d.head_dim];
    let block_row = d.block_size * kv_row;
    for p in 0..d.prefill_len {
        let phys = p / d.block_size;
        let within = p % d.block_size;
        pool[phys * block_row + within * kv_row..phys * block_row + (within + 1) * kv_row]
            .copy_from_slice(&kv[p * kv_row..(p + 1) * kv_row]);
    }
    let mut tables = vec![0i32; d.batch * d.max_blocks];
    for b in 0..d.batch {
        for l in 0..d.max_blocks {
            tables[b * d.max_blocks + l] = l as i32;
        }
    }
    let next = Executor::argmax(&logits);
    let token = vec![next; d.batch];
    let pos = vec![d.prefill_len as i32; d.batch];
    let (ctx_logits, _) = exe.decode_step(&token, &pos, &pool, &tables).unwrap();
    let empty_pool = vec![0f32; pool.len()];
    let zero_pos = vec![0i32; d.batch];
    let (empty_logits, _) = exe
        .decode_step(&token, &zero_pos, &empty_pool, &tables)
        .unwrap();
    assert!(ctx_logits.iter().all(|x| x.is_finite()));
    let diff = ctx_logits
        .iter()
        .zip(&empty_logits)
        .filter(|(a, b)| (*a - *b).abs() > 1e-4)
        .count();
    assert!(diff > d.vocab / 4, "context must change the distribution");
}

#[test]
fn golden_checksums_recorded() {
    // The JAX goldens exist and are structurally sound (the numeric
    // equivalence of params is asserted above; full output equivalence is
    // checked on the python side where the same inputs are reproducible).
    let Some(dir) = artifacts() else { return };
    let meta = ArtifactMeta::load(&dir).unwrap();
    let gold = meta.goldens().unwrap();
    for key in ["decode_step", "prefill", "kv_gather"] {
        let g = gold.get(key).unwrap_or_else(|| panic!("golden {key}"));
        let Json::Obj(m) = g else { panic!("golden {key} not an object") };
        assert!(!m.is_empty());
    }
}
