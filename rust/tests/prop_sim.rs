//! Property tests over the DES substrate (proptest-lite, util::proptest).

use dma_latte::sim::command::{Addr, AtomicOp, Command};
use dma_latte::sim::host::{ApiKind, HostOp};
use dma_latte::sim::topology::NodeId;
use dma_latte::sim::{EngineId, Sim, SimConfig};
use dma_latte::util::proptest::{check, run as prop_run, Config};
use dma_latte::util::rng::Rng;

/// Random hazard-free copy set on random engines always completes, moves
/// every byte, and simulated time is monotone.
#[test]
fn prop_random_copies_complete_and_verify() {
    check("random-copies", |rng: &mut Rng| {
        let mut sim = Sim::new(SimConfig::mi300x().functional());
        let sig = sim.alloc_signal(0);
        let n_copies = rng.range(1, 12);
        let mut script = Vec::new();
        let mut expected = Vec::new();
        for c in 0..n_copies {
            let src_gpu = rng.range(0, 7) as u8;
            let mut dst_gpu = rng.range(0, 7) as u8;
            if dst_gpu == src_gpu {
                dst_gpu = (dst_gpu + 1) % 8;
            }
            let len = 64 * rng.range(1, 64) as u64;
            // Disjoint ranges per copy index.
            let off = c as u64 * 1 << 20;
            let fill = (c as u8).wrapping_mul(37).wrapping_add(11);
            sim.memory
                .poke(NodeId::Gpu(src_gpu), off, &vec![fill; len as usize]);
            let engine = EngineId {
                gpu: src_gpu,
                idx: rng.range(0, 15) as u8,
            };
            script.push(HostOp::CreateCommands {
                engine,
                cmds: vec![
                    Command::Copy {
                        src: Addr::new(NodeId::Gpu(src_gpu), off),
                        dst: Addr::new(NodeId::Gpu(dst_gpu), off),
                        len,
                    },
                    Command::Atomic {
                        signal: sig,
                        op: AtomicOp::Add(1),
                    },
                ],
                api: ApiKind::Raw,
            });
            script.push(HostOp::RingDoorbell { engine });
            expected.push((dst_gpu, off, len, fill));
        }
        script.push(HostOp::WaitSignal {
            signal: sig,
            at_least: n_copies as i64,
        });
        sim.add_host(script, 0);
        let out = sim.run();
        assert!(out.deadlocked.is_empty());
        assert!(out.makespan > 0);
        for (gpu, off, len, fill) in expected {
            let got = sim.memory.peek(NodeId::Gpu(gpu), off, len);
            assert!(got.iter().all(|&b| b == fill), "copy landed wrong");
        }
    });
}

/// Chained (hazardous) copies on one engine always produce the final value
/// — the hazard detector must serialize them in order.
#[test]
fn prop_hazard_chains_serialize() {
    check("hazard-chains", |rng: &mut Rng| {
        let mut sim = Sim::new(SimConfig::mi300x().functional());
        let sig = sim.alloc_signal(0);
        let hops = rng.range(2, 6);
        let len = 64 * rng.range(1, 16) as u64;
        sim.memory.poke(NodeId::Gpu(0), 0, &vec![0xAB; len as usize]);
        // gpu0 -> gpu1 -> gpu2 ... chained through the same offsets.
        let mut cmds = Vec::new();
        for h in 0..hops {
            cmds.push(Command::Copy {
                src: Addr::new(NodeId::Gpu(h as u8), 0),
                dst: Addr::new(NodeId::Gpu(h as u8 + 1), 0),
                len,
            });
        }
        cmds.push(Command::Atomic {
            signal: sig,
            op: AtomicOp::Add(1),
        });
        let engine = EngineId { gpu: 0, idx: 0 };
        sim.add_host(
            vec![
                HostOp::CreateCommands {
                    engine,
                    cmds,
                    api: ApiKind::Raw,
                },
                HostOp::RingDoorbell { engine },
                HostOp::WaitSignal {
                    signal: sig,
                    at_least: 1,
                },
            ],
            0,
        );
        let out = sim.run();
        assert!(out.deadlocked.is_empty());
        let got = sim.memory.peek(NodeId::Gpu(hops as u8), 0, len);
        assert!(got.iter().all(|&b| b == 0xAB), "chain broke");
    });
}

/// A poll never fires before its condition: the gated copy lands only
/// after the trigger write, whatever the schedule.
#[test]
fn prop_poll_gating_safe() {
    prop_run(
        "poll-gating",
        Config {
            cases: 32,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let mut sim = Sim::new(SimConfig::mi300x().functional());
            let trigger = sim.alloc_signal(0);
            let done = sim.alloc_signal(0);
            let delay = rng.range(1_000, 200_000) as u64;
            sim.memory.poke(NodeId::Gpu(0), 0, &[1u8; 64]);
            let engine = EngineId { gpu: 0, idx: 3 };
            sim.add_host(
                vec![
                    HostOp::CreateCommands {
                        engine,
                        cmds: vec![
                            Command::Poll {
                                signal: trigger,
                                cond: dma_latte::sim::PollCond::Gte(1),
                            },
                            Command::Copy {
                                src: Addr::new(NodeId::Gpu(0), 0),
                                dst: Addr::new(NodeId::Gpu(1), 0),
                                len: 64,
                            },
                            Command::Atomic {
                                signal: done,
                                op: AtomicOp::Add(1),
                            },
                        ],
                        api: ApiKind::Raw,
                    },
                    HostOp::RingDoorbell { engine },
                    HostOp::Delay { ns: delay },
                    HostOp::Mark { name: "trigger" },
                    HostOp::SetSignal {
                        signal: trigger,
                        value: 1,
                    },
                    HostOp::WaitSignal {
                        signal: done,
                        at_least: 1,
                    },
                    HostOp::Mark { name: "done" },
                ],
                0,
            );
            let out = sim.run();
            assert!(out.deadlocked.is_empty());
            let h = sim.host(dma_latte::sim::HostId(0));
            let trig = h.mark("trigger").unwrap();
            let done_t = h.mark("done").unwrap();
            assert!(done_t > trig, "copy cannot complete before trigger");
            assert!(done_t - trig < 60_000, "gated path should be short");
        },
    );
}

/// Determinism: identical programs produce identical makespans.
#[test]
fn prop_deterministic_replay() {
    check("replay", |rng: &mut Rng| {
        let seed = rng.next_u64();
        let build = |seed: u64| {
            let mut r = Rng::new(seed);
            let mut sim = Sim::new(SimConfig::mi300x());
            let sig = sim.alloc_signal(0);
            let n = r.range(1, 8);
            for g in 0..n {
                let engine = EngineId {
                    gpu: g as u8,
                    idx: 0,
                };
                sim.add_host(
                    vec![
                        HostOp::CreateCommands {
                            engine,
                            cmds: vec![
                                Command::Copy {
                                    src: Addr::new(NodeId::Gpu(g as u8), 0),
                                    dst: Addr::new(NodeId::Gpu(((g + 1) % 8) as u8), 0),
                                    len: 64 * r.range(1, 100) as u64,
                                },
                                Command::Atomic {
                                    signal: sig,
                                    op: AtomicOp::Add(1),
                                },
                            ],
                            api: ApiKind::Raw,
                        },
                        HostOp::RingDoorbell { engine },
                        HostOp::WaitSignal {
                            signal: sig,
                            at_least: n as i64,
                        },
                    ],
                    0,
                );
            }
            sim.run().makespan
        };
        assert_eq!(build(seed), build(seed));
    });
}

/// Wire-traffic conservation: link bytes equal the sum of command sizes.
#[test]
fn prop_traffic_conservation() {
    check("traffic", |rng: &mut Rng| {
        let mut sim = Sim::new(SimConfig::mi300x());
        let sig = sim.alloc_signal(0);
        let n = rng.range(1, 10);
        let mut total = 0u64;
        let engine = EngineId { gpu: 0, idx: 0 };
        let mut cmds = Vec::new();
        for i in 0..n {
            let len = 64 * rng.range(1, 256) as u64;
            total += len;
            cmds.push(Command::Copy {
                src: Addr::new(NodeId::Gpu(0), i as u64 * (1 << 24)),
                dst: Addr::new(NodeId::Gpu(1 + (i % 7) as u8), i as u64 * (1 << 24)),
                len,
            });
        }
        cmds.push(Command::Atomic {
            signal: sig,
            op: AtomicOp::Add(1),
        });
        sim.add_host(
            vec![
                HostOp::CreateCommands {
                    engine,
                    cmds,
                    api: ApiKind::RawBatched,
                },
                HostOp::RingDoorbell { engine },
                HostOp::WaitSignal {
                    signal: sig,
                    at_least: 1,
                },
            ],
            0,
        );
        sim.run();
        assert_eq!(sim.link_bytes, total);
    });
}
