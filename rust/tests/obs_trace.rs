//! Integration tests for the cross-layer tracing subsystem: critical-path
//! attribution partitions end-to-end latency exactly for every
//! hierarchical collective × schedule and for a serving run; the Perfetto
//! writer emits schema-valid Chrome `trace_event` JSON; and the span tree
//! obeys its structural invariants over randomized shapes.

use dma_latte::cluster::{
    run_hier, run_hier_ar, run_hier_rs, select_allreduce, select_cluster, ClusterChoice,
    ClusterKind, ClusterTopology, HierRunOptions, InterSchedule,
};
use dma_latte::coordinator::{Request, ServeConfig, VirtualEngine};
use dma_latte::kvcache::fetch::FetchImpl;
use dma_latte::kvcache::BlockLayout;
use dma_latte::models::zoo;
use dma_latte::obs::{attribute, record, write_chrome_trace, Component, ObsTrace, SpanKind, Track};
use dma_latte::util::bytes::KB;
use dma_latte::util::json::Json;
use dma_latte::util::proptest;

/// Run one traced hierarchical collective and hand back (latency, trace).
fn run_traced(
    kind: ClusterKind,
    sched: InterSchedule,
    nodes: usize,
    size: u64,
) -> (u64, ObsTrace) {
    let topo = ClusterTopology::mi300x(nodes);
    let size = topo.pad_size(size);
    let opts = HierRunOptions {
        trace: true,
        ..Default::default()
    };
    let force = |mut c: ClusterChoice| {
        if nodes > 1 {
            c.inter = sched;
        }
        c
    };
    record::start();
    let res = match kind {
        ClusterKind::AllGather | ClusterKind::AllToAll => {
            let choice = force(select_cluster(kind, &topo, size));
            run_hier(kind.transport(), choice, &topo, size, &opts)
        }
        ClusterKind::ReduceScatter => {
            let choice = force(select_cluster(kind, &topo, size));
            run_hier_rs(choice, &topo, size, &opts)
        }
        ClusterKind::AllReduce => {
            let (rs, ag) = select_allreduce(&topo, size);
            run_hier_ar(force(rs), force(ag), &topo, size, &opts)
        }
    };
    let trace = record::finish().expect("recorder installed");
    (res.latency_ns, trace)
}

const ALL_KINDS: [ClusterKind; 4] = [
    ClusterKind::AllGather,
    ClusterKind::AllToAll,
    ClusterKind::ReduceScatter,
    ClusterKind::AllReduce,
];

const ALL_SCHEDULES: [InterSchedule; 3] = [
    InterSchedule::Sequential,
    InterSchedule::Pipelined,
    InterSchedule::Overlapped,
];

/// The headline invariant: the nine attribution components sum to the
/// modeled end-to-end latency *exactly* for every collective × schedule.
#[test]
fn attribution_partitions_every_kind_and_schedule() {
    for kind in ALL_KINDS {
        for sched in ALL_SCHEDULES {
            let (latency, trace) = run_traced(kind, sched, 2, 128 * KB);
            assert!(latency > 0);
            let attr = attribute(&trace);
            assert_eq!(attr.total(), latency, "{kind:?}/{sched:?}");
            // Cross-node runs always put NIC time on the path, and the
            // intra rounds always move bytes.
            assert!(attr.get(Component::Nic) > 0, "{kind:?}/{sched:?}: nic");
            assert!(attr.get(Component::Copy) > 0, "{kind:?}/{sched:?}: copy");
        }
    }
}

/// Serving attribution partitions the wall clock of a full run, and the
/// per-request spans land on the request track.
#[test]
fn serving_attribution_partitions_wall() {
    let n = 16u64;
    let (prefill, decode) = (512u64, 16u64);
    let mut cfg = ServeConfig::new(&zoo::QWEN25_0_5B, FetchImpl::DmaB2b);
    let layout = BlockLayout::new(cfg.model, cfg.block_tokens);
    cfg.gpu_blocks = layout.blocks_for(prefill + decode) * (cfg.max_batch as u64 + 8);
    record::start();
    let mut eng = VirtualEngine::new(cfg);
    for i in 0..n {
        eng.submit(Request::new(i, prefill, decode, 0), true);
    }
    let m = eng.run_to_completion();
    let (wall, finished) = (m.wall_ns, m.finished);
    assert_eq!(finished, n);
    let trace = record::finish().expect("recorder installed");
    let attr = attribute(&trace);
    assert_eq!(attr.total(), wall, "serving attribution must sum to wall");
    assert!(attr.get(Component::Gemm) > 0, "decode GEMMs on the path");
    assert!(attr.get(Component::Control) > 0, "framework overhead visible");
    let req_spans = trace
        .spans
        .iter()
        .filter(|s| s.kind == SpanKind::Request)
        .count();
    assert_eq!(req_spans as u64, n, "one span per finished request");
    assert!(trace
        .spans
        .iter()
        .filter(|s| s.kind == SpanKind::Request)
        .all(|s| s.track == Track::Requests));
}

/// Golden test: a 2-node overlapped all-reduce round-trips through the
/// Chrome trace writer — valid JSON, one "X" event per span, one metadata
/// pair per distinct track, nothing else.
#[test]
fn perfetto_golden_overlapped_allreduce() {
    let (latency, trace) = run_traced(
        ClusterKind::AllReduce,
        InterSchedule::Overlapped,
        2,
        128 * KB,
    );
    assert!(latency > 0);
    assert!(!trace.spans.is_empty());
    let json = write_chrome_trace(&trace);
    let doc = Json::parse(&json).expect("writer must emit valid JSON");
    assert_eq!(
        doc.get("displayTimeUnit").and_then(|v| v.str()),
        Some("ns")
    );
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.arr())
        .expect("traceEvents array");
    let ph = |e: &Json| e.get("ph").and_then(|p| p.str()).map(|s| s.to_string());
    let x_events: Vec<&Json> = events
        .iter()
        .filter(|e| ph(e).as_deref() == Some("X"))
        .collect();
    let m_events = events
        .iter()
        .filter(|e| ph(e).as_deref() == Some("M"))
        .count();
    assert_eq!(x_events.len(), trace.spans.len(), "one X per span");
    assert_eq!(
        m_events,
        2 * trace.tracks().len(),
        "process+thread metadata per distinct track"
    );
    assert_eq!(events.len(), x_events.len() + m_events, "no other events");
    for e in &x_events {
        for key in ["name", "ts", "dur", "pid", "tid"] {
            assert!(e.get(key).is_some(), "X event missing {key}");
        }
        let kind = e
            .get("args")
            .and_then(|a| a.get("kind"))
            .and_then(|k| k.str());
        assert!(kind.is_some(), "X event args carry the span kind");
    }
}

/// Randomized structural invariants of the span tree: parents resolve,
/// children nest inside their parent's interval, and exclusive resource
/// tracks never overlap.
#[test]
fn span_tree_properties() {
    proptest::run(
        "obs-span-tree",
        proptest::Config {
            cases: 8,
            base_seed: 0x0B5_7FACE,
        },
        |rng| {
            let kind = ALL_KINDS[rng.below(4) as usize];
            let sched = ALL_SCHEDULES[rng.below(3) as usize];
            let nodes = 2 + rng.below(2) as usize;
            let size = (16 + rng.below(240)) * KB;
            let (latency, trace) = run_traced(kind, sched, nodes, size);
            assert!(latency > 0);
            for s in &trace.spans {
                assert!(s.end_ns >= s.start_ns, "span {} inverted", s.id);
                if let Some(p) = s.parent {
                    // Parents resolve (measure windows adopt earlier spans
                    // at close, so parent ids may exceed child ids).
                    let parent = trace
                        .spans
                        .iter()
                        .find(|x| x.id == p)
                        .unwrap_or_else(|| panic!("span {}: dangling parent {p}", s.id));
                    assert!(
                        parent.start_ns <= s.start_ns && s.end_ns <= parent.end_ns,
                        "span {} [{}, {}] escapes parent {} [{}, {}]",
                        s.id,
                        s.start_ns,
                        s.end_ns,
                        parent.id,
                        parent.start_ns,
                        parent.end_ns
                    );
                }
            }
            for track in trace.tracks() {
                if !track.exclusive() {
                    continue;
                }
                // Known model gap: the fused all-reduce's RS-leg and
                // gather-leg NIC port spans share Track::Nic{node} and may
                // overlap (inter-leg port contention is unmodeled). The
                // wire track is checked unconditionally.
                if matches!(track, Track::Nic { .. })
                    && kind == ClusterKind::AllReduce
                    && sched == InterSchedule::Overlapped
                {
                    continue;
                }
                let mut spans: Vec<(u64, u64)> = trace
                    .on_track(track)
                    .map(|s| (s.start_ns, s.end_ns))
                    .collect();
                spans.sort_unstable();
                for w in spans.windows(2) {
                    assert!(
                        w[0].1 <= w[1].0,
                        "{track:?}: [{}, {}] overlaps [{}, {}] ({kind:?}/{sched:?})",
                        w[0].0,
                        w[0].1,
                        w[1].0,
                        w[1].1
                    );
                }
            }
        },
    );
}

/// With no recorder installed the instrumented paths are inert: runs
/// succeed, `finish` has nothing, and a traced run afterwards still works
/// (no poisoned thread-local).
#[test]
fn no_recorder_is_a_no_op() {
    assert!(!record::active());
    let topo = ClusterTopology::mi300x(2);
    let size = topo.pad_size(64 * KB);
    let choice = select_cluster(ClusterKind::AllGather, &topo, size);
    let opts = HierRunOptions {
        trace: true,
        ..Default::default()
    };
    let res = run_hier(ClusterKind::AllGather.transport(), choice, &topo, size, &opts);
    assert!(res.latency_ns > 0);
    assert!(record::finish().is_none(), "nothing recorded");
    // And the same episode traced afterwards matches its own latency.
    let (latency, trace) = run_traced(ClusterKind::AllGather, InterSchedule::Pipelined, 2, 64 * KB);
    assert_eq!(attribute(&trace).total(), latency);
    assert!(!record::active(), "finish uninstalls the recorder");
}
