//! Property tests over cross-node KV migration: whatever the layout,
//! block selection, GPUs or schedule, the bytes that land on the decode
//! node are bit-identical to the single-node save/fetch reference path.

use dma_latte::cluster::topology::NicModel;
use dma_latte::kvcache::fetch::{run_fetch, CopySpec, FetchImpl};
use dma_latte::kvcache::save::run_save;
use dma_latte::kvcache::{BlockLayout, MigrateSchedule, MigrateSpec, Migrator};
use dma_latte::models::zoo::{LLAMA32_1B, QWEN25_0_5B};
use dma_latte::sim::{Sim, SimConfig};
use dma_latte::util::proptest::{run as prop_run, Config};
use dma_latte::util::rng::Rng;

/// Draw `n` distinct ids from `lo..hi`.
fn distinct_ids(rng: &mut Rng, lo: u64, hi: u64, n: usize) -> Vec<u64> {
    let mut pool: Vec<u64> = (lo..hi).collect();
    (0..n)
        .map(|_| {
            let i = rng.range(0, pool.len() - 1);
            pool.swap_remove(i)
        })
        .collect()
}

/// A per-block fill pattern: distinct across blocks, non-uniform within.
fn block_fill(seed: u64, block: u64, len: usize) -> Vec<u8> {
    let pat = (seed ^ block.wrapping_mul(0x9e37_79b9_7f4a_7c15)).to_le_bytes();
    (0..len).map(|i| pat[i % 8] ^ (i / 8) as u8).collect()
}

#[test]
fn prop_migration_matches_single_node_save_fetch() {
    prop_run(
        "migrate-byte-identical",
        Config {
            cases: 12,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let model = if rng.chance(0.5) {
                &QWEN25_0_5B
            } else {
                &LLAMA32_1B
            };
            let layout = BlockLayout::new(model, 16);
            let bb = layout.block_bytes as usize;
            let n = rng.range(1, 8);
            // Disjoint id ranges keep src/dst from aliasing even when the
            // random GPUs coincide.
            let src = distinct_ids(rng, 0, 32, n);
            let staging = distinct_ids(rng, 0, 64, n);
            let dst = distinct_ids(rng, 32, 64, n);
            let src_gpu = rng.range(0, 3) as u8;
            let dst_gpu = rng.range(0, 3) as u8;
            let schedule = if rng.chance(0.5) {
                MigrateSchedule::Blocking
            } else {
                MigrateSchedule::LayerPipelined
            };
            let imp = *rng.pick(&[FetchImpl::DmaBaseline, FetchImpl::DmaB2b]);
            let seed = rng.next_u64();

            // Cross-node path: two functional sims bridged by the NIC relay.
            let mut mig = Migrator::functional();
            // Single-node reference: one functional sim, plain save + fetch.
            let mut reference = Sim::new(SimConfig::mi300x().functional());
            for &g in &src {
                let a = layout.gpu_block_addr(src_gpu, g);
                let fill = block_fill(seed, g, bb);
                mig.save_sim.memory.poke(a.node, a.offset, &fill);
                reference.memory.poke(a.node, a.offset, &fill);
            }
            let nic = NicModel::default();
            let spec = MigrateSpec {
                layout: &layout,
                layers: model.layers,
                imp,
                nic: &nic,
                src_gpu,
                dst_gpu,
                src_blocks: &src,
                staging_blocks: &staging,
                dst_blocks: &dst,
            };
            let out = mig.run(&spec, schedule);
            assert_eq!(out.bytes, n as u64 * layout.block_bytes);
            assert!(out.first_ready_ns <= out.total_ns);

            let saves: Vec<CopySpec> = src
                .iter()
                .zip(&staging)
                .map(|(&g, &c)| {
                    (
                        layout.gpu_block_addr(src_gpu, g),
                        layout.cpu_block_addr(c),
                        layout.block_bytes,
                    )
                })
                .collect();
            run_save(&mut reference, imp, &saves);
            let fetches: Vec<CopySpec> = staging
                .iter()
                .zip(&dst)
                .map(|(&c, &g)| {
                    (
                        layout.cpu_block_addr(c),
                        layout.gpu_block_addr(dst_gpu, g),
                        layout.block_bytes,
                    )
                })
                .collect();
            run_fetch(&mut reference, imp, &fetches);

            for &g in &dst {
                let a = layout.gpu_block_addr(dst_gpu, g);
                let migrated = mig.fetch_sim.memory.peek(a.node, a.offset, layout.block_bytes);
                let expected = reference.memory.peek(a.node, a.offset, layout.block_bytes);
                assert_eq!(
                    migrated, expected,
                    "block {g}: migrated bytes diverge from single-node reference \
                     ({schedule:?}, {imp:?}, n={n})"
                );
            }
        },
    );
}

/// The two schedules are functionally indistinguishable: same inputs,
/// same bytes on the decode node, byte for byte.
#[test]
fn prop_schedules_agree_on_bytes() {
    prop_run(
        "migrate-schedule-agreement",
        Config {
            cases: 8,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let layout = BlockLayout::new(&QWEN25_0_5B, 16);
            let bb = layout.block_bytes as usize;
            let n = rng.range(2, 10);
            let src = distinct_ids(rng, 0, 32, n);
            let staging = distinct_ids(rng, 0, 64, n);
            let dst = distinct_ids(rng, 32, 64, n);
            let seed = rng.next_u64();
            let nic = NicModel::default();
            let run = |schedule: MigrateSchedule| -> Vec<Vec<u8>> {
                let mut mig = Migrator::functional();
                for &g in &src {
                    let a = layout.gpu_block_addr(0, g);
                    mig.save_sim.memory.poke(a.node, a.offset, &block_fill(seed, g, bb));
                }
                let spec = MigrateSpec {
                    layout: &layout,
                    layers: QWEN25_0_5B.layers,
                    imp: FetchImpl::DmaB2b,
                    nic: &nic,
                    src_gpu: 0,
                    dst_gpu: 1,
                    src_blocks: &src,
                    staging_blocks: &staging,
                    dst_blocks: &dst,
                };
                mig.run(&spec, schedule);
                dst.iter()
                    .map(|&g| {
                        let a = layout.gpu_block_addr(1, g);
                        mig.fetch_sim.memory.peek(a.node, a.offset, layout.block_bytes)
                    })
                    .collect()
            };
            assert_eq!(
                run(MigrateSchedule::Blocking),
                run(MigrateSchedule::LayerPipelined),
                "schedules must move identical bytes"
            );
        },
    );
}
