//! Failure injection: stalled engines, missing triggers, OOM pools, cold
//! caches — the system must fail loudly (deadlock report) or degrade
//! gracefully (requeue/prefill), never silently corrupt.

use dma_latte::coordinator::request::Request;
use dma_latte::coordinator::{ServeConfig, VirtualEngine};
use dma_latte::kvcache::fetch::FetchImpl;
use dma_latte::kvcache::CpuStore;
use dma_latte::models::zoo::QWEN25_0_5B;
use dma_latte::sim::command::{Addr, AtomicOp, Command};
use dma_latte::sim::host::{ApiKind, HostOp};
use dma_latte::sim::topology::NodeId;
use dma_latte::sim::{EngineId, PollCond, Sim, SimConfig};

/// An engine that dies mid-stream leaves the host waiting: the run reports
/// the deadlocked host instead of fabricating completion.
#[test]
fn stalled_engine_reports_deadlock() {
    let mut sim = Sim::new(SimConfig::mi300x());
    let sig = sim.alloc_signal(0);
    let engine = EngineId { gpu: 0, idx: 0 };
    // Engine stalls immediately (before it can execute anything).
    sim.engine_mut(engine).stall_at = Some(0);
    sim.add_host(
        vec![
            HostOp::CreateCommands {
                engine,
                cmds: vec![
                    Command::Copy {
                        src: Addr::new(NodeId::Gpu(0), 0),
                        dst: Addr::new(NodeId::Gpu(1), 0),
                        len: 4096,
                    },
                    Command::Atomic {
                        signal: sig,
                        op: AtomicOp::Add(1),
                    },
                ],
                api: ApiKind::Raw,
            },
            HostOp::RingDoorbell { engine },
            HostOp::WaitSignal {
                signal: sig,
                at_least: 1,
            },
        ],
        0,
    );
    let out = sim.run();
    assert_eq!(out.deadlocked.len(), 1);
}

/// A prelaunched stream whose trigger never fires parks forever — and the
/// sim says so (this is the correctness edge of §4.5: poll gates must not
/// leak execution).
#[test]
fn missing_trigger_parks_stream() {
    let mut sim = Sim::new(SimConfig::mi300x().functional());
    let trigger = sim.alloc_signal(0);
    let done = sim.alloc_signal(0);
    sim.memory.poke(NodeId::Gpu(0), 0, &[5u8; 64]);
    let engine = EngineId { gpu: 0, idx: 0 };
    sim.add_host(
        vec![
            HostOp::CreateCommands {
                engine,
                cmds: vec![
                    Command::Poll {
                        signal: trigger,
                        cond: PollCond::Gte(1),
                    },
                    Command::Copy {
                        src: Addr::new(NodeId::Gpu(0), 0),
                        dst: Addr::new(NodeId::Gpu(1), 0),
                        len: 64,
                    },
                    Command::Atomic {
                        signal: done,
                        op: AtomicOp::Add(1),
                    },
                ],
                api: ApiKind::Raw,
            },
            HostOp::RingDoorbell { engine },
            // NOTE: no SetSignal(trigger)!
            HostOp::WaitSignal {
                signal: done,
                at_least: 1,
            },
        ],
        0,
    );
    let out = sim.run();
    assert_eq!(out.deadlocked.len(), 1);
    // And crucially: the gated copy never executed.
    assert_eq!(sim.memory.peek(NodeId::Gpu(1), 0, 64), vec![0u8; 64]);
}

/// CPU store miss mid-run (evicted entry) degrades to prefill, not loss.
#[test]
fn evicted_cache_entries_fall_back_to_prefill() {
    let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b);
    cfg.cpu_blocks = 300; // tiny CPU tier: ~1 prompt of 4096 tokens
    cfg.gpu_blocks = 1 << 18;
    let mut eng = VirtualEngine::new(cfg);
    for i in 0..8 {
        // warm=true saves each prompt, evicting earlier ones (LRU).
        eng.submit(Request::new(i, 4096, 4, 0), true);
    }
    let m = eng.run_to_completion();
    assert_eq!(m.finished, 8);
    // Most entries were evicted before admission ⇒ misses dominate.
    assert!(m.cache_misses >= 6, "misses {}", m.cache_misses);
}

/// CpuStore never hands out aliased blocks even under eviction pressure.
#[test]
fn cpu_store_eviction_pressure() {
    let mut s = CpuStore::new(50);
    let mut live: Vec<(u64, Vec<u64>)> = Vec::new();
    for k in 0..200u64 {
        if let Some(blocks) = s.save(k, 1 + k % 13, 16 * (1 + k % 13)) {
            live.push((k, blocks));
        }
        // All currently-resident entries must be disjoint.
        let mut seen = std::collections::HashSet::new();
        for (key, blocks) in &live {
            if s.lookup(*key).is_some() {
                for b in blocks {
                    assert!(seen.insert(*b), "block {b} aliased");
                }
            }
        }
    }
    assert!(s.evictions > 0);
}

/// Zero-request and zero-token workloads terminate immediately.
#[test]
fn degenerate_workloads() {
    let cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b);
    let mut eng = VirtualEngine::new(cfg);
    let m = eng.run_to_completion();
    assert_eq!(m.finished, 0);
    assert_eq!(m.tokens_out, 0);
}
