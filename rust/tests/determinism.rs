//! Determinism regression tests for the §Perf pass: episode replay on a
//! reset-reused simulator with cache-served plans must be bit-identical to
//! a fresh simulator with freshly built plans — same simulated latency,
//! same trace event count, same functional bytes. Guards `Sim::reset`, the
//! cross-episode plan cache and the hierarchical rounds cache.

use dma_latte::cluster::{
    run_hier, run_hier_ar_full, ClusterChoice, ClusterTopology, HierRunOptions, InterSchedule,
};
use dma_latte::collectives::exec::run_collective_uncached;
use dma_latte::collectives::{CollectiveKind, CollectiveRunner, RunOptions, Strategy, Variant};
use dma_latte::sim::topology::NodeId;
use dma_latte::sim::{Sim, SimConfig};
use dma_latte::util::bytes::KB;

/// Wrapping checksum of every GPU's full buffer region (input + output +
/// staging) — any byte the episode placed differently changes it.
fn checksum(sim: &Sim, extent: u64) -> u64 {
    (0..sim.cfg.topology.num_gpus)
        .map(|g| {
            sim.memory
                .peek(NodeId::Gpu(g), 0, extent)
                .iter()
                .map(|&b| b as u64)
                .sum::<u64>()
        })
        .fold(0u64, |a, x| a.wrapping_add(x))
}

#[test]
fn reused_sim_replays_every_variant_bit_identically() {
    let opts = RunOptions {
        sim: SimConfig::mi300x().traced(),
        verify: true,
    };
    let size = 64 * KB;
    // Generous extent: covers AA output + staging regions too.
    let extent = 4 * size;
    for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
        for v in Variant::all_for(kind) {
            // Twice through ONE reused simulator (second run resets)…
            let mut reused = CollectiveRunner::new(&opts);
            let first = reused.run(kind, v, size);
            let spans_first = reused.sim().trace.spans.len();
            let sum_first = checksum(reused.sim(), extent);
            let second = reused.run(kind, v, size);
            let spans_second = reused.sim().trace.spans.len();
            let sum_second = checksum(reused.sim(), extent);
            // …and once through a fresh simulator with a fresh plan build.
            let mut fresh = CollectiveRunner::new(&opts);
            let fresh_res = fresh.run(kind, v, size);
            let legacy = run_collective_uncached(kind, v, size, &opts);

            let label = format!("{} {}", kind.name(), v.name());
            assert_eq!(first.verified, Some(true), "{label}");
            assert_eq!(first.latency_ns, second.latency_ns, "{label}: reset replay");
            assert_eq!(spans_first, spans_second, "{label}: trace event count");
            assert_eq!(sum_first, sum_second, "{label}: verify checksum");
            assert_eq!(first.latency_ns, fresh_res.latency_ns, "{label}: fresh sim");
            assert_eq!(
                spans_first,
                fresh.sim().trace.spans.len(),
                "{label}: fresh trace count"
            );
            assert_eq!(sum_first, checksum(fresh.sim(), extent), "{label}: fresh sum");
            assert_eq!(first.latency_ns, legacy.latency_ns, "{label}: legacy path");
            assert_eq!(legacy.verified, Some(true), "{label}");
            assert_eq!(first.engines_used, legacy.engines_used, "{label}");
            assert_eq!(
                first.activity.hbm_bytes, legacy.activity.hbm_bytes,
                "{label}: traffic accounting"
            );
        }
    }
}

/// Interleaving different episodes between repeats must not leak state
/// through the reused simulator or the plan cache.
#[test]
fn interleaved_episodes_do_not_contaminate_replay() {
    let opts = RunOptions {
        sim: SimConfig::mi300x(),
        verify: false,
    };
    let mut runner = CollectiveRunner::new(&opts);
    let probe = |r: &mut CollectiveRunner| {
        r.run(
            CollectiveKind::AllGather,
            Variant::new(Strategy::Pcpy, true),
            256 * KB,
        )
        .latency_ns
    };
    let want = probe(&mut runner);
    // Traced twin: the replay must also reproduce the exact trace shape —
    // the phase span count AND the wire sub-spans recorded for the
    // observability layer (guards `Trace::clear` / `Sim::reset` over the
    // `wire` field: stale spans from a churned episode would change the
    // counts).
    let topts = RunOptions {
        sim: SimConfig::mi300x().traced(),
        verify: false,
    };
    let mut traced = CollectiveRunner::new(&topts);
    let tprobe = |r: &mut CollectiveRunner| {
        let lat = r
            .run(
                CollectiveKind::AllGather,
                Variant::new(Strategy::Pcpy, true),
                256 * KB,
            )
            .latency_ns;
        (lat, r.sim().trace.spans.len(), r.sim().trace.wire.len())
    };
    let twant = tprobe(&mut traced);
    assert!(twant.2 > 0, "traced runs record wire sub-spans");
    for v in Variant::all_for(CollectiveKind::AllToAll) {
        runner.run(CollectiveKind::AllToAll, v, 32 * KB);
        assert_eq!(probe(&mut runner), want, "after {}", v.name());
        traced.run(CollectiveKind::AllToAll, v, 32 * KB);
        assert_eq!(tprobe(&mut traced), twant, "traced after {}", v.name());
    }
}

/// The overlapped (chunk-granular fused) all-reduce replays bit-identically
/// across cached episodes: the first run builds the schedule-keyed rounds,
/// interleaved episodes at other shapes/schedules churn the caches, and the
/// replay must reproduce the same modeled latency split, the same trace
/// span count, and the same functional bytes.
#[test]
fn overlapped_allreduce_replays_bit_identically() {
    let rs_c = ClusterChoice {
        intra: Variant::new(Strategy::Pcpy, true),
        inter: InterSchedule::Overlapped,
    };
    let ag_c = ClusterChoice {
        intra: Variant::new(Strategy::Pcpy, true),
        inter: InterSchedule::Overlapped,
    };
    let cluster = ClusterTopology::mi300x(2);
    let size = 128 * KB;
    let run_traced = || {
        run_hier_ar_full(
            rs_c,
            ag_c,
            &cluster,
            size,
            &HierRunOptions {
                trace: true,
                ..Default::default()
            },
        )
    };
    let run_verified = || {
        run_hier_ar_full(
            rs_c,
            ag_c,
            &cluster,
            size,
            &HierRunOptions {
                verify: true,
                ..Default::default()
            },
        )
    };
    let mem_sum = |sims: &[Sim]| {
        sims.iter()
            .map(|s| checksum(s, size))
            .fold(0u64, |a, x| a.wrapping_add(x))
    };

    let (first, first_sims) = run_traced();
    let first_spans: usize = first_sims.iter().map(|s| s.trace.spans.len()).sum();
    let (vfirst, vfirst_sims) = run_verified();
    let vfirst_sum = mem_sum(&vfirst_sims);
    assert_eq!(vfirst.verified, Some(true));

    // Churn the caches: other node counts, sizes and schedules in between.
    run_hier_ar_full(
        rs_c,
        ag_c,
        &ClusterTopology::mi300x(4),
        256 * KB,
        &HierRunOptions::default(),
    );
    let mut seq_c = rs_c;
    seq_c.inter = InterSchedule::Sequential;
    run_hier_ar_full(seq_c, seq_c, &cluster, size, &HierRunOptions::default());

    let (second, second_sims) = run_traced();
    let second_spans: usize = second_sims.iter().map(|s| s.trace.spans.len()).sum();
    let (vsecond, vsecond_sims) = run_verified();

    assert_eq!(first.latency_ns, second.latency_ns, "overlapped replay latency");
    assert_eq!(first.inter_ns, second.inter_ns, "overlapped replay inter split");
    assert_eq!(first.data_cmds, second.data_cmds, "overlapped replay cmds");
    assert_eq!(first_spans, second_spans, "overlapped replay trace span count");
    assert_eq!(vsecond.verified, Some(true));
    assert_eq!(vfirst.latency_ns, vsecond.latency_ns, "verify-mode replay latency");
    assert_eq!(vfirst_sum, mem_sum(&vsecond_sims), "overlapped replay memory checksum");
}

/// A trace-driven production-traffic serving run replays bit-identically
/// across collective-cache churn: same virtual wall clock, same
/// per-request spans, same per-class SLO counters, same queue timeline.
/// Guards the workload generator's purity AND the engine's event-driven
/// admission path against cross-episode state leaks (the plan/rounds
/// cache hit-miss deltas are the one intentional difference).
#[test]
fn trace_driven_serving_replays_bit_identically() {
    use dma_latte::coordinator::workload::{default_tenants, drive, ArrivalProcess, WorkloadSpec};
    use dma_latte::figures::serving_load::serve_config;
    use dma_latte::models::zoo::QWEN25_0_5B;

    let cfg = serve_config(&QWEN25_0_5B, 2, true);
    let spec = WorkloadSpec {
        process: ArrivalProcess::Trace {
            peak_rps: 800.0,
            day_s: 0.5,
        },
        classes: default_tenants(),
        requests: 96,
        seed: 21,
    };
    let first = drive(&cfg, &spec);
    assert_eq!(first.submitted, 96);
    assert_eq!(first.finished, 96);
    assert_eq!(
        first.per_class.iter().map(|c| c.finished).sum::<u64>(),
        96,
        "every finish lands in a class bucket"
    );

    // Churn the cross-episode collective caches with other shapes.
    let choice = ClusterChoice {
        intra: Variant::new(Strategy::Pcpy, true),
        inter: InterSchedule::Overlapped,
    };
    run_hier_ar_full(
        choice,
        choice,
        &ClusterTopology::mi300x(4),
        256 * KB,
        &HierRunOptions::default(),
    );

    let second = drive(&cfg, &spec);
    assert_eq!(first.wall_ns, second.wall_ns, "serving wall clock");
    assert_eq!(first.requests, second.requests, "per-request spans");
    assert_eq!(first.ttft_ns, second.ttft_ns, "ttft distribution");
    assert_eq!(first.tpot_ns, second.tpot_ns, "tpot distribution");
    assert_eq!(first.submitted, second.submitted);
    assert_eq!(first.finished, second.finished);
    assert_eq!(first.tokens_out, second.tokens_out);
    assert_eq!(first.comm_ns, second.comm_ns, "comm total");
    assert_eq!(first.comm_exposed_ns, second.comm_exposed_ns, "comm exposed");
    assert_eq!(first.comm_hidden_ns, second.comm_hidden_ns, "comm hidden");
    assert_eq!(first.fetch_bytes, second.fetch_bytes);
    assert_eq!(first.cache_hits, second.cache_hits);
    assert_eq!(first.cache_misses, second.cache_misses);
    assert_eq!(first.per_class, second.per_class, "per-class counters");
    assert_eq!(first.queue_depth, second.queue_depth, "queue timeline");
    assert_eq!(first.queue_peak, second.queue_peak);
}

/// Disaggregated serving replays bit-identically across collective-cache
/// churn: the migration memo, the per-lane prefill/NIC frontiers and the
/// decode-pool comm sizing are all deterministic functions of the config
/// and workload, with no state leaking in from interleaved cluster
/// episodes.
#[test]
fn disagg_serving_replays_bit_identically() {
    use dma_latte::coordinator::workload::{default_tenants, drive, ArrivalProcess, WorkloadSpec};
    use dma_latte::coordinator::DisaggSpec;
    use dma_latte::figures::serving_load::serve_config;
    use dma_latte::models::zoo::QWEN25_0_5B;

    let mut cfg = serve_config(&QWEN25_0_5B, 1, true).with_disagg(DisaggSpec::new(2, 1));
    cfg.hit_rate = 0.0; // every request migrates its KV across the NIC
    let spec = WorkloadSpec {
        process: ArrivalProcess::Poisson { rate_rps: 400.0 },
        classes: default_tenants(),
        requests: 64,
        seed: 33,
    };
    let first = drive(&cfg, &spec);
    assert_eq!(first.finished, 64);
    assert_eq!(first.migrations, first.cache_misses);
    assert!(first.migrated_bytes > 0);

    // Churn the cross-episode collective caches with other shapes.
    let choice = ClusterChoice {
        intra: Variant::new(Strategy::Pcpy, true),
        inter: InterSchedule::Overlapped,
    };
    run_hier_ar_full(
        choice,
        choice,
        &ClusterTopology::mi300x(4),
        256 * KB,
        &HierRunOptions::default(),
    );

    let second = drive(&cfg, &spec);
    assert_eq!(first.wall_ns, second.wall_ns, "disagg wall clock");
    assert_eq!(first.ttft_ns, second.ttft_ns, "ttft distribution");
    assert_eq!(first.tpot_ns, second.tpot_ns, "tpot distribution");
    assert_eq!(first.requests, second.requests, "per-request spans");
    assert_eq!(first.migrations, second.migrations, "migration count");
    assert_eq!(first.migrated_bytes, second.migrated_bytes, "migrated bytes");
    assert_eq!(first.migration_ns, second.migration_ns, "migration time");
    assert_eq!(
        first.migration_nic_busy_ns, second.migration_nic_busy_ns,
        "NIC busy time"
    );
    assert_eq!(first.comm_ns, second.comm_ns, "decode-pool comm");
    assert_eq!(first.gpu_busy_ns, second.gpu_busy_ns);
    assert_eq!(first.per_class, second.per_class, "per-class counters");
    assert_eq!(first.queue_depth, second.queue_depth, "queue timeline");
    assert_eq!(first.queue_peak, second.queue_peak);
}

/// The lazy arrival stream (`submit_workload_stream`, the path `drive`
/// uses since PR 9) and the historical materialized path
/// (`generate()` + `submit_workload`) produce bit-identical serving
/// metrics, field for field — so the streamed engine inherits every
/// modeled number the BENCH_PR7/PR8 trajectories were recorded against.
#[test]
fn streamed_serving_matches_materialized_bit_identically() {
    use dma_latte::coordinator::workload::{default_tenants, drive, ArrivalProcess, WorkloadSpec};
    use dma_latte::coordinator::VirtualEngine;
    use dma_latte::figures::serving_load::serve_config;
    use dma_latte::models::zoo::QWEN25_0_5B;

    let cfg = serve_config(&QWEN25_0_5B, 2, true);
    let spec = WorkloadSpec {
        process: ArrivalProcess::Trace {
            peak_rps: 800.0,
            day_s: 0.5,
        },
        classes: default_tenants(),
        requests: 96,
        seed: 21,
    };
    let streamed = drive(&cfg, &spec);
    let mut eng = VirtualEngine::new(cfg.clone());
    eng.configure_classes(&spec.classes);
    eng.submit_workload(&spec.generate());
    let materialized = eng.run_to_completion().clone();

    assert_eq!(streamed.wall_ns, materialized.wall_ns, "serving wall clock");
    assert_eq!(streamed.requests, materialized.requests, "per-request spans");
    assert_eq!(streamed.ttft_ns, materialized.ttft_ns, "ttft distribution");
    assert_eq!(streamed.tpot_ns, materialized.tpot_ns, "tpot distribution");
    assert_eq!(streamed.submitted, materialized.submitted);
    assert_eq!(streamed.finished, materialized.finished);
    assert_eq!(streamed.tokens_out, materialized.tokens_out);
    assert_eq!(streamed.comm_ns, materialized.comm_ns, "comm total");
    assert_eq!(streamed.comm_exposed_ns, materialized.comm_exposed_ns, "comm exposed");
    assert_eq!(streamed.comm_hidden_ns, materialized.comm_hidden_ns, "comm hidden");
    assert_eq!(streamed.fetch_bytes, materialized.fetch_bytes);
    assert_eq!(streamed.cache_hits, materialized.cache_hits);
    assert_eq!(streamed.cache_misses, materialized.cache_misses);
    assert_eq!(streamed.per_class, materialized.per_class, "per-class counters");
    assert_eq!(streamed.queue_depth, materialized.queue_depth, "queue timeline");
    assert_eq!(streamed.queue_peak, materialized.queue_peak);
}

/// The hierarchical executor's cached node rounds replay identically:
/// first call builds, later calls (and other node counts in between) hit
/// the cache and must reproduce the same modeled latency split.
#[test]
fn hier_cached_rounds_replay_identically() {
    let choice = ClusterChoice {
        intra: Variant::new(Strategy::Pcpy, true),
        inter: InterSchedule::Pipelined,
    };
    let size = 128 * KB;
    let opts = HierRunOptions::default();
    for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
        let c2 = ClusterTopology::mi300x(2);
        let first = run_hier(kind, choice, &c2, size, &opts);
        // Interleave a different cluster shape, then replay.
        let c4 = ClusterTopology::mi300x(4);
        run_hier(kind, choice, &c4, size, &opts);
        let second = run_hier(kind, choice, &c2, size, &opts);
        assert_eq!(first.latency_ns, second.latency_ns, "{}", kind.name());
        assert_eq!(first.inter_ns, second.inter_ns, "{}", kind.name());
        assert_eq!(first.data_cmds, second.data_cmds, "{}", kind.name());
    }
}
