//! Property tests for the cluster layer: a hierarchical all-gather /
//! all-to-all over `nodes × gpus` ranks must deliver exactly the same
//! chunk placement as the flat single-node planner reshaped to the same
//! world size — and a hierarchical reduce-scatter / all-reduce must deliver
//! exactly the flat reference reduction's element values — for randomized
//! node counts, GPU counts, variants, schedules and sizes.

use dma_latte::cluster::allreduce::rs_result_base;
use dma_latte::cluster::{
    run_hier_ar_full, run_hier_full, run_hier_rs_full, select_cluster, ClusterChoice, ClusterKind,
    ClusterTopology, HierRunOptions, InterSchedule, LinkHealth, NicModel,
};
use dma_latte::collectives::exec::build_plan;
use dma_latte::collectives::plan::aa_out_base;
use dma_latte::collectives::reduce_scatter::{plan_transport, reduce_staged, stage_base};
use dma_latte::collectives::verify::pattern;
use dma_latte::collectives::{CollectiveKind, Strategy, Variant};
use dma_latte::sim::command::Command;
use dma_latte::sim::memory::MemorySystem;
use dma_latte::sim::{LatencyModel, NodeId, Sim, SimConfig, Topology};
use dma_latte::util::proptest::{run as prop_run, Config};
use dma_latte::util::rng::Rng;

/// Execute the FLAT single-node planner functionally at world size: init
/// the standard verification patterns, then apply the plan's data-move
/// commands to a bare [`MemorySystem`]. (All flat plans are intra-plan
/// hazard-free — each byte range is written exactly once — so application
/// order does not matter.)
fn flat_placement(kind: CollectiveKind, v: Variant, topo: &Topology, size: u64) -> MemorySystem {
    let n = topo.num_gpus;
    let chunk = size / n as u64;
    let in_place = v.strategy == Strategy::Swap;
    let mut mem = MemorySystem::new(true);
    for gpu in 0..n {
        let node = NodeId::Gpu(gpu);
        match kind {
            CollectiveKind::AllGather => {
                mem.ensure(node, size);
                mem.poke(
                    node,
                    gpu as u64 * chunk,
                    &vec![pattern(gpu, gpu); chunk as usize],
                );
            }
            CollectiveKind::AllToAll => {
                mem.ensure(node, if in_place { size } else { aa_out_base(size) + size });
                for j in 0..n {
                    mem.poke(node, j as u64 * chunk, &vec![pattern(gpu, j); chunk as usize]);
                }
            }
        }
    }
    let plan = build_plan(kind, v, topo, size);
    for r in &plan.ranks {
        for e in &r.engines {
            for cmd in &e.cmds {
                match *cmd {
                    Command::Copy { src, dst, len } => {
                        mem.dma_copy(src.node, src.offset, dst.node, dst.offset, len)
                    }
                    Command::Bcst {
                        src,
                        dst0,
                        dst1,
                        len,
                    } => mem.dma_bcst(
                        src.node,
                        src.offset,
                        (dst0.node, dst0.offset),
                        (dst1.node, dst1.offset),
                        len,
                    ),
                    Command::Swap { a, b, len } => {
                        mem.dma_swap((a.node, a.offset), (b.node, b.offset), len)
                    }
                    _ => {}
                }
            }
        }
    }
    mem
}

/// Hierarchical placement == flat placement, byte for byte, over random
/// shapes: nodes 1–4, GPUs 2–4, all applicable variants, both schedules.
#[test]
fn prop_hier_matches_flat_placement() {
    prop_run(
        "hier-flat-equivalence",
        Config {
            cases: 24,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let n = rng.range(1, 4);
            let g = rng.range(2, 4) as u8;
            let world = (n * g as usize) as u8;
            let kind = if rng.chance(0.5) {
                CollectiveKind::AllGather
            } else {
                CollectiveKind::AllToAll
            };
            let variants = Variant::all_for(kind);
            let v = *rng.pick(&variants);
            let inter = if rng.chance(0.5) {
                InterSchedule::Sequential
            } else {
                InterSchedule::Pipelined
            };
            let chunk = 256 * rng.range(1, 4) as u64;
            let size = chunk * world as u64;
            let cluster = ClusterTopology::homogeneous(
                n,
                Topology::custom(g, 16, 64.0, 64.0),
                NicModel::default(),
            );
            let (res, sims) = run_hier_full(
                kind,
                ClusterChoice { intra: v, inter },
                &cluster,
                size,
                &HierRunOptions {
                    verify: true,
                    ..Default::default()
                },
            );
            let label = format!(
                "{} {} {inter:?} n={n} g={g} size={size}",
                kind.name(),
                v.name()
            );
            assert_eq!(res.verified, Some(true), "{label}");
            assert!(res.latency_ns > 0, "{label}");

            // Flat reference at the same world size (same strategy family).
            let topo = Topology::custom(world, world.max(16), 64.0, 64.0);
            let flat = flat_placement(kind, v, &topo, size);
            let in_place = v.strategy == Strategy::Swap;
            // Input region always; out-of-place AA also compares the
            // output region (the input keeps the untouched diagonal).
            let mut regions: Vec<(u64, u64)> = vec![(0, size)];
            if kind == CollectiveKind::AllToAll && !in_place {
                regions.push((aa_out_base(size), size));
            }
            for r in 0..world as u32 {
                let (node, local) = cluster.locate(r);
                for &(base, len) in &regions {
                    assert_eq!(
                        sims[node].memory.peek(NodeId::Gpu(local), base, len),
                        flat.peek(NodeId::Gpu(r as u8), base, len),
                        "{label}: rank {r} region base {base}"
                    );
                }
            }
        },
    );
}

/// Fault injection does not change what a collective computes: with every
/// NIC link flapping (retry-with-backoff model, `cluster::faults`), the
/// hierarchical placement still equals the flat reference byte for byte —
/// flaps delay messages, they never drop or corrupt them.
#[test]
fn prop_flapped_hier_matches_flat_placement() {
    prop_run(
        "flapped-hier-flat-equivalence",
        Config {
            cases: 8,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let n = rng.range(2, 4);
            let g = rng.range(2, 4) as u8;
            let world = (n * g as usize) as u8;
            let kind = if rng.chance(0.5) {
                CollectiveKind::AllGather
            } else {
                CollectiveKind::AllToAll
            };
            let v = *rng.pick(&Variant::all_for(kind));
            let inter = if rng.chance(0.5) {
                InterSchedule::Sequential
            } else {
                InterSchedule::Pipelined
            };
            let size = 256 * rng.range(1, 4) as u64 * world as u64;
            let cluster = ClusterTopology::homogeneous(
                n,
                Topology::custom(g, 16, 64.0, 64.0),
                NicModel::default(),
            );
            let opts = HierRunOptions {
                verify: true,
                link_faults: Some(LinkHealth::uniform(n, 0.8, rng.below(1 << 30))),
                ..Default::default()
            };
            let choice = ClusterChoice { intra: v, inter };
            let (res, sims) = run_hier_full(kind, choice, &cluster, size, &opts);
            let label = format!(
                "flapped {} {} {inter:?} n={n} g={g} size={size}",
                kind.name(),
                v.name()
            );
            assert_eq!(res.verified, Some(true), "{label}");

            let topo = Topology::custom(world, world.max(16), 64.0, 64.0);
            let flat = flat_placement(kind, v, &topo, size);
            let in_place = v.strategy == Strategy::Swap;
            let mut regions: Vec<(u64, u64)> = vec![(0, size)];
            if kind == CollectiveKind::AllToAll && !in_place {
                regions.push((aa_out_base(size), size));
            }
            for r in 0..world as u32 {
                let (node, local) = cluster.locate(r);
                for &(base, len) in &regions {
                    assert_eq!(
                        sims[node].memory.peek(NodeId::Gpu(local), base, len),
                        flat.peek(NodeId::Gpu(r as u8), base, len),
                        "{label}: rank {r} region base {base}"
                    );
                }
            }
        },
    );
}

/// Hierarchical reduce-scatter / all-reduce element values match the flat
/// reference reduction (the single-node DMA transport + CU reduce split of
/// `collectives::reduce_scatter` run at world size), over random shapes:
/// nodes 1–4, GPUs 2–4, all AA-pattern transport variants, all AG gather
/// variants, both inter schedules, random sizes.
#[test]
fn prop_hier_reduce_matches_flat_reference() {
    prop_run(
        "hier-rs-ar-flat-reduction",
        Config {
            cases: 16,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let n = rng.range(1, 4);
            let g = rng.range(2, 4) as u8;
            let world = (n * g as usize) as u8;
            let rs_v = *rng.pick(&Variant::all_for(CollectiveKind::AllToAll));
            let ag_v = *rng.pick(&Variant::all_for(CollectiveKind::AllGather));
            let pick_inter = |rng: &mut Rng| {
                if rng.chance(0.5) {
                    InterSchedule::Sequential
                } else {
                    InterSchedule::Pipelined
                }
            };
            let rs_inter = pick_inter(rng);
            let ag_inter = pick_inter(rng);
            let chunk = 64 * rng.range(1, 4) as u64;
            let size = chunk * world as u64;
            let cluster = ClusterTopology::homogeneous(
                n,
                Topology::custom(g, 16, 64.0, 64.0),
                NicModel::default(),
            );
            let label = format!(
                "rs={} {rs_inter:?} ag={} {ag_inter:?} n={n} g={g} size={size}",
                rs_v.name(),
                ag_v.name()
            );

            // Flat reference: the single-node RS split (AA-pattern DMA
            // transport + staged CU reduce) at world size.
            let topo = Topology::custom(world, 16, 64.0, 64.0);
            let mut flat = Sim::new(SimConfig {
                topology: topo.clone(),
                latency: LatencyModel::default(),
                functional: true,
                trace: false,
            });
            for r in 0..world {
                for d in 0..world {
                    flat.memory.poke(
                        NodeId::Gpu(r),
                        d as u64 * chunk,
                        &vec![pattern(r, d); chunk as usize],
                    );
                }
            }
            for r in &plan_transport(&topo, size).ranks {
                for e in &r.engines {
                    for cmd in &e.cmds {
                        if let Command::Copy { src, dst, len } = *cmd {
                            flat.memory
                                .dma_copy(src.node, src.offset, dst.node, dst.offset, len);
                        }
                    }
                }
            }
            reduce_staged(&mut flat, size);
            let result_off = stage_base(size) + world as u64 * chunk;
            let expected: Vec<Vec<u8>> = (0..world)
                .map(|r| flat.memory.peek(NodeId::Gpu(r), result_off, chunk))
                .collect();

            // Hierarchical reduce-scatter must reproduce those values.
            let opts = HierRunOptions {
                verify: true,
                ..Default::default()
            };
            let (rs_res, rs_sims) = run_hier_rs_full(
                ClusterChoice {
                    intra: rs_v,
                    inter: rs_inter,
                },
                &cluster,
                size,
                &opts,
            );
            assert_eq!(rs_res.verified, Some(true), "{label}");
            for r in 0..world as u32 {
                let (node, local) = cluster.locate(r);
                assert_eq!(
                    rs_sims[node]
                        .memory
                        .peek(NodeId::Gpu(local), rs_result_base(size, chunk), chunk),
                    expected[r as usize],
                    "{label}: rank {r} reduced chunk"
                );
            }

            // Hierarchical all-reduce: every rank ends with the full
            // reduced vector.
            let (ar_res, ar_sims) = run_hier_ar_full(
                ClusterChoice {
                    intra: rs_v,
                    inter: rs_inter,
                },
                ClusterChoice {
                    intra: ag_v,
                    inter: ag_inter,
                },
                &cluster,
                size,
                &opts,
            );
            assert_eq!(ar_res.verified, Some(true), "{label}");
            assert!(ar_res.latency_ns > rs_res.latency_ns, "{label}");
            let full: Vec<u8> = expected.iter().flatten().copied().collect();
            for r in 0..world as u32 {
                let (node, local) = cluster.locate(r);
                assert_eq!(
                    ar_sims[node].memory.peek(NodeId::Gpu(local), 0, size),
                    full,
                    "{label}: rank {r} allreduce buffer"
                );
            }
        },
    );
}

/// PR 4 acceptance: the chunk-granular overlapped all-reduce is
/// byte-identical to the sequential composition (both checked against the
/// flat reference reduction) and never slower than the best of the
/// sequential/pipelined barriered compositions, over random shapes,
/// variants and node counts.
#[test]
fn prop_overlapped_ar_byte_identical_and_never_slower() {
    prop_run(
        "overlapped-ar-equivalence",
        Config {
            cases: 12,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let n = rng.range(1, 4);
            let g = rng.range(2, 4) as u8;
            let world = (n * g as usize) as u8;
            let rs_v = *rng.pick(&Variant::all_for(CollectiveKind::AllToAll));
            let ag_v = *rng.pick(&Variant::all_for(CollectiveKind::AllGather));
            let chunk = 64 * rng.range(1, 4) as u64;
            let size = chunk * world as u64;
            let cluster = ClusterTopology::homogeneous(
                n,
                Topology::custom(g, 16, 64.0, 64.0),
                NicModel::default(),
            );
            let label = format!("rs={} ag={} n={n} g={g} size={size}", rs_v.name(), ag_v.name());
            let choice = |v, inter| ClusterChoice { intra: v, inter };
            let opts = HierRunOptions {
                verify: true,
                ..Default::default()
            };

            let (ovl_res, ovl_sims) = run_hier_ar_full(
                choice(rs_v, InterSchedule::Overlapped),
                choice(ag_v, InterSchedule::Overlapped),
                &cluster,
                size,
                &opts,
            );
            let (seq_res, seq_sims) = run_hier_ar_full(
                choice(rs_v, InterSchedule::Sequential),
                choice(ag_v, InterSchedule::Sequential),
                &cluster,
                size,
                &opts,
            );
            assert_eq!(ovl_res.verified, Some(true), "{label}");
            assert_eq!(seq_res.verified, Some(true), "{label}");
            // Byte-identical final buffers on every rank.
            for r in 0..world as u32 {
                let (node, local) = cluster.locate(r);
                assert_eq!(
                    ovl_sims[node].memory.peek(NodeId::Gpu(local), 0, size),
                    seq_sims[node].memory.peek(NodeId::Gpu(local), 0, size),
                    "{label}: rank {r} allreduce buffer"
                );
            }
            // Same NIC message and data-command budget — fusion reorders,
            // it does not add or drop work.
            assert_eq!(ovl_res.nic_messages, seq_res.nic_messages, "{label}");
            assert_eq!(ovl_res.data_cmds, seq_res.data_cmds, "{label}");
            // Never slower than the best barriered composition.
            let pipe_res = run_hier_ar_full(
                choice(rs_v, InterSchedule::Pipelined),
                choice(ag_v, InterSchedule::Pipelined),
                &cluster,
                size,
                &HierRunOptions::default(),
            )
            .0;
            let best = seq_res.latency_ns.min(pipe_res.latency_ns);
            assert!(
                ovl_res.latency_ns <= best,
                "{label}: ovl {} vs best barriered {best}",
                ovl_res.latency_ns
            );
        },
    );
}

/// The cluster selector is total, applicable, and sequential on one node,
/// across the full collective set and degenerate sizes.
#[test]
fn prop_cluster_selector_total() {
    prop_run(
        "cluster-selector",
        Config {
            cases: 200,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let n = rng.range(1, 8);
            let cluster = ClusterTopology::mi300x(n);
            // Include the zero-byte degenerate in the sampled domain.
            let size = rng.below(8 << 30);
            for kind in [
                ClusterKind::AllGather,
                ClusterKind::AllToAll,
                ClusterKind::ReduceScatter,
                ClusterKind::AllReduce,
            ] {
                let ch = select_cluster(kind, &cluster, size);
                assert!(
                    ch.intra.strategy.applicable(kind.transport()),
                    "n={n} size={size}"
                );
                if n == 1 {
                    assert_eq!(ch.inter, InterSchedule::Sequential);
                }
            }
        },
    );
}

/// Global-rank mapping round-trips for random cluster shapes.
#[test]
fn prop_rank_mapping_roundtrips() {
    prop_run(
        "rank-mapping",
        Config {
            cases: 32,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let n = rng.range(1, 8);
            let g = rng.range(1, 16) as u8;
            let cluster = ClusterTopology::homogeneous(
                n,
                Topology::custom(g, 4, 64.0, 64.0),
                NicModel::default(),
            );
            assert_eq!(cluster.world_size(), n * g as usize);
            for r in 0..cluster.world_size() as u32 {
                let (k, local) = cluster.locate(r);
                assert_eq!(cluster.global_rank(k, local), r);
            }
        },
    );
}
