//! Collectives integration: every variant × kind × a spread of sizes runs
//! on the DES with functional memory and verifies byte-exactly.

use dma_latte::collectives::{
    run_collective, select_variant, CollectiveKind, RunOptions, Variant,
};
use dma_latte::sim::SimConfig;
use dma_latte::util::bytes::KB;

fn opts() -> RunOptions {
    RunOptions {
        sim: SimConfig::mi300x(),
        verify: true,
    }
}

#[test]
fn every_variant_every_size_verifies() {
    for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
        for v in Variant::all_for(kind) {
            for size in [8 * KB, 64 * KB, 512 * KB] {
                let r = run_collective(kind, v, size, &opts());
                assert_eq!(
                    r.verified,
                    Some(true),
                    "{} {} at {size}",
                    kind.name(),
                    v.name()
                );
                assert!(r.latency_ns > 0);
                assert!(r.data_cmds > 0);
            }
        }
    }
}

#[test]
fn auto_selected_variant_verifies_across_spectrum() {
    for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
        for size in [KB, 16 * KB, 256 * KB, 1024 * KB] {
            let v = select_variant(kind, size);
            let r = run_collective(kind, v, size, &opts());
            assert_eq!(r.verified, Some(true), "{} @{size}", v.name());
        }
    }
}

#[test]
fn non_power_of_two_gpu_counts() {
    // 3, 5, 6 GPUs: planners must still cover all peers / pairs.
    for n in [3u8, 5, 6] {
        let mut o = opts();
        o.sim.topology = dma_latte::sim::Topology::custom(n, 8, 64.0, 64.0);
        for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
            for v in Variant::all_for(kind) {
                let size = n as u64 * 8 * KB; // divisible chunks
                let r = run_collective(kind, v, size, &o);
                assert_eq!(
                    r.verified,
                    Some(true),
                    "{} {} n={n}",
                    kind.name(),
                    v.name()
                );
            }
        }
    }
}

#[test]
fn engine_counts_match_paper() {
    // pcpy: 56 engines; bcst: 32; swap: 28; b2b: 8 (8-GPU platform).
    use dma_latte::collectives::Strategy;
    let o = opts();
    let r = run_collective(
        CollectiveKind::AllGather,
        Variant::new(Strategy::Pcpy, false),
        64 * KB,
        &o,
    );
    assert_eq!(r.engines_used, 56);
    let r = run_collective(
        CollectiveKind::AllGather,
        Variant::new(Strategy::Bcst, false),
        64 * KB,
        &o,
    );
    assert_eq!(r.engines_used, 32);
    let r = run_collective(
        CollectiveKind::AllToAll,
        Variant::new(Strategy::Swap, false),
        64 * KB,
        &o,
    );
    assert_eq!(r.engines_used, 28);
    let r = run_collective(
        CollectiveKind::AllToAll,
        Variant::new(Strategy::B2b, false),
        64 * KB,
        &o,
    );
    assert_eq!(r.engines_used, 8);
}

#[test]
fn reduce_scatter_transport_plus_reduce() {
    // The §7 RS dataflow is covered in-module; here: the transport plan
    // has AA's command pattern and one engine stream per rank (b2b style).
    use dma_latte::collectives::reduce_scatter;
    let topo = dma_latte::sim::Topology::mi300x_platform();
    let plan = reduce_scatter::plan_transport(&topo, 64 * KB);
    assert_eq!(plan.ranks.len(), 8);
    assert_eq!(plan.total_data_cmds(), 56);
    assert_eq!(plan.total_engines(), 8);
}
