//! Property tests over the workload generators
//! (`coordinator::workload`): seed-purity of the event streams, the
//! statistical contracts of each arrival process, and conversation-replay
//! ordering invariants.

use dma_latte::coordinator::workload::{
    default_tenants, ArrivalProcess, LenDist, TenantClass, WorkloadSpec,
};
use dma_latte::util::proptest::{run as prop_run, Config};
use dma_latte::util::rng::Rng;
use std::collections::HashMap;

/// One deterministic single-turn class: the process statistics are then
/// exactly the request statistics (no turn-rate scaling, no think gaps).
fn single_turn() -> Vec<TenantClass> {
    vec![TenantClass::simple(
        "uni",
        1.0,
        LenDist::Fixed(256),
        LenDist::Fixed(32),
    )]
}

/// The same spec always generates the identical stream, byte for byte;
/// changing only the seed changes it.
#[test]
fn prop_same_seed_same_stream() {
    prop_run(
        "workload-seed-purity",
        Config {
            cases: 24,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let process = match rng.below(3) {
                0 => ArrivalProcess::Poisson {
                    rate_rps: 50.0 + rng.f64() * 950.0,
                },
                1 => ArrivalProcess::Bursty {
                    rate_on_rps: 500.0 + rng.f64() * 1500.0,
                    on_ms: 10.0 + rng.f64() * 40.0,
                    off_ms: 10.0 + rng.f64() * 40.0,
                },
                _ => ArrivalProcess::Trace {
                    peak_rps: 200.0 + rng.f64() * 800.0,
                    day_s: 0.2 + rng.f64(),
                },
            };
            let spec = WorkloadSpec {
                process,
                classes: default_tenants(),
                requests: 64 + rng.below(128),
                seed: rng.next_u64(),
            };
            assert_eq!(spec.generate(), spec.generate(), "replay must be exact");
            let other = WorkloadSpec {
                seed: spec.seed.wrapping_add(1),
                ..spec.clone()
            };
            assert_ne!(spec.generate(), other.generate(), "seed must matter");
        },
    );
}

/// The lazy stream ([`WorkloadSpec::stream`]) is the materialized
/// reference ([`WorkloadSpec::generate`]), event for event, over random
/// specs covering all three arrival processes, multi-turn sessions and
/// degenerate request counts (including 0) — the exact-equality contract
/// the engine's O(active-sessions) arrival path rests on.
#[test]
fn prop_stream_matches_materialized() {
    prop_run(
        "stream-vs-materialized",
        Config {
            cases: 24,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let process = match rng.below(3) {
                0 => ArrivalProcess::Poisson {
                    rate_rps: 50.0 + rng.f64() * 950.0,
                },
                1 => ArrivalProcess::Bursty {
                    rate_on_rps: 500.0 + rng.f64() * 1500.0,
                    on_ms: 10.0 + rng.f64() * 40.0,
                    off_ms: 10.0 + rng.f64() * 40.0,
                },
                _ => ArrivalProcess::Trace {
                    peak_rps: 200.0 + rng.f64() * 800.0,
                    day_s: 0.2 + rng.f64(),
                },
            };
            let spec = WorkloadSpec {
                process,
                classes: default_tenants(),
                requests: rng.below(160),
                seed: rng.next_u64(),
            };
            let streamed: Vec<_> = spec.stream().collect();
            assert_eq!(
                streamed,
                spec.generate(),
                "stream must replay generate() exactly (requests={})",
                spec.requests
            );
        },
    );
}

/// Poisson arrivals: the measured rate over a long stream matches the
/// requested rate (mean inter-arrival ≈ 1/λ, well within 10%).
#[test]
fn prop_poisson_mean_rate() {
    prop_run(
        "poisson-mean-rate",
        Config {
            cases: 16,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let rate = 100.0 + rng.f64() * 900.0;
            let n = 3000u64;
            let spec = WorkloadSpec {
                process: ArrivalProcess::Poisson { rate_rps: rate },
                classes: single_turn(),
                requests: n,
                seed: rng.next_u64(),
            };
            let ev = spec.generate();
            let span_s = ev.last().unwrap().at_ns as f64 / 1e9;
            let measured = n as f64 / span_s;
            assert!(
                (measured / rate - 1.0).abs() < 0.10,
                "requested {rate:.0} rps, measured {measured:.0} rps"
            );
        },
    );
}

/// Bursty (on/off) arrivals: the long-run rate matches the duty cycle —
/// `rate_on × on/(on+off)` — and arrivals really cluster (the stream is
/// not just a slower Poisson).
#[test]
fn prop_bursty_duty_cycle() {
    prop_run(
        "bursty-duty-cycle",
        Config {
            cases: 12,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let (on_ms, off_ms) = *rng.pick(&[(10.0, 30.0), (20.0, 20.0), (30.0, 10.0)]);
            let rate_on = 2000.0 + rng.f64() * 2000.0;
            let duty = on_ms / (on_ms + off_ms);
            let n = 4000u64;
            let spec = WorkloadSpec {
                process: ArrivalProcess::Bursty {
                    rate_on_rps: rate_on,
                    on_ms,
                    off_ms,
                },
                classes: single_turn(),
                requests: n,
                seed: rng.next_u64(),
            };
            let ev = spec.generate();
            let span_s = ev.last().unwrap().at_ns as f64 / 1e9;
            let measured = n as f64 / span_s;
            let expected = rate_on * duty;
            assert!(
                measured > expected * 0.5 && measured < expected * 2.0,
                "duty {duty:.2}: expected ~{expected:.0} rps, measured {measured:.0}"
            );
            // Clustering: the within-burst gap is 1/rate_on, far below the
            // long-run mean gap — so the median gap sits well under it.
            let mut gaps: Vec<u64> = ev.windows(2).map(|w| w[1].at_ns - w[0].at_ns).collect();
            gaps.sort_unstable();
            let median = gaps[gaps.len() / 2] as f64;
            let mean_gap = span_s * 1e9 / n as f64;
            assert!(
                median < mean_gap * 0.75,
                "median gap {median:.0}ns not bursty vs mean {mean_gap:.0}ns"
            );
        },
    );
}

/// Conversation replays: turns of one session keep their order under the
/// global time-sort and truncation (contiguous indices from 0, strictly
/// increasing timestamps), share the class, grow the prompt with the
/// accumulated context, and are always warm after the first turn.
#[test]
fn prop_conversations_never_reorder() {
    prop_run(
        "conversation-ordering",
        Config {
            cases: 16,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let spec = WorkloadSpec {
                process: ArrivalProcess::Poisson {
                    rate_rps: 100.0 + rng.f64() * 900.0,
                },
                classes: default_tenants(),
                requests: 300,
                seed: rng.next_u64(),
            };
            let ev = spec.generate();
            assert!(
                ev.windows(2).all(|w| w[0].at_ns <= w[1].at_ns),
                "stream must be time-sorted"
            );
            let mut sessions: HashMap<u64, Vec<usize>> = HashMap::new();
            for (i, e) in ev.iter().enumerate() {
                sessions.entry(e.session).or_default().push(i);
            }
            let mut multi_turn = 0usize;
            for (session, idx) in &sessions {
                if idx.len() > 1 {
                    multi_turn += 1;
                }
                for (k, &i) in idx.iter().enumerate() {
                    let e = &ev[i];
                    assert_eq!(e.turn as usize, k, "session {session}: turn gap");
                    assert_eq!(e.class, ev[idx[0]].class, "session {session}: class");
                    if k > 0 {
                        let prev = &ev[idx[k - 1]];
                        assert!(e.at_ns > prev.at_ns, "session {session}: time order");
                        assert!(e.warm, "session {session}: follow-ups are warm");
                        assert!(
                            e.prompt_tokens > prev.prompt_tokens,
                            "session {session}: context must grow"
                        );
                    }
                }
            }
            // The default chat class is multi-turn: conversations must
            // actually appear, or this property tests nothing.
            assert!(multi_turn > 0, "no multi-turn session generated");
        },
    );
}
