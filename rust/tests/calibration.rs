//! Calibration integration tests: the paper's headline *shape* claims must
//! emerge from the simulator (DESIGN.md §5). Bands are deliberately loose —
//! we reproduce who wins, by roughly what factor, and where crossovers
//! fall, not absolute MI300X numbers.

use dma_latte::collectives::{CollectiveKind, Strategy, Variant};
use dma_latte::figures::collectives as fig;
use dma_latte::util::bytes::{size_sweep, GB, KB, MB};
use dma_latte::util::stats::geomean;

fn sweep(kind: CollectiveKind) -> Vec<fig::SweepRow> {
    fig::sweep(kind, Some(size_sweep(KB, GB, 2)))
}

#[test]
fn allgather_headline_ratios() {
    let rows = sweep(CollectiveKind::AllGather);
    let below = 32 * MB;

    // pcpy: paper 4.5x slower geomean <32MB; accept 2.5–6x.
    let pcpy = fig::geomean_speedup(&rows, Variant::new(Strategy::Pcpy, false), below);
    assert!((2.5..6.0).contains(&(1.0 / pcpy)), "pcpy slowdown {:.2}", 1.0 / pcpy);

    // Best DMA: paper 30% slower geomean; accept 10–60%.
    let best = fig::geomean_best(&rows, below);
    assert!((1.1..1.6).contains(&(1.0 / best)), "best slowdown {:.2}", 1.0 / best);

    // Large sizes: DMA wins ~14-20%.
    let large: Vec<f64> = rows
        .iter()
        .filter(|r| r.size >= 32 * MB)
        .map(|r| r.best().1)
        .collect();
    let g = geomean(&large);
    assert!((1.05..1.35).contains(&g), "large-size speedup {g:.2}");

    // b2b over pcpy below 1MB: paper 2.7x; accept 1.8–3.5x.
    let b = fig::geomean_speedup(&rows, Variant::new(Strategy::B2b, false), MB)
        / fig::geomean_speedup(&rows, Variant::new(Strategy::Pcpy, false), MB);
    assert!((1.8..3.5).contains(&b), "b2b/pcpy {b:.2}");

    // bcst over pcpy up to 4MB: paper 1.7x; accept 1.2–2.2x.
    let c = fig::geomean_speedup(&rows, Variant::new(Strategy::Bcst, false), 4 * MB)
        / fig::geomean_speedup(&rows, Variant::new(Strategy::Pcpy, false), 4 * MB);
    assert!((1.2..2.2).contains(&c), "bcst/pcpy {c:.2}");
}

#[test]
fn alltoall_headline_ratios() {
    let rows = sweep(CollectiveKind::AllToAll);
    let below = 32 * MB;

    // pcpy: paper 2.5x slower; accept 1.7–3.5x.
    let pcpy = fig::geomean_speedup(&rows, Variant::new(Strategy::Pcpy, false), below);
    assert!((1.7..3.5).contains(&(1.0 / pcpy)), "pcpy slowdown {:.2}", 1.0 / pcpy);

    // Best DMA: paper 20% FASTER; accept 0.9–1.4x.
    let best = fig::geomean_best(&rows, below);
    assert!((0.9..1.4).contains(&best), "best speedup {best:.2}");

    // swap over pcpy up to 4MB: paper 1.7x; accept 1.2–2.2x.
    let s = fig::geomean_speedup(&rows, Variant::new(Strategy::Swap, false), 4 * MB)
        / fig::geomean_speedup(&rows, Variant::new(Strategy::Pcpy, false), 4 * MB);
    assert!((1.2..2.2).contains(&s), "swap/pcpy {s:.2}");
}

#[test]
fn prelaunch_gains_ordered_like_paper() {
    // Paper §5.2.8: prelaunch speeds up pcpy 1.9x > bcst/swap 1.5x > b2b
    // 1.2x geomean across the range (more engines ⇒ more hidden overhead).
    let rows = sweep(CollectiveKind::AllGather);
    let gain = |s: Strategy| {
        let xs: Vec<f64> = rows
            .iter()
            .map(|r| r.speedup(Variant::new(s, true)) / r.speedup(Variant::new(s, false)))
            .collect();
        geomean(&xs)
    };
    let (p, b, bb) = (gain(Strategy::Pcpy), gain(Strategy::Bcst), gain(Strategy::B2b));
    assert!(p > b && b > bb, "ordering p={p:.2} bcst={b:.2} b2b={bb:.2}");
    assert!((1.4..2.8).contains(&p), "prelaunch on pcpy {p:.2}");
    assert!((1.05..1.8).contains(&bb), "prelaunch on b2b {bb:.2}");
}

#[test]
fn table2_structure_emerges() {
    // The empirically best variant must follow Table 2's structure:
    // b2b+prelaunch at small sizes, bcst+prelaunch in the middle band,
    // pcpy(+prelaunch) at large sizes.
    let rows = sweep(CollectiveKind::AllGather);
    let best = |size: u64| {
        rows.iter()
            .find(|r| r.size == size)
            .unwrap()
            .best()
            .0
            .strategy
    };
    assert_eq!(best(4 * KB), Strategy::B2b);
    assert_eq!(best(64 * KB), Strategy::B2b);
    assert_eq!(best(512 * KB), Strategy::Bcst);
    assert_eq!(best(16 * MB), Strategy::Pcpy);
    assert_eq!(best(512 * MB), Strategy::Pcpy);
}

#[test]
fn table3_structure_emerges() {
    let rows = sweep(CollectiveKind::AllToAll);
    let best = |size: u64| {
        rows.iter()
            .find(|r| r.size == size)
            .unwrap()
            .best()
            .0
            .strategy
    };
    assert_eq!(best(4 * KB), Strategy::B2b);
    assert_eq!(best(MB), Strategy::Swap);
    assert_eq!(best(64 * MB), Strategy::Pcpy);
}

#[test]
fn serving_headline_ratios() {
    use dma_latte::coordinator::{ServeConfig, VirtualEngine};
    use dma_latte::kvcache::fetch::FetchImpl;
    use dma_latte::models::zoo::QWEN25_0_5B;

    // TTFT_GPU speedup: paper up to 2.29x (accept 1.6–3.2); TTFT_total up
    // to 1.5x (accept 1.2–1.9) — smallest model, 4096 & 8192.
    for prefill in [4096u64, 8192] {
        let base = VirtualEngine::measure_ttft(
            &ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaBaseline),
            prefill,
        );
        let b2b = VirtualEngine::measure_ttft(
            &ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b),
            prefill,
        );
        let gpu = base.0 as f64 / b2b.0 as f64;
        let total = base.1 as f64 / b2b.1 as f64;
        assert!((1.6..3.2).contains(&gpu), "@{prefill}: gpu {gpu:.2}");
        assert!((1.2..1.9).contains(&total), "@{prefill}: total {total:.2}");
    }
}
