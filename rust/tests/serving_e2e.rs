//! Serving-stack integration (no PJRT; the compiled-model path is covered
//! by `runtime_load.rs` and the `llm_serving` example): virtual engine +
//! threaded server behave like one system across fetch impls and hit
//! rates.

use dma_latte::coordinator::request::Request;
use dma_latte::coordinator::server::{ModelBackend, Server, ServerConfig};
use dma_latte::coordinator::{ServeConfig, VirtualEngine};
use dma_latte::kvcache::fetch::FetchImpl;
use dma_latte::kvcache::BlockLayout;
use dma_latte::models::zoo::{QWEN25_0_5B, QWEN25_7B};

struct CountingBackend {
    prefills: usize,
    decodes: usize,
}
impl ModelBackend for CountingBackend {
    fn prefill(&mut self, prompt: &[u32]) -> u32 {
        self.prefills += 1;
        prompt.iter().sum::<u32>() % 1000
    }
    fn decode(&mut self, last: &[u32]) -> Vec<u32> {
        self.decodes += 1;
        last.iter().map(|&t| (t * 31 + 7) % 1000).collect()
    }
    fn kv_bytes_per_token(&self) -> u64 {
        12_288
    }
}

#[test]
fn threaded_server_under_load() {
    let server = Server::start(
        ServerConfig {
            layout: BlockLayout::new(&QWEN25_0_5B, 16),
            fetch: FetchImpl::DmaB2b,
            gpu_blocks: 1 << 16,
            cpu_blocks: 1 << 18,
            max_batch: 16,
        },
        || CountingBackend {
            prefills: 0,
            decodes: 0,
        },
    );
    let n = 100u64;
    for i in 0..n {
        server.submit(
            Request::new(i, 64, 1 + (i % 7), 0),
            vec![(i % 100) as u32; 64],
        );
    }
    let mut total_tokens = 0u64;
    for _ in 0..n {
        let c = server.next_completion().unwrap();
        total_tokens += c.tokens.len() as u64;
        assert!(c.ttft <= c.total);
    }
    let m = server.shutdown();
    assert_eq!(m.finished, n);
    // Token accounting: tokens returned = sum over requests of max_new.
    let want: u64 = (0..n).map(|i| 1 + (i % 7)).sum();
    assert_eq!(total_tokens, want);
    // Everything hit the (warmed) CPU cache.
    assert_eq!(m.cache_hits, n);
    assert!(m.fetch_bytes > 0);
}

#[test]
fn virtual_engine_tput_ordering_holds_across_models() {
    // b2b ≥ kernel ≥ baseline in throughput for small models at full hit
    // rate (the paper's Fig. 17 ordering; kernel sits between because it
    // saves host time but burns GPU time).
    for model in [&QWEN25_0_5B, &QWEN25_7B] {
        let tps = |fetch| {
            let mut cfg = ServeConfig::new(model, fetch);
            cfg.gpu_blocks = 1 << 18;
            let mut eng = VirtualEngine::new(cfg);
            for i in 0..96 {
                eng.submit(Request::new(i, 2048, 16, 0), true);
            }
            eng.run_to_completion().tps()
        };
        let base = tps(FetchImpl::DmaBaseline);
        let b2b = tps(FetchImpl::DmaB2b);
        assert!(
            b2b > base,
            "{}: b2b {b2b:.0} must beat baseline {base:.0}",
            model.name
        );
    }
}

#[test]
fn hit_rate_sweep_degrades_gracefully() {
    // As hit% drops, prefill replaces fetch: everything still completes
    // and the b2b advantage shrinks (§5.3.3).
    let run = |fetch, hit| {
        let mut cfg = ServeConfig::new(&QWEN25_0_5B, fetch);
        cfg.hit_rate = hit;
        cfg.gpu_blocks = 1 << 18;
        let mut eng = VirtualEngine::new(cfg);
        for i in 0..64 {
            eng.submit(Request::new(i, 2048, 8, 0), true);
        }
        eng.run_to_completion().clone()
    };
    let mut prev_gain = f64::INFINITY;
    for hit in [1.0, 0.7, 0.5] {
        let base = run(FetchImpl::DmaBaseline, hit);
        let b2b = run(FetchImpl::DmaB2b, hit);
        assert_eq!(base.finished, 64);
        assert_eq!(b2b.finished, 64);
        let gain = b2b.tps() / base.tps();
        assert!(
            gain <= prev_gain * 1.10,
            "gain should shrink with hit rate: {gain:.2} after {prev_gain:.2}"
        );
        prev_gain = gain;
    }
}

#[test]
fn backpressure_with_tiny_block_pool() {
    // A pool that fits only a couple of requests forces queueing but must
    // not deadlock or lose requests.
    let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b);
    cfg.gpu_blocks = 600; // ~2 requests of 2048+8 tokens (129 blocks each)
    let mut eng = VirtualEngine::new(cfg);
    for i in 0..12 {
        eng.submit(Request::new(i, 2048, 8, 0), true);
    }
    let m = eng.run_to_completion();
    assert_eq!(m.finished, 12);
}

#[test]
fn multi_replica_routing_scales_throughput() {
    // Two virtual-engine replicas behind a least-outstanding router should
    // finish a fixed workload in roughly half the virtual time of one.
    use dma_latte::coordinator::router::{RoutePolicy, Router};
    let run_replicas = |replicas: usize| -> u64 {
        let mut router = Router::new(replicas, RoutePolicy::LeastOutstanding);
        let mut engines: Vec<VirtualEngine> = (0..replicas)
            .map(|_| {
                let mut cfg = ServeConfig::new(&QWEN25_0_5B, FetchImpl::DmaB2b);
                cfg.gpu_blocks = 1 << 18;
                VirtualEngine::new(cfg)
            })
            .collect();
        for i in 0..64u64 {
            let r = router.route(i, None);
            engines[r].submit(Request::new(i, 2048, 16, 0), true);
        }
        engines
            .iter_mut()
            .map(|e| e.run_to_completion().wall_ns)
            .max()
            .unwrap()
    };
    let one = run_replicas(1);
    let two = run_replicas(2);
    assert!(
        (two as f64) < 0.65 * one as f64,
        "2 replicas {two} vs 1 replica {one}"
    );
}

#[test]
fn kv_save_integrates_with_store() {
    // Save a finished request's KV to the CPU tier, then admit a new
    // request with the same key: it must hit and fetch.
    use dma_latte::kvcache::save::{plan_save, run_save};
    use dma_latte::kvcache::BlockLayout;
    use dma_latte::sim::{Sim, SimConfig};
    let layout = BlockLayout::new(&QWEN25_0_5B, 16);
    let mut sim = Sim::new(SimConfig::mi300x());
    let gpu_blocks: Vec<u64> = (0..32).collect();
    let cpu_blocks: Vec<u64> = (0..32).collect();
    let saves = plan_save(&layout, 0, &gpu_blocks, &cpu_blocks);
    let out = run_save(&mut sim, FetchImpl::DmaB2b, &saves);
    assert!(out.total_ns > 0);
    assert!(out.api_calls <= 2);
    // Batched save must not hog the host (fire-and-forget friendly).
    assert!(out.host_ns < 100_000, "host {}", out.host_ns);
}
