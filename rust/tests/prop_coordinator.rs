//! Property tests over the serving coordinator: request conservation,
//! block-accounting safety, router balance, fetch-impl equivalence.

use dma_latte::coordinator::request::Request;
use dma_latte::coordinator::router::{RoutePolicy, Router};
use dma_latte::coordinator::{ServeConfig, VirtualEngine};
use dma_latte::kvcache::fetch::FetchImpl;
use dma_latte::kvcache::BlockAllocator;
use dma_latte::models::zoo::{LLAMA32_1B, QWEN25_0_5B};
use dma_latte::util::proptest::{run as prop_run, Config};
use dma_latte::util::rng::Rng;

/// Whatever the workload, the virtual engine finishes every request and
/// conserves token counts (no loss, no duplication).
#[test]
fn prop_engine_conserves_requests() {
    prop_run(
        "engine-conservation",
        Config {
            cases: 24,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let model = if rng.chance(0.5) {
                &QWEN25_0_5B
            } else {
                &LLAMA32_1B
            };
            let fetch = *rng.pick(&[
                FetchImpl::DmaBaseline,
                FetchImpl::DmaB2b,
                FetchImpl::Kernel,
            ]);
            let mut cfg = ServeConfig::new(model, fetch);
            cfg.hit_rate = rng.f64();
            cfg.max_batch = rng.range(1, 16);
            cfg.gpu_blocks = 1 << 18;
            cfg.seed = rng.next_u64();
            let n = rng.range(1, 40) as u64;
            let decode = rng.range(1, 12) as u64;
            let prompt = 16 * rng.range(1, 64) as u64;
            let mut eng = VirtualEngine::new(cfg);
            for i in 0..n {
                eng.submit(Request::new(i, prompt, decode, 0), true);
            }
            let m = eng.run_to_completion();
            assert_eq!(m.finished, n, "every request finishes");
            assert_eq!(m.tokens_out, n * decode, "token conservation");
            assert_eq!(m.ttft_ns.len(), n as usize, "one TTFT per request");
            assert_eq!(m.cache_hits + m.cache_misses, n);
            assert!(m.wall_ns > 0);
        },
    );
}

/// Block allocator safety under random alloc/release interleavings.
#[test]
fn prop_allocator_never_double_allocates() {
    prop_run(
        "allocator",
        Config {
            cases: 64,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let cap = rng.range(1, 200) as u64;
            let mut a = BlockAllocator::new(cap);
            let mut live: Vec<u64> = Vec::new();
            for step in 0..rng.range(5, 60) {
                if rng.chance(0.6) || live.is_empty() {
                    let req = step as u64;
                    let n = rng.range(0, 12) as u64;
                    if a.alloc(req, n).is_ok() && n > 0 {
                        live.push(req);
                    }
                } else {
                    let idx = rng.range(0, live.len() - 1);
                    let req = live.swap_remove(idx);
                    a.release(req);
                }
                a.check_invariants();
            }
            for req in live {
                a.release(req);
            }
            a.check_invariants();
            assert_eq!(a.available(), cap);
        },
    );
}

/// Router: completes cancel outstanding exactly; least-outstanding keeps
/// the load spread within 1 when requests complete uniformly.
#[test]
fn prop_router_balance() {
    prop_run(
        "router",
        Config {
            cases: 32,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let replicas = rng.range(1, 8);
            let mut r = Router::new(replicas, RoutePolicy::LeastOutstanding);
            let n = rng.range(1, 100) as u64;
            for i in 0..n {
                r.route(i, None);
            }
            let max = *r.load().iter().max().unwrap();
            let min = *r.load().iter().min().unwrap();
            assert!(max - min <= 1, "load {:?}", r.load());
            for i in 0..n {
                r.complete(i);
            }
            assert!(r.load().iter().all(|&x| x == 0));
        },
    );
}

/// All three fetch impls produce byte-identical GPU state for the same
/// random copy set.
#[test]
fn prop_fetch_functional_equivalence() {
    use dma_latte::kvcache::fetch::run_fetch;
    use dma_latte::sim::topology::NodeId;
    use dma_latte::sim::{Addr, Sim, SimConfig};
    prop_run(
        "fetch-equivalence",
        Config {
            cases: 20,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let n = rng.range(1, 24) as u64;
            let len = 256 * rng.range(1, 64) as u64;
            let copies: Vec<_> = (0..n)
                .map(|i| {
                    (
                        Addr::new(NodeId::Cpu, i * len),
                        Addr::new(NodeId::Gpu(0), i * len),
                        len,
                    )
                })
                .collect();
            let mut images = Vec::new();
            for imp in [FetchImpl::DmaBaseline, FetchImpl::DmaB2b, FetchImpl::Kernel] {
                let mut sim = Sim::new(SimConfig::mi300x().functional());
                let mut fill = vec![0u8; (n * len) as usize];
                let mut r2 = Rng::new(1234);
                r2.fill_bytes(&mut fill);
                sim.memory.poke(NodeId::Cpu, 0, &fill);
                run_fetch(&mut sim, imp, &copies);
                images.push(sim.memory.peek(NodeId::Gpu(0), 0, n * len));
            }
            assert_eq!(images[0], images[1]);
            assert_eq!(images[1], images[2]);
        },
    );
}
