//! Property and acceptance tests for the fault-injection subsystem
//! (PR 8): the fault plan is a pure function of `(spec, nodes, seed)`,
//! an empty plan replays the healthy serving run bit for bit, flapped
//! collectives deliver byte-identical placements (flaps delay, never
//! drop), and the degradation-aware serving policy strictly beats the
//! degradation-blind baseline on chat-class SLO attainment under a
//! seeded single-node NIC derate.

use std::cell::Cell;

use dma_latte::cluster::{
    run_hier_full, ClusterChoice, ClusterTopology, FaultPlan, FaultSpec, HierRunOptions,
    InterSchedule, LinkHealth, NicModel,
};
use dma_latte::collectives::plan::aa_out_base;
use dma_latte::collectives::{CollectiveKind, Strategy, Variant};
use dma_latte::coordinator::config::DegradePolicy;
use dma_latte::coordinator::workload::{default_tenants, drive, ArrivalProcess, WorkloadSpec};
use dma_latte::figures::faults::chat_attainment;
use dma_latte::figures::serving_load as sl;
use dma_latte::models::zoo::QWEN25_0_5B;
use dma_latte::sim::topology::NodeId;
use dma_latte::sim::Topology;
use dma_latte::util::proptest::{run as prop_run, Config};
use dma_latte::util::rng::Rng;

/// Same `(spec, nodes, seed)` ⇒ bit-identical fault plan, across random
/// specs; the healthy spec generates the empty plan at every seed.
#[test]
fn prop_fault_plan_is_a_pure_function_of_spec_and_seed() {
    prop_run(
        "fault-plan-purity",
        Config {
            cases: 64,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let n = rng.range(1, 16);
            let spec = FaultSpec {
                nic_nodes: rng.range(0, n),
                nic_factor: 0.05 + 0.9 * rng.f64(),
                flap_prob: 0.5 * rng.f64(),
                stuck_engines: rng.below(16) as u8,
                xgmi_factor: 0.25 + 0.75 * rng.f64(),
                straggler_nodes: rng.range(0, n),
                straggler_factor: 1.0 + rng.f64(),
                window_s: if rng.chance(0.5) { 0.0 } else { rng.f64() },
            };
            let seed = rng.below(1 << 30);
            let a = FaultPlan::generate(&spec, n, seed);
            let b = FaultPlan::generate(&spec, n, seed);
            assert_eq!(a, b, "same (spec, nodes, seed) must give the same plan");
            assert_eq!(a.num_nodes(), n);
            let h = FaultPlan::generate(&FaultSpec::default(), n, seed);
            assert!(h.is_empty(), "healthy spec must generate the empty plan");
        },
    );
}

/// The zero-perturbation contract end to end: a serving config carrying
/// an empty (all-healthy) fault spec — under either degradation policy —
/// replays the no-faults run bit for bit, and never trips a counter.
#[test]
fn empty_fault_plan_replays_the_healthy_serving_run_bit_identically() {
    let base = sl::serve_config(&QWEN25_0_5B, 2, true);
    let spec = WorkloadSpec {
        process: ArrivalProcess::Poisson { rate_rps: 600.0 },
        classes: default_tenants(),
        requests: 96,
        seed: 21,
    };
    let healthy = drive(&base, &spec);
    for policy in [DegradePolicy::aware(), DegradePolicy::blind()] {
        let empty = base
            .clone()
            .with_faults(FaultSpec::default())
            .with_degrade(policy);
        let replay = drive(&empty, &spec);
        assert_eq!(healthy.wall_ns, replay.wall_ns, "serving wall clock");
        assert_eq!(healthy.ttft_ns, replay.ttft_ns, "ttft distribution");
        assert_eq!(healthy.tpot_ns, replay.tpot_ns, "tpot distribution");
        assert_eq!(healthy.comm_ns, replay.comm_ns, "comm total");
        assert_eq!(healthy.per_class, replay.per_class, "per-class counters");
        assert_eq!(healthy.queue_depth, replay.queue_depth, "queue timeline");
        assert_eq!(
            (replay.retries, replay.timeouts, replay.shed),
            (0, 0, 0),
            "no fault counter may trip on an empty plan"
        );
        assert_eq!(replay.preemptions, 0);
        assert_eq!(replay.drained_nodes, 0);
    }
}

/// A faulted serving run is itself deterministic: same seed, same spec,
/// same degraded outcome — including every fault counter.
#[test]
fn faulted_serving_is_deterministic_for_a_fixed_seed() {
    let cfg = sl::serve_config(&QWEN25_0_5B, 2, true)
        .with_faults(FaultSpec::parse("nic=1:0.25,flap=0.1").expect("literal spec"));
    let spec = WorkloadSpec {
        process: ArrivalProcess::Poisson { rate_rps: 500.0 },
        classes: default_tenants(),
        requests: 96,
        seed: 11,
    };
    let a = drive(&cfg, &spec);
    let b = drive(&cfg, &spec);
    assert_eq!(a.wall_ns, b.wall_ns, "faulted wall clock");
    assert_eq!(a.ttft_ns, b.ttft_ns, "faulted ttft distribution");
    assert_eq!(a.tpot_ns, b.tpot_ns, "faulted tpot distribution");
    assert_eq!(a.per_class, b.per_class, "faulted per-class counters");
    assert_eq!(
        (a.retries, a.timeouts, a.shed, a.preemptions, a.drained_nodes),
        (b.retries, b.timeouts, b.shed, b.preemptions, b.drained_nodes),
        "fault counters must replay"
    );
}

/// Flaps delay messages, they never drop or reorder bytes: a flapped
/// hierarchical collective verifies functionally, lands the exact same
/// placement as its healthy twin on every rank, and is never faster.
#[test]
fn prop_flapped_collectives_deliver_identical_bytes() {
    let total_retries = Cell::new(0u64);
    prop_run(
        "flap-byte-equality",
        Config {
            cases: 12,
            ..Default::default()
        },
        |rng: &mut Rng| {
            let n = rng.range(2, 4);
            let g = rng.range(2, 4) as u8;
            let world = (n * g as usize) as u8;
            let kind = if rng.chance(0.5) {
                CollectiveKind::AllGather
            } else {
                CollectiveKind::AllToAll
            };
            let v = *rng.pick(&Variant::all_for(kind));
            let inter = if rng.chance(0.5) {
                InterSchedule::Sequential
            } else {
                InterSchedule::Pipelined
            };
            let size = 256 * rng.range(1, 4) as u64 * world as u64;
            let cluster = ClusterTopology::homogeneous(
                n,
                Topology::custom(g, 16, 64.0, 64.0),
                NicModel::default(),
            );
            let choice = ClusterChoice { intra: v, inter };
            let label = format!("{} {} {inter:?} n={n} g={g} size={size}", kind.name(), v.name());

            let healthy_opts = HierRunOptions {
                verify: true,
                ..Default::default()
            };
            let (healthy, healthy_sims) =
                run_hier_full(kind, choice, &cluster, size, &healthy_opts);
            let flap_opts = HierRunOptions {
                verify: true,
                link_faults: Some(LinkHealth::uniform(n, 0.9, rng.below(1 << 30))),
                ..Default::default()
            };
            let (flapped, flapped_sims) = run_hier_full(kind, choice, &cluster, size, &flap_opts);

            assert_eq!(healthy.verified, Some(true), "{label}");
            assert_eq!(flapped.verified, Some(true), "{label}: flapped placement");
            assert_eq!(healthy.faults.retries, 0, "{label}: healthy run never retries");
            assert!(
                flapped.latency_ns >= healthy.latency_ns,
                "{label}: flaps may only delay"
            );
            total_retries.set(total_retries.get() + flapped.faults.retries);

            let in_place = v.strategy == Strategy::Swap;
            let mut regions: Vec<(u64, u64)> = vec![(0, size)];
            if kind == CollectiveKind::AllToAll && !in_place {
                regions.push((aa_out_base(size), size));
            }
            for r in 0..world as u32 {
                let (node, local) = cluster.locate(r);
                for &(base, len) in &regions {
                    assert_eq!(
                        flapped_sims[node].memory.peek(NodeId::Gpu(local), base, len),
                        healthy_sims[node].memory.peek(NodeId::Gpu(local), base, len),
                        "{label}: rank {r} region base {base}"
                    );
                }
            }
        },
    );
    // p=0.9 per message over 12 cases × ≥2 inter-node messages each: the
    // retry path is exercised with near-certainty.
    assert!(total_retries.get() > 0, "no case exercised the retry path");
}

/// PR 8 acceptance: with a seeded single-node NIC derate (20× slower),
/// the degradation-aware policy (re-select + drain + shed + preempt)
/// achieves strictly higher chat-class SLO attainment than the
/// degradation-blind baseline at the same offered load.
#[test]
fn degradation_aware_serving_beats_blind_on_chat_slo_under_nic_derate() {
    let classes = default_tenants();
    let base = sl::serve_config(&QWEN25_0_5B, 2, true);
    let cap = sl::estimate_capacity_rps(&base, &classes, 96, 7);
    let spec = FaultSpec::parse("nic=1:0.05").expect("literal spec");
    let wl = WorkloadSpec {
        process: ArrivalProcess::Poisson {
            rate_rps: 0.4 * cap,
        },
        classes,
        requests: 160,
        seed: 7,
    };
    let blind_cfg = base
        .clone()
        .with_faults(spec.clone())
        .with_degrade(DegradePolicy::blind());
    let aware_cfg = base.with_faults(spec).with_degrade(DegradePolicy::aware());
    let blind = drive(&blind_cfg, &wl);
    let aware = drive(&aware_cfg, &wl);

    // Blind keeps the full (sick) world; aware drains the derated node.
    assert_eq!(blind.drained_nodes, 0, "blind must not drain");
    assert_eq!(aware.drained_nodes, 1, "aware must drain the derated node");

    let chat_blind = chat_attainment(&blind);
    let chat_aware = chat_attainment(&aware);
    assert!(
        chat_aware > chat_blind,
        "degradation-aware must beat blind on chat SLO attainment: \
         aware {:.3} vs blind {:.3}",
        chat_aware,
        chat_blind
    );
}
