//! Architecture configs of the paper's evaluation models (§5.3.2):
//! Qwen 2.5 (0.5B–32B, incl. DeepSeek-R1-Distill-Qwen-32B which shares the
//! Qwen2.5-32B architecture) and Llama 3.1/3.2. Values from the public
//! model cards.

/// Decoder-only transformer architecture description.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub params_b: f64,
    pub layers: u32,
    pub hidden: u32,
    pub heads: u32,
    pub kv_heads: u32,
    pub head_dim: u32,
    pub intermediate: u32,
    pub vocab: u32,
}

impl ModelConfig {
    /// KV-cache bytes per token (fp16/bf16: 2 bytes), both K and V, all
    /// layers — the quantity that sets transfer sizes for KV save/fetch.
    pub fn kv_bytes_per_token(&self) -> u64 {
        2 * self.layers as u64 * self.kv_heads as u64 * self.head_dim as u64 * 2
    }

    /// Bytes of one PagedAttention block (`block_tokens` tokens, all layers
    /// contiguous — the optimized layout of [28] the paper assumes).
    pub fn kv_block_bytes(&self, block_tokens: u32) -> u64 {
        self.kv_bytes_per_token() * block_tokens as u64
    }

    /// Total parameter bytes at bf16.
    pub fn weight_bytes(&self) -> u64 {
        (self.params_b * 1e9) as u64 * 2
    }

    /// Approximate FLOPs for one token of forward pass (2 × params, the
    /// standard decoder estimate) — attention over context adds
    /// `2 × layers × 2 × context × kv-width` handled in `perf`.
    pub fn flops_per_token(&self) -> f64 {
        2.0 * self.params_b * 1e9
    }
}

/// Qwen2.5-0.5B
pub const QWEN25_0_5B: ModelConfig = ModelConfig {
    name: "Qwen2.5-0.5B",
    params_b: 0.49,
    layers: 24,
    hidden: 896,
    heads: 14,
    kv_heads: 2,
    head_dim: 64,
    intermediate: 4864,
    vocab: 151_936,
};

/// Llama-3.2-1B
pub const LLAMA32_1B: ModelConfig = ModelConfig {
    name: "Llama-3.2-1B",
    params_b: 1.24,
    layers: 16,
    hidden: 2048,
    heads: 32,
    kv_heads: 8,
    head_dim: 64,
    intermediate: 8192,
    vocab: 128_256,
};

/// Llama-3.2-3B
pub const LLAMA32_3B: ModelConfig = ModelConfig {
    name: "Llama-3.2-3B",
    params_b: 3.21,
    layers: 28,
    hidden: 3072,
    heads: 24,
    kv_heads: 8,
    head_dim: 128,
    intermediate: 8192,
    vocab: 128_256,
};

/// Qwen2.5-7B
pub const QWEN25_7B: ModelConfig = ModelConfig {
    name: "Qwen2.5-7B",
    params_b: 7.62,
    layers: 28,
    hidden: 3584,
    heads: 28,
    kv_heads: 4,
    head_dim: 128,
    intermediate: 18_944,
    vocab: 152_064,
};

/// Llama-3.1-8B
pub const LLAMA31_8B: ModelConfig = ModelConfig {
    name: "Llama-3.1-8B",
    params_b: 8.03,
    layers: 32,
    hidden: 4096,
    heads: 32,
    kv_heads: 8,
    head_dim: 128,
    intermediate: 14_336,
    vocab: 128_256,
};

/// Qwen2.5-14B
pub const QWEN25_14B: ModelConfig = ModelConfig {
    name: "Qwen2.5-14B",
    params_b: 14.77,
    layers: 48,
    hidden: 5120,
    heads: 40,
    kv_heads: 8,
    head_dim: 128,
    intermediate: 13_824,
    vocab: 152_064,
};

/// DeepSeek-R1-Distill-Qwen-32B (Qwen2.5-32B architecture)
pub const QWEN25_32B: ModelConfig = ModelConfig {
    name: "DeepSeek-R1-Qwen-32B",
    params_b: 32.76,
    layers: 64,
    hidden: 5120,
    heads: 40,
    kv_heads: 8,
    head_dim: 128,
    intermediate: 27_648,
    vocab: 152_064,
};

/// The paper's evaluation set, smallest → largest.
pub const ALL_MODELS: &[&ModelConfig] = &[
    &QWEN25_0_5B,
    &LLAMA32_1B,
    &LLAMA32_3B,
    &QWEN25_7B,
    &LLAMA31_8B,
    &QWEN25_14B,
    &QWEN25_32B,
];

/// Look up a model by (case-insensitive substring of) name.
pub fn find(name: &str) -> Option<&'static ModelConfig> {
    let n = name.to_ascii_lowercase();
    ALL_MODELS
        .iter()
        .copied()
        .find(|m| m.name.to_ascii_lowercase().contains(&n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_geometry_llama8b() {
        // 2 × 32 layers × 8 kv-heads × 128 dim × 2 B = 131072 B/token.
        assert_eq!(LLAMA31_8B.kv_bytes_per_token(), 131_072);
        // 16-token block, all layers contiguous: 2 MiB.
        assert_eq!(LLAMA31_8B.kv_block_bytes(16), 2 * 1024 * 1024);
    }

    #[test]
    fn kv_geometry_qwen05b() {
        // 2 × 24 × 2 × 64 × 2 = 12288 B/token → 192 KiB / 16-token block.
        assert_eq!(QWEN25_0_5B.kv_bytes_per_token(), 12_288);
        assert_eq!(QWEN25_0_5B.kv_block_bytes(16), 196_608);
    }

    #[test]
    fn zoo_ordered_by_size() {
        for w in ALL_MODELS.windows(2) {
            assert!(w[0].params_b <= w[1].params_b);
        }
        assert_eq!(ALL_MODELS.len(), 7);
    }

    #[test]
    fn find_by_substring() {
        assert_eq!(find("llama-3.1").unwrap().name, "Llama-3.1-8B");
        assert_eq!(find("0.5b").unwrap().name, "Qwen2.5-0.5B");
        assert!(find("gpt-5").is_none());
    }
}
