//! MI300X roofline execution-time model.
//!
//! GPU times for prefill/decode are needed to compose TTFT and throughput
//! (Figs. 16/17); the local CPU PJRT execution of the tiny compiled model
//! proves functional composition but cannot stand in for MI300X timing, so
//! figure generation uses this analytic model (DESIGN.md §1).

use super::zoo::ModelConfig;

/// Hardware throughput description.
#[derive(Debug, Clone)]
pub struct PerfModel {
    /// Peak dense bf16 FLOP/s.
    pub peak_flops: f64,
    /// Achievable fraction of peak for large GEMMs (prefill).
    pub gemm_eff: f64,
    /// HBM bandwidth bytes/s.
    pub hbm_bytes_per_s: f64,
    /// Achievable fraction of HBM bandwidth for decode (weight streaming).
    pub hbm_eff: f64,
    /// Fixed per-step launch/framework cost on the GPU path, s.
    pub step_overhead_s: f64,
}

/// MI300X data sheet values: 1307 TFLOPS bf16, 5.3 TB/s HBM3.
pub type Mi300xPerf = PerfModel;

impl Default for PerfModel {
    fn default() -> Self {
        PerfModel {
            peak_flops: 1.307e15,
            gemm_eff: 0.52,
            hbm_bytes_per_s: 5.3e12,
            hbm_eff: 0.72,
            step_overhead_s: 25e-6,
        }
    }
}

impl PerfModel {
    /// Prefill GPU time for `tokens` prompt tokens (compute-bound):
    /// 2·P FLOPs/token plus quadratic attention term.
    pub fn prefill_s(&self, m: &ModelConfig, tokens: u64) -> f64 {
        let gemm_flops = m.flops_per_token() * tokens as f64;
        // Attention: 2 (QK^T + PV) × 2 FLOPs × heads × head_dim × T²/2 per layer.
        let attn_flops = 2.0
            * 2.0
            * (m.heads as f64 * m.head_dim as f64)
            * (tokens as f64 * tokens as f64 / 2.0)
            * m.layers as f64;
        self.step_overhead_s + (gemm_flops + attn_flops) / (self.peak_flops * self.gemm_eff)
    }

    /// One decode step for a batch of `batch` sequences at `context` tokens
    /// of KV (memory-bound: weights stream once per step; KV streams per
    /// sequence).
    pub fn decode_step_s(&self, m: &ModelConfig, batch: u64, context: u64) -> f64 {
        let weight_bytes = m.weight_bytes() as f64;
        let kv_bytes = m.kv_bytes_per_token() as f64 * context as f64 * batch as f64;
        let mem_s = (weight_bytes + kv_bytes) / (self.hbm_bytes_per_s * self.hbm_eff);
        let flop_s = m.flops_per_token() * batch as f64 / (self.peak_flops * self.gemm_eff);
        self.step_overhead_s + mem_s.max(flop_s)
    }

    /// Decode throughput ceiling (tokens/s) at given batch and context.
    pub fn decode_tps(&self, m: &ModelConfig, batch: u64, context: u64) -> f64 {
        batch as f64 / self.decode_step_s(m, batch, context)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::{LLAMA31_8B, QWEN25_0_5B, QWEN25_32B};

    #[test]
    fn prefill_scales_superlinearly() {
        let p = PerfModel::default();
        let t4k = p.prefill_s(&LLAMA31_8B, 4096);
        let t8k = p.prefill_s(&LLAMA31_8B, 8192);
        assert!(t8k > 2.0 * t4k, "attention term should bend the curve");
        // Sanity: 8B @ 4096 on MI300X ≈ 2·8e9·4096/6.8e14 ≈ 0.1 s.
        assert!((0.05..0.3).contains(&t4k), "t4k={t4k}");
    }

    #[test]
    fn decode_is_memory_bound_at_small_batch() {
        let p = PerfModel::default();
        let s = p.decode_step_s(&LLAMA31_8B, 1, 4096);
        // ≈ 16GB / 3.8 TB/s ≈ 4.2 ms + overhead.
        assert!((0.003..0.008).contains(&s), "s={s}");
        // Bigger batch amortizes weights → higher tps.
        assert!(p.decode_tps(&LLAMA31_8B, 64, 4096) > 20.0 * p.decode_tps(&LLAMA31_8B, 1, 4096));
    }

    #[test]
    fn bigger_models_slower() {
        let p = PerfModel::default();
        assert!(p.prefill_s(&QWEN25_32B, 4096) > p.prefill_s(&QWEN25_0_5B, 4096));
        assert!(
            p.decode_step_s(&QWEN25_32B, 8, 4096) > p.decode_step_s(&QWEN25_0_5B, 8, 4096)
        );
    }
}
