//! LLM architecture zoo + MI300X execution-time model.
//!
//! The serving experiments (Figs. 16/17) depend on each model's KV-cache
//! geometry (bytes per token, block size) and on GPU execution time for
//! prefill/decode. [`zoo`] carries the architectures the paper evaluates
//! (Qwen 2.5 0.5B–32B, Llama 3.1/3.2); [`perf`] converts an architecture +
//! workload into MI300X-roofline times.

pub mod perf;
pub mod zoo;

pub use perf::{Mi300xPerf, PerfModel};
pub use zoo::{ModelConfig, ALL_MODELS};
