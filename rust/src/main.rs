//! DMA-Latte CLI: figure regeneration, sweeps, and diagnostics.
//!
//! ```text
//! dma-latte figures   [--out results/] [--quick]   # all paper figures
//! dma-latte sweep     [--kind allgather|alltoall] [--max 4G]
//! dma-latte cluster   [--kind allgather|alltoall|reduce-scatter|allreduce]
//!                     [--nodes 1,2,4] [--max 1G]
//!                     [--schedule auto|sequential|pipelined|overlapped]
//!                     # hierarchical scaling (overlapped = chunk-granular
//!                     # fused all-reduce; auto lets the selector pick)
//! dma-latte breakdown                              # Fig. 7
//! dma-latte power                                  # Fig. 15 + cluster
//!                                                  # KV-migration NIC watts
//! dma-latte ttft      [--prefill 4096]             # Fig. 16
//! dma-latte throughput [--requests 200] [--hit 1.0]# Fig. 17
//! dma-latte serve     [--workload poisson|bursty|trace] [--rate R|R1,R2,..]
//!                     [--requests 512] [--nodes 1] [--seed 7]
//!                     [--tenants default|name:w:prompt:output[:ttft[:tpot]],..]
//!                     [--faults SPEC] [--degrade aware|blind]
//!                     [--no-overlap] [--disagg P:D] [--out results/]
//!                     # trace-driven serving: sweep offered load (points
//!                     # run in parallel across host cores, results
//!                     # order-independent), report per-class TTFT/TPOT
//!                     # percentiles + SLO attainment; --faults degrades
//!                     # the fleet (preset name or
//!                     # nic=N:F,flap=P,engines=K,xgmi=F,straggler=N:F,window=S);
//!                     # --disagg P:D splits the fleet into P prefill +
//!                     # D decode nodes with layer-pipelined KV migration
//!                     # and prints the colocated/blocking/pipelined
//!                     # comparison sweep first
//! dma-latte faults    [--nodes 2] [--requests 256] [--seed 7] [--out results/]
//!                     # canned fault scenarios: degraded-vs-healthy SLO
//!                     # attainment, aware vs blind policy, healthy-replay check
//! dma-latte selftest                               # quick invariants
//! dma-latte trace     [--kind allreduce] [--nodes 2] [--size 1M]
//!                     [--schedule auto|sequential|pipelined|overlapped]
//!                     [--out results/]
//! dma-latte trace     --serve [--requests 24] [--nodes 1] [--out results/]
//!                     # cross-layer trace: Perfetto timeline JSON +
//!                     # critical-path attribution table; prints a
//!                     # greppable attribution-sum-check line
//! ```

use dma_latte::cli::Args;
use dma_latte::collectives::CollectiveKind;
use dma_latte::figures::{
    breakdown, cluster as figcl, cluster_breakdown as figcb, collectives as figc, power, serving,
};
use dma_latte::models::{zoo, ALL_MODELS};
use dma_latte::util::bytes::{parse_size, size_sweep, GB, KB, MB};

fn cmd_sweep(args: &Args) {
    let kind = match args.get("kind", "allgather").as_str() {
        "alltoall" => CollectiveKind::AllToAll,
        _ => CollectiveKind::AllGather,
    };
    let max = parse_size(&args.get("max", "4G")).expect("bad --max");
    let rows = figc::sweep(kind, Some(size_sweep(KB, max, 2)));
    print!("{}", figc::render(kind, &rows));
    println!("\nbest per range:");
    for (lo, hi, v) in figc::best_table(&rows) {
        println!(
            "  {:>6}..{:>6} -> {}",
            dma_latte::util::bytes::fmt_size(lo),
            dma_latte::util::bytes::fmt_size(hi),
            v.name()
        );
    }
}

fn cmd_cluster(args: &Args) {
    let kind = match args.get("kind", "allgather").as_str() {
        "alltoall" => dma_latte::cluster::ClusterKind::AllToAll,
        "reduce-scatter" | "reduce_scatter" | "reducescatter" | "rs" => {
            dma_latte::cluster::ClusterKind::ReduceScatter
        }
        "allreduce" | "all-reduce" | "ar" => dma_latte::cluster::ClusterKind::AllReduce,
        _ => dma_latte::cluster::ClusterKind::AllGather,
    };
    let max = parse_size(&args.get("max", "1G")).expect("bad --max");
    let spec = args.get("nodes", "1,2,4");
    let mut nodes = Vec::new();
    for tok in spec.split(',') {
        match tok.trim().parse::<usize>() {
            Ok(n) if (1..=dma_latte::cluster::hier::MAX_NODES).contains(&n) => nodes.push(n),
            _ => {
                eprintln!(
                    "bad --nodes entry {tok:?} (need integers in 1..={})",
                    dma_latte::cluster::hier::MAX_NODES
                );
                std::process::exit(2);
            }
        }
    }
    let schedule = match args.get("schedule", "auto").as_str() {
        "auto" => None,
        "sequential" | "seq" => Some(dma_latte::cluster::InterSchedule::Sequential),
        "pipelined" | "pipe" => Some(dma_latte::cluster::InterSchedule::Pipelined),
        "overlapped" | "overlap" | "ovl" => Some(dma_latte::cluster::InterSchedule::Overlapped),
        other => {
            eprintln!("bad --schedule {other:?} (need auto|sequential|pipelined|overlapped)");
            std::process::exit(2);
        }
    };
    // Sweep sizes are rounded up per cell to a multiple of that cell's
    // world size by figures::cluster::scaling.
    let rows = figcl::scaling_with_schedule(kind, &nodes, Some(size_sweep(KB, max, 2)), schedule);
    print!("{}", figcl::render(kind, &rows));
}

fn cmd_figures(args: &Args) {
    let out = args.get("out", "results");
    let quick = args.has("quick");
    let max = if quick { 64 * MB } else { 4 * GB };
    std::fs::create_dir_all(&out).expect("mkdir results");

    println!("# Fig 1/13 + Table 2 — all-gather");
    let ag = figc::sweep(CollectiveKind::AllGather, Some(size_sweep(KB, max, 2)));
    print!("{}", figc::render(CollectiveKind::AllGather, &ag));
    figc::to_csv(CollectiveKind::AllGather, &ag)
        .write(format!("{out}/fig13_allgather.csv"))
        .unwrap();

    println!("\n# Fig 14 + Table 3 — all-to-all");
    let aa = figc::sweep(CollectiveKind::AllToAll, Some(size_sweep(KB, max, 2)));
    print!("{}", figc::render(CollectiveKind::AllToAll, &aa));
    figc::to_csv(CollectiveKind::AllToAll, &aa)
        .write(format!("{out}/fig14_alltoall.csv"))
        .unwrap();

    println!("\n# Cluster scaling — hierarchical AG/AA/RS/AR over 1/2/4 nodes");
    let cl_sizes = Some(size_sweep(KB, if quick { 16 * MB } else { GB }, 4));
    for kind in [
        dma_latte::cluster::ClusterKind::AllGather,
        dma_latte::cluster::ClusterKind::AllToAll,
        dma_latte::cluster::ClusterKind::ReduceScatter,
        dma_latte::cluster::ClusterKind::AllReduce,
    ] {
        let rows = figcl::scaling(kind, &[1, 2, 4], cl_sizes.clone());
        print!("{}", figcl::render(kind, &rows));
        figcl::to_csv(&rows)
            .write(format!("{out}/cluster_{}.csv", kind.name()))
            .unwrap();
    }

    println!("\n# Fig 7 — single-copy latency breakdown");
    let bd = breakdown::fig7();
    print!("{}", breakdown::render(&bd));
    breakdown::to_csv(&bd).write(format!("{out}/fig7_breakdown.csv")).unwrap();

    println!("\n# Cluster latency breakdown — critical-path attribution (2 nodes)");
    let cb = figcb::fig_cluster_breakdown(if quick { Some(vec![64 * KB, MB]) } else { None });
    print!("{}", figcb::render(&cb));
    figcb::to_csv(&cb)
        .write(format!("{out}/cluster_breakdown.csv"))
        .unwrap();

    println!("\n# Fig 15 — power");
    let pw = power::fig15(if quick {
        Some(vec![64 * KB, MB, 16 * MB, 64 * MB])
    } else {
        None
    });
    print!("{}", power::render(&pw));
    power::to_csv(&pw).write(format!("{out}/fig15_power.csv")).unwrap();

    println!("\n# Cluster power — KV migration over the NIC");
    print!("{}", power::render_migration(&power::migration_power(256)));

    println!("\n# Disaggregated serving — colocated vs migration schedules");
    let dg = dma_latte::figures::disagg::sweep(&if quick {
        dma_latte::figures::disagg::default_cells()
            .into_iter()
            .take(2)
            .collect::<Vec<_>>()
    } else {
        dma_latte::figures::disagg::default_cells()
    });
    print!("{}", dma_latte::figures::disagg::render(&dg));
    dma_latte::figures::disagg::to_csv(&dg)
        .write(format!("{out}/disagg.csv"))
        .unwrap();

    println!("\n# Fig 16 — TTFT");
    let f16 = if quick {
        serving::fig16(&[&zoo::QWEN25_0_5B, &zoo::LLAMA31_8B], &[4096])
    } else {
        serving::fig16_default()
    };
    print!("{}", serving::render_fig16(&f16));
    serving::fig16_csv(&f16).write(format!("{out}/fig16_ttft.csv")).unwrap();

    println!("\n# Fig 17 — throughput");
    let n = if quick { 64 } else { 400 };
    let rows: Vec<_> = (if quick {
        vec![&zoo::QWEN25_0_5B, &zoo::QWEN25_7B]
    } else {
        ALL_MODELS.to_vec()
    })
    .into_iter()
    .map(|m| serving::throughput(m, 1024, n, 32, 1.0))
    .collect();
    print!("{}", serving::render_fig17(&rows));
    serving::fig17_csv(&rows).write(format!("{out}/fig17_throughput.csv")).unwrap();

    println!("\nCSV written under {out}/");
}

fn cmd_ttft(args: &Args) {
    let prefill: u64 = args.get_num("prefill", 4096);
    let rows = serving::fig16(ALL_MODELS, &[prefill]);
    print!("{}", serving::render_fig16(&rows));
}

fn cmd_throughput(args: &Args) {
    let n: u64 = args.get_num("requests", 200);
    let hit: f64 = args.get_num("hit", 1.0);
    let rows: Vec<_> = ALL_MODELS
        .iter()
        .map(|m| serving::throughput(m, 1024, n, 32, hit))
        .collect();
    print!("{}", serving::render_fig17(&rows));
}

fn cmd_trace(args: &Args) {
    use dma_latte::cluster::{
        run_hier, run_hier_ar, run_hier_rs, select_allreduce, select_cluster, ClusterChoice,
        ClusterKind, ClusterTopology, HierRunOptions, InterSchedule,
    };
    use dma_latte::coordinator::{Request, ServeConfig, VirtualEngine};
    use dma_latte::kvcache::fetch::FetchImpl;
    use dma_latte::obs::{attribute, record, write_chrome_trace};

    let out = args.get("out", "results");
    std::fs::create_dir_all(&out).expect("mkdir results");

    let (label, wall_ns, trace) = if args.has("serve") {
        let n: u64 = args.get_num("requests", 24);
        let nodes: usize = args.get_num("nodes", 1);
        let prefill: u64 = args.get_num("prefill", 512);
        let decode: u64 = args.get_num("decode", 16);
        let model = &zoo::QWEN25_0_5B;
        let mut cfg = ServeConfig::new(model, FetchImpl::DmaB2b);
        cfg.num_nodes = nodes;
        let layout = dma_latte::kvcache::BlockLayout::new(model, cfg.block_tokens);
        cfg.gpu_blocks = layout.blocks_for(prefill + decode) * (cfg.max_batch as u64 + 8);
        record::start();
        let mut eng = VirtualEngine::new(cfg);
        for i in 0..n {
            eng.submit(Request::new(i, prefill, decode, 0), true);
        }
        let m = eng.run_to_completion().clone();
        let trace = record::finish().expect("recorder installed above");
        println!(
            "# serving trace — {} · {n} reqs · {nodes} node(s)",
            model.name
        );
        println!("{}", m.summary());
        ("serving".to_string(), m.wall_ns, trace)
    } else {
        let kind = match args.get("kind", "allreduce").as_str() {
            "allgather" | "all-gather" | "ag" => ClusterKind::AllGather,
            "alltoall" | "all-to-all" | "aa" => ClusterKind::AllToAll,
            "reduce-scatter" | "reduce_scatter" | "reducescatter" | "rs" => {
                ClusterKind::ReduceScatter
            }
            "allreduce" | "all-reduce" | "ar" => ClusterKind::AllReduce,
            other => {
                eprintln!("bad --kind {other:?} (need allgather|alltoall|reduce-scatter|allreduce)");
                std::process::exit(2);
            }
        };
        let nodes: usize = args.get_num("nodes", 2);
        if !(1..=dma_latte::cluster::hier::MAX_NODES).contains(&nodes) {
            eprintln!(
                "bad --nodes {nodes} (need 1..={})",
                dma_latte::cluster::hier::MAX_NODES
            );
            std::process::exit(2);
        }
        let schedule = match args.get("schedule", "auto").as_str() {
            "auto" => None,
            "sequential" | "seq" => Some(InterSchedule::Sequential),
            "pipelined" | "pipe" => Some(InterSchedule::Pipelined),
            "overlapped" | "overlap" | "ovl" => Some(InterSchedule::Overlapped),
            other => {
                eprintln!("bad --schedule {other:?} (need auto|sequential|pipelined|overlapped)");
                std::process::exit(2);
            }
        };
        let topo = ClusterTopology::mi300x(nodes);
        let size = topo.pad_size(parse_size(&args.get("size", "1M")).expect("bad --size"));
        let opts = HierRunOptions {
            trace: true,
            ..Default::default()
        };
        let force = |mut c: ClusterChoice| {
            if nodes > 1 {
                if let Some(s) = schedule {
                    c.inter = s;
                }
            }
            c
        };
        record::start();
        let res = match kind {
            ClusterKind::AllGather | ClusterKind::AllToAll => {
                let choice = force(select_cluster(kind, &topo, size));
                run_hier(kind.transport(), choice, &topo, size, &opts)
            }
            ClusterKind::ReduceScatter => {
                let choice = force(select_cluster(kind, &topo, size));
                run_hier_rs(choice, &topo, size, &opts)
            }
            ClusterKind::AllReduce => {
                let (rs, ag) = select_allreduce(&topo, size);
                run_hier_ar(force(rs), force(ag), &topo, size, &opts)
            }
        };
        let trace = record::finish().expect("recorder installed above");
        println!(
            "# collective trace — {} · {} · {nodes} node(s) · {} ns",
            kind.name(),
            dma_latte::util::bytes::fmt_size(size),
            res.latency_ns
        );
        (
            format!("{}_{}n", kind.name(), nodes),
            res.latency_ns,
            trace,
        )
    };

    let attr = attribute(&trace);
    print!("{}", attr.render());
    if attr.total() == wall_ns {
        println!(
            "attribution-sum-check: OK ({} ns attributed == {} ns end-to-end)",
            attr.total(),
            wall_ns
        );
    } else {
        println!(
            "attribution-sum-check: FAIL ({} ns attributed != {} ns end-to-end)",
            attr.total(),
            wall_ns
        );
        std::process::exit(1);
    }
    let path = format!("{out}/trace_{label}.json");
    std::fs::write(&path, write_chrome_trace(&trace)).expect("write trace json");
    println!("perfetto timeline: {path} ({} spans)", trace.spans.len());
}

fn cmd_serve(args: &Args) {
    use dma_latte::cluster::FaultSpec;
    use dma_latte::coordinator::config::DegradePolicy;
    use dma_latte::coordinator::workload::{parse_tenants, ArrivalProcess};
    use dma_latte::figures::serving_load as sl;

    let kind = args.get("workload", "poisson");
    if ArrivalProcess::for_kind(&kind, 1.0, 1.0).is_none() {
        eprintln!("bad --workload {kind:?} (need poisson|bursty|trace)");
        std::process::exit(2);
    }
    let nodes: usize = args.get_num("nodes", 1);
    let requests: u64 = args.get_num("requests", 512);
    let seed: u64 = args.get_num("seed", 7);
    let overlap = !args.has("no-overlap");
    let classes = match parse_tenants(&args.get("tenants", "default")) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("bad --tenants: {e}");
            std::process::exit(2);
        }
    };
    let model = &zoo::QWEN25_0_5B;
    let mut cfg = sl::serve_config(model, nodes, overlap);
    if let Some(spec) = args.opt("faults") {
        let fs = match FaultSpec::preset(spec) {
            Some(p) => p,
            None => match FaultSpec::parse(spec) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("bad --faults: {e}");
                    std::process::exit(2);
                }
            },
        };
        cfg.faults = Some(fs);
    }
    match args.get("degrade", "aware").as_str() {
        "aware" => {}
        "blind" => cfg.degrade = DegradePolicy::blind(),
        other => {
            eprintln!("bad --degrade {other:?} (need aware|blind)");
            std::process::exit(2);
        }
    }
    if let Some(spec) = args.opt("disagg") {
        use dma_latte::figures::disagg as figd;
        let d = match dma_latte::coordinator::DisaggSpec::parse(spec) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("bad --disagg: {e}");
                std::process::exit(2);
            }
        };
        // The split sizes the world itself: P prefill + D decode nodes
        // (--nodes is superseded).
        cfg = cfg.with_disagg(d);
        println!(
            "# disaggregated {}:{} — colocated vs blocking vs layer-pipelined migration",
            d.prefill_nodes, d.decode_nodes
        );
        let cell = |workload, prompt_tokens, decode_tokens| figd::DisaggCell {
            model,
            prefill_nodes: d.prefill_nodes,
            decode_nodes: d.decode_nodes,
            workload,
            prompt_tokens,
            decode_tokens,
            requests: 16,
        };
        let pts = figd::sweep(&[
            cell("prefill_heavy", 4096, 8),
            cell("decode_heavy", 512, 128),
        ]);
        print!("{}", figd::render(&pts));
        let out = args.get("out", "results");
        std::fs::create_dir_all(&out).expect("mkdir results");
        let path = format!("{out}/disagg.csv");
        figd::to_csv(&pts).write(&path).expect("write disagg.csv");
        println!("csv: {path}\n");
    }

    let parse_rate = |tok: &str| -> f64 {
        match tok.trim().parse::<f64>() {
            Ok(r) if r > 0.0 => r,
            _ => {
                eprintln!("bad --rate entry {tok:?} (need a positive req/s number)");
                std::process::exit(2);
            }
        }
    };
    // A single --rate anchors a sweep; a comma list is used verbatim; no
    // --rate sweeps around the measured closed-loop capacity.
    const SWEEP: [f64; 7] = [0.25, 0.5, 0.75, 1.0, 1.25, 1.5, 2.0];
    let rates: Vec<f64> = match args.opt("rate") {
        Some(spec) if spec.contains(',') => spec.split(',').map(parse_rate).collect(),
        Some(one) => {
            let r = parse_rate(one);
            SWEEP.iter().map(|m| m * r).collect()
        }
        None => {
            let probe = requests.clamp(32, 128);
            let cap = sl::estimate_capacity_rps(&cfg, &classes, probe, seed);
            println!("# closed-loop capacity ≈ {cap:.0} req/s — sweeping 0.25–2.0×");
            SWEEP.iter().map(|m| m * cap).collect()
        }
    };

    println!(
        "# serving load — {} · {kind} · {nodes} node(s) · {requests} reqs/point · overlap {} · {} points across host threads",
        model.name,
        if overlap { "on" } else { "off" },
        rates.len(),
    );
    let pts = sl::sweep(&cfg, &classes, &kind, &rates, requests, seed);
    print!("{}", sl::render(&pts));
    println!("\nper-class breakdown:");
    print!("{}", sl::render_classes(&pts));
    let out = args.get("out", "results");
    let path = format!("{out}/serving_load.csv");
    sl::to_csv(&pts).write(&path).expect("write serving_load.csv");
    println!("\ncsv: {path}");
}

fn cmd_faults(args: &Args) {
    use dma_latte::figures::faults as ff;

    let nodes: usize = args.get_num("nodes", 2);
    if !(1..=dma_latte::cluster::hier::MAX_NODES).contains(&nodes) {
        eprintln!(
            "bad --nodes {nodes} (need 1..={})",
            dma_latte::cluster::hier::MAX_NODES
        );
        std::process::exit(2);
    }
    let requests: u64 = args.get_num("requests", 256);
    let seed: u64 = args.get_num("seed", 7);
    let model = &zoo::QWEN25_0_5B;

    println!(
        "# fault scenarios — {} · {nodes} node(s) · {requests} reqs/run · seed {seed}",
        model.name
    );
    let rows = ff::fig_faults(model, nodes, requests, seed);
    print!("{}", ff::render(&rows));
    if ff::healthy_replay_ok(model, nodes, requests.min(64), seed) {
        println!("faults: healthy-replay OK");
    } else {
        println!("faults: healthy-replay FAIL");
        std::process::exit(1);
    }
    let out = args.get("out", "results");
    std::fs::create_dir_all(&out).expect("mkdir results");
    let path = format!("{out}/faults.csv");
    ff::to_csv(&rows).write(&path).expect("write faults.csv");
    println!("csv: {path}");
}

fn cmd_selftest() {
    use dma_latte::collectives::{run_collective, select_variant, RunOptions};
    use dma_latte::sim::SimConfig;
    let opts = RunOptions {
        sim: SimConfig::mi300x(),
        verify: true,
    };
    for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
        for size in [8 * KB, 256 * KB] {
            let v = select_variant(kind, size);
            let r = run_collective(kind, v, size, &opts);
            assert_eq!(r.verified, Some(true));
            println!(
                "{} {:>6} {} ok ({} ns)",
                kind.name(),
                dma_latte::util::bytes::fmt_size(size),
                v.name(),
                r.latency_ns
            );
        }
    }
    println!("selftest ok");
}

fn main() {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("sweep") => cmd_sweep(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("figures") => cmd_figures(&args),
        Some("breakdown") => print!("{}", breakdown::render(&breakdown::fig7())),
        Some("power") => {
            print!("{}", power::render(&power::fig15(None)));
            println!("\n# cluster power — KV migration over the NIC (256 blocks)");
            print!("{}", power::render_migration(&power::migration_power(256)));
        }
        Some("ttft") => cmd_ttft(&args),
        Some("throughput") => cmd_throughput(&args),
        Some("serve") => cmd_serve(&args),
        Some("faults") => cmd_faults(&args),
        Some("selftest") => cmd_selftest(),
        Some("trace") => cmd_trace(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown command {o:?}\n");
            }
            eprintln!(
                "usage: dma-latte <figures|sweep|cluster|breakdown|power|ttft|throughput|serve|faults|trace|selftest> [--flags]"
            );
            std::process::exit(2);
        }
    }
}
