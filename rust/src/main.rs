//! DMA-Latte CLI: figure regeneration, sweeps, and diagnostics.
//!
//! ```text
//! dma-latte figures   [--out results/] [--quick]   # all paper figures
//! dma-latte sweep     [--kind allgather|alltoall] [--max 4G]
//! dma-latte cluster   [--kind allgather|alltoall|reduce-scatter|allreduce]
//!                     [--nodes 1,2,4] [--max 1G]
//!                     [--schedule auto|sequential|pipelined|overlapped]
//!                     # hierarchical scaling (overlapped = chunk-granular
//!                     # fused all-reduce; auto lets the selector pick)
//! dma-latte breakdown                              # Fig. 7
//! dma-latte power                                  # Fig. 15
//! dma-latte ttft      [--prefill 4096]             # Fig. 16
//! dma-latte throughput [--requests 200] [--hit 1.0]# Fig. 17
//! dma-latte selftest                               # quick invariants
//! ```

use dma_latte::cli::Args;
use dma_latte::collectives::CollectiveKind;
use dma_latte::figures::{breakdown, cluster as figcl, collectives as figc, power, serving};
use dma_latte::models::{zoo, ALL_MODELS};
use dma_latte::util::bytes::{parse_size, size_sweep, GB, KB, MB};

fn cmd_sweep(args: &Args) {
    let kind = match args.get("kind", "allgather").as_str() {
        "alltoall" => CollectiveKind::AllToAll,
        _ => CollectiveKind::AllGather,
    };
    let max = parse_size(&args.get("max", "4G")).expect("bad --max");
    let rows = figc::sweep(kind, Some(size_sweep(KB, max, 2)));
    print!("{}", figc::render(kind, &rows));
    println!("\nbest per range:");
    for (lo, hi, v) in figc::best_table(&rows) {
        println!(
            "  {:>6}..{:>6} -> {}",
            dma_latte::util::bytes::fmt_size(lo),
            dma_latte::util::bytes::fmt_size(hi),
            v.name()
        );
    }
}

fn cmd_cluster(args: &Args) {
    let kind = match args.get("kind", "allgather").as_str() {
        "alltoall" => dma_latte::cluster::ClusterKind::AllToAll,
        "reduce-scatter" | "reduce_scatter" | "reducescatter" | "rs" => {
            dma_latte::cluster::ClusterKind::ReduceScatter
        }
        "allreduce" | "all-reduce" | "ar" => dma_latte::cluster::ClusterKind::AllReduce,
        _ => dma_latte::cluster::ClusterKind::AllGather,
    };
    let max = parse_size(&args.get("max", "1G")).expect("bad --max");
    let spec = args.get("nodes", "1,2,4");
    let mut nodes = Vec::new();
    for tok in spec.split(',') {
        match tok.trim().parse::<usize>() {
            Ok(n) if (1..=dma_latte::cluster::hier::MAX_NODES).contains(&n) => nodes.push(n),
            _ => {
                eprintln!(
                    "bad --nodes entry {tok:?} (need integers in 1..={})",
                    dma_latte::cluster::hier::MAX_NODES
                );
                std::process::exit(2);
            }
        }
    }
    let schedule = match args.get("schedule", "auto").as_str() {
        "auto" => None,
        "sequential" | "seq" => Some(dma_latte::cluster::InterSchedule::Sequential),
        "pipelined" | "pipe" => Some(dma_latte::cluster::InterSchedule::Pipelined),
        "overlapped" | "overlap" | "ovl" => Some(dma_latte::cluster::InterSchedule::Overlapped),
        other => {
            eprintln!("bad --schedule {other:?} (need auto|sequential|pipelined|overlapped)");
            std::process::exit(2);
        }
    };
    // Sweep sizes are rounded up per cell to a multiple of that cell's
    // world size by figures::cluster::scaling.
    let rows = figcl::scaling_with_schedule(kind, &nodes, Some(size_sweep(KB, max, 2)), schedule);
    print!("{}", figcl::render(kind, &rows));
}

fn cmd_figures(args: &Args) {
    let out = args.get("out", "results");
    let quick = args.has("quick");
    let max = if quick { 64 * MB } else { 4 * GB };
    std::fs::create_dir_all(&out).expect("mkdir results");

    println!("# Fig 1/13 + Table 2 — all-gather");
    let ag = figc::sweep(CollectiveKind::AllGather, Some(size_sweep(KB, max, 2)));
    print!("{}", figc::render(CollectiveKind::AllGather, &ag));
    figc::to_csv(CollectiveKind::AllGather, &ag)
        .write(format!("{out}/fig13_allgather.csv"))
        .unwrap();

    println!("\n# Fig 14 + Table 3 — all-to-all");
    let aa = figc::sweep(CollectiveKind::AllToAll, Some(size_sweep(KB, max, 2)));
    print!("{}", figc::render(CollectiveKind::AllToAll, &aa));
    figc::to_csv(CollectiveKind::AllToAll, &aa)
        .write(format!("{out}/fig14_alltoall.csv"))
        .unwrap();

    println!("\n# Cluster scaling — hierarchical AG/AA/RS/AR over 1/2/4 nodes");
    let cl_sizes = Some(size_sweep(KB, if quick { 16 * MB } else { GB }, 4));
    for kind in [
        dma_latte::cluster::ClusterKind::AllGather,
        dma_latte::cluster::ClusterKind::AllToAll,
        dma_latte::cluster::ClusterKind::ReduceScatter,
        dma_latte::cluster::ClusterKind::AllReduce,
    ] {
        let rows = figcl::scaling(kind, &[1, 2, 4], cl_sizes.clone());
        print!("{}", figcl::render(kind, &rows));
        figcl::to_csv(&rows)
            .write(format!("{out}/cluster_{}.csv", kind.name()))
            .unwrap();
    }

    println!("\n# Fig 7 — single-copy latency breakdown");
    let bd = breakdown::fig7();
    print!("{}", breakdown::render(&bd));
    breakdown::to_csv(&bd).write(format!("{out}/fig7_breakdown.csv")).unwrap();

    println!("\n# Fig 15 — power");
    let pw = power::fig15(if quick {
        Some(vec![64 * KB, MB, 16 * MB, 64 * MB])
    } else {
        None
    });
    print!("{}", power::render(&pw));
    power::to_csv(&pw).write(format!("{out}/fig15_power.csv")).unwrap();

    println!("\n# Fig 16 — TTFT");
    let f16 = if quick {
        serving::fig16(&[&zoo::QWEN25_0_5B, &zoo::LLAMA31_8B], &[4096])
    } else {
        serving::fig16_default()
    };
    print!("{}", serving::render_fig16(&f16));
    serving::fig16_csv(&f16).write(format!("{out}/fig16_ttft.csv")).unwrap();

    println!("\n# Fig 17 — throughput");
    let n = if quick { 64 } else { 400 };
    let rows: Vec<_> = (if quick {
        vec![&zoo::QWEN25_0_5B, &zoo::QWEN25_7B]
    } else {
        ALL_MODELS.to_vec()
    })
    .into_iter()
    .map(|m| serving::throughput(m, 1024, n, 32, 1.0))
    .collect();
    print!("{}", serving::render_fig17(&rows));
    serving::fig17_csv(&rows).write(format!("{out}/fig17_throughput.csv")).unwrap();

    println!("\nCSV written under {out}/");
}

fn cmd_ttft(args: &Args) {
    let prefill: u64 = args.get_num("prefill", 4096);
    let rows = serving::fig16(ALL_MODELS, &[prefill]);
    print!("{}", serving::render_fig16(&rows));
}

fn cmd_throughput(args: &Args) {
    let n: u64 = args.get_num("requests", 200);
    let hit: f64 = args.get_num("hit", 1.0);
    let rows: Vec<_> = ALL_MODELS
        .iter()
        .map(|m| serving::throughput(m, 1024, n, 32, hit))
        .collect();
    print!("{}", serving::render_fig17(&rows));
}

fn cmd_selftest() {
    use dma_latte::collectives::{run_collective, select_variant, RunOptions};
    use dma_latte::sim::SimConfig;
    let opts = RunOptions {
        sim: SimConfig::mi300x(),
        verify: true,
    };
    for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
        for size in [8 * KB, 256 * KB] {
            let v = select_variant(kind, size);
            let r = run_collective(kind, v, size, &opts);
            assert_eq!(r.verified, Some(true));
            println!(
                "{} {:>6} {} ok ({} ns)",
                kind.name(),
                dma_latte::util::bytes::fmt_size(size),
                v.name(),
                r.latency_ns
            );
        }
    }
    println!("selftest ok");
}

fn main() {
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("sweep") => cmd_sweep(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("figures") => cmd_figures(&args),
        Some("breakdown") => print!("{}", breakdown::render(&breakdown::fig7())),
        Some("power") => print!("{}", power::render(&power::fig15(None))),
        Some("ttft") => cmd_ttft(&args),
        Some("throughput") => cmd_throughput(&args),
        Some("selftest") => cmd_selftest(),
        other => {
            if let Some(o) = other {
                eprintln!("unknown command {o:?}\n");
            }
            eprintln!(
                "usage: dma-latte <figures|sweep|cluster|breakdown|power|ttft|throughput|selftest> [--flags]"
            );
            std::process::exit(2);
        }
    }
}
