//! Analytic latency + power model for CU-based AG/AA on the 8-GPU platform.

use crate::collectives::CollectiveKind;
use crate::sim::power::Activity;
use crate::sim::topology::Topology;

/// Calibrated RCCL model.
#[derive(Debug, Clone)]
pub struct RcclModel {
    /// Kernel launch with hipGraph capture (amortized), ns.
    pub t_launch: f64,
    /// Extra algorithm setup for all-to-all (less-optimized path), ns.
    pub t_aa_extra: f64,
    /// Per-peer protocol cost inside the kernel (flag exchange, chunk
    /// bookkeeping), ns.
    pub t_per_peer: f64,
    /// Fraction of raw link bandwidth a CU-driven AG sustains
    /// (payload + protocol metadata → below DMA's 0.97; paper §5.2.4).
    pub ag_link_eff: f64,
    /// Same for AA (harder access pattern).
    pub aa_link_eff: f64,
    /// CU occupancy while the collective runs (power model):
    /// fraction of XCD capacity used at bandwidth-bound sizes.
    pub cu_util_large: f64,
    /// CU occupancy at latency-bound sizes (few CTAs resident).
    pub cu_util_small: f64,
}

impl Default for RcclModel {
    fn default() -> Self {
        RcclModel {
            t_launch: 4_100.0,
            t_aa_extra: 2_400.0,
            t_per_peer: 70.0,
            ag_link_eff: 0.85,
            aa_link_eff: 0.80,
            cu_util_large: 0.85,
            cu_util_small: 0.22,
        }
    }
}

impl RcclModel {
    /// Collective latency in ns for buffer `size` bytes per GPU on `topo`.
    pub fn latency_ns(&self, kind: CollectiveKind, topo: &Topology, size: u64) -> f64 {
        let n = topo.num_gpus as f64;
        let chunk = size as f64 / n;
        let link_bw = topo.gpu_fanout_bw() / (n - 1.0); // per-link bytes/ns
        let (eff, extra) = match kind {
            CollectiveKind::AllGather => (self.ag_link_eff, 0.0),
            CollectiveKind::AllToAll => (self.aa_link_eff, self.t_aa_extra),
        };
        // Each GPU receives (n-1) chunks over (n-1) links in parallel.
        let data = chunk / (link_bw * eff);
        self.t_launch + extra + self.t_per_peer * (n - 1.0) + data
    }

    /// CU utilization at this size (power model input).
    pub fn cu_util(&self, size: u64) -> f64 {
        // Smooth ramp between the latency-bound and bandwidth-bound regimes.
        // Centered near 16 MB: the paper observes RCCL "stresses both CUs
        // and memory resources less" at latency-bound sizes, with the full
        // power gap opening only at ≥64MB (§5.2.9).
        let x = (size as f64 / (16 << 20) as f64).ln().max(-8.0).min(8.0);
        let s = 1.0 / (1.0 + (-0.9 * x).exp());
        self.cu_util_small + (self.cu_util_large - self.cu_util_small) * s
    }

    /// Power-model activity for a collective window (per GPU normalized).
    ///
    /// CU collectives move each chunk through HBM on both ends AND touch
    /// intermediate protocol buffers; DMA's direct reads/writes skip that
    /// (paper credits DMA's ~32% power saving to idle XCDs, §5.2.9).
    pub fn activity(&self, kind: CollectiveKind, topo: &Topology, size: u64) -> Activity {
        let dur = self.latency_ns(kind, topo, size);
        let n = topo.num_gpus as f64;
        let chunk = size as f64 / n;
        // Per-GPU: (n-1) chunks sent over links; HBM sees the source reads,
        // the destination writes, and ~25% protocol/intermediate traffic.
        let wire = chunk * (n - 1.0);
        Activity {
            duration_ns: dur,
            engine_busy_ns: 0.0,
            engines_used: 0,
            cu_busy_ns: dur * self.cu_util(size),
            hbm_bytes: wire * 2.25,
            link_bytes: wire,
            nic_bytes: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::{GB, KB, MB};

    #[test]
    fn small_sizes_are_launch_bound() {
        let m = RcclModel::default();
        let topo = Topology::mi300x_platform();
        let l1k = m.latency_ns(CollectiveKind::AllGather, &topo, KB);
        let l64k = m.latency_ns(CollectiveKind::AllGather, &topo, 64 * KB);
        // Flat-ish region: 64KB within 2× of 1KB.
        assert!(l64k < 2.0 * l1k, "l1k={l1k} l64k={l64k}");
        assert!(l1k > 2_500.0 && l1k < 6_000.0, "l1k={l1k}");
    }

    #[test]
    fn large_sizes_are_bandwidth_bound() {
        let m = RcclModel::default();
        let topo = Topology::mi300x_platform();
        let l = m.latency_ns(CollectiveKind::AllGather, &topo, GB);
        // (1GB/8) / (64 B/ns × 0.85) ≈ 2.47 ms
        assert!((l - 2.47e6).abs() / 2.47e6 < 0.05, "l={l}");
    }

    #[test]
    fn aa_slower_than_ag() {
        let m = RcclModel::default();
        let topo = Topology::mi300x_platform();
        for size in [KB, MB, 64 * MB] {
            assert!(
                m.latency_ns(CollectiveKind::AllToAll, &topo, size)
                    > m.latency_ns(CollectiveKind::AllGather, &topo, size)
            );
        }
    }

    #[test]
    fn cu_util_ramps_with_size() {
        let m = RcclModel::default();
        assert!(m.cu_util(4 * KB) < 0.45);
        assert!(m.cu_util(256 * MB) > 0.8);
        assert!(m.cu_util(MB) > m.cu_util(64 * KB));
    }

    #[test]
    fn activity_reflects_cu_occupancy() {
        let m = RcclModel::default();
        let topo = Topology::mi300x_platform();
        let a = m.activity(CollectiveKind::AllGather, &topo, 256 * MB);
        assert!(a.cu_busy_ns > 0.8 * a.duration_ns);
        assert_eq!(a.engines_used, 0);
        assert!(a.hbm_bytes > a.link_bytes);
    }
}
