//! RCCL stand-in: calibrated analytic model of CU-driven collectives.
//!
//! The paper uses RCCL (with MSCCL/MSCCL++ algorithms and hipGraph launch)
//! purely as the measured baseline curve that DMA collectives are compared
//! against (Figs. 1/13/14/15). We model it analytically — launch overhead +
//! per-peer protocol cost + bandwidth term at CU-collective efficiency —
//! with constants calibrated against public RCCL behaviour so the paper's
//! DMA/CU ratios emerge (see `rust/tests/calibration.rs`).

pub mod model;

pub use model::RcclModel;
