//! Cluster topology: N single-node [`Topology`] instances composed with
//! directed cross-node NIC links, plus the global-rank ↔ (node, local GPU)
//! mapping used by the hierarchical planners.

use std::collections::HashMap;

use crate::sim::topology::{LinkIdx, NodeId, Topology};

/// Global rank across the whole cluster: `node * gpus_per_node + local_gpu`.
pub type GlobalRank = u32;

/// NIC / RDMA link model parameters, uniform across the cluster.
///
/// See `cluster/mod.rs` for the modeling assumptions (full duplex, no
/// congestion, port-serialized payloads).
#[derive(Debug, Clone)]
pub struct NicModel {
    /// Per-direction bandwidth in bytes/ns (1 GB/s == 1 byte/ns, matching
    /// the xGMI convention). Default 50.0 ≈ 400 Gb/s RoCE.
    pub bw_bytes_per_ns: f64,
    /// One-way base latency per message: propagation + NIC processing +
    /// remote write posting, ns.
    pub t_latency: f64,
    /// Host/NIC cost to post one RDMA work request, ns.
    pub t_post_per_msg: f64,
}

impl Default for NicModel {
    fn default() -> Self {
        NicModel {
            bw_bytes_per_ns: 50.0,
            t_latency: 2_000.0,
            t_post_per_msg: 450.0,
        }
    }
}

impl NicModel {
    /// Pure payload (wire) time for `bytes` at the link bandwidth.
    pub fn payload_ns(&self, bytes: u64) -> f64 {
        bytes as f64 / self.bw_bytes_per_ns
    }

    /// Single message of `bytes` to one peer: post + payload + latency.
    pub fn message_ns(&self, bytes: u64) -> f64 {
        self.t_post_per_msg + self.payload_ns(bytes) + self.t_latency
    }

    /// Arrival time (relative to the leg start) of the `pos`-th message
    /// (1-based) when one rank streams equal-size messages to distinct
    /// peers through its single full-duplex port: posts and payloads
    /// serialize on the port, propagation pipelines.
    pub fn arrival_ns(&self, pos: usize, bytes_per_peer: u64) -> f64 {
        pos as f64 * (self.t_post_per_msg + self.payload_ns(bytes_per_peer)) + self.t_latency
    }

    /// Total time for one rank to deliver `bytes_per_peer` to each of
    /// `peers` peers (the arrival of the last message).
    pub fn leg_ns(&self, peers: usize, bytes_per_peer: u64) -> f64 {
        if peers == 0 {
            0.0
        } else {
            self.arrival_ns(peers, bytes_per_peer)
        }
    }
}

/// A directed cross-node NIC link between two global ranks' ports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NicLink {
    pub src: GlobalRank,
    pub dst: GlobalRank,
}

/// Dense NIC link index.
pub type NicLinkIdx = usize;

/// How two global ranks are connected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RankPath {
    /// Same node: an xGMI link inside that node's [`Topology`].
    Intra(LinkIdx),
    /// Different nodes: a directed NIC link.
    Nic(NicLinkIdx),
}

/// N single-node platforms joined by a full-mesh of directed NIC links
/// (one per ordered cross-node pair of global ranks).
#[derive(Debug, Clone)]
pub struct ClusterTopology {
    nodes: Vec<Topology>,
    pub nic: NicModel,
    links: Vec<NicLink>,
    index: HashMap<(GlobalRank, GlobalRank), NicLinkIdx>,
}

impl ClusterTopology {
    /// Compose `nodes` (must be homogeneous in GPU count — the hierarchical
    /// planners assume identical intra-node shapes) with `nic` links.
    pub fn new(nodes: Vec<Topology>, nic: NicModel) -> Self {
        assert!(!nodes.is_empty(), "cluster needs at least one node");
        let g = nodes[0].num_gpus;
        assert!(
            nodes.iter().all(|t| t.num_gpus == g),
            "heterogeneous GPU counts are not supported"
        );
        let n = nodes.len();
        let world = n as u32 * g as u32;
        let mut links = Vec::new();
        let mut index = HashMap::new();
        for src in 0..world {
            for dst in 0..world {
                // Cross-node pairs only: intra-node pairs ride xGMI.
                if src != dst && src / g as u32 != dst / g as u32 {
                    index.insert((src, dst), links.len());
                    links.push(NicLink { src, dst });
                }
            }
        }
        ClusterTopology {
            nodes,
            nic,
            links,
            index,
        }
    }

    /// `num_nodes` copies of `node` with the given NIC model. A degenerate
    /// `num_nodes == 0` is clamped to a single node: callers sizing
    /// deployments from config should get the flat single-node fall-through
    /// (no NIC links), not a panic.
    pub fn homogeneous(num_nodes: usize, node: Topology, nic: NicModel) -> Self {
        Self::new(vec![node; num_nodes.max(1)], nic)
    }

    /// `num_nodes` MI300X platforms over default 400 Gb/s RoCE links
    /// (clamped to ≥ 1 node like [`ClusterTopology::homogeneous`]).
    pub fn mi300x(num_nodes: usize) -> Self {
        Self::homogeneous(num_nodes, Topology::mi300x_platform(), NicModel::default())
    }

    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// GPUs per node.
    pub fn gpus_per_node(&self) -> u8 {
        self.nodes[0].num_gpus
    }

    /// Total GPU count across the cluster.
    pub fn world_size(&self) -> usize {
        self.nodes.len() * self.gpus_per_node() as usize
    }

    /// Round `bytes` up to a positive multiple of the world size (the
    /// collective chunking requirement shared by the serving path, the
    /// figures, and the hierarchical executors' size asserts).
    pub fn pad_size(&self, bytes: u64) -> u64 {
        let w = self.world_size() as u64;
        bytes.div_ceil(w).max(1) * w
    }

    /// Single-node topology of node `k`.
    pub fn node(&self, k: usize) -> &Topology {
        &self.nodes[k]
    }

    /// (node, local GPU) → global rank.
    pub fn global_rank(&self, node: usize, gpu: u8) -> GlobalRank {
        assert!(node < self.num_nodes() && gpu < self.gpus_per_node());
        (node * self.gpus_per_node() as usize) as u32 + gpu as u32
    }

    /// Global rank → (node, local GPU).
    pub fn locate(&self, r: GlobalRank) -> (usize, u8) {
        assert!((r as usize) < self.world_size(), "rank {r} out of range");
        let g = self.gpus_per_node() as u32;
        ((r / g) as usize, (r % g) as u8)
    }

    /// Directed NIC link between two cross-node global ranks.
    pub fn try_nic_link(&self, src: GlobalRank, dst: GlobalRank) -> Option<NicLinkIdx> {
        self.index.get(&(src, dst)).copied()
    }

    /// NIC link metadata by dense index.
    pub fn nic_link(&self, idx: NicLinkIdx) -> &NicLink {
        &self.links[idx]
    }

    /// Total number of directed NIC links (`world² − world − nodes·gpus²
    /// + nodes·gpus`, i.e. every ordered cross-node rank pair).
    pub fn num_nic_links(&self) -> usize {
        self.links.len()
    }

    /// How global ranks `a` and `b` are connected; `None` when `a == b`.
    /// Same-node pairs resolve through [`Topology::try_link_index`] —
    /// cross-node pairs have no intra-node link and route over the NIC.
    pub fn path(&self, a: GlobalRank, b: GlobalRank) -> Option<RankPath> {
        if a == b {
            return None;
        }
        let (na, ga) = self.locate(a);
        let (nb, gb) = self.locate(b);
        if na == nb {
            self.nodes[na]
                .try_link_index(NodeId::Gpu(ga), NodeId::Gpu(gb))
                .map(RankPath::Intra)
        } else {
            self.try_nic_link(a, b).map(RankPath::Nic)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_mapping_roundtrips() {
        let c = ClusterTopology::mi300x(4);
        assert_eq!(c.num_nodes(), 4);
        assert_eq!(c.gpus_per_node(), 8);
        assert_eq!(c.world_size(), 32);
        for r in 0..32u32 {
            let (n, g) = c.locate(r);
            assert_eq!(c.global_rank(n, g), r);
        }
        assert_eq!(c.locate(17), (2, 1));
    }

    #[test]
    fn nic_links_cover_cross_node_pairs_only() {
        let c = ClusterTopology::mi300x(2);
        // 16 ranks, 8 per node: 16·15 ordered pairs − 2·8·7 intra = 128.
        assert_eq!(c.num_nic_links(), 128);
        assert!(c.try_nic_link(0, 8).is_some());
        assert!(c.try_nic_link(0, 1).is_none()); // same node
        assert!(c.try_nic_link(3, 3).is_none());
        let l = c.nic_link(c.try_nic_link(0, 8).unwrap());
        assert_eq!((l.src, l.dst), (0, 8));
    }

    #[test]
    fn path_classifies_pairs() {
        let c = ClusterTopology::mi300x(2);
        assert!(matches!(c.path(0, 1), Some(RankPath::Intra(_))));
        assert!(matches!(c.path(0, 9), Some(RankPath::Nic(_))));
        assert_eq!(c.path(5, 5), None);
    }

    #[test]
    fn single_node_cluster_has_no_nic_links() {
        let c = ClusterTopology::mi300x(1);
        assert_eq!(c.num_nic_links(), 0);
        assert_eq!(c.world_size(), 8);
    }

    #[test]
    fn pad_size_rounds_to_world_multiple() {
        let c = ClusterTopology::mi300x(2); // world 16
        assert_eq!(c.pad_size(0), 16);
        assert_eq!(c.pad_size(1), 16);
        assert_eq!(c.pad_size(16), 16);
        assert_eq!(c.pad_size(17), 32);
    }

    #[test]
    fn zero_nodes_clamps_to_single_node() {
        let c = ClusterTopology::mi300x(0);
        assert_eq!(c.num_nodes(), 1);
        assert_eq!(c.num_nic_links(), 0);
    }

    #[test]
    fn all_engines_degraded_topology_composes() {
        // A fault-derated node — every sDMA engine but one stuck, xGMI at
        // the 1% derate floor — must still compose into a cluster and
        // answer sizing queries without panicking.
        let sick = Topology::custom(8, 1, 64.0 * 0.01, 64.0);
        let c = ClusterTopology::homogeneous(2, sick, NicModel::default());
        assert_eq!(c.world_size(), 16);
        assert_eq!(c.node(0).engines_per_gpu, 1);
        assert_eq!(c.pad_size(0), 16);
        assert!(c.node(0).gpu_fanout_bw() > 0.0);
    }

    #[test]
    fn derated_nic_stays_finite_on_zero_bytes() {
        // Zero-byte collectives over a near-dead NIC: the model must
        // produce finite, latency-dominated times, never NaN/inf.
        let m = NicModel {
            bw_bytes_per_ns: 50.0 * 0.01,
            ..NicModel::default()
        };
        assert_eq!(m.payload_ns(0), 0.0);
        assert!(m.message_ns(0).is_finite() && m.message_ns(0) >= m.t_latency);
        assert!(m.leg_ns(15, 0).is_finite());
        assert!(m.payload_ns(1 << 20).is_finite());
    }

    #[test]
    fn nic_model_timing() {
        let m = NicModel::default();
        // 1 MB at 50 B/ns ≈ 21 µs payload.
        assert!((m.payload_ns(1 << 20) - 20_971.52).abs() < 1e-6);
        assert!(m.message_ns(0) >= m.t_latency);
        // Port serialization: last of 3 arrives after 3 payloads.
        let one = m.arrival_ns(1, 1 << 20);
        let three = m.arrival_ns(3, 1 << 20);
        assert!(three > 2.9 * (one - m.t_latency));
        assert_eq!(m.leg_ns(0, 123), 0.0);
    }
}
