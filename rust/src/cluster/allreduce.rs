//! Hierarchical reduce-scatter / all-reduce over `nodes × gpus` ranks.
//!
//! DMA engines move bytes but cannot reduce (paper §2.1.1/§7), so the
//! cluster-level reduction collectives follow the same software-feasible
//! split as the flat [`crate::collectives::reduce_scatter`]: **DMA (and the
//! NIC) move chunks, CUs reduce**. The lowering is the standard two-level
//! recipe (hierarchical NCCL/RCCL algorithms):
//!
//! - **Reduce-scatter** (intra → reduce → inter → reduce): round `k'` is a
//!   flat intra-node **all-to-all** of the input block destined to node `k'`
//!   — RS "has a similar communication pattern as AA" (paper §2.1.1) — run
//!   through the existing [`CollectivePlan`] planners (`pcpy`/`swap`/`b2b`,
//!   ± prelaunch) rebased into the global layout, exactly like
//!   [`super::hier`]. After round `k'`, GPU `p` holds its node's `g`
//!   contribution chunks for destination rank `(k',p)` and a CU pass folds
//!   them into one **partial** chunk ([`cu_reduce_ns`]). Partials then ride
//!   the NIC to their destination node's same-local-rank GPU (`c` bytes per
//!   peer node — the minimal inter-node RS volume), where a final CU pass
//!   folds the `n` node partials into the reduced chunk at
//!   [`rs_result_base`]. A
//!   [`Pipelined`](super::InterSchedule::Pipelined) schedule streams each
//!   partial as its round's reduction completes; a
//!   [`Sequential`](super::InterSchedule::Sequential) schedule barriers
//!   the NIC leg behind the whole intra phase.
//! - **All-reduce** = reduce-scatter + the already-shipped hierarchical
//!   all-gather of the reduced chunks ([`super::hier::run_hier`]). Under a
//!   [`Sequential`](super::InterSchedule::Sequential) or
//!   [`Pipelined`](super::InterSchedule::Pipelined) phase choice the two
//!   phases compose behind a strict barrier; the
//!   [`Overlapped`](super::InterSchedule::Overlapped) schedule instead
//!   fuses them at chunk granularity ([`super::overlap`]) — the gather of
//!   chunk `k` launches the moment chunk `k`'s final CU reduction lands
//!   (a chunk's gather still cannot start before that chunk exists; the
//!   *other* chunks no longer wait for it).
//!
//! Chunk bookkeeping is verified `collectives::verify`-style: inputs carry
//! per-(rank, chunk) patterns, the transport rounds move real bytes on the
//! per-node DES, reductions are u8 wrapping adds (order-independent, so any
//! reduction tree matches the flat reference), the NIC legs move real bytes
//! between per-node memories, and `tests/prop_cluster.rs` checks the final
//! values byte-for-byte against the flat single-node reduce-scatter
//! ([`crate::collectives::reduce_scatter::plan_transport`] +
//! [`crate::collectives::reduce_scatter::reduce_staged`]) at the same world
//! size.

use crate::collectives::plan::{aa_out_base, CollectivePlan};
use crate::collectives::reduce_scatter::cu_reduce_ns;
use crate::collectives::verify::pattern;
use crate::collectives::{CollectiveKind, Strategy};
use crate::obs::{self, record, SpanKind, Track};
use crate::sim::clock::ns;
use crate::sim::topology::NodeId;
use crate::sim::{Sim, SimConfig, SimTime};

use std::sync::Arc;

use super::faults::FaultStats;
use super::hier::{
    aa_stage_base, cached_node_rounds, count_nic_messages, emit_nic_msg_spans, exchange_ag,
    nic_exchange_arrivals, nic_exchange_arrivals_faulted, nic_exchange_messages,
    nic_exchange_messages_faulted, prelaunch_t0, queue_node_scripts, run_hier, HierResult,
    HierRunOptions, MAX_NODES, ROUND_MARKS,
};
use super::selector::{ClusterChoice, InterSchedule};
use super::topology::ClusterTopology;

/// Base of the outbound partial region: the node-local partial sum destined
/// to node `k'` lives at `rs_partial_base(size) + k' * chunk`.
pub fn rs_partial_base(size: u64) -> u64 {
    aa_stage_base(size) + size + 256
}

/// Base of the inbox region: the partial received from node `k` lands at
/// `rs_inbox_base(size, chunk) + k * chunk` (slots sized for [`MAX_NODES`]).
pub fn rs_inbox_base(size: u64, chunk: u64) -> u64 {
    rs_partial_base(size) + MAX_NODES as u64 * chunk + 256
}

/// Offset of the final reduced chunk (`chunk` bytes) on every GPU.
pub fn rs_result_base(size: u64, chunk: u64) -> u64 {
    rs_inbox_base(size, chunk) + MAX_NODES as u64 * chunk + 256
}

/// CU pass 1 (functional): fold each round's `g` transported chunks into
/// the node-local partial for destination node `k2` at [`rs_partial_base`].
fn reduce_node_partials(
    sim: &mut Sim,
    node_idx: usize,
    num_nodes: usize,
    size: u64,
    chunk: u64,
    in_place: bool,
) {
    let gpn = sim.cfg.topology.num_gpus;
    // Offset (on GPU `gpu`) of the post-transport chunk contributed by
    // local source `q` for destination rank `(k2, gpu)` — where round
    // `k2`'s rebased all-to-all left it.
    let chunk_off = |k2: usize, q: u8, gpu: u8| -> u64 {
        let base = k2 as u64 * gpn as u64 * chunk;
        if in_place {
            // swap transposes inside the input block.
            base + q as u64 * chunk
        } else if k2 == node_idx {
            if q == gpu {
                // Out-of-place diagonal stays in the input (flat convention).
                base + q as u64 * chunk
            } else {
                aa_out_base(size) + base + q as u64 * chunk
            }
        } else {
            // Remote-destination blocks are fully staged (incl. the
            // diagonal, which build_node_rounds copies explicitly).
            aa_stage_base(size) + base + q as u64 * chunk
        }
    };
    for gpu in 0..gpn {
        for k2 in 0..num_nodes {
            let mut acc = vec![0u8; chunk as usize];
            for q in 0..gpn {
                let data = sim.memory.peek(NodeId::Gpu(gpu), chunk_off(k2, q, gpu), chunk);
                for (a, b) in acc.iter_mut().zip(data) {
                    *a = a.wrapping_add(b);
                }
            }
            sim.memory
                .poke(NodeId::Gpu(gpu), rs_partial_base(size) + k2 as u64 * chunk, &acc);
        }
    }
}

/// Inter leg (functional): every node's partial for destination `(k2, p)`
/// lands in node `k2` GPU `p`'s inbox slot indexed by the *source* node
/// (the own-node partial is copied into its own slot so the final fold is
/// uniform).
fn exchange_partials(sims: &mut [Sim], cluster: &ClusterTopology, size: u64, chunk: u64) {
    let n = sims.len();
    let gpn = cluster.gpus_per_node();
    let mut blocks: Vec<(usize, u8, u64, Vec<u8>)> = Vec::new();
    for (k, sim) in sims.iter().enumerate() {
        for g in 0..gpn {
            for k2 in 0..n {
                let data =
                    sim.memory
                        .peek(NodeId::Gpu(g), rs_partial_base(size) + k2 as u64 * chunk, chunk);
                blocks.push((k2, g, rs_inbox_base(size, chunk) + k as u64 * chunk, data));
            }
        }
    }
    for (k2, g, off, data) in blocks {
        sims[k2].memory.poke(NodeId::Gpu(g), off, &data);
    }
}

/// CU pass 2 (functional): fold the `n` inbox partials into the reduced
/// chunk at [`rs_result_base`].
fn reduce_final(sims: &mut [Sim], num_nodes: usize, size: u64, chunk: u64) {
    for sim in sims.iter_mut() {
        let gpn = sim.cfg.topology.num_gpus;
        for gpu in 0..gpn {
            let mut acc = vec![0u8; chunk as usize];
            for k in 0..num_nodes {
                let data = sim.memory.peek(
                    NodeId::Gpu(gpu),
                    rs_inbox_base(size, chunk) + k as u64 * chunk,
                    chunk,
                );
                for (a, b) in acc.iter_mut().zip(data) {
                    *a = a.wrapping_add(b);
                }
            }
            sim.memory
                .poke(NodeId::Gpu(gpu), rs_result_base(size, chunk), &acc);
        }
    }
}

/// Expected reduced byte for destination rank `r`: the wrapping sum of
/// every rank's input pattern for chunk `r` (the flat reference reduction;
/// wrapping add is order-independent, so any reduction tree must agree).
pub fn expected_reduced_byte(world: u32, r: u32) -> u8 {
    (0..world).fold(0u8, |acc, s| acc.wrapping_add(pattern(s as u8, r as u8)))
}

/// Check every rank's reduced chunk against the flat reference.
fn check_rs(sims: &[Sim], cluster: &ClusterTopology, size: u64, chunk: u64) -> bool {
    let w = cluster.world_size() as u32;
    for (k, sim) in sims.iter().enumerate() {
        for g in 0..cluster.gpus_per_node() {
            let r = cluster.global_rank(k, g);
            let want = expected_reduced_byte(w, r);
            let got = sim
                .memory
                .peek(NodeId::Gpu(g), rs_result_base(size, chunk), chunk);
            if got.iter().any(|&b| b != want) {
                crate::log_error!(
                    "cluster RS verify failed: rank {r} (node {k} gpu {g}): want {want}, \
                     got {:?}…",
                    &got[..got.len().min(4)]
                );
                return false;
            }
        }
    }
    true
}

/// Check every rank's all-reduce output buffer `[0, size)` against the flat
/// reference (every chunk fully reduced, replicated everywhere).
fn check_ar(sims: &[Sim], cluster: &ClusterTopology, size: u64, chunk: u64) -> bool {
    let w = cluster.world_size() as u32;
    for (k, sim) in sims.iter().enumerate() {
        for g in 0..cluster.gpus_per_node() {
            for d in 0..w {
                let want = expected_reduced_byte(w, d);
                let got = sim.memory.peek(NodeId::Gpu(g), d as u64 * chunk, chunk);
                if got.iter().any(|&b| b != want) {
                    crate::log_error!(
                        "cluster AR verify failed: node {k} gpu {g} chunk {d}: want {want}, \
                         got {:?}…",
                        &got[..got.len().min(4)]
                    );
                    return false;
                }
            }
        }
    }
    true
}

/// Run one hierarchical reduce-scatter end to end; see [`run_hier_rs_full`].
pub fn run_hier_rs(
    choice: ClusterChoice,
    cluster: &ClusterTopology,
    size: u64,
    opts: &HierRunOptions,
) -> HierResult {
    run_hier_rs_full(choice, cluster, size, opts).0
}

/// Per-chunk readiness of a hierarchical reduce-scatter on the absolute
/// episode timeline — the dependency information the chunk-granular
/// overlap scheduler ([`super::overlap`]) threads into the gather leg.
#[derive(Debug, Clone)]
pub struct RsChunkTimes {
    /// Trigger instant of the reduce-scatter phase (prelaunch setup epoch
    /// excluded from latency accounting, exactly like [`HierResult`]).
    pub t0: SimTime,
    /// `ready[k]`: absolute instant at which destination node `k`'s
    /// reduced chunk lands (final CU fold complete on every GPU of node
    /// `k`). `max(ready) − t0 == latency_ns` of the reduce-scatter.
    pub ready: Vec<SimTime>,
}

/// Hierarchical reduce-scatter: intra-node all-to-all transport rounds on
/// per-node DES instances, CU partial reduction, NIC partial exchange, CU
/// final reduction. Returns the per-node simulators so callers can inspect
/// the reduced chunks at [`rs_result_base`]. With `verify` off only node 0
/// is simulated (homogeneous symmetry).
pub fn run_hier_rs_full(
    choice: ClusterChoice,
    cluster: &ClusterTopology,
    size: u64,
    opts: &HierRunOptions,
) -> (HierResult, Vec<Sim>) {
    let (res, sims, _) = run_hier_rs_timed(choice, cluster, size, opts);
    (res, sims)
}

/// [`run_hier_rs_full`], additionally returning the per-destination-node
/// chunk ready instants ([`RsChunkTimes`]) that drive the overlapped
/// all-reduce schedule.
pub fn run_hier_rs_timed(
    choice: ClusterChoice,
    cluster: &ClusterTopology,
    size: u64,
    opts: &HierRunOptions,
) -> (HierResult, Vec<Sim>, RsChunkTimes) {
    let n = cluster.num_nodes();
    let gpn = cluster.gpus_per_node();
    assert!(n <= MAX_NODES, "at most {MAX_NODES} nodes supported");
    assert!(gpn >= 2, "hierarchical planners need ≥ 2 GPUs per node");
    assert!(
        choice.intra.strategy.applicable(CollectiveKind::AllToAll),
        "{} not applicable to the RS transport (AA pattern)",
        choice.intra.strategy.name()
    );
    let w = cluster.world_size() as u64;
    assert!(
        size % w == 0 && size >= w,
        "size {size} must be a positive multiple of world size {w}"
    );
    if opts.verify {
        assert!(w <= 256, "verification patterns need world size ≤ 256");
    }
    let c = size / w;
    let in_place = choice.intra.strategy == Strategy::Swap;
    let prelaunch = choice.intra.prelaunch;
    let observe = opts.latency.t_host_observe;
    let nic = cluster.nic.clone();

    // Joins the all-reduce episode when one is open; owns its own when the
    // reduce-scatter runs standalone.
    let emitting = opts.trace && record::active();
    let episode = if emitting {
        record::with(|r| r.open_episode("collective:reduce-scatter"))
    } else {
        None
    };

    let sim_nodes = if opts.verify { n } else { 1 };
    let mut sims: Vec<Sim> = (0..sim_nodes)
        .map(|k| {
            Sim::new(SimConfig {
                topology: cluster.node(k).clone(),
                latency: opts.latency.clone(),
                functional: opts.verify,
                trace: opts.trace,
            })
        })
        .collect();
    let rounds: Vec<Arc<Vec<CollectivePlan>>> = (0..sim_nodes)
        .map(|k| {
            cached_node_rounds(CollectiveKind::AllToAll, cluster.node(k), n, k, size, c, choice)
        })
        .collect();

    let t0 = prelaunch_t0(&rounds[0], gpn, &opts.latency, prelaunch);
    let data_cmds = rounds[0].iter().map(|p| p.total_data_cmds()).sum::<usize>() * n;
    let nic_messages = count_nic_messages(cluster);
    let mut fault_stats = FaultStats::default();

    if opts.verify {
        for (k, sim) in sims.iter_mut().enumerate() {
            for g in 0..gpn {
                let r = cluster.global_rank(k, g);
                let node = NodeId::Gpu(g);
                sim.memory.ensure(node, rs_result_base(size, c) + c);
                for d in 0..w as u32 {
                    sim.memory.poke(
                        node,
                        d as u64 * c,
                        &vec![pattern(r as u8, d as u8); c as usize],
                    );
                }
            }
        }
    }

    // Intra transport rounds, all triggered at t0 (like hierarchical AA).
    let triggers = vec![t0; n];
    let mut round_done = vec![0u64; n];
    for (k, sim) in sims.iter_mut().enumerate() {
        let hosts = queue_node_scripts(sim, &rounds[k], prelaunch, t0, &triggers);
        let out = sim.run();
        assert!(
            out.deadlocked.is_empty(),
            "hier reduce-scatter deadlocked on node {k}: {:?}",
            out.deadlocked
        );
        for h in hosts {
            let host = sim.host(h);
            for (j, rd) in round_done.iter_mut().enumerate() {
                *rd = (*rd).max(host.mark(ROUND_MARKS[j]).unwrap());
            }
        }
    }

    // CU pass 1: fold round k2's g chunks into one partial per destination
    // node. Homogeneous nodes ⇒ every node's round j completes at
    // round_done[j].
    let reduce_intra = ns(cu_reduce_ns(c, gpn));
    let partial_ready: Vec<SimTime> = round_done.iter().map(|&rd| rd + reduce_intra).collect();
    if opts.verify {
        for (k, sim) in sims.iter_mut().enumerate() {
            reduce_node_partials(sim, k, n, size, c, in_place);
        }
    }

    let (latency_ns, inter_ns, chunk_ready) = if n == 1 {
        // Degenerate single node: one transport round + one CU fold — the
        // flat RS split, no NIC plan is ever built.
        if emitting {
            record::with(|r| {
                for (k, sim) in sims.iter().enumerate() {
                    obs::lift_sim_trace(r, k as u8, &sim.trace);
                }
                r.span(
                    "partial r0".to_string(),
                    SpanKind::CuReduce,
                    Track::Cu { node: 0 },
                    round_done[0],
                    partial_ready[0],
                );
                r.measure("reduce-scatter", t0, partial_ready[0]);
            });
        }
        (partial_ready[0] - t0, 0, vec![partial_ready[0]])
    } else {
        // Port-serialized partial sends (c bytes each), scheduled at
        // partial readiness (pipelined/overlapped) or after the whole
        // intra + reduce phase (sequential); same vectored-message
        // accounting as the hierarchical AA inter leg.
        let ready: Vec<f64> = partial_ready.iter().map(|&pr| pr as f64).collect();
        let last_arrival = match &opts.link_faults {
            None => nic_exchange_arrivals(&nic, choice.inter, &ready, c, observe),
            Some(h) => {
                let (arr, fs) =
                    nic_exchange_arrivals_faulted(&nic, choice.inter, &ready, c, observe, h);
                fault_stats.absorb(fs);
                arr
            }
        };
        // CU pass 2 on each destination node: wait for the last incoming
        // partial AND the own-node partial, then fold n chunks.
        let reduce_inter = cu_reduce_ns(c, n as u8);
        let chunk_ready: Vec<SimTime> = last_arrival
            .iter()
            .enumerate()
            .map(|(j, arr)| ns(arr.max(partial_ready[j] as f64) + reduce_inter))
            .collect();
        let done = *chunk_ready.iter().max().unwrap();
        let latency = done - t0;
        let intra_span = *partial_ready.iter().max().unwrap() - t0;
        if emitting {
            let msgs = match &opts.link_faults {
                None => nic_exchange_messages(&nic, choice.inter, &ready, c, observe),
                Some(h) => {
                    nic_exchange_messages_faulted(&nic, choice.inter, &ready, c, observe, h).0
                }
            };
            record::with(|r| {
                for (k, sim) in sims.iter().enumerate() {
                    obs::lift_sim_trace(r, k as u8, &sim.trace);
                }
                // CU pass 1 on every node (homogeneous symmetry — emitted
                // even when only node 0 was simulated), then the NIC
                // partial exchange, then CU pass 2 on each destination.
                for k in 0..n {
                    for (j, &rd) in round_done.iter().enumerate() {
                        r.span(
                            format!("partial r{j}"),
                            SpanKind::CuReduce,
                            Track::Cu { node: k as u8 },
                            rd,
                            partial_ready[j],
                        );
                    }
                }
                emit_nic_msg_spans(r, &msgs);
                for (j, arr) in last_arrival.iter().enumerate() {
                    r.span(
                        "final".to_string(),
                        SpanKind::CuReduce,
                        Track::Cu { node: j as u8 },
                        ns(arr.max(partial_ready[j] as f64)),
                        chunk_ready[j],
                    );
                }
                r.measure("reduce-scatter", t0, done);
            });
        }
        (latency, latency.saturating_sub(intra_span), chunk_ready)
    };

    if matches!(episode, Some((_, true))) {
        record::with(|r| r.close_episode());
    }

    if opts.verify {
        exchange_partials(&mut sims, cluster, size, c);
        reduce_final(&mut sims, n, size, c);
    }
    let verified = if opts.verify {
        Some(check_rs(&sims, cluster, size, c))
    } else {
        None
    };

    (
        HierResult {
            latency_ns,
            inter_ns,
            intra_ns: latency_ns.saturating_sub(inter_ns),
            data_cmds,
            nic_messages,
            verified,
            faults: fault_stats,
        },
        sims,
        RsChunkTimes {
            t0,
            ready: chunk_ready,
        },
    )
}

/// Run one hierarchical all-reduce end to end; see [`run_hier_ar_full`].
pub fn run_hier_ar(
    rs_choice: ClusterChoice,
    ag_choice: ClusterChoice,
    cluster: &ClusterTopology,
    size: u64,
    opts: &HierRunOptions,
) -> HierResult {
    run_hier_ar_full(rs_choice, ag_choice, cluster, size, opts).0
}

/// Hierarchical all-reduce = hierarchical reduce-scatter (`rs_choice`) +
/// hierarchical all-gather of the reduced chunks (`ag_choice`). With
/// either phase choice carrying [`InterSchedule::Overlapped`] the phases
/// fuse at chunk granularity ([`super::overlap`]: the gather of chunk `k`
/// launches at chunk `k`'s final reduction); otherwise they compose as a
/// strictly sequential barrier. Returns the gather-phase simulators whose
/// `[0, size)` buffers hold the fully reduced, fully replicated result
/// (the reduce-scatter simulators when `verify` is off — timing-only runs
/// don't materialize the gather memories).
pub fn run_hier_ar_full(
    rs_choice: ClusterChoice,
    ag_choice: ClusterChoice,
    cluster: &ClusterTopology,
    size: u64,
    opts: &HierRunOptions,
) -> (HierResult, Vec<Sim>) {
    if rs_choice.inter == InterSchedule::Overlapped
        || ag_choice.inter == InterSchedule::Overlapped
    {
        return super::overlap::run_hier_ar_overlapped_full(
            rs_choice,
            ag_choice,
            cluster,
            size,
            opts,
        );
    }
    assert!(
        ag_choice.intra.strategy.applicable(CollectiveKind::AllGather),
        "{} not applicable to the AR gather phase",
        ag_choice.intra.strategy.name()
    );
    // Own the episode before the phases run so both join it; the rebase
    // between them stacks the gather's t0-anchored timeline after the
    // reduce-scatter's, making the two measure windows sum to the
    // composite latency.
    let emitting = opts.trace && record::active();
    let episode = if emitting {
        record::with(|r| r.open_episode("collective:allreduce"))
    } else {
        None
    };
    let (rs_res, rs_sims) = run_hier_rs_full(rs_choice, cluster, size, opts);
    if emitting {
        record::with(|r| r.rebase_to_end());
    }
    // Gather-phase timing on its own DES episode (the phases share no
    // overlap: the gather input is the reduce output).
    let ag_res = run_hier(
        CollectiveKind::AllGather,
        ag_choice,
        cluster,
        size,
        &HierRunOptions {
            latency: opts.latency.clone(),
            verify: false,
            trace: opts.trace,
            // The AG inter leg is derate-only (chunk sends ride `leg_ns`,
            // no per-message flap model) — see `run_hier_full`.
            link_faults: None,
        },
    );
    if matches!(episode, Some((_, true))) {
        record::with(|r| r.close_episode());
    }

    let (verified, sims) = if opts.verify {
        let (ok, sims) = gather_functional_pass(&rs_sims, ag_choice, cluster, size, opts);
        (Some(rs_res.verified == Some(true) && ok), sims)
    } else {
        (None, rs_sims)
    };

    let latency_ns = rs_res.latency_ns + ag_res.latency_ns;
    let inter_ns = rs_res.inter_ns + ag_res.inter_ns;
    let mut faults = rs_res.faults;
    faults.absorb(ag_res.faults);
    (
        HierResult {
            latency_ns,
            inter_ns,
            intra_ns: latency_ns.saturating_sub(inter_ns),
            data_cmds: rs_res.data_cmds + ag_res.data_cmds,
            nic_messages: rs_res.nic_messages + ag_res.nic_messages,
            verified,
            faults,
        },
        sims,
    )
}

/// Functional gather over the real reduced bytes, shared by the
/// sequential and overlapped all-reduce compositions: seed fresh per-node
/// memories with each rank's reduced chunk at its AG slot, stage the
/// inter leg, then run the same rebased AG rounds the timing path uses
/// (schedule choice does not affect placement, so the functional pass
/// runs untriggered). Returns whether the final placement checks out and
/// the gather simulators.
pub(crate) fn gather_functional_pass(
    rs_sims: &[Sim],
    ag_choice: ClusterChoice,
    cluster: &ClusterTopology,
    size: u64,
    opts: &HierRunOptions,
) -> (bool, Vec<Sim>) {
    let n = cluster.num_nodes();
    let gpn = cluster.gpus_per_node();
    let c = size / cluster.world_size() as u64;
    let mut sims: Vec<Sim> = (0..n)
        .map(|k| {
            Sim::new(SimConfig {
                topology: cluster.node(k).clone(),
                latency: opts.latency.clone(),
                functional: true,
                trace: false,
            })
        })
        .collect();
    for (k, sim) in sims.iter_mut().enumerate() {
        for g in 0..gpn {
            let r = cluster.global_rank(k, g) as u64;
            let red = rs_sims[k]
                .memory
                .peek(NodeId::Gpu(g), rs_result_base(size, c), c);
            sim.memory.ensure(NodeId::Gpu(g), size);
            sim.memory.poke(NodeId::Gpu(g), r * c, &red);
        }
    }
    exchange_ag(&mut sims, cluster, c);
    for (k, sim) in sims.iter_mut().enumerate() {
        let rounds = cached_node_rounds(
            CollectiveKind::AllGather,
            cluster.node(k),
            n,
            k,
            size,
            c,
            ag_choice,
        );
        let triggers = vec![0; n];
        queue_node_scripts(sim, &rounds, false, 0, &triggers);
        let out = sim.run();
        assert!(
            out.deadlocked.is_empty(),
            "hier allreduce gather deadlocked on node {k}: {:?}",
            out.deadlocked
        );
    }
    let ok = check_ar(&sims, cluster, size, c);
    (ok, sims)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::InterSchedule;
    use crate::collectives::Variant;
    use crate::util::bytes::KB;

    fn choice(s: Strategy, prelaunch: bool, inter: InterSchedule) -> ClusterChoice {
        ClusterChoice {
            intra: Variant::new(s, prelaunch),
            inter,
        }
    }

    fn verify_opts() -> HierRunOptions {
        HierRunOptions {
            verify: true,
            ..Default::default()
        }
    }

    #[test]
    fn two_node_reduce_scatter_verifies_all_variants() {
        let cluster = ClusterTopology::mi300x(2);
        let size = 64u64 * 1024 * 2;
        for strat in [Strategy::Pcpy, Strategy::Swap, Strategy::B2b] {
            for inter in [InterSchedule::Sequential, InterSchedule::Pipelined] {
                let r = run_hier_rs(
                    choice(strat, false, inter),
                    &cluster,
                    size,
                    &verify_opts(),
                );
                assert_eq!(r.verified, Some(true), "{} {inter:?}", strat.name());
                assert!(r.inter_ns > 0 && r.latency_ns > r.inter_ns);
                assert_eq!(r.nic_messages, 16);
            }
        }
    }

    #[test]
    fn two_node_allreduce_verifies() {
        let cluster = ClusterTopology::mi300x(2);
        let size = 64u64 * 1024 * 2;
        for inter in [InterSchedule::Sequential, InterSchedule::Pipelined] {
            let (r, sims) = run_hier_ar_full(
                choice(Strategy::Pcpy, true, inter),
                choice(Strategy::Pcpy, true, inter),
                &cluster,
                size,
                &verify_opts(),
            );
            assert_eq!(r.verified, Some(true), "{inter:?}");
            assert!(r.inter_ns > 0);
            // Fully replicated: every GPU's buffer holds the reduced vector.
            let w = cluster.world_size() as u32;
            let c = size / w as u64;
            let b = sims[1].memory.peek(NodeId::Gpu(3), 5 * c, c);
            assert!(b.iter().all(|&x| x == expected_reduced_byte(w, 5)));
        }
    }

    #[test]
    fn single_node_rs_has_no_nic_leg() {
        let cluster = ClusterTopology::mi300x(1);
        let r = run_hier_rs(
            choice(Strategy::Swap, true, InterSchedule::Sequential),
            &cluster,
            64 * KB,
            &verify_opts(),
        );
        assert_eq!(r.verified, Some(true));
        assert_eq!(r.inter_ns, 0);
        assert_eq!(r.nic_messages, 0);
    }

    #[test]
    fn allreduce_is_rs_plus_ag() {
        let cluster = ClusterTopology::mi300x(4);
        let size = 4u64 << 20;
        let rs_c = choice(Strategy::Pcpy, true, InterSchedule::Pipelined);
        let ag_c = choice(Strategy::Pcpy, true, InterSchedule::Pipelined);
        let rs = run_hier_rs(rs_c, &cluster, size, &HierRunOptions::default());
        let ag = run_hier(
            CollectiveKind::AllGather,
            ag_c,
            &cluster,
            size,
            &HierRunOptions::default(),
        );
        let ar = run_hier_ar(rs_c, ag_c, &cluster, size, &HierRunOptions::default());
        assert_eq!(ar.latency_ns, rs.latency_ns + ag.latency_ns);
        assert_eq!(ar.inter_ns, rs.inter_ns + ag.inter_ns);
        assert_eq!(ar.nic_messages, rs.nic_messages + ag.nic_messages);
    }

    #[test]
    fn pipelined_rs_never_slower_than_sequential() {
        let cluster = ClusterTopology::mi300x(4);
        for size in [16u64 << 20, 32u64 << 20] {
            let seq = run_hier_rs(
                choice(Strategy::Pcpy, true, InterSchedule::Sequential),
                &cluster,
                size,
                &HierRunOptions::default(),
            );
            let pipe = run_hier_rs(
                choice(Strategy::Pcpy, true, InterSchedule::Pipelined),
                &cluster,
                size,
                &HierRunOptions::default(),
            );
            assert!(
                pipe.latency_ns <= seq.latency_ns,
                "size {size}: pipe {} vs seq {}",
                pipe.latency_ns,
                seq.latency_ns
            );
        }
    }

    #[test]
    fn rs_latency_grows_with_node_count() {
        let size = 4u64 << 20;
        let mut prev = 0u64;
        for n in [1usize, 2, 4] {
            let cluster = ClusterTopology::mi300x(n);
            let r = run_hier_rs(
                choice(Strategy::Pcpy, true, InterSchedule::Pipelined),
                &cluster,
                size,
                &HierRunOptions::default(),
            );
            assert!(r.latency_ns > prev, "n={n}: {} !> {prev}", r.latency_ns);
            prev = r.latency_ns;
        }
    }
}
