//! Cluster-aware variant selection: extends the single-node best-per-size
//! policy ([`select_variant`], Tables 2/3) to a per-(size, node count)
//! choice of **(intra-node variant, inter-node schedule)**.
//!
//! - The intra leg of a hierarchical collective runs per-node rounds of
//!   size `size / nodes`, so the intra variant is the flat policy evaluated
//!   at the per-round size — more nodes push the intra leg toward the
//!   latency-bound regime where `b2b`/`bcst`/`swap` win.
//! - The inter schedule trades a single cheap barrier (sequential: one
//!   trigger write, one completion observation per rank) against per-block
//!   overlap (pipelined: a trigger + CQ poll per node block). Pipelining
//!   pays once the per-peer NIC payload time dominates that per-block
//!   overhead.

use crate::collectives::{select_variant, CollectiveKind, Variant};

use super::topology::ClusterTopology;

/// How the inter-node exchange is scheduled against the intra-node rounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum InterSchedule {
    /// Strict phase barrier: the NIC leg completes (or starts) as one unit;
    /// a single trigger write / completion observation per rank.
    Sequential,
    /// Per-block overlap: each node block triggers its intra round (AG) or
    /// NIC send (AA) as soon as it is ready; one trigger + CQ poll per
    /// block.
    Pipelined,
}

impl InterSchedule {
    /// Short name as used in figure labels.
    pub fn name(&self) -> &'static str {
        match self {
            InterSchedule::Sequential => "seq",
            InterSchedule::Pipelined => "pipe",
        }
    }
}

/// A full cluster configuration: intra-node variant × inter-node schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ClusterChoice {
    pub intra: Variant,
    pub inter: InterSchedule,
}

impl ClusterChoice {
    /// Figure-label name, e.g. `prelaunch_b2b/pipe`.
    pub fn name(&self) -> String {
        format!("{}/{}", self.intra.name(), self.inter.name())
    }
}

/// Minimum per-peer NIC payload time (ns) before pipelining's per-block
/// trigger/poll overhead pays for itself (≈ a few sync round-trips).
pub const PIPELINE_MIN_BLOCK_NS: f64 = 4_000.0;

/// Pick (intra variant, inter schedule) for `kind` at global buffer `size`
/// bytes per rank on `cluster`.
pub fn select_cluster(kind: CollectiveKind, cluster: &ClusterTopology, size: u64) -> ClusterChoice {
    let n = cluster.num_nodes() as u64;
    // Intra rounds are per-node-block collectives of size/n.
    let intra = select_variant(kind, (size / n.max(1)).max(1));
    let inter = if cluster.num_nodes() <= 1 {
        InterSchedule::Sequential
    } else {
        let per_peer = match kind {
            // AG inter leg moves each rank's own chunk; AA moves a staged
            // per-node block of gpus_per_node chunks.
            CollectiveKind::AllGather => size / cluster.world_size() as u64,
            CollectiveKind::AllToAll => size / n,
        };
        if cluster.nic.payload_ns(per_peer) >= PIPELINE_MIN_BLOCK_NS {
            InterSchedule::Pipelined
        } else {
            InterSchedule::Sequential
        }
    };
    ClusterChoice { intra, inter }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::Strategy;
    use crate::util::bytes::{GB, KB, MB};

    #[test]
    fn intra_variant_follows_per_round_size() {
        let c = ClusterTopology::mi300x(4);
        for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
            for size in [8 * KB, MB, 64 * MB, GB] {
                let ch = select_cluster(kind, &c, size);
                assert_eq!(ch.intra, select_variant(kind, size / 4));
                assert!(ch.intra.strategy.applicable(kind));
            }
        }
    }

    #[test]
    fn single_node_is_sequential_and_flat() {
        let c = ClusterTopology::mi300x(1);
        let ch = select_cluster(CollectiveKind::AllGather, &c, 32 * MB);
        assert_eq!(ch.inter, InterSchedule::Sequential);
        assert_eq!(ch.intra, select_variant(CollectiveKind::AllGather, 32 * MB));
    }

    #[test]
    fn schedule_cuts_over_with_size() {
        let c = ClusterTopology::mi300x(2);
        for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
            assert_eq!(
                select_cluster(kind, &c, 64 * KB).inter,
                InterSchedule::Sequential,
                "{}",
                kind.name()
            );
            assert_eq!(
                select_cluster(kind, &c, GB).inter,
                InterSchedule::Pipelined,
                "{}",
                kind.name()
            );
        }
        // AA blocks are gpus_per_node× larger than AG chunks, so AA
        // pipelines earlier.
        let mid = 2 * MB;
        let ag = select_cluster(CollectiveKind::AllGather, &c, mid);
        let aa = select_cluster(CollectiveKind::AllToAll, &c, mid);
        assert_eq!(ag.inter, InterSchedule::Sequential);
        assert_eq!(aa.inter, InterSchedule::Pipelined);
    }

    #[test]
    fn more_nodes_shift_intra_toward_latency_bound() {
        // A 16MB flat AA picks pcpy+prelaunch (Table 3); at 8 nodes the
        // 2MB per-node rounds fall back into swap's window.
        let c8 = ClusterTopology::mi300x(8);
        let flat = select_variant(CollectiveKind::AllToAll, 16 * MB);
        let hier = select_cluster(CollectiveKind::AllToAll, &c8, 16 * MB);
        assert_eq!(flat.strategy, Strategy::Pcpy);
        assert_eq!(hier.intra.strategy, Strategy::Swap);
    }

    #[test]
    fn choice_names_compose() {
        let ch = ClusterChoice {
            intra: Variant::new(Strategy::B2b, true),
            inter: InterSchedule::Pipelined,
        };
        assert_eq!(ch.name(), "prelaunch_b2b/pipe");
    }
}
