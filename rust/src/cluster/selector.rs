//! Cluster-aware variant selection: extends the single-node best-per-size
//! policy ([`select_variant`], Tables 2/3) to a per-(size, node count)
//! choice of **(intra-node variant, inter-node schedule)**, covering the
//! full hierarchical collective set ([`ClusterKind`]: all-gather,
//! all-to-all, reduce-scatter, all-reduce).
//!
//! - The intra leg of a hierarchical collective runs per-node rounds of
//!   size `size / nodes` through the flat planners of its *transport
//!   pattern* ([`ClusterKind::transport`]: reduce-scatter rides the
//!   all-to-all pattern, paper §2.1.1), so the intra variant is the flat
//!   policy evaluated at the per-round size — more nodes push the intra leg
//!   toward the latency-bound regime where `b2b`/`bcst`/`swap` win.
//! - The inter schedule trades a single cheap barrier (sequential: one
//!   trigger write, one completion observation per rank) against per-block
//!   overlap (pipelined: a trigger + CQ poll per node block). Pipelining
//!   pays once the per-peer NIC payload time dominates that per-block
//!   overhead. The per-peer unit differs by collective: AG moves a rank
//!   chunk, AA a staged node block, RS a reduced partial chunk.
//! - All-reduce is two-phase (reduce-scatter then all-gather), each phase
//!   with its own choice: [`select_allreduce`]. On a multi-node cluster the
//!   phases are fused by the chunk-granular [`InterSchedule::Overlapped`]
//!   schedule (the gather of chunk `k` launches at chunk `k`'s final
//!   reduction, [`crate::cluster::overlap`]), which subsumes per-phase
//!   pipelining and is never slower than the barriered compositions.

use crate::collectives::{select_variant, CollectiveKind, Variant};

use super::faults::FaultPlan;
use super::topology::ClusterTopology;

/// Which hierarchical collective — a superset of the single-node
/// [`CollectiveKind`] adding the reduction collectives whose transport legs
/// ride the same DMA planners.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ClusterKind {
    AllGather,
    AllToAll,
    /// All-to-all-pattern DMA transport + CU reduction
    /// ([`crate::cluster::allreduce::run_hier_rs`]).
    ReduceScatter,
    /// Reduce-scatter followed by a hierarchical all-gather
    /// ([`crate::cluster::allreduce::run_hier_ar`]).
    AllReduce,
}

impl ClusterKind {
    /// Short name as used in figure labels and CSV file names.
    pub fn name(&self) -> &'static str {
        match self {
            ClusterKind::AllGather => "allgather",
            ClusterKind::AllToAll => "alltoall",
            ClusterKind::ReduceScatter => "reduce_scatter",
            ClusterKind::AllReduce => "allreduce",
        }
    }

    /// Intra-node transport pattern of the (first-phase) leg: the flat
    /// planner family whose variants apply. Reduce-scatter and all-reduce
    /// move chunks in the all-to-all pattern (paper §2.1.1).
    pub fn transport(&self) -> CollectiveKind {
        match self {
            ClusterKind::AllGather => CollectiveKind::AllGather,
            _ => CollectiveKind::AllToAll,
        }
    }
}

impl From<CollectiveKind> for ClusterKind {
    fn from(k: CollectiveKind) -> Self {
        match k {
            CollectiveKind::AllGather => ClusterKind::AllGather,
            CollectiveKind::AllToAll => ClusterKind::AllToAll,
        }
    }
}

/// How the inter-node exchange is scheduled against the intra-node rounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum InterSchedule {
    /// Strict phase barrier: the NIC leg completes (or starts) as one unit;
    /// a single trigger write / completion observation per rank.
    Sequential,
    /// Per-block overlap: each node block triggers its intra round (AG) or
    /// NIC send (AA) as soon as it is ready; one trigger + CQ poll per
    /// block.
    Pipelined,
    /// Chunk-granular cross-phase fusion ([`crate::cluster::overlap`]): a
    /// two-phase collective (all-reduce) launches the gather of chunk `k`
    /// as soon as chunk `k`'s final CU reduction lands instead of
    /// barriering the phases. Within a single-phase leg it degenerates to
    /// [`Pipelined`] eligibility (per-block readiness), so it strictly
    /// subsumes pipelining.
    Overlapped,
}

impl InterSchedule {
    /// Short name as used in figure labels.
    pub fn name(&self) -> &'static str {
        match self {
            InterSchedule::Sequential => "seq",
            InterSchedule::Pipelined => "pipe",
            InterSchedule::Overlapped => "ovl",
        }
    }
}

/// A full cluster configuration: intra-node variant × inter-node schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ClusterChoice {
    pub intra: Variant,
    pub inter: InterSchedule,
}

impl ClusterChoice {
    /// Figure-label name, e.g. `prelaunch_b2b/pipe`.
    pub fn name(&self) -> String {
        format!("{}/{}", self.intra.name(), self.inter.name())
    }
}

/// Minimum per-peer NIC payload time (ns) before pipelining's per-block
/// trigger/poll overhead pays for itself (≈ a few sync round-trips).
pub const PIPELINE_MIN_BLOCK_NS: f64 = 4_000.0;

/// Pick (intra variant, inter schedule) for `kind` at global buffer `size`
/// bytes per rank on `cluster`. Total and non-panicking on degenerate
/// inputs: a single-node cluster falls through to the flat
/// [`select_variant`] policy (Sequential, no NIC plan is consulted), and
/// `size == 0` selects at the minimal flat size.
pub fn select_cluster<K: Into<ClusterKind>>(
    kind: K,
    cluster: &ClusterTopology,
    size: u64,
) -> ClusterChoice {
    let kind = kind.into();
    let n = cluster.num_nodes() as u64;
    // Intra rounds are per-node-block collectives of size/n, planned by
    // the kind's transport pattern.
    let intra = select_variant(kind.transport(), (size / n.max(1)).max(1));
    let inter = if cluster.num_nodes() <= 1 {
        InterSchedule::Sequential
    } else if kind == ClusterKind::AllReduce {
        // Two-phase collective: the fused chunk-granular schedule launches
        // the gather of chunk k at chunk k's reduction, subsumes per-block
        // pipelining inside each phase, and coalesces its triggers when
        // ready instants collide — so it is never slower than the best of
        // Sequential/Pipelined at any size (prop-tested), and the policy
        // needs no cutover.
        InterSchedule::Overlapped
    } else {
        let per_peer = match kind {
            // AA moves a staged per-node block of gpus_per_node chunks; AG
            // moves each rank's own chunk; RS (and AR's reduce phase) move
            // one reduced partial chunk per peer node.
            ClusterKind::AllToAll => size / n,
            _ => size / cluster.world_size() as u64,
        };
        if cluster.nic.payload_ns(per_peer) >= PIPELINE_MIN_BLOCK_NS {
            InterSchedule::Pipelined
        } else {
            InterSchedule::Sequential
        }
    };
    ClusterChoice { intra, inter }
}

/// Degradation-aware [`select_cluster`]: re-pick (intra variant, inter
/// schedule) against the topology **as the fault plan derates it** —
/// slower NICs stretch the per-peer payload time, which moves the
/// Sequential → Pipelined cutover down by the derate factor (e.g. the
/// healthy AG cutover sits at `PIPELINE_MIN_BLOCK_NS · bw` = 200 KB per
/// peer chunk; a 4× NIC derate drags it to 50 KB, so mid-size collectives
/// that sequenced when healthy now pipeline — `tests/prop_faults.rs`
/// pins a flip). Stuck-engine derates shrink the per-node engine pool the
/// intra planner sees. All-reduce keeps [`InterSchedule::Overlapped`]
/// even degraded: fusion is never slower than the barriered compositions
/// *on the same (derated) topology* (schedule monotonicity is
/// bandwidth-independent), so demoting it would only slow the degraded
/// run further. An empty plan is exactly [`select_cluster`].
pub fn select_cluster_degraded<K: Into<ClusterKind>>(
    kind: K,
    cluster: &ClusterTopology,
    size: u64,
    plan: &FaultPlan,
) -> ClusterChoice {
    if plan.is_empty() {
        return select_cluster(kind, cluster, size);
    }
    select_cluster(kind, &plan.derate_cluster(cluster, None), size)
}

/// Degradation-aware [`select_allreduce`]: both phase choices re-picked
/// against the derated topology (see [`select_cluster_degraded`]).
pub fn select_allreduce_degraded(
    cluster: &ClusterTopology,
    size: u64,
    plan: &FaultPlan,
) -> (ClusterChoice, ClusterChoice) {
    if plan.is_empty() {
        return select_allreduce(cluster, size);
    }
    select_allreduce(&plan.derate_cluster(cluster, None), size)
}

/// Both phases of a hierarchical all-reduce: the reduce-scatter leg and the
/// all-gather leg each get their own (variant, schedule) choice — the
/// gather phase moves the same per-peer chunk volume but through the AG
/// planner family. On a multi-node cluster both phases carry the
/// [`InterSchedule::Overlapped`] schedule (matching
/// [`select_cluster`]`(AllReduce)`): the phases fuse at chunk granularity
/// instead of barriering, so `run_hier_ar` routes through
/// [`crate::cluster::overlap`]. A single node keeps the per-phase flat
/// choices (there is nothing to fuse across).
pub fn select_allreduce(cluster: &ClusterTopology, size: u64) -> (ClusterChoice, ClusterChoice) {
    let mut rs = select_cluster(ClusterKind::ReduceScatter, cluster, size);
    let mut ag = select_cluster(ClusterKind::AllGather, cluster, size);
    // Single source of truth for the AR schedule policy: whatever
    // select_cluster decides for the composite collective governs both
    // phases (Overlapped fuses them; a barriered decision keeps each
    // phase's own streaming policy).
    let ar = select_cluster(ClusterKind::AllReduce, cluster, size).inter;
    if ar == InterSchedule::Overlapped {
        rs.inter = InterSchedule::Overlapped;
        ag.inter = InterSchedule::Overlapped;
    }
    (rs, ag)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::Strategy;
    use crate::util::bytes::{GB, KB, MB};

    #[test]
    fn intra_variant_follows_per_round_size() {
        let c = ClusterTopology::mi300x(4);
        for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
            for size in [8 * KB, MB, 64 * MB, GB] {
                let ch = select_cluster(kind, &c, size);
                assert_eq!(ch.intra, select_variant(kind, size / 4));
                assert!(ch.intra.strategy.applicable(kind));
            }
        }
    }

    #[test]
    fn single_node_is_sequential_and_flat() {
        let c = ClusterTopology::mi300x(1);
        let ch = select_cluster(CollectiveKind::AllGather, &c, 32 * MB);
        assert_eq!(ch.inter, InterSchedule::Sequential);
        assert_eq!(ch.intra, select_variant(CollectiveKind::AllGather, 32 * MB));
    }

    #[test]
    fn schedule_cuts_over_with_size() {
        let c = ClusterTopology::mi300x(2);
        for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
            assert_eq!(
                select_cluster(kind, &c, 64 * KB).inter,
                InterSchedule::Sequential,
                "{}",
                kind.name()
            );
            assert_eq!(
                select_cluster(kind, &c, GB).inter,
                InterSchedule::Pipelined,
                "{}",
                kind.name()
            );
        }
        // AA blocks are gpus_per_node× larger than AG chunks, so AA
        // pipelines earlier.
        let mid = 2 * MB;
        let ag = select_cluster(CollectiveKind::AllGather, &c, mid);
        let aa = select_cluster(CollectiveKind::AllToAll, &c, mid);
        assert_eq!(ag.inter, InterSchedule::Sequential);
        assert_eq!(aa.inter, InterSchedule::Pipelined);
    }

    #[test]
    fn more_nodes_shift_intra_toward_latency_bound() {
        // A 16MB flat AA picks pcpy+prelaunch (Table 3); at 8 nodes the
        // 2MB per-node rounds fall back into swap's window.
        let c8 = ClusterTopology::mi300x(8);
        let flat = select_variant(CollectiveKind::AllToAll, 16 * MB);
        let hier = select_cluster(CollectiveKind::AllToAll, &c8, 16 * MB);
        assert_eq!(flat.strategy, Strategy::Pcpy);
        assert_eq!(hier.intra.strategy, Strategy::Swap);
    }

    #[test]
    fn reduce_kinds_use_aa_transport_variants() {
        let c = ClusterTopology::mi300x(4);
        for size in [8 * KB, MB, 64 * MB, GB] {
            for kind in [ClusterKind::ReduceScatter, ClusterKind::AllReduce] {
                let ch = select_cluster(kind, &c, size);
                assert_eq!(ch.intra, select_variant(CollectiveKind::AllToAll, size / 4));
                assert!(ch.intra.strategy.applicable(CollectiveKind::AllToAll));
            }
        }
        // RS partials are per-chunk (world-divided), so RS pipelines later
        // than AA at the same size.
        let mid = 2 * MB;
        let aa = select_cluster(ClusterKind::AllToAll, &ClusterTopology::mi300x(2), mid);
        let rs = select_cluster(ClusterKind::ReduceScatter, &ClusterTopology::mi300x(2), mid);
        assert_eq!(aa.inter, InterSchedule::Pipelined);
        assert_eq!(rs.inter, InterSchedule::Sequential);
    }

    #[test]
    fn allreduce_phases_pair_rs_and_ag() {
        let c = ClusterTopology::mi300x(2);
        let (rs, ag) = select_allreduce(&c, 32 * MB);
        // Intra variants come from the per-phase flat policies; the inter
        // schedule is the fused chunk-granular one on a multi-node cluster.
        assert_eq!(
            rs.intra,
            select_cluster(ClusterKind::ReduceScatter, &c, 32 * MB).intra
        );
        assert_eq!(
            ag.intra,
            select_cluster(ClusterKind::AllGather, &c, 32 * MB).intra
        );
        assert_eq!(rs.inter, InterSchedule::Overlapped);
        assert_eq!(ag.inter, InterSchedule::Overlapped);
        assert!(rs.intra.strategy.applicable(CollectiveKind::AllToAll));
        assert!(ag.intra.strategy.applicable(CollectiveKind::AllGather));
    }

    #[test]
    fn allreduce_overlaps_multi_node_only() {
        // Multi-node AR fuses its phases; a single node has nothing to
        // fuse and keeps the flat sequential composition.
        for size in [8 * KB, 64 * MB] {
            let multi = select_cluster(ClusterKind::AllReduce, &ClusterTopology::mi300x(4), size);
            assert_eq!(multi.inter, InterSchedule::Overlapped, "size {size}");
            let single = select_cluster(ClusterKind::AllReduce, &ClusterTopology::mi300x(1), size);
            assert_eq!(single.inter, InterSchedule::Sequential, "size {size}");
            let (rs, ag) = select_allreduce(&ClusterTopology::mi300x(1), size);
            assert_ne!(rs.inter, InterSchedule::Overlapped);
            assert_ne!(ag.inter, InterSchedule::Overlapped);
        }
        assert_eq!(InterSchedule::Overlapped.name(), "ovl");
    }

    #[test]
    fn degenerate_inputs_do_not_panic() {
        // Zero-byte transfers fall back to the minimal flat size; a
        // single-node cluster never consults the NIC model.
        for n in [1usize, 2] {
            let c = ClusterTopology::mi300x(n);
            for kind in [
                ClusterKind::AllGather,
                ClusterKind::AllToAll,
                ClusterKind::ReduceScatter,
                ClusterKind::AllReduce,
            ] {
                let ch = select_cluster(kind, &c, 0);
                assert!(ch.intra.strategy.applicable(kind.transport()));
            }
        }
        let single = select_cluster(ClusterKind::ReduceScatter, &ClusterTopology::mi300x(1), MB);
        assert_eq!(single.inter, InterSchedule::Sequential);
        assert_eq!(single.intra, select_variant(CollectiveKind::AllToAll, MB));
    }

    /// The degradation-aware selector flips the inter schedule where the
    /// derated NIC moves the pipelining cutover: healthy per-peer AG
    /// chunks of 128 KB pay 2.56 µs on the wire (< 4 µs ⇒ Sequential);
    /// at a 4× NIC derate the same chunk takes 10.2 µs (⇒ Pipelined).
    #[test]
    fn degraded_selector_flips_schedule_at_the_derated_cutover() {
        use crate::cluster::faults::FaultSpec;
        let c = ClusterTopology::mi300x(2);
        let size = 2 * MB; // per-peer AG chunk = size/world = 128 KB
        let healthy = select_cluster(ClusterKind::AllGather, &c, size);
        assert_eq!(healthy.inter, InterSchedule::Sequential);

        let spec = FaultSpec::parse("nic=1:0.25").unwrap();
        let plan = FaultPlan::generate(&spec, 2, 7);
        let degraded = select_cluster_degraded(ClusterKind::AllGather, &c, size, &plan);
        assert_eq!(
            degraded.inter,
            InterSchedule::Pipelined,
            "4x NIC derate must flip the AG schedule at 2 MB"
        );
        // Intra variant is untouched by a NIC-only fault.
        assert_eq!(degraded.intra, healthy.intra);

        // Empty plan ⇒ exactly the healthy policy.
        let none = FaultPlan::healthy(2);
        assert_eq!(
            select_cluster_degraded(ClusterKind::AllGather, &c, size, &none),
            healthy
        );
    }

    /// All-reduce stays fused under degradation: Overlapped is never
    /// slower than the barriered compositions on the *derated* topology,
    /// so the aware policy must not demote it.
    #[test]
    fn degraded_allreduce_keeps_overlap() {
        use crate::cluster::faults::FaultSpec;
        let c = ClusterTopology::mi300x(2);
        let spec = FaultSpec::parse("nic=1:0.25,engines=8").unwrap();
        let plan = FaultPlan::generate(&spec, 2, 7);
        let ch = select_cluster_degraded(ClusterKind::AllReduce, &c, 32 * MB, &plan);
        assert_eq!(ch.inter, InterSchedule::Overlapped);
        let (rs, ag) = select_allreduce_degraded(&c, 32 * MB, &plan);
        assert_eq!(rs.inter, InterSchedule::Overlapped);
        assert_eq!(ag.inter, InterSchedule::Overlapped);
    }

    #[test]
    fn choice_names_compose() {
        let ch = ClusterChoice {
            intra: Variant::new(Strategy::B2b, true),
            inter: InterSchedule::Pipelined,
        };
        assert_eq!(ch.name(), "prelaunch_b2b/pipe");
    }
}
