//! Chunk-granular overlap scheduler: fused hierarchical all-reduce.
//!
//! The barriered composition in [`super::allreduce`] charges the full
//! all-gather behind the full reduce-scatter — yet the gather of chunk `k`
//! only depends on chunk `k`'s final CU reduction, not on every other
//! chunk's. The paper's thesis (DMA engines move bytes while GPU cores do
//! something useful) plus the finer-grain DMA chunking literature say
//! exactly where the headroom is, so this module replaces the phase
//! barrier with a **chunk-level dependency schedule**:
//!
//! - The reduce-scatter leg runs with per-partial streaming
//!   ([`run_hier_rs_timed`] under per-block eligibility) and reports each
//!   destination node's reduced-chunk ready instant
//!   ([`RsChunkTimes::ready`]).
//! - The gather leg reuses the exact rebased AG rounds of the barriered
//!   path, but threads those ready instants into the trigger times: node
//!   `k2`'s NIC send of its reduced chunk departs at `ready[k2]` (port
//!   serialization preserved), and node `k`'s intra round for block `k2`
//!   triggers at that message's arrival — via the existing
//!   `DelayUntil`/trigger-signal machinery of
//!   [`queue_node_scripts`](super::hier), with triggers landing at the
//!   same instant coalescing into one trigger write per rank.
//! - Per-node trigger times now differ across nodes (chunk `k2` of a
//!   pipelined exchange lands at different instants on different
//!   destinations), so the gather leg simulates **every** node instead of
//!   leaning on homogeneous symmetry; the critical path is the latest
//!   `end` mark.
//!
//! Every trigger instant is ≤ its counterpart in the barriered
//! composition (each phase-composition trigger is the same expression
//! with `max(ready)` in place of `ready[k2]`), the round scripts are
//! identical, and the DES is monotone in trigger times — so the fused
//! schedule is never slower than the best barriered composition
//! (prop-tested in `tests/prop_cluster.rs` and asserted per figure-sweep
//! cell by `benches/overlap.rs`). Placement is schedule-independent, so
//! the result is byte-identical to the sequential composition (and hence
//! to the flat reference reduction).

use crate::collectives::CollectiveKind;
use crate::obs::{self, record, SpanKind, Track};
use crate::sim::clock::ns;
use crate::sim::{Sim, SimConfig, SimTime};

use super::allreduce::{gather_functional_pass, run_hier_rs_timed, RsChunkTimes};
use super::hier::{
    cached_node_rounds, count_nic_messages, queue_node_scripts, HierResult, HierRunOptions,
};
use super::selector::{ClusterChoice, InterSchedule};
use super::topology::ClusterTopology;

/// Overlap accounting for one fused all-reduce episode, on top of the
/// plain [`HierResult`]: what the chunk-granular schedule saved relative
/// to the barriered composition of the same intra variants.
#[derive(Debug, Clone)]
pub struct OverlapReport {
    /// The fused chunk-granular episode.
    pub overlapped: HierResult,
    /// The barriered (strict RS → AG) composition of the same intra
    /// variants with per-block pipelining inside each phase — the
    /// strongest non-fused baseline.
    pub barrier: HierResult,
    /// `barrier.latency_ns − overlapped.latency_ns` (≥ 0 by schedule
    /// monotonicity).
    pub saved_ns: u64,
}

/// Downgrade an [`InterSchedule::Overlapped`] choice to its per-phase
/// equivalent (per-block pipelining without cross-phase fusion).
fn barriered(mut c: ClusterChoice) -> ClusterChoice {
    if c.inter == InterSchedule::Overlapped {
        c.inter = InterSchedule::Pipelined;
    }
    c
}

/// Run one fused hierarchical all-reduce; see
/// [`run_hier_ar_overlapped_full`].
pub fn run_hier_ar_overlapped(
    rs_choice: ClusterChoice,
    ag_choice: ClusterChoice,
    cluster: &ClusterTopology,
    size: u64,
    opts: &HierRunOptions,
) -> HierResult {
    run_hier_ar_overlapped_full(rs_choice, ag_choice, cluster, size, opts).0
}

/// Chunk-granular fused all-reduce: reduce-scatter with per-partial
/// streaming, then the all-gather of chunk `k2` launched at `ready[k2]`
/// instead of behind a phase barrier. Returned simulators follow the
/// [`super::allreduce::run_hier_ar_full`] convention (gather memories
/// when `verify` is on, reduce-scatter simulators otherwise).
pub fn run_hier_ar_overlapped_full(
    rs_choice: ClusterChoice,
    ag_choice: ClusterChoice,
    cluster: &ClusterTopology,
    size: u64,
    opts: &HierRunOptions,
) -> (HierResult, Vec<Sim>) {
    assert!(
        ag_choice.intra.strategy.applicable(CollectiveKind::AllGather),
        "{} not applicable to the AR gather phase",
        ag_choice.intra.strategy.name()
    );
    let n = cluster.num_nodes();
    let c = size / cluster.world_size().max(1) as u64;
    let nic = cluster.nic.clone();
    let observe = opts.latency.t_host_observe;

    // Own the episode before phase 1 so the reduce-scatter joins it. The
    // fused phases share one absolute timeline (no rebase): the gather's
    // measure window is the remainder [t0 + rs latency, end] — the two
    // windows partition the fused end-to-end latency.
    let emitting = opts.trace && record::active();
    let episode = if emitting {
        record::with(|r| r.open_episode("collective:allreduce"))
    } else {
        None
    };

    // Phase 1: reduce-scatter with per-partial streaming (Overlapped
    // eligibility == per-block readiness inside a single leg).
    let (rs_res, rs_sims, times) = run_hier_rs_timed(rs_choice, cluster, size, opts);
    let RsChunkTimes { t0, ready } = &times;

    // Phase 2: the gather leg with chunk-granular triggers. Ready instants
    // differ per destination node, so every node is simulated (no
    // homogeneous shortcut) — the scripts are identical to the barriered
    // path, only the trigger times move.
    let prelaunch = ag_choice.intra.prelaunch;
    let mut end_max: SimTime = 0;
    let mut ag_tail: SimTime = 0;
    let mut ag_data_cmds = 0usize;
    for k in 0..n {
        let mut sim = Sim::new(SimConfig {
            topology: cluster.node(k).clone(),
            latency: opts.latency.clone(),
            functional: false,
            trace: opts.trace,
        });
        let rounds = cached_node_rounds(
            CollectiveKind::AllGather,
            cluster.node(k),
            n,
            k,
            size,
            c,
            ag_choice,
        );
        if k == 0 {
            ag_data_cmds = rounds.iter().map(|p| p.total_data_cmds()).sum::<usize>() * n;
        }
        let triggers: Vec<SimTime> = (0..n)
            .map(|k2| {
                if k2 == k {
                    // Own block: the reduced chunk is already resident.
                    ready[k]
                } else {
                    // Node k2 streams its reduced chunk through its single
                    // NIC port starting at ready[k2]; ring send order puts
                    // the message for node (k2+j) mod n at position j.
                    let j = (k + n - k2) % n;
                    ready[k2] + ns(nic.arrival_ns(j, c) + observe)
                }
            })
            .collect();
        let last_trigger = *triggers.iter().max().unwrap();
        let hosts = queue_node_scripts(&mut sim, &rounds, prelaunch, *t0, &triggers);
        let out = sim.run();
        assert!(
            out.deadlocked.is_empty(),
            "overlapped allreduce gather deadlocked on node {k}: {:?}",
            out.deadlocked
        );
        for h in hosts {
            let end = sim.host(h).mark("end").unwrap();
            end_max = end_max.max(end);
            ag_tail = ag_tail.max(end.saturating_sub(last_trigger));
        }
        if emitting {
            // The gather sim is dropped at the end of this iteration —
            // lift its spans now (all n nodes are simulated here).
            record::with(|r| obs::lift_sim_trace(r, k as u8, &sim.trace));
        }
    }

    if emitting {
        record::with(|r| {
            // Gather-leg NIC timeline: node k2 streams its reduced chunk
            // from ready[k2] through its port; ring order puts position p
            // at destination (k2+p) mod n — matching the trigger formula
            // above.
            if n > 1 {
                let step = nic.t_post_per_msg + nic.payload_ns(c);
                for (k2, &rdy) in ready.iter().enumerate() {
                    for p in 1..n {
                        let dest = (k2 + p) % n;
                        r.span(
                            format!("send->{dest}"),
                            SpanKind::Nic,
                            Track::Nic { node: k2 as u8 },
                            rdy + ns((p - 1) as f64 * step),
                            rdy + ns(p as f64 * step),
                        );
                        r.span(
                            format!("flight {k2}->{dest}"),
                            SpanKind::NicFlight,
                            Track::NicFlight { node: dest as u8 },
                            rdy + ns(p as f64 * step),
                            rdy + ns(nic.arrival_ns(p, c)),
                        );
                    }
                }
            }
            r.measure("gather", *t0 + rs_res.latency_ns, end_max);
        });
    }

    let latency_ns = end_max - t0;
    // NIC/exchange span on the critical path: whatever the intra DES work
    // (reduce-scatter rounds + the gather tail after the final trigger)
    // does not cover. Overlap shrinks exactly this component relative to
    // `rs.inter + ag.inter` of the barriered composition.
    let inter_ns = latency_ns.saturating_sub(rs_res.intra_ns + ag_tail);

    if matches!(episode, Some((_, true))) {
        record::with(|r| r.close_episode());
    }

    let (verified, sims) = if opts.verify {
        let (ok, sims) = gather_functional_pass(&rs_sims, ag_choice, cluster, size, opts);
        (Some(rs_res.verified == Some(true) && ok), sims)
    } else {
        (None, rs_sims)
    };

    (
        HierResult {
            latency_ns,
            inter_ns,
            intra_ns: latency_ns.saturating_sub(inter_ns),
            data_cmds: rs_res.data_cmds + ag_data_cmds,
            nic_messages: rs_res.nic_messages + count_nic_messages(cluster),
            verified,
            // The gather leg is derate-only; all flap retries were paid in
            // the reduce-scatter exchange.
            faults: rs_res.faults,
        },
        sims,
    )
}

/// Fused episode plus its barriered baseline ([`OverlapReport`]): what the
/// chunk-granular schedule buys at this (cluster, size) point. Figures and
/// the overlap bench report `saved_ns` per cell.
pub fn overlap_report(
    rs_choice: ClusterChoice,
    ag_choice: ClusterChoice,
    cluster: &ClusterTopology,
    size: u64,
    opts: &HierRunOptions,
) -> OverlapReport {
    let overlapped = run_hier_ar_overlapped(rs_choice, ag_choice, cluster, size, opts);
    let barrier = super::allreduce::run_hier_ar(
        barriered(rs_choice),
        barriered(ag_choice),
        cluster,
        size,
        opts,
    );
    OverlapReport {
        saved_ns: barrier.latency_ns.saturating_sub(overlapped.latency_ns),
        overlapped,
        barrier,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::allreduce::{expected_reduced_byte, run_hier_ar, run_hier_ar_full};
    use crate::collectives::{Strategy, Variant};
    use crate::sim::topology::NodeId;

    fn choice(s: Strategy, prelaunch: bool, inter: InterSchedule) -> ClusterChoice {
        ClusterChoice {
            intra: Variant::new(s, prelaunch),
            inter,
        }
    }

    fn verify_opts() -> HierRunOptions {
        HierRunOptions {
            verify: true,
            ..Default::default()
        }
    }

    /// The fused schedule routes through `run_hier_ar` dispatch, verifies
    /// byte-for-byte, and beats both barriered compositions.
    #[test]
    fn overlapped_allreduce_verifies_and_wins() {
        let cluster = ClusterTopology::mi300x(2);
        let size = 64u64 * 1024 * 2;
        let (r, sims) = run_hier_ar_full(
            choice(Strategy::Pcpy, true, InterSchedule::Overlapped),
            choice(Strategy::Pcpy, true, InterSchedule::Overlapped),
            &cluster,
            size,
            &verify_opts(),
        );
        assert_eq!(r.verified, Some(true));
        assert!(r.inter_ns > 0 && r.latency_ns > r.inter_ns);
        let w = cluster.world_size() as u32;
        let c = size / w as u64;
        let b = sims[1].memory.peek(NodeId::Gpu(3), 5 * c, c);
        assert!(b.iter().all(|&x| x == expected_reduced_byte(w, 5)));

        for inter in [InterSchedule::Sequential, InterSchedule::Pipelined] {
            let base = run_hier_ar(
                choice(Strategy::Pcpy, true, inter),
                choice(Strategy::Pcpy, true, inter),
                &cluster,
                size,
                &HierRunOptions::default(),
            );
            let ovl = run_hier_ar(
                choice(Strategy::Pcpy, true, InterSchedule::Overlapped),
                choice(Strategy::Pcpy, true, InterSchedule::Overlapped),
                &cluster,
                size,
                &HierRunOptions::default(),
            );
            assert!(
                ovl.latency_ns <= base.latency_ns,
                "{inter:?}: ovl {} vs {}",
                ovl.latency_ns,
                base.latency_ns
            );
        }
    }

    /// Savings exist and grow once the NIC legs matter; a single node has
    /// nothing to fuse (the report degenerates to ~zero savings).
    #[test]
    fn overlap_report_quantifies_savings() {
        let opts = HierRunOptions::default();
        let cluster = ClusterTopology::mi300x(4);
        let size = 16u64 << 20;
        let (rs_c, ag_c) = crate::cluster::select_allreduce(&cluster, size);
        assert_eq!(rs_c.inter, InterSchedule::Overlapped);
        let rep = overlap_report(rs_c, ag_c, &cluster, size, &opts);
        assert!(rep.saved_ns > 0, "no overlap win at {size}B on 4 nodes");
        assert_eq!(
            rep.barrier.latency_ns,
            rep.overlapped.latency_ns + rep.saved_ns
        );
        assert_eq!(rep.overlapped.nic_messages, rep.barrier.nic_messages);
        assert_eq!(rep.overlapped.data_cmds, rep.barrier.data_cmds);

        let single = ClusterTopology::mi300x(1);
        let (rs1, ag1) = crate::cluster::select_allreduce(&single, size);
        let rep1 = overlap_report(
            ClusterChoice {
                inter: InterSchedule::Overlapped,
                ..rs1
            },
            ClusterChoice {
                inter: InterSchedule::Overlapped,
                ..ag1
            },
            &single,
            size,
            &opts,
        );
        assert_eq!(rep1.overlapped.nic_messages, 0);
    }

    /// Fused latency is bounded below by the reduce-scatter alone and the
    /// all-gather alone — fusion hides latency, it cannot delete work.
    #[test]
    fn overlap_is_bounded_by_each_phase() {
        let cluster = ClusterTopology::mi300x(2);
        let size = 4u64 << 20;
        let opts = HierRunOptions::default();
        let rs_c = choice(Strategy::Pcpy, true, InterSchedule::Overlapped);
        let ag_c = choice(Strategy::Pcpy, true, InterSchedule::Overlapped);
        let ovl = run_hier_ar(rs_c, ag_c, &cluster, size, &opts);
        let rs = crate::cluster::run_hier_rs(barriered(rs_c), &cluster, size, &opts);
        let ag = crate::cluster::run_hier(
            CollectiveKind::AllGather,
            barriered(ag_c),
            &cluster,
            size,
            &opts,
        );
        assert!(ovl.latency_ns >= rs.latency_ns);
        assert!(ovl.latency_ns >= ag.latency_ns);
        assert!(ovl.latency_ns < rs.latency_ns + ag.latency_ns);
    }
}
