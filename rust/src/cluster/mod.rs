//! Multi-node cluster layer: hierarchical DMA collectives across N
//! DMA-simulated nodes joined by NIC/RDMA links.
//!
//! The paper evaluates DMA collectives inside a single 8-GPU MI300X node;
//! production serving and training scale out across nodes, where the
//! standard recipe (GPU-centric communication surveys, hierarchical NCCL/
//! RCCL algorithms) is a two-level collective: an intra-node leg over the
//! fast fabric (here: sDMA offloads over xGMI, reusing the paper's
//! `pcpy`/`bcst`/`swap`/`b2b`/prelaunch variants unchanged) and an
//! inter-node leg over the NIC. This layer provides:
//!
//! - [`topology::ClusterTopology`] — N single-node [`crate::sim::Topology`]
//!   instances, directed NIC links per cross-node rank pair, and the
//!   global-rank ↔ (node, local GPU) mapping.
//! - [`hier`] — hierarchical all-gather / all-to-all planners + executor:
//!   intra rounds lowered through the existing [`crate::collectives`]
//!   planners onto per-node DES instances, inter exchange on the NIC
//!   model, placement verified byte-for-byte.
//! - [`allreduce`] — hierarchical **reduce-scatter** and **all-reduce**:
//!   all-to-all-pattern DMA transport rounds + CU reductions
//!   ([`crate::collectives::reduce_scatter`]'s split: DMA/NIC move, CUs
//!   reduce), a partial-chunk reduce-exchange leg on the NIC, and the
//!   hierarchical all-gather as all-reduce's second phase; values verified
//!   against the flat reference reduction.
//! - [`overlap`] — chunk-granular overlap scheduler: the all-reduce
//!   phases fused at chunk granularity (the gather of chunk `k` launches
//!   at chunk `k`'s final CU reduction, ready-times threaded into the
//!   gather triggers), replacing the strict RS → AG barrier.
//! - [`selector`] — cluster-aware policy: (intra variant, inter schedule)
//!   per [`ClusterKind`] (AG / AA / RS / AR), size and node count,
//!   extending `collectives::select_variant`; the serving path routes
//!   through it via `coordinator::comm` whenever
//!   `ServeConfig::num_nodes > 1`.
//! - [`faults`] — seeded fault injection ([`faults::FaultPlan`], a pure
//!   function of `(spec, seed)`): degraded DMA engines, derated/flapping
//!   NIC links and compute stragglers, applied through the existing
//!   link tables; [`selector::select_cluster_degraded`] re-picks
//!   variant/schedule against the derated topology and the hierarchical
//!   executors model timeout-watchdog retries with exponential backoff
//!   in virtual time.
//!
//! # Schedule taxonomy ([`InterSchedule`])
//!
//! - **Sequential** — strict phase barrier; one trigger write and one
//!   completion observation per rank. Cheapest control, zero overlap.
//! - **Pipelined** — per-block overlap *inside* one leg: each node block
//!   triggers its intra round (AG) or NIC send (AA/RS) at its own
//!   readiness; one trigger + CQ poll per block.
//! - **Overlapped** — chunk-granular fusion *across* phases ([`overlap`]):
//!   all-reduce's gather of chunk `k` launches at chunk `k`'s final
//!   reduction. Subsumes Pipelined inside each leg (identical per-block
//!   eligibility) and coalesces coincident triggers, so it is never
//!   slower than either barriered composition; the selector picks it for
//!   every multi-node all-reduce.
//!
//! # Health / fault taxonomy ([`faults`])
//!
//! Faults are *intensities materialized by seed*: a [`faults::FaultSpec`]
//! names what can go wrong, [`faults::FaultPlan::generate`] draws which
//! nodes it happens to. Three fault families, three reaction layers:
//!
//! - **Engine faults** (stuck sDMA engines, xGMI bandwidth derates) —
//!   applied by rebuilding the node [`crate::sim::Topology`] with a
//!   smaller engine pool / scaled link tables; fleet-wide, because the
//!   planners require homogeneous nodes and lockstep collectives gate on
//!   the slowest participant anyway.
//! - **Link faults** (NIC bandwidth derates, transient message flaps) —
//!   derates scale [`topology::NicModel::bw_bytes_per_ns`]; flaps are
//!   per-message seeded draws that the executors recover from with a
//!   timeout watchdog + retry-with-exponential-backoff
//!   ([`faults::RetryPolicy`]), all in virtual time. Flaps delay bytes,
//!   never drop them — retried collectives stay byte-identical.
//! - **Node faults** (compute stragglers) — per-node compute-time
//!   multipliers; lockstep TP batches gate on the worst survivor, and
//!   the serving coordinator may *drain* sick nodes (shrink the world,
//!   re-route through the selector) instead of gating on them.
//!
//! The healthy path is zero-perturbation by contract: an empty plan is
//! never consulted, pinned bit-identical by `tests/determinism.rs` and
//! `tests/prop_faults.rs`.
//!
//! # NIC link model assumptions ([`topology::NicModel`])
//!
//! - **Bandwidth**: every directed cross-node rank pair runs at a uniform
//!   `bw_bytes_per_ns` (default 50 B/ns ≈ 400 Gb/s RoCE per GPU NIC),
//!   full duplex — sends and receives do not contend.
//! - **Per-message latency**: each message pays a one-way base latency
//!   (`t_latency`, default 2 µs: propagation + NIC processing) plus a host
//!   posting cost (`t_post_per_msg`, default 450 ns per RDMA work request).
//! - **Port serialization**: one rank's concurrent messages to distinct
//!   peers serialize their payloads through its single NIC port; the base
//!   latency pipelines across messages.
//! - **No congestion**: the fabric core is non-blocking — no incast or
//!   switch contention is modeled (future work; the per-port serialization
//!   above is the only shared-resource effect).
//! - **Scatter/gather**: one staged node block travels as a single
//!   vectored message (RDMA gather lists), so hierarchical AA posts
//!   `n−1` messages per rank, not `n·g`.

pub mod allreduce;
pub mod faults;
pub mod hier;
pub mod overlap;
pub mod selector;
pub mod topology;

pub use allreduce::{
    run_hier_ar, run_hier_ar_full, run_hier_rs, run_hier_rs_full, run_hier_rs_timed, RsChunkTimes,
};
pub use faults::{FaultPlan, FaultSpec, FaultStats, LinkHealth, NodeHealth, RetryPolicy};
pub use hier::{rounds_cache_stats, run_hier, run_hier_full, HierResult, HierRunOptions};
pub use overlap::{overlap_report, run_hier_ar_overlapped, OverlapReport};
pub use selector::{
    select_allreduce, select_allreduce_degraded, select_cluster, select_cluster_degraded,
    ClusterChoice, ClusterKind, InterSchedule,
};
pub use topology::{ClusterTopology, GlobalRank, NicModel};
