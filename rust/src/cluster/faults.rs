//! Seeded fault injection: degraded DMA engines, derated/flapping NIC
//! links, compute stragglers — and the health view the cluster executors
//! and the serving coordinator consume to degrade gracefully.
//!
//! The paper's core finding is that DMA collective performance is fragile
//! at the margins: command scheduling and synchronization costs dominate
//! exactly when resources misbehave. This module makes misbehavior a
//! first-class, **deterministic** input:
//!
//! - [`FaultSpec`] — intensity knobs (how many nodes, how hard), parsed
//!   from the CLI `--faults` spec ([`FaultSpec::parse`]) or one of the
//!   canned presets ([`FaultSpec::preset`]).
//! - [`FaultPlan`] — the materialized per-node health table, a **pure
//!   function of `(spec, num_nodes, seed)`** exactly like
//!   `WorkloadSpec::generate`: the same seed always yields the same sick
//!   nodes, derate windows and flap schedule. A healthy spec yields an
//!   empty plan, and an empty plan perturbs **nothing** — the healthy
//!   code path never consults it (pinned by `tests/determinism.rs` and
//!   `tests/prop_faults.rs`).
//! - [`FaultPlan::derate_cluster`] — applies the plan to a
//!   [`ClusterTopology`] through the *existing* link tables: stuck sDMA
//!   engines shrink `engines_per_gpu`, engine bandwidth derates scale the
//!   xGMI links, NIC derates scale [`NicModel::bw_bytes_per_ns`]. Because
//!   the hierarchical planners require homogeneous nodes and the lockstep
//!   collectives gate on the slowest participant anyway, per-node derates
//!   are applied at the **fleet-worst** value (worst-node semantics ==
//!   fleet-wide semantics for the modeled latency).
//! - [`LinkHealth`] / [`RetryPolicy`] / [`FaultStats`] — the inter-leg
//!   flap model: each NIC message draws its transient-failure count as a
//!   pure function of `(seed, sender, dest)`; the hierarchical executors'
//!   timeout watchdog detects each loss after [`RetryPolicy::timeout_ns`]
//!   and retransmits with exponential backoff
//!   (`cluster::hier::nic_exchange_arrivals_faulted`), all in virtual
//!   time. Flaps delay messages, they never drop bytes — retried
//!   collectives stay byte-identical to the flat reference
//!   (`tests/prop_cluster.rs`).
//!
//! The serving coordinator layers its graceful-degradation policy on top
//! (`coordinator::config::DegradePolicy`): node drain, SLO-aware shedding
//! and priority preemption all key off the plan built here.

use crate::sim::topology::{NodeId, Topology};
use crate::util::rng::Rng;

use super::topology::{ClusterTopology, NicModel};

/// Dedicated RNG stream for fault placement, xor-folded into the user
/// seed so fault draws never alias workload or scheduler draws (the same
/// convention as `coordinator::workload::ARRIVAL_STREAM`).
pub const FAULT_STREAM: u64 = 0xFA17_0F0F_5EED_C0DE;

/// Floor applied to every bandwidth derate factor: a fully stuck link
/// would make payload times infinite; 1% of nominal keeps the DES finite
/// while still modeling a near-dead resource.
pub const MIN_DERATE_FACTOR: f64 = 0.01;

/// What can go wrong, as intensities. All-defaults == perfectly healthy.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Number of nodes whose NIC runs derated (placement drawn from seed).
    pub nic_nodes: usize,
    /// NIC bandwidth multiplier on derated nodes, in `(0, 1]`.
    pub nic_factor: f64,
    /// Per-message transient flap probability on derated nodes' links.
    pub flap_prob: f64,
    /// Stuck sDMA engines per GPU (removed from the engine pool).
    pub stuck_engines: u8,
    /// xGMI (intra-node DMA) bandwidth multiplier in `(0, 1]` — models
    /// uniformly derated engines.
    pub xgmi_factor: f64,
    /// Number of compute-straggler nodes (placement drawn from seed).
    pub straggler_nodes: usize,
    /// Compute-time multiplier on straggler nodes, `>= 1`.
    pub straggler_factor: f64,
    /// NIC derate window length in seconds; `0` = the whole run. Window
    /// start instants are drawn from the seed.
    pub window_s: f64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            nic_nodes: 0,
            nic_factor: 1.0,
            flap_prob: 0.0,
            stuck_engines: 0,
            xgmi_factor: 1.0,
            straggler_nodes: 0,
            straggler_factor: 1.0,
            window_s: 0.0,
        }
    }
}

impl FaultSpec {
    /// True iff this spec injects nothing.
    pub fn is_healthy(&self) -> bool {
        (self.nic_nodes == 0 || self.nic_factor >= 1.0)
            && self.flap_prob <= 0.0
            && self.stuck_engines == 0
            && self.xgmi_factor >= 1.0
            && (self.straggler_nodes == 0 || self.straggler_factor <= 1.0)
    }

    /// Canned scenario by name (the CLI/bench chaos set).
    pub fn preset(name: &str) -> Option<FaultSpec> {
        match name {
            "none" | "healthy" => Some(FaultSpec::default()),
            // One node's NIC browns out to a quarter of nominal bandwidth.
            "nic-brownout" => Some(FaultSpec {
                nic_nodes: 1,
                nic_factor: 0.25,
                ..FaultSpec::default()
            }),
            // One node's NIC runs at half speed and flaps 15% of messages.
            "flaky-links" => Some(FaultSpec {
                nic_nodes: 1,
                nic_factor: 0.5,
                flap_prob: 0.15,
                ..FaultSpec::default()
            }),
            // One node computes 1.8x slower (thermal throttling, noisy
            // neighbor) — the lockstep TP batch gates on it.
            "straggler" => Some(FaultSpec {
                straggler_nodes: 1,
                straggler_factor: 1.8,
                ..FaultSpec::default()
            }),
            // Half the sDMA engines are stuck and the survivors run at
            // 3/4 bandwidth: the intra leg degrades, the NIC is fine.
            "engines-stuck" => Some(FaultSpec {
                stuck_engines: 8,
                xgmi_factor: 0.75,
                ..FaultSpec::default()
            }),
            _ => None,
        }
    }

    /// Parse a `--faults` spec: a preset name, or comma-separated clauses
    ///
    /// - `nic=N:F` — N nodes with NIC bandwidth × F (0 < F <= 1)
    /// - `flap=P` — per-message flap probability on derated nodes
    /// - `engines=K` — K stuck sDMA engines per GPU
    /// - `xgmi=F` — intra-node DMA bandwidth × F (0 < F <= 1)
    /// - `straggler=N:F` — N nodes computing F× slower (F >= 1)
    /// - `window=S` — NIC derate window length in seconds (0 = whole run)
    ///
    /// Errors are descriptive — malformed clauses never fail silently.
    pub fn parse(spec: &str) -> Result<FaultSpec, String> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Err("empty fault spec: want a preset (nic-brownout, flaky-links, \
                 straggler, engines-stuck, none) or clauses like nic=1:0.25,flap=0.1"
                .to_string());
        }
        if let Some(p) = FaultSpec::preset(spec) {
            return Ok(p);
        }
        let mut out = FaultSpec::default();
        for clause in spec.split(',') {
            let (key, val) = clause
                .split_once('=')
                .ok_or_else(|| format!("fault clause `{clause}` is not key=value"))?;
            let unit = |v: &str, key: &str| -> Result<f64, String> {
                let f: f64 = v
                    .parse()
                    .map_err(|_| format!("fault clause `{key}`: `{v}` is not a number"))?;
                if !(f > 0.0 && f <= 1.0) {
                    return Err(format!(
                        "fault clause `{key}`: factor {f} out of range (0, 1]"
                    ));
                }
                Ok(f)
            };
            match key {
                "nic" => {
                    let (n, f) = val.split_once(':').ok_or_else(|| {
                        format!("fault clause `nic`: want nic=NODES:FACTOR, got `{val}`")
                    })?;
                    out.nic_nodes = n
                        .parse()
                        .map_err(|_| format!("fault clause `nic`: `{n}` is not a node count"))?;
                    out.nic_factor = unit(f, "nic")?;
                }
                "flap" => {
                    let p: f64 = val
                        .parse()
                        .map_err(|_| format!("fault clause `flap`: `{val}` is not a number"))?;
                    if !(0.0..1.0).contains(&p) {
                        return Err(format!(
                            "fault clause `flap`: probability {p} out of range [0, 1)"
                        ));
                    }
                    out.flap_prob = p;
                }
                "engines" => {
                    out.stuck_engines = val.parse().map_err(|_| {
                        format!("fault clause `engines`: `{val}` is not an engine count")
                    })?;
                }
                "xgmi" => out.xgmi_factor = unit(val, "xgmi")?,
                "straggler" => {
                    let (n, f) = val.split_once(':').ok_or_else(|| {
                        format!("fault clause `straggler`: want straggler=NODES:FACTOR, got `{val}`")
                    })?;
                    out.straggler_nodes = n.parse().map_err(|_| {
                        format!("fault clause `straggler`: `{n}` is not a node count")
                    })?;
                    out.straggler_factor = f.parse().map_err(|_| {
                        format!("fault clause `straggler`: `{f}` is not a number")
                    })?;
                    if out.straggler_factor < 1.0 {
                        return Err(format!(
                            "fault clause `straggler`: factor {} must be >= 1 (a multiplier \
                             on compute time)",
                            out.straggler_factor
                        ));
                    }
                }
                "window" => {
                    out.window_s = val.parse().map_err(|_| {
                        format!("fault clause `window`: `{val}` is not a number of seconds")
                    })?;
                    if out.window_s < 0.0 {
                        return Err("fault clause `window`: negative window".to_string());
                    }
                }
                other => {
                    return Err(format!(
                        "unknown fault clause `{other}` (want nic/flap/engines/xgmi/\
                         straggler/window or a preset name)"
                    ))
                }
            }
        }
        Ok(out)
    }
}

/// One node's materialized health.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeHealth {
    /// NIC bandwidth multiplier (1.0 = healthy).
    pub nic_factor: f64,
    /// Per-message transient flap probability on this node's sends.
    pub flap_prob: f64,
    /// Stuck sDMA engines per GPU.
    pub stuck_engines: u8,
    /// xGMI bandwidth multiplier (1.0 = healthy).
    pub xgmi_factor: f64,
    /// Compute-time multiplier (1.0 = healthy, > 1 = straggler).
    pub compute_factor: f64,
    /// NIC derate window `[start, end)` in virtual ns; `None` = always.
    pub window_ns: Option<(u64, u64)>,
}

impl NodeHealth {
    fn healthy() -> Self {
        NodeHealth {
            nic_factor: 1.0,
            flap_prob: 0.0,
            stuck_engines: 0,
            xgmi_factor: 1.0,
            compute_factor: 1.0,
            window_ns: None,
        }
    }

    /// True iff nothing on this node is degraded.
    pub fn is_healthy(&self) -> bool {
        self.nic_factor >= 1.0
            && self.flap_prob <= 0.0
            && self.stuck_engines == 0
            && self.xgmi_factor >= 1.0
            && self.compute_factor <= 1.0
    }
}

/// The materialized fault schedule: a pure function of
/// `(spec, num_nodes, seed)`. See the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed the plan (and its per-message flap draws) derive from.
    pub seed: u64,
    /// Per-node health, indexed by node.
    pub nodes: Vec<NodeHealth>,
}

impl FaultPlan {
    /// An all-healthy plan for `num_nodes` nodes.
    pub fn healthy(num_nodes: usize) -> FaultPlan {
        FaultPlan {
            seed: 0,
            nodes: vec![NodeHealth::healthy(); num_nodes.max(1)],
        }
    }

    /// Materialize `spec` over `num_nodes` nodes. Deterministic: same
    /// `(spec, num_nodes, seed)` ⇒ identical plan, bit for bit.
    pub fn generate(spec: &FaultSpec, num_nodes: usize, seed: u64) -> FaultPlan {
        let n = num_nodes.max(1);
        let mut nodes = vec![NodeHealth::healthy(); n];
        if spec.is_healthy() {
            return FaultPlan { seed, nodes };
        }
        let mut rng = Rng::new(seed ^ FAULT_STREAM);
        let mut draw_nodes = |rng: &mut Rng, count: usize| -> Vec<usize> {
            let mut idx: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut idx);
            idx.truncate(count.min(n));
            idx
        };
        // NIC derates (and their flap probability + window) land together.
        if spec.nic_nodes > 0 && (spec.nic_factor < 1.0 || spec.flap_prob > 0.0) {
            for k in draw_nodes(&mut rng, spec.nic_nodes) {
                nodes[k].nic_factor = spec.nic_factor.max(MIN_DERATE_FACTOR);
                nodes[k].flap_prob = spec.flap_prob;
                if spec.window_s > 0.0 {
                    let len = (spec.window_s * 1e9) as u64;
                    let start = (rng.f64() * spec.window_s * 1e9) as u64;
                    nodes[k].window_ns = Some((start, start.saturating_add(len)));
                }
            }
        }
        // Compute stragglers draw independently of the NIC placement.
        if spec.straggler_nodes > 0 && spec.straggler_factor > 1.0 {
            for k in draw_nodes(&mut rng, spec.straggler_nodes) {
                nodes[k].compute_factor = spec.straggler_factor;
            }
        }
        // Engine faults are fleet-wide: the hierarchical planners require
        // homogeneous nodes, and lockstep collectives gate on the slowest
        // node anyway, so worst-node and fleet-wide semantics coincide.
        if spec.stuck_engines > 0 || spec.xgmi_factor < 1.0 {
            for h in nodes.iter_mut() {
                h.stuck_engines = spec.stuck_engines;
                h.xgmi_factor = spec.xgmi_factor.max(MIN_DERATE_FACTOR);
            }
        }
        FaultPlan { seed, nodes }
    }

    /// Number of nodes the plan covers.
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// True iff every node is healthy — the zero-perturbation contract:
    /// callers skip every fault branch when this holds.
    pub fn is_empty(&self) -> bool {
        self.nodes.iter().all(NodeHealth::is_healthy)
    }

    /// Worst NIC derate factor among `nodes[i]` where `keep[i]` (all nodes
    /// when `keep` is `None`).
    pub fn worst_nic_factor(&self, keep: Option<&[bool]>) -> f64 {
        self.fold_kept(keep, 1.0, |acc, h| acc.min(h.nic_factor))
    }

    /// Worst compute-straggler factor among the kept nodes.
    pub fn worst_compute_factor(&self, keep: Option<&[bool]>) -> f64 {
        self.fold_kept(keep, 1.0, |acc, h| acc.max(h.compute_factor))
    }

    /// Worst per-message flap probability among the kept nodes.
    pub fn worst_flap_prob(&self, keep: Option<&[bool]>) -> f64 {
        self.fold_kept(keep, 0.0, |acc, h| acc.max(h.flap_prob))
    }

    fn fold_kept(
        &self,
        keep: Option<&[bool]>,
        init: f64,
        f: impl Fn(f64, &NodeHealth) -> f64,
    ) -> f64 {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(i, _)| keep.map(|k| k.get(*i).copied().unwrap_or(true)).unwrap_or(true))
            .fold(init, |acc, (_, h)| f(acc, h))
    }

    /// Apply the plan to `cluster` through the existing `Topology` / NIC
    /// link tables: stuck engines shrink the per-GPU engine pool (clamped
    /// to ≥ 1), xGMI derates scale the intra-node link bandwidth, NIC
    /// derates scale the NIC model — all at the fleet-worst factor among
    /// the kept nodes. `keep[i] == false` drops node `i` (a drained
    /// node); at least one node always survives. An empty plan with no
    /// drains returns an exact clone (shared `Arc` link tables — the
    /// healthy path is untouched).
    pub fn derate_cluster(
        &self,
        cluster: &ClusterTopology,
        keep: Option<&[bool]>,
    ) -> ClusterTopology {
        let kept = match keep {
            Some(k) => (0..cluster.num_nodes())
                .filter(|i| k.get(*i).copied().unwrap_or(true))
                .count()
                .max(1),
            None => cluster.num_nodes(),
        };
        if self.is_empty() && kept == cluster.num_nodes() {
            return cluster.clone();
        }
        let node = cluster.node(0);
        let g = node.num_gpus;
        let stuck = self
            .nodes
            .iter()
            .map(|h| h.stuck_engines)
            .max()
            .unwrap_or(0);
        let engines = node.engines_per_gpu.saturating_sub(stuck).max(1);
        let xgmi_factor = self
            .fold_kept(keep, 1.0, |acc, h| acc.min(h.xgmi_factor))
            .max(MIN_DERATE_FACTOR);
        // Read the nominal bandwidths back off the link tables.
        let xgmi_gbps = if g >= 2 {
            node.link(node.link_index(NodeId::Gpu(0), NodeId::Gpu(1)))
                .bw_bytes_per_ns
        } else {
            64.0
        };
        let pcie_gbps = node
            .link(node.link_index(NodeId::Gpu(0), NodeId::Cpu))
            .bw_bytes_per_ns;
        let derated = Topology::custom(g, engines, xgmi_gbps * xgmi_factor, pcie_gbps);
        let nic_factor = self.worst_nic_factor(keep).max(MIN_DERATE_FACTOR);
        let nic = NicModel {
            bw_bytes_per_ns: cluster.nic.bw_bytes_per_ns * nic_factor,
            ..cluster.nic.clone()
        };
        ClusterTopology::homogeneous(kept, derated, nic)
    }

    /// The inter-leg flap view over the kept nodes (compacted to the
    /// surviving node order), or `None` when no kept node flaps — the
    /// hierarchical executors take the healthy code path in that case.
    pub fn link_health(&self, keep: Option<&[bool]>) -> Option<LinkHealth> {
        let flap: Vec<f64> = self
            .nodes
            .iter()
            .enumerate()
            .filter(|(i, _)| keep.map(|k| k.get(*i).copied().unwrap_or(true)).unwrap_or(true))
            .map(|(_, h)| h.flap_prob)
            .collect();
        if flap.iter().all(|&p| p <= 0.0) {
            return None;
        }
        Some(LinkHealth {
            flap,
            retry: RetryPolicy::default(),
            seed: self.seed,
        })
    }
}

/// Timeout-watchdog + retry policy for flapped NIC messages, in virtual
/// nanoseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Silence after a send before the watchdog declares it lost
    /// (a few NIC base latencies: ack round-trip + margin).
    pub timeout_ns: f64,
    /// Base backoff before the first retransmit; doubles per attempt.
    pub backoff_ns: f64,
    /// Retransmission budget; exhausting it counts a hard timeout (the
    /// message is escalated and force-delivered so the collective still
    /// completes — flaps delay bytes, they never drop them).
    pub max_retries: u32,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            timeout_ns: 10_000.0,
            backoff_ns: 2_000.0,
            max_retries: 4,
        }
    }
}

/// Per-sender flap probabilities + the retry policy, consumed by
/// `cluster::hier::nic_exchange_arrivals_faulted`.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkHealth {
    /// `flap[k]`: probability any single message **sent by** node `k`
    /// transiently fails and must be retransmitted.
    pub flap: Vec<f64>,
    pub retry: RetryPolicy,
    /// Seed for the per-message draws.
    pub seed: u64,
}

impl LinkHealth {
    /// Uniform flap probability across `n` sender nodes (test/bench
    /// convenience).
    pub fn uniform(n: usize, prob: f64, seed: u64) -> LinkHealth {
        LinkHealth {
            flap: vec![prob; n],
            retry: RetryPolicy::default(),
            seed,
        }
    }

    /// Transient-failure count for the `sender → dest` message: a pure
    /// function of `(seed, sender, dest)` — independent of the order the
    /// executor walks messages in. Returns `(retransmissions, timed_out)`
    /// where `timed_out` marks an exhausted retry budget (escalated
    /// delivery).
    pub fn flaps(&self, sender: usize, dest: usize) -> (u32, bool) {
        let p = self.flap.get(sender).copied().unwrap_or(0.0);
        if p <= 0.0 {
            return (0, false);
        }
        let key = ((sender as u64) << 32 | dest as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(self.seed ^ FAULT_STREAM ^ key);
        let mut fails = 0u32;
        while fails < self.retry.max_retries && rng.chance(p) {
            fails += 1;
        }
        (fails, fails == self.retry.max_retries)
    }
}

/// Retry/timeout counters accumulated by a faulted collective run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// NIC message retransmissions (each preceded by a watchdog firing
    /// and an exponential backoff).
    pub retries: u64,
    /// Messages that exhausted the retry budget and were escalated.
    pub timeouts: u64,
}

impl FaultStats {
    /// Accumulate another run's counters.
    pub fn absorb(&mut self, other: FaultStats) {
        self.retries += other.retries;
        self.timeouts += other.timeouts;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_presets_and_clauses() {
        assert!(FaultSpec::parse("none").unwrap().is_healthy());
        assert!(FaultSpec::parse("healthy").unwrap().is_healthy());
        let b = FaultSpec::parse("nic-brownout").unwrap();
        assert_eq!((b.nic_nodes, b.nic_factor), (1, 0.25));
        let s = FaultSpec::parse("nic=2:0.5,flap=0.1,engines=4,xgmi=0.8,straggler=1:1.5,window=2")
            .unwrap();
        assert_eq!(s.nic_nodes, 2);
        assert_eq!(s.nic_factor, 0.5);
        assert_eq!(s.flap_prob, 0.1);
        assert_eq!(s.stuck_engines, 4);
        assert_eq!(s.xgmi_factor, 0.8);
        assert_eq!((s.straggler_nodes, s.straggler_factor), (1, 1.5));
        assert_eq!(s.window_s, 2.0);
        assert!(!s.is_healthy());
    }

    #[test]
    fn parse_errors_are_descriptive() {
        for (bad, needle) in [
            ("", "empty fault spec"),
            ("bogus", "key=value"),
            ("nic=1", "nic=NODES:FACTOR"),
            ("nic=x:0.5", "not a node count"),
            ("nic=1:1.5", "out of range"),
            ("nic=1:0", "out of range"),
            ("flap=1.5", "out of range"),
            ("straggler=1:0.5", "must be >= 1"),
            ("window=-1", "negative"),
            ("teapot=1", "unknown fault clause"),
        ] {
            let err = FaultSpec::parse(bad).unwrap_err();
            assert!(err.contains(needle), "`{bad}` -> `{err}` missing `{needle}`");
        }
    }

    #[test]
    fn generate_is_pure_and_seed_sensitive() {
        let spec = FaultSpec::parse("nic=1:0.25,flap=0.1,straggler=1:1.5").unwrap();
        let a = FaultPlan::generate(&spec, 4, 7);
        let b = FaultPlan::generate(&spec, 4, 7);
        assert_eq!(a, b, "same (spec, n, seed) must materialize identically");
        assert!(!a.is_empty());
        // Some seed in a small set must move the sick node.
        let sick = |p: &FaultPlan| p.nodes.iter().position(|h| h.nic_factor < 1.0).unwrap();
        assert!(
            (0..16u64).any(|s| sick(&FaultPlan::generate(&spec, 4, s)) != sick(&a)),
            "fault placement never varies with the seed"
        );
    }

    #[test]
    fn healthy_spec_yields_empty_plan_and_exact_clone() {
        let plan = FaultPlan::generate(&FaultSpec::default(), 2, 99);
        assert!(plan.is_empty());
        let cluster = ClusterTopology::mi300x(2);
        let same = plan.derate_cluster(&cluster, None);
        assert_eq!(same.num_nodes(), 2);
        assert_eq!(same.nic.bw_bytes_per_ns, cluster.nic.bw_bytes_per_ns);
        assert_eq!(
            same.node(0).engines_per_gpu,
            cluster.node(0).engines_per_gpu
        );
        assert!(plan.link_health(None).is_none());
    }

    #[test]
    fn derate_scales_nic_and_engine_tables() {
        let spec = FaultSpec::parse("nic=1:0.25,engines=8,xgmi=0.5").unwrap();
        let plan = FaultPlan::generate(&spec, 2, 3);
        let cluster = ClusterTopology::mi300x(2);
        let d = plan.derate_cluster(&cluster, None);
        assert!((d.nic.bw_bytes_per_ns - 50.0 * 0.25).abs() < 1e-12);
        assert_eq!(d.node(0).engines_per_gpu, 8);
        let xgmi = d
            .node(0)
            .link(d.node(0).link_index(NodeId::Gpu(0), NodeId::Gpu(1)))
            .bw_bytes_per_ns;
        assert!((xgmi - 32.0).abs() < 1e-12);
        // NIC latency terms are untouched — derates hit bandwidth only.
        assert_eq!(d.nic.t_latency, cluster.nic.t_latency);
        assert_eq!(d.nic.t_post_per_msg, cluster.nic.t_post_per_msg);
    }

    #[test]
    fn drained_nodes_shrink_and_drop_their_derates() {
        let spec = FaultSpec::parse("nic=1:0.25,flap=0.2").unwrap();
        let plan = FaultPlan::generate(&spec, 2, 3);
        let sick = plan.nodes.iter().position(|h| h.nic_factor < 1.0).unwrap();
        let keep: Vec<bool> = (0..2).map(|i| i != sick).collect();
        let cluster = ClusterTopology::mi300x(2);
        let d = plan.derate_cluster(&cluster, Some(&keep));
        assert_eq!(d.num_nodes(), 1);
        // The survivor is healthy, so the NIC model is back to nominal.
        assert_eq!(d.nic.bw_bytes_per_ns, cluster.nic.bw_bytes_per_ns);
        assert!(plan.link_health(Some(&keep)).is_none());
        assert!(plan.link_health(None).is_some());
    }

    #[test]
    fn all_nodes_drained_clamps_to_one() {
        let plan = FaultPlan::healthy(2);
        let cluster = ClusterTopology::mi300x(2);
        let d = plan.derate_cluster(&cluster, Some(&[false, false]));
        assert_eq!(d.num_nodes(), 1);
    }

    #[test]
    fn all_engines_stuck_clamps_to_one_engine() {
        let spec = FaultSpec::parse("engines=255,xgmi=0.5").unwrap();
        let plan = FaultPlan::generate(&spec, 1, 0);
        let d = plan.derate_cluster(&ClusterTopology::mi300x(1), None);
        assert_eq!(d.node(0).engines_per_gpu, 1);
    }

    #[test]
    fn flap_draws_are_pure_per_message() {
        let h = LinkHealth::uniform(4, 0.5, 42);
        for s in 0..4 {
            for d in 0..4 {
                assert_eq!(h.flaps(s, d), h.flaps(s, d));
            }
        }
        // High probability ⇒ some message flaps; zero ⇒ none.
        let any = (0..4).any(|s| (0..4).any(|d| h.flaps(s, d).0 > 0));
        assert!(any, "p=0.5 over 16 independent draws must flap something");
        let quiet = LinkHealth::uniform(4, 0.0, 42);
        assert_eq!(quiet.flaps(0, 1), (0, false));
    }

    #[test]
    fn windows_are_drawn_when_requested() {
        let spec = FaultSpec::parse("nic=2:0.5,window=1").unwrap();
        let plan = FaultPlan::generate(&spec, 2, 11);
        for h in plan.nodes.iter().filter(|h| h.nic_factor < 1.0) {
            let (s, e) = h.window_ns.expect("derated node must carry a window");
            assert!(e > s && e - s == 1_000_000_000);
        }
    }
}
