//! Hierarchical all-gather / all-to-all over `nodes × gpus` ranks.
//!
//! A global collective is lowered into (a) an **intra-node DMA phase** —
//! per-node rounds of the existing single-node planners (`pcpy` / `bcst` /
//! `swap` / `b2b` via [`CollectivePlan`]), rebased into the global buffer
//! layout and executed on the per-node DES — and (b) an **inter-node
//! exchange phase** over the NIC model. The decomposition keeps the small
//! leg on the NIC and the large leg on xGMI:
//!
//! - **All-gather** (inter → intra): rank `(k,g)` first sends its own chunk
//!   to rank `(k',g)` of every other node (NIC volume `c·(n−1)` per rank),
//!   then each node runs `n` intra rounds, round `k'` = a flat all-gather of
//!   node block `k'` (xGMI volume `n·c·(g−1)` per rank). Under a
//!   [`InterSchedule::Pipelined`] schedule, round `k'` triggers as soon as
//!   block `k'` lands; [`InterSchedule::Sequential`] barriers all rounds
//!   behind the full inter leg with a single trigger write.
//! - **All-to-all** (intra → inter): round `k'` is a flat all-to-all of the
//!   input block destined to node `k'`, staging the outbound block ordered
//!   by local source; completed blocks then stream to their peer nodes
//!   (pipelined: per-round, as each completes; sequential: after all
//!   rounds). The in-place `swap` variant stages inside the input buffer
//!   itself — the post-swap block *is* the outbound block — and the inter
//!   exchange is a buffered full-duplex block swap.
//!
//! Buffer layout (per GPU, chunk `c = size/world`): input `[0, size)` by
//! global destination (AA) / output slot (AG); out-of-place AA output at
//! [`aa_out_base`]`(size)` by global source; AA staging region after that.
//!
//! Chunk bookkeeping is verified `collectives::verify`-style: buffers are
//! initialized with per-(rank, chunk) patterns, the intra rounds execute
//! functionally on the per-node DES, the inter exchange moves real bytes
//! between the per-node memories, and the final placement is checked
//! against the mathematical definition (and, in `tests/prop_cluster.rs`,
//! byte-for-byte against the flat single-node planner at the same world
//! size).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::collectives::cache::{get_or_build, WorldShape};
use crate::obs::{self, record, SpanKind, Track};
use crate::collectives::exec::{self, PRELAUNCH_PARK_NS};
use crate::collectives::plan::{aa_out_base, CollectivePlan};
use crate::collectives::verify::pattern;
use crate::collectives::{CollectiveKind, Strategy, Variant};
use crate::sim::clock::ns;
use crate::sim::command::{Addr, Command};
use crate::sim::host::HostOp;
use crate::sim::topology::{NodeId, Topology};
use crate::sim::{HostId, LatencyModel, Sim, SimConfig, SimTime, SignalId};

use super::faults::{FaultStats, LinkHealth};
use super::selector::{ClusterChoice, InterSchedule};
use super::topology::{ClusterTopology, NicModel, RankPath};

/// Planner limit on node count (mark names are static).
pub const MAX_NODES: usize = 16;

pub(crate) const ROUND_MARKS: [&str; MAX_NODES] = [
    "round0", "round1", "round2", "round3", "round4", "round5", "round6", "round7", "round8",
    "round9", "round10", "round11", "round12", "round13", "round14", "round15",
];

/// Base of the all-to-all staging region (outbound blocks ordered by local
/// source), after the input and out-of-place output regions.
pub fn aa_stage_base(size: u64) -> u64 {
    aa_out_base(size) + size + 256
}

/// Execution options for a hierarchical collective.
#[derive(Debug, Clone, Default)]
pub struct HierRunOptions {
    /// Intra-node latency calibration (shared by every node).
    pub latency: LatencyModel,
    /// Initialize buffers, move bytes for real and verify the placement.
    pub verify: bool,
    /// Record trace spans on the per-node DES instances (determinism tests
    /// compare span counts across cached/fresh episodes).
    pub trace: bool,
    /// Transient NIC-flap model for the inter leg (fault injection,
    /// [`crate::cluster::faults`]). `None` — the default, and the only
    /// value healthy callers ever pass — takes the exact original code
    /// path: the faulted exchange functions are never called, so the
    /// healthy timeline stays bit-identical.
    pub link_faults: Option<LinkHealth>,
}

/// Outcome of one hierarchical collective.
#[derive(Debug, Clone)]
pub struct HierResult {
    /// End-to-end critical path in ns (trigger → last rank complete).
    pub latency_ns: u64,
    /// NIC span on the critical path: the inter-leg delivery window (AG)
    /// or the post-intra NIC tail (AA). 0 for a single node.
    pub inter_ns: u64,
    /// Remaining (intra-node DES) span: `latency_ns − inter_ns`.
    pub intra_ns: u64,
    /// Total data-move commands across all nodes' intra rounds.
    pub data_cmds: usize,
    /// NIC messages posted cluster-wide.
    pub nic_messages: usize,
    /// Functional placement check (None when not requested).
    pub verified: Option<bool>,
    /// Retry/timeout counters from the flap model (all zero on a healthy
    /// run — the fault path is never entered).
    pub faults: FaultStats,
}

/// Cache key for a node's rebased intra rounds: the flat plan-cache key
/// ([`crate::collectives::cache::PlanKey`] analogue) extended with the
/// node coordinates that drive the rebase AND the inter schedule the
/// rounds will run under. Today every schedule executes structurally
/// identical rounds (triggers are applied at queue time, never baked into
/// the plan), but keying on the schedule guarantees an
/// [`InterSchedule::Overlapped`] episode can never be served a build made
/// for a `Sequential` one if a future builder specializes — the cost is a
/// handful of duplicate entries, the poison test below proves the
/// isolation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct RoundsKey {
    kind: CollectiveKind,
    variant: Variant,
    schedule: InterSchedule,
    size: u64,
    num_nodes: u8,
    node_idx: u8,
    shape: WorldShape,
}

/// Runaway guard, mirroring the flat plan cache's flush-at-cap policy.
const ROUNDS_CACHE_CAP: usize = 1024;

static ROUNDS: OnceLock<Mutex<HashMap<RoundsKey, Arc<Vec<CollectivePlan>>>>> = OnceLock::new();
static ROUNDS_HITS: AtomicU64 = AtomicU64::new(0);
static ROUNDS_MISSES: AtomicU64 = AtomicU64::new(0);

/// Lifetime (hit, miss) counters of the rounds cache, mirroring the flat
/// plan cache's [`crate::collectives::cache::stats`] — the serving CLI
/// summary reports both so replay efficiency is visible per run.
pub fn rounds_cache_stats() -> (u64, u64) {
    (
        ROUNDS_HITS.load(Ordering::Relaxed),
        ROUNDS_MISSES.load(Ordering::Relaxed),
    )
}

/// [`build_node_rounds`] through the cross-episode cache (§Perf pass): the
/// rebased per-node scripts are a pure function of the key, so selector
/// calibration, `coordinator::comm`'s per-batch-shape sizing and repeated
/// hierarchical episodes replay one shared build.
///
/// `chunk` is deliberately NOT part of the key: it must equal
/// `size / (num_nodes * gpus_per_node)` (the hierarchical layout's only
/// chunking), which the assert below enforces so a future caller with a
/// different chunking cannot silently receive mismatched cached rounds.
/// The full [`ClusterChoice`] (intra variant AND inter schedule) is part
/// of the key — see `RoundsKey`.
pub fn cached_node_rounds(
    kind: CollectiveKind,
    node_topo: &Topology,
    num_nodes: usize,
    node_idx: usize,
    size: u64,
    chunk: u64,
    choice: ClusterChoice,
) -> Arc<Vec<CollectivePlan>> {
    assert!(num_nodes <= MAX_NODES && node_idx < num_nodes.max(1));
    assert_eq!(
        chunk * num_nodes as u64 * node_topo.num_gpus as u64,
        size,
        "chunk must be size / world (it is excluded from the cache key)"
    );
    let key = RoundsKey {
        kind,
        variant: choice.intra,
        schedule: choice.inter,
        size,
        num_nodes: num_nodes as u8,
        node_idx: node_idx as u8,
        shape: WorldShape::of(node_topo),
    };
    let table = ROUNDS.get_or_init(|| Mutex::new(HashMap::new()));
    let (rounds, hit) = get_or_build(table, ROUNDS_CACHE_CAP, key, || {
        build_node_rounds(kind, node_topo, num_nodes, node_idx, size, chunk, choice.intra)
    });
    let counter = if hit { &ROUNDS_HITS } else { &ROUNDS_MISSES };
    counter.fetch_add(1, Ordering::Relaxed);
    rounds
}

/// Build node `node_idx`'s intra rounds for the global collective: one
/// rebased single-node [`CollectivePlan`] per node block.
pub fn build_node_rounds(
    kind: CollectiveKind,
    node_topo: &Topology,
    num_nodes: usize,
    node_idx: usize,
    size: u64,
    chunk: u64,
    variant: crate::collectives::Variant,
) -> Vec<CollectivePlan> {
    let g = node_topo.num_gpus;
    let intra = g as u64 * chunk;
    let mut rounds = Vec::with_capacity(num_nodes);
    for k in 0..num_nodes {
        let base = k as u64 * intra;
        let mut p = exec::build_plan(kind, variant, node_topo, intra);
        match kind {
            CollectiveKind::AllGather => rebase_plan(&mut p, u64::MAX, base, 0),
            CollectiveKind::AllToAll => {
                if variant.strategy == Strategy::Swap {
                    // In-place: the post-swap input block IS the staged
                    // outbound block (or the final block when k == self).
                    rebase_plan(&mut p, u64::MAX, base, 0);
                } else {
                    let out = if k == node_idx {
                        aa_out_base(size) + base
                    } else {
                        aa_stage_base(size) + base
                    };
                    rebase_plan(&mut p, aa_out_base(intra), base, out);
                    if k != node_idx {
                        // The flat planner leaves each GPU's own chunk in
                        // place ("frameworks do the local move"); here the
                        // cluster layer IS the framework: the diagonal must
                        // reach the staging block to ride the NIC message.
                        for r in &mut p.ranks {
                            let gpu = r.gpu;
                            let diag = Command::Copy {
                                src: Addr::new(NodeId::Gpu(gpu), base + gpu as u64 * chunk),
                                dst: Addr::new(
                                    NodeId::Gpu(gpu),
                                    aa_stage_base(size) + base + gpu as u64 * chunk,
                                ),
                                len: chunk,
                            };
                            // Hazard-free vs the round's other commands
                            // (disjoint ranges): ride the first engine.
                            r.engines[0].cmds.push(diag);
                        }
                    }
                }
            }
        }
        rounds.push(p);
    }
    rounds
}

/// Shift every address in `plan`: offsets below `split` move to
/// `in_base + offset` (input region), offsets at or above it to
/// `out_base + (offset − split)` (output region). `split = u64::MAX`
/// rebases a single-region plan.
fn rebase_plan(plan: &mut CollectivePlan, split: u64, in_base: u64, out_base: u64) {
    let shift = |a: Addr| -> Addr {
        if a.offset >= split {
            Addr::new(a.node, out_base + (a.offset - split))
        } else {
            Addr::new(a.node, in_base + a.offset)
        }
    };
    for r in &mut plan.ranks {
        for e in &mut r.engines {
            for c in &mut e.cmds {
                match c {
                    Command::Copy { src, dst, .. } => {
                        *src = shift(*src);
                        *dst = shift(*dst);
                    }
                    Command::Bcst {
                        src, dst0, dst1, ..
                    } => {
                        *src = shift(*src);
                        *dst0 = shift(*dst0);
                        *dst1 = shift(*dst1);
                    }
                    Command::Swap { a, b, .. } => {
                        *a = shift(*a);
                        *b = shift(*b);
                    }
                    _ => {}
                }
            }
        }
    }
}

/// Absolute trigger instant `t0` for a prelaunched hierarchical phase (0
/// when not prelaunching). Unlike the flat executor's relative `Delay`, the
/// NIC leg aligns to an absolute instant, so budget the worst rank's stream
/// creation cost from the latency model (`engine_stream` adds the poll gate
/// + completion atomic) and park the flat executor's margin on top.
pub(crate) fn prelaunch_t0(
    rounds: &[CollectivePlan],
    num_gpus: u8,
    l: &LatencyModel,
    prelaunch: bool,
) -> SimTime {
    if !prelaunch {
        return 0;
    }
    let setup: SimTime = (0..num_gpus)
        .map(|g| {
            rounds
                .iter()
                .flat_map(|p| p.ranks.iter().filter(|r| r.gpu == g))
                .flat_map(|r| r.engines.iter())
                .map(|ep| {
                    ns(l.control_ns(ep.cmds.len() + 2, ep.batched_control)) + ns(l.t_doorbell)
                })
                .sum()
        })
        .max()
        .unwrap_or(0);
    setup + PRELAUNCH_PARK_NS
}

/// NIC messages posted cluster-wide by a same-local-rank exchange: rank
/// (k,g) talks to rank (k',g) of every other node, one (gathered) message
/// per partner. Each pair is classified through the topology — cross-node
/// pairs have no intra-node link ([`Topology::try_link_index`] returns
/// `None`) and resolve to NIC links.
pub(crate) fn count_nic_messages(cluster: &ClusterTopology) -> usize {
    let n = cluster.num_nodes();
    (0..cluster.world_size() as u32)
        .map(|r| {
            let (_, g) = cluster.locate(r);
            (0..n)
                .filter(|&k2| {
                    matches!(
                        cluster.path(r, cluster.global_rank(k2, g)),
                        Some(RankPath::Nic(_))
                    )
                })
                .count()
        })
        .sum()
}

/// NIC exchange timing shared by the hierarchical AA and RS inter legs:
/// every node streams one `payload`-byte message per peer node through its
/// single full-duplex port (posts and payloads serialize, propagation
/// pipelines), the message for destination `j` becoming eligible at
/// `ready[j]` under [`InterSchedule::Pipelined`] or at the phase maximum
/// under [`InterSchedule::Sequential`]. Homogeneous nodes ⇒ one sender
/// timeline per node. Returns the latest arrival (incl. the `observe`
/// host observation cost) per destination-node index.
pub(crate) fn nic_exchange_arrivals(
    nic: &NicModel,
    inter: InterSchedule,
    ready: &[f64],
    payload: u64,
    observe: f64,
) -> Vec<f64> {
    let n = ready.len();
    let all_ready = ready.iter().copied().fold(0f64, f64::max);
    let mut last_arrival = vec![0f64; n];
    for sender in 0..n {
        let mut port = 0f64;
        for (j, r) in ready.iter().enumerate() {
            if j == sender {
                continue;
            }
            let eligible = match inter {
                // Overlapped degenerates to per-block readiness inside a
                // single leg (the fusion lives across phases).
                InterSchedule::Pipelined | InterSchedule::Overlapped => *r,
                InterSchedule::Sequential => all_ready,
            };
            let start = eligible.max(port);
            port = start + nic.t_post_per_msg + nic.payload_ns(payload);
            last_arrival[j] = last_arrival[j].max(port + nic.t_latency + observe);
        }
    }
    last_arrival
}

/// One NIC message of an exchange, with its full port/flight timeline
/// (absolute f64 ns, same clock as [`nic_exchange_arrivals`]). Used only
/// by the tracing path: the latency-critical arrivals fold above is kept
/// untouched (bit-identical float evaluation order matters to the
/// determinism tests), and a unit test pins the two to each other.
#[derive(Debug, Clone, Copy)]
pub(crate) struct NicMsg {
    pub sender: usize,
    pub dest: usize,
    /// Port occupancy begins (post issued).
    pub start: f64,
    /// Port released (post + payload fully serialized).
    pub port_end: f64,
    /// Delivery incl. the receiving host's observe cost —
    /// `port_end + t_latency + observe`.
    pub arrive: f64,
}

/// Per-message mirror of [`nic_exchange_arrivals`]: the identical loop,
/// returning every message instead of folding the per-destination max.
pub(crate) fn nic_exchange_messages(
    nic: &NicModel,
    inter: InterSchedule,
    ready: &[f64],
    payload: u64,
    observe: f64,
) -> Vec<NicMsg> {
    let n = ready.len();
    let all_ready = ready.iter().copied().fold(0f64, f64::max);
    let mut msgs = Vec::with_capacity(n * n.saturating_sub(1));
    for sender in 0..n {
        let mut port = 0f64;
        for (j, r) in ready.iter().enumerate() {
            if j == sender {
                continue;
            }
            let eligible = match inter {
                InterSchedule::Pipelined | InterSchedule::Overlapped => *r,
                InterSchedule::Sequential => all_ready,
            };
            let start = eligible.max(port);
            port = start + nic.t_post_per_msg + nic.payload_ns(payload);
            msgs.push(NicMsg {
                sender,
                dest: j,
                start,
                port_end: port,
                arrive: port + nic.t_latency + observe,
            });
        }
    }
    msgs
}

/// [`nic_exchange_messages`] with the transient-flap model layered on:
/// each message's failure count is a pure draw from `health`
/// ([`LinkHealth::flaps`] — keyed by `(seed, sender, dest)`, independent
/// of walk order). Every failed attempt costs the timeout watchdog
/// ([`crate::cluster::faults::RetryPolicy::timeout_ns`] of silence after
/// the lost payload clears the port) plus an exponential backoff, and the
/// retransmission re-serializes through the sender's port — pessimistic
/// by design: a retry also delays the sender's later messages, which is
/// what a single-QP RDMA retransmit does. Messages that exhaust the
/// retry budget are escalated and force-delivered (`timed_out` counted):
/// flaps delay bytes, they never drop them, so retried collectives stay
/// byte-identical to the healthy placement. An all-zero flap table
/// reduces exactly to the healthy timeline (no draws are made).
pub(crate) fn nic_exchange_messages_faulted(
    nic: &NicModel,
    inter: InterSchedule,
    ready: &[f64],
    payload: u64,
    observe: f64,
    health: &LinkHealth,
) -> (Vec<NicMsg>, FaultStats) {
    let n = ready.len();
    let all_ready = ready.iter().copied().fold(0f64, f64::max);
    let mut msgs = Vec::with_capacity(n * n.saturating_sub(1));
    let mut stats = FaultStats::default();
    for sender in 0..n {
        let mut port = 0f64;
        for (j, r) in ready.iter().enumerate() {
            if j == sender {
                continue;
            }
            let eligible = match inter {
                InterSchedule::Pipelined | InterSchedule::Overlapped => *r,
                InterSchedule::Sequential => all_ready,
            };
            let start = eligible.max(port);
            port = start + nic.t_post_per_msg + nic.payload_ns(payload);
            let (fails, timed_out) = health.flaps(sender, j);
            for a in 0..fails {
                let resume =
                    port + health.retry.timeout_ns + health.retry.backoff_ns * 2f64.powi(a as i32);
                port = resume + nic.t_post_per_msg + nic.payload_ns(payload);
                stats.retries += 1;
            }
            if timed_out {
                stats.timeouts += 1;
            }
            msgs.push(NicMsg {
                sender,
                dest: j,
                start,
                port_end: port,
                arrive: port + nic.t_latency + observe,
            });
        }
    }
    (msgs, stats)
}

/// Per-destination last arrivals of the faulted exchange, **defined as
/// the fold** of [`nic_exchange_messages_faulted`] — one implementation,
/// so the tracing and latency views cannot drift (the healthy pair needs
/// a pinning test instead; here fold-consistency holds by construction).
pub(crate) fn nic_exchange_arrivals_faulted(
    nic: &NicModel,
    inter: InterSchedule,
    ready: &[f64],
    payload: u64,
    observe: f64,
    health: &LinkHealth,
) -> (Vec<f64>, FaultStats) {
    let (msgs, stats) = nic_exchange_messages_faulted(nic, inter, ready, payload, observe, health);
    let mut last = vec![0f64; ready.len()];
    for m in &msgs {
        last[m.dest] = last[m.dest].max(m.arrive);
    }
    (last, stats)
}

/// Emit port + flight spans for `msgs` into the active recorder (AA inter
/// leg, and the RS leg in `cluster::allreduce`). Port spans land on each
/// sender's exclusive [`Track::Nic`]; flights on the destination's
/// overlap-tolerant [`Track::NicFlight`].
pub(crate) fn emit_nic_msg_spans(rec: &mut record::Recorder, msgs: &[NicMsg]) {
    for m in msgs {
        rec.span(
            format!("send->{}", m.dest),
            SpanKind::Nic,
            Track::Nic {
                node: m.sender as u8,
            },
            ns(m.start),
            ns(m.port_end),
        );
        rec.span(
            format!("flight {}->{}", m.sender, m.dest),
            SpanKind::NicFlight,
            Track::NicFlight { node: m.dest as u8 },
            ns(m.port_end),
            ns(m.arrive),
        );
    }
}

/// Queue one node's per-rank host programs for all intra rounds onto its
/// DES. `triggers[i]` is the absolute time round `i` may start; rounds
/// sharing a trigger instant share ONE trigger write per rank (this is what
/// makes a sequential schedule's single barrier cheaper than pipelining's
/// per-block triggers). Prelaunch creates every round's poll-gated streams
/// in the setup epoch before `t0`.
pub(crate) fn queue_node_scripts(
    sim: &mut Sim,
    rounds: &[CollectivePlan],
    prelaunch: bool,
    t0: SimTime,
    triggers: &[SimTime],
) -> Vec<HostId> {
    assert_eq!(rounds.len(), triggers.len());
    let num_gpus = sim.cfg.topology.num_gpus;
    let mut order: Vec<usize> = (0..rounds.len()).collect();
    order.sort_by_key(|&i| (triggers[i], i));
    let mut groups: Vec<(SimTime, Vec<usize>)> = Vec::new();
    for &i in &order {
        match groups.last_mut() {
            Some((t, is)) if *t == triggers[i] => is.push(i),
            _ => groups.push((triggers[i], vec![i])),
        }
    }
    let mut hosts = Vec::new();
    for g in 0..num_gpus {
        let mut done: Vec<Vec<SignalId>> = vec![Vec::new(); rounds.len()];
        for (i, round) in rounds.iter().enumerate() {
            if let Some(r) = round.ranks.iter().find(|r| r.gpu == g) {
                done[i] = r.engines.iter().map(|_| sim.alloc_signal(0)).collect();
            }
        }
        let mut script = Vec::new();
        if prelaunch {
            let trig: Vec<SignalId> = groups.iter().map(|_| sim.alloc_signal(0)).collect();
            for (gi, (_, is)) in groups.iter().enumerate() {
                for &i in is {
                    let Some(r) = rounds[i].ranks.iter().find(|r| r.gpu == g) else {
                        continue;
                    };
                    for (ei, ep) in r.engines.iter().enumerate() {
                        script.push(HostOp::CreateCommands {
                            engine: ep.engine,
                            cmds: exec::engine_stream(ep, Some(trig[gi]), done[i][ei]),
                            api: exec::api_kind(ep),
                        });
                        script.push(HostOp::RingDoorbell { engine: ep.engine });
                    }
                }
            }
            script.push(HostOp::DelayUntil { at: t0 });
            script.push(HostOp::Mark { name: "start" });
            for (gi, (t, _)) in groups.iter().enumerate() {
                script.push(HostOp::DelayUntil { at: *t });
                script.push(HostOp::SetSignal {
                    signal: trig[gi],
                    value: 1,
                });
            }
        } else {
            script.push(HostOp::DelayUntil { at: t0 });
            script.push(HostOp::Mark { name: "start" });
            for (t, is) in &groups {
                script.push(HostOp::DelayUntil { at: *t });
                for &i in is {
                    let Some(r) = rounds[i].ranks.iter().find(|r| r.gpu == g) else {
                        continue;
                    };
                    for (ei, ep) in r.engines.iter().enumerate() {
                        script.push(HostOp::CreateCommands {
                            engine: ep.engine,
                            cmds: exec::engine_stream(ep, None, done[i][ei]),
                            api: exec::api_kind(ep),
                        });
                        script.push(HostOp::RingDoorbell { engine: ep.engine });
                    }
                }
            }
        }
        for &i in &order {
            for s in &done[i] {
                script.push(HostOp::WaitSignal {
                    signal: *s,
                    at_least: 1,
                });
            }
            script.push(HostOp::Mark {
                name: ROUND_MARKS[i],
            });
        }
        script.push(HostOp::Mark { name: "end" });
        hosts.push(sim.add_host(script, 0));
    }
    hosts
}

/// Run one hierarchical collective end to end: intra rounds on per-node
/// DES instances, inter exchange on the NIC model, placement optionally
/// verified byte-for-byte.
pub fn run_hier(
    kind: CollectiveKind,
    choice: ClusterChoice,
    cluster: &ClusterTopology,
    size: u64,
    opts: &HierRunOptions,
) -> HierResult {
    run_hier_full(kind, choice, cluster, size, opts).0
}

/// [`run_hier`], additionally returning the per-node simulators so callers
/// (equivalence tests, figure probes) can inspect the final memories. With
/// `verify` off only node 0 is simulated (homogeneous symmetry).
pub fn run_hier_full(
    kind: CollectiveKind,
    choice: ClusterChoice,
    cluster: &ClusterTopology,
    size: u64,
    opts: &HierRunOptions,
) -> (HierResult, Vec<Sim>) {
    let n = cluster.num_nodes();
    let gpn = cluster.gpus_per_node();
    assert!(n <= MAX_NODES, "at most {MAX_NODES} nodes supported");
    assert!(gpn >= 2, "hierarchical planners need ≥ 2 GPUs per node");
    assert!(
        choice.intra.strategy.applicable(kind),
        "{} not applicable to {}",
        choice.intra.strategy.name(),
        kind.name()
    );
    let w = cluster.world_size() as u64;
    assert!(
        size % w == 0 && size >= w,
        "size {size} must be a positive multiple of world size {w}"
    );
    if opts.verify {
        assert!(w <= 256, "verification patterns need world size ≤ 256");
    }
    let c = size / w;
    let intra = gpn as u64 * c;
    let in_place = choice.intra.strategy == Strategy::Swap;
    let prelaunch = choice.intra.prelaunch;
    let observe = opts.latency.t_host_observe;
    let nic = cluster.nic.clone();

    // Tracing gate: one thread-local check per episode, zero work when no
    // recorder is installed or the caller did not opt in.
    let emitting = opts.trace && record::active();
    let episode = if emitting {
        record::with(|r| r.open_episode(&format!("collective:{}", kind.name())))
    } else {
        None
    };

    // Homogeneous nodes ⇒ identical per-node timing: simulate only node 0
    // for timing sweeps, every node when moving bytes for verification.
    let sim_nodes = if opts.verify { n } else { 1 };
    let mut sims: Vec<Sim> = (0..sim_nodes)
        .map(|k| {
            Sim::new(SimConfig {
                topology: cluster.node(k).clone(),
                latency: opts.latency.clone(),
                functional: opts.verify,
                trace: opts.trace,
            })
        })
        .collect();
    let rounds: Vec<Arc<Vec<CollectivePlan>>> = (0..sim_nodes)
        .map(|k| cached_node_rounds(kind, cluster.node(k), n, k, size, c, choice))
        .collect();

    // Prelaunch setup epoch: stream creation + doorbells happen before the
    // collective triggers at t0.
    let t0 = prelaunch_t0(&rounds[0], gpn, &opts.latency, prelaunch);
    let data_cmds = rounds[0].iter().map(|p| p.total_data_cmds()).sum::<usize>() * n;
    let nic_messages = count_nic_messages(cluster);
    // Flap-retry counters; stays zero unless the faulted exchange runs
    // (the AG inter leg is derate-only: its chunk sends ride `leg_ns`
    // directly and do not model per-message flaps).
    let mut fault_stats = FaultStats::default();

    if opts.verify {
        init_buffers_cluster(&mut sims, kind, cluster, size, in_place);
    }

    let (latency_ns, inter_ns) = match kind {
        CollectiveKind::AllGather => {
            // Inter leg first: every rank's own chunk crosses the NIC. The
            // bytes are staged into the receivers' memories up front (they
            // are initial data); the DES rounds still wait for the modeled
            // arrival times before touching them.
            if opts.verify && n > 1 {
                exchange_ag(&mut sims, cluster, c);
            }
            let inter = if n > 1 {
                ns(nic.leg_ns(n - 1, c) + observe)
            } else {
                0
            };
            let mut end_max: SimTime = 0;
            for (k, sim) in sims.iter_mut().enumerate() {
                let triggers: Vec<SimTime> = (0..n)
                    .map(|k2| {
                        if n == 1 {
                            t0
                        } else {
                            match choice.inter {
                                InterSchedule::Sequential => t0 + inter,
                                InterSchedule::Pipelined | InterSchedule::Overlapped => {
                                    if k2 == k {
                                        t0
                                    } else {
                                        // Ring send order: node k2's j-th
                                        // message reaches node (k2+j) mod n.
                                        let j = (k + n - k2) % n;
                                        t0 + ns(nic.arrival_ns(j, c) + observe)
                                    }
                                }
                            }
                        }
                    })
                    .collect();
                let hosts = queue_node_scripts(sim, &rounds[k], prelaunch, t0, &triggers);
                let out = sim.run();
                assert!(
                    out.deadlocked.is_empty(),
                    "hier allgather deadlocked on node {k}: {:?}",
                    out.deadlocked
                );
                for h in hosts {
                    end_max = end_max.max(sim.host(h).mark("end").unwrap());
                }
            }
            if emitting {
                record::with(|r| {
                    for (k, sim) in sims.iter().enumerate() {
                        obs::lift_sim_trace(r, k as u8, &sim.trace);
                    }
                    // Synthesize the inter-leg NIC timeline for every node
                    // (homogeneous symmetry — emitted even when only node 0
                    // was simulated): sender k2's p-th message serializes on
                    // its port, then flies to node (k2+p) mod n, matching
                    // the round-trigger formula above.
                    if n > 1 {
                        let step = nic.t_post_per_msg + nic.payload_ns(c);
                        for k2 in 0..n {
                            for p in 1..n {
                                let dest = (k2 + p) % n;
                                let port_s = t0 + ns((p - 1) as f64 * step);
                                let port_e = t0 + ns(p as f64 * step);
                                r.span(
                                    format!("send->{dest}"),
                                    SpanKind::Nic,
                                    Track::Nic { node: k2 as u8 },
                                    port_s,
                                    port_e,
                                );
                                r.span(
                                    format!("flight {k2}->{dest}"),
                                    SpanKind::NicFlight,
                                    Track::NicFlight { node: dest as u8 },
                                    port_e,
                                    t0 + ns(nic.arrival_ns(p, c)),
                                );
                            }
                        }
                    }
                    r.measure(kind.name(), t0, end_max);
                });
            }
            (end_max - t0, inter)
        }
        CollectiveKind::AllToAll => {
            // Intra rounds first (all triggered at t0), then the staged
            // blocks stream over the NIC.
            let triggers = vec![t0; n];
            let mut round_done = vec![0u64; n];
            let mut end_max: SimTime = 0;
            for (k, sim) in sims.iter_mut().enumerate() {
                let hosts = queue_node_scripts(sim, &rounds[k], prelaunch, t0, &triggers);
                let out = sim.run();
                assert!(
                    out.deadlocked.is_empty(),
                    "hier alltoall deadlocked on node {k}: {:?}",
                    out.deadlocked
                );
                for h in hosts {
                    let host = sim.host(h);
                    end_max = end_max.max(host.mark("end").unwrap());
                    for (j, rd) in round_done.iter_mut().enumerate() {
                        *rd = (*rd).max(host.mark(ROUND_MARKS[j]).unwrap());
                    }
                }
            }
            if opts.verify && n > 1 {
                exchange_aa(&mut sims, cluster, size, in_place);
            }
            if n == 1 {
                if emitting {
                    record::with(|r| {
                        for (k, sim) in sims.iter().enumerate() {
                            obs::lift_sim_trace(r, k as u8, &sim.trace);
                        }
                        r.measure(kind.name(), t0, end_max);
                    });
                }
                (end_max - t0, 0)
            } else {
                // Port-serialized sends, one per remote block, scheduled at
                // block readiness (pipelined) or after the whole intra
                // phase (sequential). With a flap model installed the
                // faulted exchange models watchdog + backoff retries; the
                // healthy arm is the untouched original path.
                let ready: Vec<f64> = round_done.iter().map(|&rd| rd as f64).collect();
                let last_arrival = match &opts.link_faults {
                    None => nic_exchange_arrivals(&nic, choice.inter, &ready, intra, observe),
                    Some(h) => {
                        let (arr, fs) = nic_exchange_arrivals_faulted(
                            &nic,
                            choice.inter,
                            &ready,
                            intra,
                            observe,
                            h,
                        );
                        fault_stats.absorb(fs);
                        arr
                    }
                };
                let mut total = 0f64;
                for (j, arr) in last_arrival.iter().enumerate() {
                    total = total.max(arr.max(round_done[j] as f64));
                }
                let latency = ns(total) - t0;
                let intra_span = round_done.iter().copied().max().unwrap() - t0;
                if emitting {
                    let msgs = match &opts.link_faults {
                        None => nic_exchange_messages(&nic, choice.inter, &ready, intra, observe),
                        Some(h) => {
                            nic_exchange_messages_faulted(
                                &nic,
                                choice.inter,
                                &ready,
                                intra,
                                observe,
                                h,
                            )
                            .0
                        }
                    };
                    record::with(|r| {
                        for (k, sim) in sims.iter().enumerate() {
                            obs::lift_sim_trace(r, k as u8, &sim.trace);
                        }
                        emit_nic_msg_spans(r, &msgs);
                        r.measure(kind.name(), t0, t0 + latency);
                    });
                }
                (latency, latency.saturating_sub(intra_span))
            }
        }
    };

    if matches!(episode, Some((_, true))) {
        record::with(|r| r.close_episode());
    }

    let verified = if opts.verify {
        Some(check_cluster(&sims, kind, cluster, size, in_place))
    } else {
        None
    };

    (
        HierResult {
            latency_ns,
            inter_ns,
            intra_ns: latency_ns.saturating_sub(inter_ns),
            data_cmds,
            nic_messages,
            verified,
            faults: fault_stats,
        },
        sims,
    )
}

/// Initialize every rank's buffers with the global verification patterns
/// (`collectives::verify::pattern` keyed by global rank / global chunk).
fn init_buffers_cluster(
    sims: &mut [Sim],
    kind: CollectiveKind,
    cluster: &ClusterTopology,
    size: u64,
    in_place: bool,
) {
    let w = cluster.world_size() as u32;
    let c = size / w as u64;
    for (k, sim) in sims.iter_mut().enumerate() {
        for g in 0..cluster.gpus_per_node() {
            let r = cluster.global_rank(k, g);
            let node = NodeId::Gpu(g);
            match kind {
                CollectiveKind::AllGather => {
                    sim.memory.ensure(node, size);
                    sim.memory.poke(
                        node,
                        r as u64 * c,
                        &vec![pattern(r as u8, r as u8); c as usize],
                    );
                }
                CollectiveKind::AllToAll => {
                    let cap = if in_place {
                        size
                    } else {
                        aa_stage_base(size) + size
                    };
                    sim.memory.ensure(node, cap);
                    for d in 0..w {
                        sim.memory.poke(
                            node,
                            d as u64 * c,
                            &vec![pattern(r as u8, d as u8); c as usize],
                        );
                    }
                }
            }
        }
    }
}

/// All-gather inter leg: every rank's own chunk lands at the same offset on
/// its same-local-rank peers in every other node.
pub(crate) fn exchange_ag(sims: &mut [Sim], cluster: &ClusterTopology, c: u64) {
    let n = sims.len();
    for k in 0..n {
        for g in 0..cluster.gpus_per_node() {
            let r = cluster.global_rank(k, g) as u64;
            let data = sims[k].memory.peek(NodeId::Gpu(g), r * c, c);
            for (k2, sim2) in sims.iter_mut().enumerate() {
                if k2 != k {
                    sim2.memory.poke(NodeId::Gpu(g), r * c, &data);
                }
            }
        }
    }
}

/// All-to-all inter leg: buffered block exchange — all outbound blocks are
/// snapshotted before any receive lands (full-duplex RDMA semantics), which
/// is what lets the in-place variant reuse the input blocks as staging.
fn exchange_aa(sims: &mut [Sim], cluster: &ClusterTopology, size: u64, in_place: bool) {
    let n = sims.len();
    let gpn = cluster.gpus_per_node();
    let intra = gpn as u64 * (size / cluster.world_size() as u64);
    let mut blocks: Vec<(usize, u8, u64, Vec<u8>)> = Vec::new();
    for (k, sim) in sims.iter().enumerate() {
        for g in 0..gpn {
            for k2 in 0..n {
                if k2 == k {
                    continue;
                }
                let src_off = if in_place {
                    k2 as u64 * intra
                } else {
                    aa_stage_base(size) + k2 as u64 * intra
                };
                let dst_off = if in_place {
                    k as u64 * intra
                } else {
                    aa_out_base(size) + k as u64 * intra
                };
                let data = sim.memory.peek(NodeId::Gpu(g), src_off, intra);
                blocks.push((k2, g, dst_off, data));
            }
        }
    }
    for (k2, g, off, data) in blocks {
        sims[k2].memory.poke(NodeId::Gpu(g), off, &data);
    }
}

/// Check the post-collective placement against the mathematical definition
/// (AG = concatenation of all ranks' chunks; AA = global transpose).
pub fn check_cluster(
    sims: &[Sim],
    kind: CollectiveKind,
    cluster: &ClusterTopology,
    size: u64,
    in_place: bool,
) -> bool {
    let w = cluster.world_size() as u32;
    let c = size / w as u64;
    for (k, sim) in sims.iter().enumerate() {
        for g in 0..cluster.gpus_per_node() {
            let r = cluster.global_rank(k, g);
            for d in 0..w {
                let (off, want) = match kind {
                    CollectiveKind::AllGather => (d as u64 * c, pattern(d as u8, d as u8)),
                    CollectiveKind::AllToAll => {
                        if in_place {
                            (d as u64 * c, pattern(d as u8, r as u8))
                        } else if d == r {
                            // Global diagonal stays in the input, exactly
                            // like the flat out-of-place convention.
                            (d as u64 * c, pattern(r as u8, r as u8))
                        } else {
                            (aa_out_base(size) + d as u64 * c, pattern(d as u8, r as u8))
                        }
                    }
                };
                let got = sim.memory.peek(NodeId::Gpu(g), off, c);
                if got.iter().any(|&b| b != want) {
                    crate::log_error!(
                        "cluster verify failed: rank {r} (node {k} gpu {g}) chunk {d}: \
                         want {want}, got {:?}…",
                        &got[..got.len().min(4)]
                    );
                    return false;
                }
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{run_collective, RunOptions, Variant};
    use crate::util::bytes::KB;

    fn choice(s: Strategy, prelaunch: bool, inter: InterSchedule) -> ClusterChoice {
        ClusterChoice {
            intra: Variant::new(s, prelaunch),
            inter,
        }
    }

    /// A 1-node cluster must reproduce the flat collective's latency
    /// exactly (same plans, same engine streams, same trigger instant).
    #[test]
    fn single_node_matches_flat_latency() {
        let cluster = ClusterTopology::mi300x(1);
        let size = 64 * KB;
        for (kind, strat) in [
            (CollectiveKind::AllGather, Strategy::Pcpy),
            (CollectiveKind::AllGather, Strategy::B2b),
            (CollectiveKind::AllToAll, Strategy::Pcpy),
        ] {
            for prelaunch in [false, true] {
                let flat = run_collective(
                    kind,
                    Variant::new(strat, prelaunch),
                    size,
                    &RunOptions::default(),
                );
                let hier = run_hier(
                    kind,
                    choice(strat, prelaunch, InterSchedule::Sequential),
                    &cluster,
                    size,
                    &HierRunOptions::default(),
                );
                assert_eq!(
                    hier.latency_ns, flat.latency_ns,
                    "{} {} prelaunch={prelaunch}",
                    kind.name(),
                    strat.name()
                );
                assert_eq!(hier.inter_ns, 0);
                assert_eq!(hier.nic_messages, 0);
            }
        }
    }

    #[test]
    fn two_node_allgather_verifies_all_variants() {
        let cluster = ClusterTopology::mi300x(2);
        let size = 64u64 * 1024 * 2; // 2 KB per rank chunk
        for strat in [Strategy::Pcpy, Strategy::Bcst, Strategy::B2b] {
            for inter in [InterSchedule::Sequential, InterSchedule::Pipelined] {
                let r = run_hier(
                    CollectiveKind::AllGather,
                    choice(strat, true, inter),
                    &cluster,
                    size,
                    &HierRunOptions {
                        verify: true,
                        ..Default::default()
                    },
                );
                assert_eq!(r.verified, Some(true), "{} {inter:?}", strat.name());
                assert!(r.inter_ns > 0 && r.latency_ns > r.inter_ns);
            }
        }
    }

    #[test]
    fn two_node_alltoall_verifies_all_variants() {
        let cluster = ClusterTopology::mi300x(2);
        let size = 64u64 * 1024 * 2;
        for strat in [Strategy::Pcpy, Strategy::Swap, Strategy::B2b] {
            for inter in [InterSchedule::Sequential, InterSchedule::Pipelined] {
                let r = run_hier(
                    CollectiveKind::AllToAll,
                    choice(strat, false, inter),
                    &cluster,
                    size,
                    &HierRunOptions {
                        verify: true,
                        ..Default::default()
                    },
                );
                assert_eq!(r.verified, Some(true), "{} {inter:?}", strat.name());
                assert!(r.inter_ns > 0);
            }
        }
    }

    #[test]
    fn pipelined_never_slower_than_sequential() {
        let cluster = ClusterTopology::mi300x(4);
        let size = 32u64 << 20;
        for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
            let seq = run_hier(
                kind,
                choice(Strategy::Pcpy, true, InterSchedule::Sequential),
                &cluster,
                size,
                &HierRunOptions::default(),
            );
            let pipe = run_hier(
                kind,
                choice(Strategy::Pcpy, true, InterSchedule::Pipelined),
                &cluster,
                size,
                &HierRunOptions::default(),
            );
            assert!(
                pipe.latency_ns <= seq.latency_ns,
                "{}: pipe {} vs seq {}",
                kind.name(),
                pipe.latency_ns,
                seq.latency_ns
            );
        }
    }

    #[test]
    fn latency_grows_with_node_count() {
        let size = 4u64 << 20;
        for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
            let mut prev = 0u64;
            for n in [1usize, 2, 4] {
                let cluster = ClusterTopology::mi300x(n);
                let r = run_hier(
                    kind,
                    choice(Strategy::Pcpy, true, InterSchedule::Pipelined),
                    &cluster,
                    size,
                    &HierRunOptions::default(),
                );
                assert!(
                    r.latency_ns > prev,
                    "{} n={n}: {} !> {prev}",
                    kind.name(),
                    r.latency_ns
                );
                prev = r.latency_ns;
            }
        }
    }

    /// Satellite (PR 4): the rounds cache key includes the inter schedule,
    /// so a build cached under one schedule can never be served to
    /// another. Proven by poisoning: a bogus (empty) entry planted under
    /// the `Sequential` key must be invisible to an `Overlapped` lookup of
    /// the otherwise-identical coordinates — and must be exactly what the
    /// same-schedule lookup returns (showing the probe actually reaches
    /// the poisoned slot, not a different table).
    #[test]
    fn rounds_cache_isolates_schedules() {
        // Unique world shape (3 GPUs × 5 engines) so the poison cannot
        // collide with any other test sharing the process-wide cache.
        let node = Topology::custom(3, 5, 64.0, 64.0);
        let (n, chunk) = (2usize, 64u64);
        let size = chunk * n as u64 * node.num_gpus as u64;
        let variant = Variant::new(Strategy::Pcpy, false);
        let key = |schedule: InterSchedule| RoundsKey {
            kind: CollectiveKind::AllToAll,
            variant,
            schedule,
            size,
            num_nodes: n as u8,
            node_idx: 0,
            shape: WorldShape::of(&node),
        };
        let table = ROUNDS.get_or_init(|| Mutex::new(HashMap::new()));
        table
            .lock()
            .unwrap()
            .insert(key(InterSchedule::Sequential), Arc::new(Vec::new()));

        let choice = |inter| ClusterChoice {
            intra: variant,
            inter,
        };
        let ovl = cached_node_rounds(
            CollectiveKind::AllToAll,
            &node,
            n,
            0,
            size,
            chunk,
            choice(InterSchedule::Overlapped),
        );
        assert!(
            !ovl.is_empty(),
            "Overlapped lookup was served the poisoned Sequential build"
        );
        let seq = cached_node_rounds(
            CollectiveKind::AllToAll,
            &node,
            n,
            0,
            size,
            chunk,
            choice(InterSchedule::Sequential),
        );
        assert!(
            seq.is_empty(),
            "same-schedule lookup must hit the poisoned slot (probe sanity)"
        );

        // Un-poison so no later caller of this exact shape can trip.
        let mut t = table.lock().unwrap();
        t.remove(&key(InterSchedule::Sequential));
        t.remove(&key(InterSchedule::Overlapped));
    }

    /// The tracing-path message list must fold back to exactly the float
    /// arrivals the latency path computes — same loop, same evaluation
    /// order, so `==` on f64 is the right comparison.
    #[test]
    fn nic_messages_fold_to_arrivals() {
        let nic = NicModel::default();
        let ready = [1_000.0, 2_500.0, 1_800.0, 4_000.0];
        for inter in [
            InterSchedule::Sequential,
            InterSchedule::Pipelined,
            InterSchedule::Overlapped,
        ] {
            let arr = nic_exchange_arrivals(&nic, inter, &ready, 4096, 120.0);
            let msgs = nic_exchange_messages(&nic, inter, &ready, 4096, 120.0);
            assert_eq!(msgs.len(), ready.len() * (ready.len() - 1));
            let mut folded = vec![0f64; ready.len()];
            for m in &msgs {
                assert!(m.start < m.port_end && m.port_end < m.arrive);
                folded[m.dest] = folded[m.dest].max(m.arrive);
            }
            assert_eq!(arr, folded, "{inter:?}");
        }
    }

    /// With an all-zero flap table the faulted exchange must reproduce the
    /// healthy timeline bit-for-bit (same loop, no draws); with flapping
    /// senders it must only ever delay arrivals, and must count retries.
    #[test]
    fn faulted_exchange_reduces_to_healthy_and_only_delays() {
        let nic = NicModel::default();
        let ready = [1_000.0, 2_500.0, 1_800.0, 4_000.0];
        for inter in [
            InterSchedule::Sequential,
            InterSchedule::Pipelined,
            InterSchedule::Overlapped,
        ] {
            let healthy = nic_exchange_arrivals(&nic, inter, &ready, 4096, 120.0);
            let quiet = LinkHealth::uniform(ready.len(), 0.0, 9);
            let (same, stats) =
                nic_exchange_arrivals_faulted(&nic, inter, &ready, 4096, 120.0, &quiet);
            assert_eq!(healthy, same, "{inter:?}: zero flaps must be bit-identical");
            assert_eq!(stats, FaultStats::default());

            let flappy = LinkHealth::uniform(ready.len(), 0.6, 9);
            let (delayed, stats) =
                nic_exchange_arrivals_faulted(&nic, inter, &ready, 4096, 120.0, &flappy);
            assert!(stats.retries > 0, "{inter:?}: p=0.6 must flap something");
            for (d, h) in delayed.iter().zip(healthy.iter()) {
                assert!(d >= h, "{inter:?}: retries may only delay arrivals");
            }
            assert!(
                delayed.iter().sum::<f64>() > healthy.iter().sum::<f64>(),
                "{inter:?}: retries must show up in the timeline"
            );
        }
    }

    /// Each retry costs at least the watchdog timeout + first backoff, and
    /// the draw is pure: identical (seed, sender, dest) ⇒ identical
    /// timeline regardless of how many times we ask.
    #[test]
    fn faulted_exchange_is_pure_and_prices_retries() {
        let nic = NicModel::default();
        let ready = [0.0, 0.0, 0.0, 0.0];
        let h = LinkHealth::uniform(4, 0.9, 1234);
        let run =
            || nic_exchange_messages_faulted(&nic, InterSchedule::Pipelined, &ready, 1024, 0.0, &h);
        let (m1, s1) = run();
        let (m2, s2) = run();
        assert_eq!(s1, s2);
        assert_eq!(m1.len(), m2.len());
        for (a, b) in m1.iter().zip(m2.iter()) {
            assert_eq!((a.start, a.port_end, a.arrive), (b.start, b.port_end, b.arrive));
        }
        // Find a message with k retries: its port occupancy must cover the
        // base send plus k·(timeout + backoff_i + resend).
        let healthy_occ = nic.t_post_per_msg + nic.payload_ns(1024);
        let mut saw_retry = false;
        for m in &m1 {
            let (fails, _) = h.flaps(m.sender, m.dest);
            let mut want = healthy_occ;
            for a in 0..fails {
                let backoff = h.retry.backoff_ns * 2f64.powi(a as i32);
                want += h.retry.timeout_ns + backoff + healthy_occ;
            }
            assert!((m.port_end - m.start - want).abs() < 1e-9, "occupancy mismatch");
            saw_retry |= fails > 0;
        }
        assert!(saw_retry, "p=0.9 must produce at least one retry");
    }

    #[test]
    fn round_plans_cover_global_volume() {
        let cluster = ClusterTopology::mi300x(2);
        let size = 16u64 * 1024;
        let c = size / 16;
        let rounds = build_node_rounds(
            CollectiveKind::AllGather,
            cluster.node(0),
            2,
            0,
            size,
            c,
            Variant::new(Strategy::Pcpy, false),
        );
        assert_eq!(rounds.len(), 2);
        // Each round is a full single-node AG: 8×7 copies.
        for r in &rounds {
            assert_eq!(r.total_data_cmds(), 56);
        }
        // Round 1 operates on the second node block.
        let intra = 8 * c;
        for rank in &rounds[1].ranks {
            for e in &rank.engines {
                for cmd in &e.cmds {
                    if let Command::Copy { src, dst, .. } = cmd {
                        assert!(src.offset >= intra && dst.offset >= intra);
                    }
                }
            }
        }
    }
}
