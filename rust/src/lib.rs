//! # DMA-Latte
//!
//! Reproduction of *"DMA-Latte: Expanding the Reach of DMA Offloads to
//! Latency-bound ML Communication"* (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas system. See `DESIGN.md` for the full inventory and
//! `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! Layer map:
//! - [`sim`] — discrete-event MI300X DMA-subsystem simulator (substrate).
//!   Allocation-free hot path: `Sim::reset` lets sweeps and the serving
//!   engine reuse one simulator per episode; the event queue keeps a
//!   front-slot fast path and in-flight retirement drains a sorted deque.
//! - [`collectives`] — the paper's optimized DMA collectives (pcpy / bcst /
//!   swap / b2b / prelaunch) over the simulator. Plans are built once per
//!   (kind, variant, size, world shape) and replayed from the
//!   cross-episode cache ([`collectives::cache`]); sweeps drive episodes
//!   through the reusable [`collectives::CollectiveRunner`]. Before/after
//!   wall-clock numbers live in `BENCH_PR3.json`
//!   (`benches/perf_hotpath.rs`, methodology in `benches/README.md`).
//! - [`cluster`] — multi-node layer: N simulated nodes over NIC links,
//!   hierarchical all-gather / all-to-all / reduce-scatter / all-reduce
//!   (intra-node DMA leg + inter-node exchange; reductions on CUs per the
//!   paper's §7 split), the chunk-granular overlap scheduler
//!   ([`cluster::overlap`]: all-reduce's gather of chunk `k` launches at
//!   chunk `k`'s final reduction instead of behind a phase barrier —
//!   schedule taxonomy Sequential / Pipelined / Overlapped in
//!   [`cluster::InterSchedule`]), and the cluster-aware (variant,
//!   schedule) selector covering all four collectives per size × node
//!   count. Overlap wins per size live in `BENCH_PR4.json`
//!   (`benches/overlap.rs`).
//! - [`rccl`] — calibrated CU-based collective baseline (RCCL stand-in).
//! - [`models`] — LLM architecture zoo + MI300X roofline timing model.
//! - [`kvcache`] — paged KV cache, CPU offload tier, fetch engines, and
//!   cross-node migration ([`kvcache::migrate`]): prefill-side DMA b2b
//!   save, one scatter-gather RDMA post per chunk over the cluster NIC
//!   model, decode-side DMA b2b fetch — chunked at layer granularity
//!   ([`kvcache::MigrateSchedule::LayerPipelined`]) so the decode node's
//!   first chunk lands (`first_ready_ns`) long before the full cache
//!   does; byte-identical to the single-node save/fetch reference
//!   (`tests/prop_migrate.rs`).
//! - [`coordinator`] — vLLM-like serving stack (router, batcher, scheduler);
//!   multi-node deployments route collective sizing through the cluster
//!   selector (`coordinator::comm`) and charge the critical path only the
//!   **exposed** part of each step's all-reduces — the remainder hides
//!   behind the producing layers' GEMMs
//!   ([`coordinator::comm::CommCost`]; `ServeMetrics` splits `comm_ns`
//!   into exposed + hidden). Production-shaped load comes from
//!   [`coordinator::workload`]: seeded Poisson / bursty / diurnal-trace
//!   arrival processes, multi-tenant classes with per-class SLOs, and
//!   conversation replays hitting the CPU-tier prefix cache — ingested
//!   event-driven on the engine's virtual clock, reported as per-class
//!   percentiles / SLO attainment / goodput (`dma-latte serve`,
//!   `benches/serving_load.rs`, `BENCH_PR7.json`). The arrival path
//!   scales to millions of requests per episode: the lazy
//!   `WorkloadSpec::stream()` (k-way merge over per-session generators,
//!   O(active-sessions) resident, event-identical to `generate()`) feeds
//!   the engine's streaming submission slot, latency series live in
//!   [`util::stats::LatHist`] (exact below `metrics_sample_cap`, ≤ 1 %
//!   log-bucket sketch above) with request spans in a seeded
//!   [`util::stats::Reservoir`], and load sweeps fan independent points
//!   across host threads (`benches/serve_scale.rs`, `BENCH_PR9.json`).
//!   Fault injection and
//!   graceful degradation ride the same stack: [`cluster::faults`] turns a
//!   `FaultSpec` into a seeded per-node health plan (NIC/xGMI derates,
//!   stuck engines, compute stragglers, transient link flaps priced by a
//!   retry-with-backoff watchdog in [`cluster::hier`]), and the serving
//!   engine reacts per [`coordinator::config::DegradePolicy`] — re-pick
//!   schedules against the derated topology, drain sick nodes, shed
//!   best-effort arrivals, preempt for SLO'd work (`dma-latte faults`,
//!   `benches/faults.rs`, `BENCH_PR8.json`). An empty plan is
//!   bit-identical to the healthy path (`tests/prop_faults.rs`).
//!   Disaggregated prefill/decode serving splits the fleet into node
//!   pools (`ServeConfig::with_disagg`, `dma-latte serve --disagg P:D`):
//!   prefill lanes run the compute-heavy phase, KV caches migrate to the
//!   decode pool over the [`kvcache::migrate`] DMA/NIC path (charged on
//!   PCIe + NIC tracks with obs spans, memoized per `(schedule,
//!   n_blocks)`), and the decode pool sizes its own TP collectives —
//!   TTFT/throughput vs colocated serving swept in
//!   [`figures::disagg`] (`benches/disagg.rs`, `BENCH_PR10.json`), NIC
//!   wattage of the migration in the cluster power figure
//!   (`figures::power`).
//! - [`obs`] — observability: cross-layer tracing threading one span
//!   hierarchy from serving requests through engine steps, cluster
//!   collectives and per-phase legs down to the simulator's DMA phases;
//!   Chrome `trace_event` export for Perfetto (one track per simulated
//!   resource) and interval-partition critical-path attribution whose
//!   nine components provably sum to end-to-end latency. Zero-cost when
//!   no recorder is installed (`dma-latte trace` turns it on).
//! - [`runtime`] — PJRT loader/executor for the AOT-compiled JAX artifacts.
//! - [`figures`] — one generator per paper figure/table.

pub mod cli;
pub mod cluster;
pub mod collectives;
pub mod coordinator;
pub mod figures;
pub mod hip;
pub mod kvcache;
pub mod models;
pub mod obs;
pub mod rccl;
pub mod runtime;
pub mod sim;
pub mod util;
