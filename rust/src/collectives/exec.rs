//! Collective executor: turns a [`CollectivePlan`] into per-rank host
//! scripts (direct or prelaunched), drives the DES, measures the critical
//! path, and optionally verifies the functional result.
//!
//! Synchronization model: every engine stream ends with an `Atomic(+1)` on
//! a global completion signal; every rank waits for the global count (the
//! collective is complete when all transfers have landed). Prelaunch mode
//! (§4.5) pays command creation + doorbells in a setup epoch, parks engines
//! on a per-rank trigger `Poll`, and the measured window starts at the
//! trigger write.

use crate::sim::command::{AtomicOp, Command, PollCond};
use crate::sim::host::{ApiKind, HostId, HostOp};
use crate::sim::power::Activity;
use crate::sim::{Sim, SimConfig, SignalId};

use super::plan::{CollectivePlan, EnginePlan};
use super::{b2b, bcst, cache, pcpy, swap, verify, CollectiveKind, Strategy, Variant};

/// Prelaunch setup-epoch margin: after creating poll-gated streams and
/// ringing doorbells, hosts wait this long for engines to park on their
/// polls before starting the measured window (§4.5).
pub const PRELAUNCH_PARK_NS: u64 = 20_000;

/// One engine's queue contents for a collective: optional prelaunch gate,
/// the plan's data commands, and the completion atomic. Shared between
/// [`run_collective`] and the hierarchical `cluster::hier` executor.
pub fn engine_stream(ep: &EnginePlan, trigger: Option<SignalId>, done: SignalId) -> Vec<Command> {
    let mut cmds = Vec::with_capacity(ep.cmds.len() + 2);
    if let Some(t) = trigger {
        cmds.push(Command::Poll {
            signal: t,
            cond: PollCond::Gte(1),
        });
    }
    cmds.extend(ep.cmds.iter().cloned());
    cmds.push(Command::Atomic {
        signal: done,
        op: AtomicOp::Add(1),
    });
    cmds
}

/// Control-path API style for an engine plan (batched or per-command raw
/// queue writes).
pub fn api_kind(ep: &EnginePlan) -> ApiKind {
    if ep.batched_control {
        ApiKind::RawBatched
    } else {
        ApiKind::Raw
    }
}

/// Execution options.
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Simulator config (topology + latency calibration).
    pub sim: SimConfig,
    /// Initialize buffers and verify the collective's functional result
    /// (forces functional memory; keep sizes modest).
    pub verify: bool,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            sim: SimConfig::mi300x(),
            verify: false,
        }
    }
}

/// Outcome of one collective execution.
#[derive(Debug, Clone)]
pub struct CollectiveResult {
    /// Critical-path latency in ns (trigger/start → last rank observes
    /// completion).
    pub latency_ns: u64,
    /// Engines that executed at least one command.
    pub engines_used: usize,
    /// Total data-move commands.
    pub data_cmds: usize,
    /// Power-model activity over the collective window.
    pub activity: Activity,
    /// Functional verification result (None when not requested).
    pub verified: Option<bool>,
}

/// Plan `variant` for `kind` at `size` bytes.
pub fn build_plan(
    kind: CollectiveKind,
    variant: Variant,
    topo: &crate::sim::Topology,
    size: u64,
) -> CollectivePlan {
    assert!(
        variant.strategy.applicable(kind),
        "{} not applicable to {}",
        variant.strategy.name(),
        kind.name()
    );
    match variant.strategy {
        Strategy::Pcpy => pcpy::plan(kind, topo, size),
        Strategy::Bcst => bcst::plan(topo, size),
        Strategy::Swap => swap::plan(topo, size),
        Strategy::B2b => b2b::plan(kind, topo, size),
    }
}

/// Run one collective end to end on the DES and measure it.
///
/// Builds a fresh [`CollectiveRunner`] per call; the plan still comes from
/// the cross-episode cache and the topology clone is two `Arc` bumps, so a
/// one-shot call is already cheap — but sweeps should hold a runner and
/// reuse its simulator across episodes.
pub fn run_collective(
    kind: CollectiveKind,
    variant: Variant,
    size: u64,
    opts: &RunOptions,
) -> CollectiveResult {
    CollectiveRunner::new(opts).run(kind, variant, size)
}

/// The pre-optimization episode path, kept for `benches/perf_hotpath`'s
/// before/after rows (`BENCH_PR3.json`): a fresh simulator, a fresh
/// planner walk (no cross-episode cache) and fresh signal scratch on every
/// call — exactly what the §Perf pass removed. Results are bit-identical
/// to [`run_collective`]; only the wall-clock differs.
pub fn run_collective_uncached(
    kind: CollectiveKind,
    variant: Variant,
    size: u64,
    opts: &RunOptions,
) -> CollectiveResult {
    let mut cfg = opts.sim.clone();
    if opts.verify {
        cfg.functional = true;
    }
    let mut sim = Sim::new(cfg);
    let plan = build_plan(kind, variant, &sim.cfg.topology, size);
    run_episode(&mut sim, &plan, variant, opts.verify, &mut Vec::new(), &mut Vec::new())
}

/// Reusable collective-episode driver (§Perf pass): one simulator
/// ([`Sim::reset`] between episodes instead of a rebuild), scratch signal
/// buffers reused across episodes, plans served from the cross-episode
/// cache ([`cache::cached_plan`]). Episodes are bit-identical to one-shot
/// [`run_collective`] runs — `tests/determinism.rs` pins this.
pub struct CollectiveRunner {
    sim: Sim,
    verify: bool,
    /// Per-(rank, engine) completion-signal scratch, reused across
    /// episodes (the satellite fix for the per-call `alloc_signal` vecs).
    eng_signals: Vec<Vec<SignalId>>,
    /// Per-rank prelaunch-trigger scratch.
    triggers: Vec<SignalId>,
    used: bool,
}

impl CollectiveRunner {
    /// Build a runner for `opts` (the simulator is constructed once here).
    pub fn new(opts: &RunOptions) -> Self {
        let mut cfg = opts.sim.clone();
        if opts.verify {
            cfg.functional = true;
        }
        CollectiveRunner {
            sim: Sim::new(cfg),
            verify: opts.verify,
            eng_signals: Vec::new(),
            triggers: Vec::new(),
            used: false,
        }
    }

    /// The simulator, holding the state of the most recent episode
    /// (trace inspection, memory checksums).
    pub fn sim(&self) -> &Sim {
        &self.sim
    }

    /// Run one episode, resetting the simulator first if it was used.
    pub fn run(&mut self, kind: CollectiveKind, variant: Variant, size: u64) -> CollectiveResult {
        if self.used {
            self.sim.reset();
        }
        self.used = true;
        let plan = cache::cached_plan(kind, variant, &self.sim.cfg.topology, size);
        run_episode(
            &mut self.sim,
            &plan,
            variant,
            self.verify,
            &mut self.eng_signals,
            &mut self.triggers,
        )
    }
}

/// One collective episode on a pristine (fresh or reset) simulator.
fn run_episode(
    sim: &mut Sim,
    plan: &CollectivePlan,
    variant: Variant,
    verify: bool,
    eng_signals: &mut Vec<Vec<SignalId>>,
    triggers: &mut Vec<SignalId>,
) -> CollectiveResult {
    let kind = plan.kind;
    let size = plan.size;

    // Buffers (also sizes non-functional accounting consistently).
    let in_place_swap = variant.strategy == Strategy::Swap;
    if verify {
        verify::init_buffers(sim, kind, size, in_place_swap);
    }

    // Per-engine completion signals: each engine stream ends with its own
    // Atomic, and the owning rank's host observes each of its engines'
    // signals in turn. This is the paper's sync-scaling mechanism: more
    // engines ⇒ more sync commands AND more host-side completions to
    // observe (§5.2.4), which bcst/swap/b2b then halve or collapse.
    // The outer/inner Vecs are scratch reused across episodes; post-reset
    // the allocated ids repeat deterministically.
    while eng_signals.len() < plan.ranks.len() {
        eng_signals.push(Vec::new());
    }
    eng_signals.truncate(plan.ranks.len());
    for (ri, rank) in plan.ranks.iter().enumerate() {
        eng_signals[ri].clear();
        for _ in &rank.engines {
            let s = sim.alloc_signal(0);
            eng_signals[ri].push(s);
        }
    }

    // Per-rank prelaunch triggers.
    triggers.clear();
    for _ in 0..sim.cfg.topology.num_gpus {
        let s = sim.alloc_signal(0);
        triggers.push(s);
    }

    for (ri, rank) in plan.ranks.iter().enumerate() {
        let mut script = Vec::new();
        let g = rank.gpu as usize;
        if variant.prelaunch {
            // Setup epoch: create poll-gated streams + ring doorbells.
            for (ei, ep) in rank.engines.iter().enumerate() {
                script.push(HostOp::CreateCommands {
                    engine: ep.engine,
                    cmds: engine_stream(ep, Some(triggers[g]), eng_signals[ri][ei]),
                    api: api_kind(ep),
                });
                script.push(HostOp::RingDoorbell { engine: ep.engine });
            }
            // Let engines park on their polls, then start the clock.
            script.push(HostOp::Delay {
                ns: PRELAUNCH_PARK_NS,
            });
            script.push(HostOp::Mark { name: "start" });
            script.push(HostOp::SetSignal {
                signal: triggers[g],
                value: 1,
            });
        } else {
            script.push(HostOp::Mark { name: "start" });
            for (ei, ep) in rank.engines.iter().enumerate() {
                script.push(HostOp::CreateCommands {
                    engine: ep.engine,
                    cmds: engine_stream(ep, None, eng_signals[ri][ei]),
                    api: api_kind(ep),
                });
                script.push(HostOp::RingDoorbell { engine: ep.engine });
            }
        }
        for sig in &eng_signals[ri] {
            script.push(HostOp::WaitSignal {
                signal: *sig,
                at_least: 1,
            });
        }
        script.push(HostOp::Mark { name: "end" });
        sim.add_host(script, 0);
    }

    let out = sim.run();
    assert!(
        out.deadlocked.is_empty(),
        "collective deadlocked: {:?}",
        out.deadlocked
    );

    // Critical path: the longest per-rank window (collective benchmarks
    // time each rank and take the max; a global max−min would also charge
    // per-rank setup skew, which is off the measured path under prelaunch).
    let latency_ns = (0..plan.ranks.len())
        .map(|h| {
            let host = sim.host(HostId(h as u32));
            host.mark("end").unwrap() - host.mark("start").unwrap()
        })
        .max()
        .unwrap();

    let verified = if verify {
        Some(verify::check(sim, kind, size, in_place_swap))
    } else {
        None
    };

    CollectiveResult {
        latency_ns,
        engines_used: sim.engines_used(),
        data_cmds: plan.total_data_cmds(),
        activity: sim.activity(latency_ns as f64),
        verified,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::{KB, MB};

    fn run(kind: CollectiveKind, v: Variant, size: u64) -> CollectiveResult {
        run_collective(
            kind,
            v,
            size,
            &RunOptions {
                sim: SimConfig::mi300x(),
                verify: size <= MB,
            },
        )
    }

    /// A reused runner (reset simulator + cached plan) must reproduce the
    /// one-shot path exactly, even when episodes of different kinds and
    /// variants interleave between repeats.
    #[test]
    fn runner_reuse_matches_one_shot() {
        let opts = RunOptions {
            sim: SimConfig::mi300x(),
            verify: true,
        };
        let ag = Variant::new(Strategy::B2b, true);
        let mut runner = CollectiveRunner::new(&opts);
        let a = runner.run(CollectiveKind::AllGather, ag, 64 * KB);
        let b = runner.run(CollectiveKind::AllToAll, Variant::new(Strategy::Swap, true), 64 * KB);
        let c = runner.run(CollectiveKind::AllGather, ag, 64 * KB);
        assert_eq!(a.verified, Some(true));
        assert_eq!(b.verified, Some(true));
        assert_eq!(a.latency_ns, c.latency_ns);
        assert_eq!(a.activity.hbm_bytes, c.activity.hbm_bytes);
        let one_shot = run_collective(CollectiveKind::AllGather, ag, 64 * KB, &opts);
        assert_eq!(one_shot.latency_ns, a.latency_ns);
        assert_eq!(one_shot.engines_used, a.engines_used);
        let legacy = run_collective_uncached(CollectiveKind::AllGather, ag, 64 * KB, &opts);
        assert_eq!(legacy.latency_ns, a.latency_ns);
        assert_eq!(legacy.verified, a.verified);
    }

    #[test]
    fn all_ag_variants_verify() {
        for v in Variant::all_for(CollectiveKind::AllGather) {
            let r = run(CollectiveKind::AllGather, v, 64 * KB);
            assert_eq!(r.verified, Some(true), "variant {}", v.name());
            assert!(r.latency_ns > 0);
        }
    }

    #[test]
    fn all_aa_variants_verify() {
        for v in Variant::all_for(CollectiveKind::AllToAll) {
            let r = run(CollectiveKind::AllToAll, v, 64 * KB);
            assert_eq!(r.verified, Some(true), "variant {}", v.name());
        }
    }

    #[test]
    fn b2b_beats_pcpy_at_small_sizes() {
        let k = CollectiveKind::AllGather;
        let p = run(k, Variant::new(Strategy::Pcpy, false), 16 * KB);
        let b = run(k, Variant::new(Strategy::B2b, false), 16 * KB);
        assert!(
            (b.latency_ns as f64) < 0.6 * p.latency_ns as f64,
            "b2b {} vs pcpy {}",
            b.latency_ns,
            p.latency_ns
        );
    }

    #[test]
    fn pcpy_beats_b2b_at_large_sizes() {
        let k = CollectiveKind::AllGather;
        let p = run(k, Variant::new(Strategy::Pcpy, false), 64 * MB);
        let b = run(k, Variant::new(Strategy::B2b, false), 64 * MB);
        assert!(p.latency_ns < b.latency_ns);
    }

    #[test]
    fn prelaunch_always_helps() {
        for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
            for s in [Strategy::Pcpy, Strategy::B2b] {
                let d = run(kind, Variant::new(s, false), 256 * KB);
                let p = run(kind, Variant::new(s, true), 256 * KB);
                assert!(
                    p.latency_ns < d.latency_ns,
                    "{kind:?}/{}: prelaunch {} !< direct {}",
                    s.name(),
                    p.latency_ns,
                    d.latency_ns
                );
            }
        }
    }

    #[test]
    fn bcst_uses_half_the_engines_of_pcpy() {
        let k = CollectiveKind::AllGather;
        let p = run(k, Variant::new(Strategy::Pcpy, false), 256 * KB);
        let b = run(k, Variant::new(Strategy::Bcst, false), 256 * KB);
        assert_eq!(p.engines_used, 56);
        assert_eq!(b.engines_used, 32);
        assert!(b.latency_ns < p.latency_ns);
    }

    #[test]
    fn bcst_lowers_memory_reads() {
        let k = CollectiveKind::AllGather;
        let size = 512 * KB;
        let p = run(k, Variant::new(Strategy::Pcpy, false), size);
        let b = run(k, Variant::new(Strategy::Bcst, false), size);
        // pcpy reads each source chunk 7×; bcst 4× (3 bcst + 1 copy).
        assert!(b.activity.hbm_bytes < p.activity.hbm_bytes);
    }
}
