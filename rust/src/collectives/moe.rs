//! MoE token dispatch with broadcast commands (paper §4.2, last note):
//! "mixture-of-expert models which employ all-to-all often send a given
//! token to multiple (top-k) experts which bcst is well-suited for."
//!
//! Expert-parallel dispatch: each GPU holds a batch of token activations;
//! the router assigns every token to its top-k experts, each expert living
//! on some GPU. With k=2 (the common case), one `bcst` command replicates
//! a token to both expert GPUs — halving commands vs copy-based dispatch.

use crate::sim::command::{Addr, AtomicOp, Command};
use crate::sim::host::{ApiKind, HostOp};
use crate::sim::topology::{NodeId, Topology};
use crate::sim::{EngineId, Sim};
use crate::util::rng::Rng;

/// Routing decision for one token: the GPUs hosting its top-k experts.
#[derive(Debug, Clone)]
pub struct TokenRoute {
    pub token_idx: u32,
    pub expert_gpus: Vec<u8>,
}

/// Generate a random top-k routing for `tokens` tokens on `src_gpu`
/// (experts spread over all GPUs; a token's experts are distinct GPUs —
/// same-GPU experts need no wire transfer).
pub fn random_routing(rng: &mut Rng, topo: &Topology, src_gpu: u8, tokens: u32, k: usize) -> Vec<TokenRoute> {
    let peers = topo.peers(src_gpu);
    (0..tokens)
        .map(|t| {
            let mut gpus = peers.clone();
            rng.shuffle(&mut gpus);
            TokenRoute {
                token_idx: t,
                expert_gpus: gpus[..k.min(gpus.len())].to_vec(),
            }
        })
        .collect()
}

/// Layout: token `t` of `src_gpu` lives at `t * token_bytes`; the expert
/// GPU's receive buffer slot for (src, token) sits after the send region:
/// `max_tokens*token_bytes + (src * max_tokens + t) * token_bytes`.
pub fn rx_offset(src_gpu: u8, token_idx: u32, max_tokens: u32, token_bytes: u64) -> u64 {
    let rx_base = max_tokens as u64 * token_bytes;
    rx_base + (src_gpu as u64 * max_tokens as u64 + token_idx as u64) * token_bytes
}

/// Dispatch strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchMode {
    /// One copy per (token, expert) — today's runtime behaviour.
    CopyPerExpert,
    /// bcst pairs for k=2 (odd remainders fall back to copy).
    Broadcast,
}

/// Result of a dispatch episode.
#[derive(Debug)]
pub struct DispatchResult {
    pub latency_ns: u64,
    pub commands: usize,
    pub wire_bytes: u64,
}

/// Run one GPU's token dispatch on the DES; all commands b2b on one engine
/// with a single sync (both modes benefit equally from b2b — the ablation
/// isolates the command-count effect of `bcst`).
pub fn run_dispatch(
    sim: &mut Sim,
    src_gpu: u8,
    routes: &[TokenRoute],
    max_tokens: u32,
    token_bytes: u64,
    mode: DispatchMode,
) -> DispatchResult {
    let mut cmds = Vec::new();
    for r in routes {
        let src = Addr::new(NodeId::Gpu(src_gpu), r.token_idx as u64 * token_bytes);
        let mk_dst = |g: u8| {
            Addr::new(
                NodeId::Gpu(g),
                rx_offset(src_gpu, r.token_idx, max_tokens, token_bytes),
            )
        };
        match mode {
            DispatchMode::CopyPerExpert => {
                for &g in &r.expert_gpus {
                    cmds.push(Command::Copy {
                        src,
                        dst: mk_dst(g),
                        len: token_bytes,
                    });
                }
            }
            DispatchMode::Broadcast => {
                let mut it = r.expert_gpus.chunks(2);
                for pair in &mut it {
                    if pair.len() == 2 {
                        cmds.push(Command::Bcst {
                            src,
                            dst0: mk_dst(pair[0]),
                            dst1: mk_dst(pair[1]),
                            len: token_bytes,
                        });
                    } else {
                        cmds.push(Command::Copy {
                            src,
                            dst: mk_dst(pair[0]),
                            len: token_bytes,
                        });
                    }
                }
            }
        }
    }
    let n_cmds = cmds.len();
    let wire: u64 = cmds.iter().map(|c| c.wire_bytes()).sum();
    let sig = sim.alloc_signal(0);
    let engine = EngineId {
        gpu: src_gpu,
        idx: 0,
    };
    cmds.push(Command::Atomic {
        signal: sig,
        op: AtomicOp::Add(1),
    });
    let start = sim.time;
    sim.add_host(
        vec![
            HostOp::Mark { name: "dispatch_start" },
            HostOp::CreateCommands {
                engine,
                cmds,
                api: ApiKind::RawBatched,
            },
            HostOp::RingDoorbell { engine },
            HostOp::WaitSignal {
                signal: sig,
                at_least: 1,
            },
            HostOp::Mark { name: "dispatch_end" },
        ],
        start,
    );
    let out = sim.run();
    assert!(out.deadlocked.is_empty());
    let hosts = out.makespan; // borrow dance: fetch marks via last host
    let _ = hosts;
    let hid = crate::sim::HostId(0);
    // Find the most recent host (this episode's): scan back from the end.
    let mut latency = 0;
    for i in (0..=hid.0).rev() {
        let _ = i;
        break;
    }
    // The episode's host is the last added; Sim doesn't expose a count, so
    // track via marks on the latest host id. We know it's the only host in
    // this sim for the ablation usage; assert that.
    let h = sim.host(hid);
    if let (Some(s), Some(e)) = (h.mark("dispatch_start"), h.mark("dispatch_end")) {
        latency = e - s;
    }
    DispatchResult {
        latency_ns: latency,
        commands: n_cmds,
        wire_bytes: wire,
    }
}

/// Functional verify: every token's bytes arrived at each of its experts.
pub fn verify_dispatch(
    sim: &Sim,
    src_gpu: u8,
    routes: &[TokenRoute],
    max_tokens: u32,
    token_bytes: u64,
) -> bool {
    for r in routes {
        let want = sim.memory.peek(
            NodeId::Gpu(src_gpu),
            r.token_idx as u64 * token_bytes,
            token_bytes,
        );
        for &g in &r.expert_gpus {
            let got = sim.memory.peek(
                NodeId::Gpu(g),
                rx_offset(src_gpu, r.token_idx, max_tokens, token_bytes),
                token_bytes,
            );
            if got != want {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimConfig;

    fn setup(tokens: u32, token_bytes: u64, k: usize) -> (Sim, Vec<TokenRoute>) {
        let mut sim = Sim::new(SimConfig::mi300x().functional());
        let mut rng = Rng::new(99);
        let routes = random_routing(&mut rng, &sim.cfg.topology, 0, tokens, k);
        for t in 0..tokens {
            let fill = (t as u8).wrapping_mul(73).wrapping_add(5);
            sim.memory.poke(
                NodeId::Gpu(0),
                t as u64 * token_bytes,
                &vec![fill; token_bytes as usize],
            );
        }
        (sim, routes)
    }

    #[test]
    fn both_modes_deliver_all_tokens() {
        for mode in [DispatchMode::CopyPerExpert, DispatchMode::Broadcast] {
            let (mut sim, routes) = setup(32, 4096, 2);
            let r = run_dispatch(&mut sim, 0, &routes, 32, 4096, mode);
            assert!(r.latency_ns > 0);
            assert!(verify_dispatch(&sim, 0, &routes, 32, 4096), "{mode:?}");
        }
    }

    #[test]
    fn broadcast_halves_commands_for_k2() {
        let (mut s1, routes) = setup(64, 2048, 2);
        let copy = run_dispatch(&mut s1, 0, &routes, 64, 2048, DispatchMode::CopyPerExpert);
        let (mut s2, _) = setup(64, 2048, 2);
        let bcst = run_dispatch(&mut s2, 0, &routes, 64, 2048, DispatchMode::Broadcast);
        assert_eq!(copy.commands, 128);
        assert_eq!(bcst.commands, 64);
        assert_eq!(copy.wire_bytes, bcst.wire_bytes); // same data delivered
        assert!(bcst.latency_ns < copy.latency_ns, "{} vs {}", bcst.latency_ns, copy.latency_ns);
    }

    #[test]
    fn k3_mixes_bcst_and_copy() {
        let (mut sim, routes) = setup(10, 1024, 3);
        let r = run_dispatch(&mut sim, 0, &routes, 10, 1024, DispatchMode::Broadcast);
        assert_eq!(r.commands, 20); // per token: 1 bcst + 1 copy
        assert!(verify_dispatch(&sim, 0, &routes, 10, 1024));
    }
}
