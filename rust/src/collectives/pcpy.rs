//! `pcpy` — the baseline DMA collective (paper §4.1, Fig. 8): the
//! `n*(n-1)` independent copies of an AG/AA are spread across engines,
//! one engine per peer, every engine carrying exactly one copy command.
//! Maximum parallelism, maximum per-engine overhead (doorbells, syncs).

use crate::sim::command::{Addr, Command};
use crate::sim::engine::EngineId;
use crate::sim::topology::{NodeId, Topology};

use super::plan::{aa_out_base, CollectivePlan, EnginePlan, RankPlan};
use super::CollectiveKind;

/// Build the pcpy plan for `kind` at `size` bytes per GPU.
pub fn plan(kind: CollectiveKind, topo: &Topology, size: u64) -> CollectivePlan {
    let n = topo.num_gpus;
    let chunk = CollectivePlan::chunk(size, n);
    assert!(chunk > 0, "size {size} too small for {n} GPUs");
    let mut ranks = Vec::new();
    for g in 0..n {
        let mut engines = Vec::new();
        for (k, peer) in topo.peers(g).into_iter().enumerate() {
            let cmd = match kind {
                CollectiveKind::AllGather => Command::Copy {
                    // Own chunk lives at g*chunk; same offset on the peer.
                    src: Addr::new(NodeId::Gpu(g), g as u64 * chunk),
                    dst: Addr::new(NodeId::Gpu(peer), g as u64 * chunk),
                    len: chunk,
                },
                CollectiveKind::AllToAll => Command::Copy {
                    // Input chunk `peer` → peer's output chunk `g`.
                    src: Addr::new(NodeId::Gpu(g), peer as u64 * chunk),
                    dst: Addr::new(NodeId::Gpu(peer), aa_out_base(size) + g as u64 * chunk),
                    len: chunk,
                },
            };
            engines.push(EnginePlan {
                engine: EngineId {
                    gpu: g,
                    idx: k as u8,
                },
                cmds: vec![cmd],
                batched_control: false,
            });
        }
        ranks.push(RankPlan { gpu: g, engines });
    }
    let p = CollectivePlan { kind, size, ranks };
    p.validate(topo);
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ag_uses_one_engine_per_peer() {
        let topo = Topology::mi300x_platform();
        let p = plan(CollectiveKind::AllGather, &topo, 8192);
        assert_eq!(p.ranks.len(), 8);
        assert_eq!(p.total_engines(), 56); // 8 × 7 — the paper's count
        assert_eq!(p.total_data_cmds(), 56);
        // every engine has exactly one copy
        for r in &p.ranks {
            for e in &r.engines {
                assert_eq!(e.cmds.len(), 1);
            }
        }
    }

    #[test]
    fn aa_targets_output_region() {
        let topo = Topology::mi300x_platform();
        let size = 8192u64;
        let p = plan(CollectiveKind::AllToAll, &topo, size);
        for r in &p.ranks {
            for e in &r.engines {
                match e.cmds[0] {
                    Command::Copy { dst, .. } => {
                        assert!(dst.offset >= aa_out_base(size));
                    }
                    _ => panic!("pcpy must use Copy"),
                }
            }
        }
    }
}
