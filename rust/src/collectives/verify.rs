//! Functional verification of collectives against their mathematical
//! definition: AG = concatenation of per-rank chunks; AA = distributed
//! transpose (out-of-place for copy-based variants, in-place for swap).

use crate::sim::topology::NodeId;
use crate::sim::Sim;

use super::plan::{aa_out_base, CollectivePlan};
use super::CollectiveKind;

/// Deterministic fill byte for (rank, chunk) — distinct across the matrix.
pub fn pattern(gpu: u8, chunk_idx: u8) -> u8 {
    (gpu as u32 * 31 + chunk_idx as u32 * 17 + 7) as u8
}

/// Initialize input buffers per the layout in `plan.rs`.
pub fn init_buffers(sim: &mut Sim, kind: CollectiveKind, size: u64, in_place_swap: bool) {
    let n = sim.cfg.topology.num_gpus;
    let chunk = CollectivePlan::chunk(size, n);
    for g in 0..n {
        match kind {
            CollectiveKind::AllGather => {
                // Own chunk at g*chunk inside the output buffer.
                sim.memory.ensure(NodeId::Gpu(g), size);
                sim.memory.poke(
                    NodeId::Gpu(g),
                    g as u64 * chunk,
                    &vec![pattern(g, g); chunk as usize],
                );
            }
            CollectiveKind::AllToAll => {
                if in_place_swap {
                    sim.memory.ensure(NodeId::Gpu(g), size);
                } else {
                    sim.memory.ensure(NodeId::Gpu(g), aa_out_base(size) + size);
                }
                for j in 0..n {
                    sim.memory.poke(
                        NodeId::Gpu(g),
                        j as u64 * chunk,
                        &vec![pattern(g, j); chunk as usize],
                    );
                }
            }
        }
    }
}

/// Check the post-collective state. Returns true when every byte matches.
pub fn check(sim: &Sim, kind: CollectiveKind, size: u64, in_place_swap: bool) -> bool {
    let n = sim.cfg.topology.num_gpus;
    let chunk = CollectivePlan::chunk(size, n);
    for g in 0..n {
        for j in 0..n {
            let (offset, want) = match kind {
                // AG: every GPU holds chunk j = rank j's pattern.
                CollectiveKind::AllGather => (j as u64 * chunk, pattern(j, j)),
                CollectiveKind::AllToAll => {
                    if in_place_swap {
                        // In-place transpose: g's chunk j now holds j's chunk g.
                        (j as u64 * chunk, pattern(j, g))
                    } else if j == g {
                        // Diagonal chunk stays local: frameworks do the
                        // intra-GPU move outside the collective (the paper's
                        // n*(n-1) copy count excludes it). Check the input.
                        (j as u64 * chunk, pattern(g, g))
                    } else {
                        // Out-of-place: g's output chunk j = rank j's input chunk g.
                        (aa_out_base(size) + j as u64 * chunk, pattern(j, g))
                    }
                }
            };
            let got = sim.memory.peek(NodeId::Gpu(g), offset, chunk);
            if got.iter().any(|&b| b != want) {
                crate::log_error!(
                    "verify failed: gpu{g} chunk {j}: want {want}, got {:?}…",
                    &got[..got.len().min(4)]
                );
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn patterns_distinct_enough() {
        // No two (g, j) pairs in an 8-GPU AA share a pattern byte with the
        // transposed cell they'd be confused with.
        for g in 0..8 {
            for j in 0..8 {
                if g != j {
                    assert_ne!(pattern(g, j), pattern(j, g), "({g},{j})");
                }
            }
        }
    }
}
