//! Reduce-scatter with DMA transport (paper §2.1.1 and §7).
//!
//! DMAs lack compute support, so RS cannot be fully offloaded today. The
//! paper proposes (§7 "Hardware - Reduction In DMA") adding math support;
//! here we implement the software-feasible split the paper implies:
//! **DMA moves the chunks, CUs do the reduction** — each rank's peers push
//! their contribution chunk into per-peer staging slots via DMA (any
//! variant), then a CU kernel reduces the staged chunks into the output.
//! We also model the hypothetical DMA-native reduction for the co-design
//! discussion (ablation bench).

use crate::sim::command::{Addr, Command};
use crate::sim::engine::EngineId;
use crate::sim::topology::{NodeId, Topology};
use crate::sim::Sim;

use super::plan::{CollectivePlan, EnginePlan, RankPlan};
use super::CollectiveKind;

/// Staging region base: peer slot `k` for chunk of size `c` lives at
/// `STAGE_BASE + k*c` in the destination GPU's memory.
pub fn stage_base(size: u64) -> u64 {
    2 * size + 512
}

/// Plan the transport phase of RS: rank g pushes its input chunk j to rank
/// j's staging slot for g. Communication pattern is identical to AA
/// (the paper notes RS "has a similar communication pattern as AA").
pub fn plan_transport(topo: &Topology, size: u64) -> CollectivePlan {
    let n = topo.num_gpus;
    let chunk = CollectivePlan::chunk(size, n);
    assert!(chunk > 0);
    let mut ranks = Vec::new();
    for g in 0..n {
        let mut cmds = Vec::new();
        for peer in topo.peers(g) {
            // Slot index: sender's rank (stable, distinct per sender).
            cmds.push(Command::Copy {
                src: Addr::new(NodeId::Gpu(g), peer as u64 * chunk),
                dst: Addr::new(NodeId::Gpu(peer), stage_base(size) + g as u64 * chunk),
                len: chunk,
            });
        }
        ranks.push(RankPlan {
            gpu: g,
            engines: vec![EnginePlan {
                engine: EngineId { gpu: g, idx: 0 },
                cmds,
                batched_control: true,
            }],
        });
    }
    CollectivePlan {
        kind: CollectiveKind::AllToAll,
        size,
        ranks,
    }
}

/// Host-side (stand-in for CU kernel) reduction over the staged chunks:
/// out[g] = own_chunk[g] + Σ_peers staged[peer]. u8 wrapping-add elements,
/// enough to verify the dataflow end to end.
pub fn reduce_staged(sim: &mut Sim, size: u64) {
    let n = sim.cfg.topology.num_gpus;
    let chunk = CollectivePlan::chunk(size, n);
    for g in 0..n {
        let mut acc = sim.memory.peek(NodeId::Gpu(g), g as u64 * chunk, chunk);
        for peer in sim.cfg.topology.peers(g) {
            let staged = sim.memory.peek(
                NodeId::Gpu(g),
                stage_base(size) + peer as u64 * chunk,
                chunk,
            );
            for (a, b) in acc.iter_mut().zip(staged) {
                *a = a.wrapping_add(b);
            }
        }
        // RS convention: rank g ends with the reduced chunk g at offset 0
        // of a result region; reuse the staging base + n slots.
        let result_off = stage_base(size) + n as u64 * chunk;
        sim.memory.poke(NodeId::Gpu(g), result_off, &acc);
    }
}

/// CU time to reduce `n-1` staged chunks of `chunk` bytes (roofline: read
/// (n-1)+1 chunks, write 1, at HBM bandwidth; MI300X ≈ 5.3 TB/s → derated).
pub fn cu_reduce_ns(chunk: u64, n: u8) -> f64 {
    let bytes = (n as u64 + 1) * chunk;
    let hbm_bytes_per_ns = 3500.0; // effective
    let kernel_launch = 6_000.0;
    kernel_launch + bytes as f64 / hbm_bytes_per_ns
}

/// Hypothetical §7 co-design: DMA engines reduce in flight — no staging,
/// no CU kernel; copy time inflates by a reduce factor on the write path.
pub fn dma_native_reduce_ns(transport_ns: f64) -> f64 {
    transport_ns * 1.12 // ALU-in-DMA write amplification estimate
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimConfig;
    use crate::sim::host::{ApiKind, HostOp};
    use crate::sim::command::AtomicOp;

    /// Full RS dataflow: AA-like DMA transport + host-side reduce.
    #[test]
    fn reduce_scatter_end_to_end() {
        let size = 8 * 1024u64;
        let topo = Topology::mi300x_platform();
        let n = topo.num_gpus;
        let chunk = CollectivePlan::chunk(size, n);
        let plan = plan_transport(&topo, size);
        let mut sim = Sim::new(SimConfig::mi300x().functional());
        // input: gpu g chunk j filled with (g + j).
        for g in 0..n {
            sim.memory.ensure(NodeId::Gpu(g), stage_base(size) + (n as u64 + 2) * chunk);
            for j in 0..n {
                sim.memory.poke(
                    NodeId::Gpu(g),
                    j as u64 * chunk,
                    &vec![g.wrapping_add(j); chunk as usize],
                );
            }
        }
        let done = sim.alloc_signal(0);
        for r in &plan.ranks {
            let mut script = Vec::new();
            for ep in &r.engines {
                let mut cmds = ep.cmds.clone();
                cmds.push(Command::Atomic {
                    signal: done,
                    op: AtomicOp::Add(1),
                });
                script.push(HostOp::CreateCommands {
                    engine: ep.engine,
                    cmds,
                    api: ApiKind::RawBatched,
                });
                script.push(HostOp::RingDoorbell { engine: ep.engine });
            }
            script.push(HostOp::WaitSignal {
                signal: done,
                at_least: n as i64,
            });
            sim.add_host(script, 0);
        }
        let out = sim.run();
        assert!(out.deadlocked.is_empty());
        reduce_staged(&mut sim, size);
        // Expected reduced chunk g: Σ_j (j + g) over all ranks j (u8 wrap).
        for g in 0..n {
            let mut want = 0u8;
            for j in 0..n {
                want = want.wrapping_add(j.wrapping_add(g));
            }
            let result_off = stage_base(size) + n as u64 * chunk;
            let got = sim.memory.peek(NodeId::Gpu(g), result_off, chunk);
            assert!(got.iter().all(|&b| b == want), "gpu{g}: want {want}");
        }
    }

    #[test]
    fn cu_reduce_scales_with_chunk() {
        assert!(cu_reduce_ns(1 << 20, 8) > cu_reduce_ns(1 << 10, 8));
        // Launch dominates tiny chunks.
        assert!(cu_reduce_ns(64, 8) < 7_000.0);
    }
}
