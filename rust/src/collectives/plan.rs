//! Communication-plan IR: a collective → per-rank, per-engine DMA command
//! streams. Mirrors the user-level ROCt prototyping of §5.2.1: the planner
//! decides engine placement and command choice; the executor
//! ([`super::exec`]) wraps the streams with sync/poll commands and host
//! scripts.

use crate::sim::command::Command;
use crate::sim::engine::EngineId;
use crate::sim::topology::Topology;

use super::CollectiveKind;

/// Data-move commands assigned to one engine (sync appended by the executor).
#[derive(Debug, Clone)]
pub struct EnginePlan {
    pub engine: EngineId,
    pub cmds: Vec<Command>,
    /// Control-path API style is batched (one call for the whole stream).
    pub batched_control: bool,
}

/// One rank's (GPU's) share of the collective.
#[derive(Debug, Clone)]
pub struct RankPlan {
    pub gpu: u8,
    pub engines: Vec<EnginePlan>,
}

/// Full collective plan.
#[derive(Debug, Clone)]
pub struct CollectivePlan {
    pub kind: CollectiveKind,
    /// Total collective size (bytes of the per-GPU buffer, benchmark
    /// convention: AG output size / AA array size).
    pub size: u64,
    pub ranks: Vec<RankPlan>,
}

impl CollectivePlan {
    /// Per-peer chunk size.
    pub fn chunk(size: u64, num_gpus: u8) -> u64 {
        size / num_gpus as u64
    }

    /// Total data-move commands across all ranks.
    pub fn total_data_cmds(&self) -> usize {
        self.ranks
            .iter()
            .flat_map(|r| &r.engines)
            .map(|e| e.cmds.len())
            .sum()
    }

    /// Total engines engaged across all ranks.
    pub fn total_engines(&self) -> usize {
        self.ranks.iter().map(|r| r.engines.len()).sum()
    }

    /// Sanity checks shared by all planners (chunk alignment, engine
    /// capacity, command/GPU consistency).
    pub fn validate(&self, topo: &Topology) {
        for r in &self.ranks {
            assert!(r.gpu < topo.num_gpus, "rank gpu {} out of range", r.gpu);
            for e in &r.engines {
                assert_eq!(e.engine.gpu, r.gpu, "engine must live on its rank's GPU");
                assert!(
                    e.engine.idx < topo.engines_per_gpu,
                    "engine idx {} exceeds {} per GPU",
                    e.engine.idx,
                    topo.engines_per_gpu
                );
                assert!(!e.cmds.is_empty(), "empty engine plan");
            }
        }
    }
}

/// Memory-layout constants shared by planners and the verifier.
///
/// AG (in-place): each GPU's buffer `[0, size)`; rank g's own chunk starts
/// pre-filled at `g*chunk` and is pushed to every peer's same offset.
///
/// AA (out-of-place): input `[0, size)`, output `[AA_OUT_BASE(size), …)`;
/// chunk j of rank g's input lands at chunk g of rank j's output.
///
/// AA in-place (swap): single buffer `[0, size)`; ranks g and j exchange
/// chunk j of g with chunk g of j.
pub fn aa_out_base(size: u64) -> u64 {
    // Output region placed after the input with a cache-line pad.
    size + 256
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::command::Addr;
    use crate::sim::topology::NodeId;

    #[test]
    fn chunking() {
        assert_eq!(CollectivePlan::chunk(1024, 8), 128);
    }

    #[test]
    fn validate_catches_wrong_gpu() {
        let topo = Topology::mi300x_platform();
        let plan = CollectivePlan {
            kind: CollectiveKind::AllGather,
            size: 1024,
            ranks: vec![RankPlan {
                gpu: 0,
                engines: vec![EnginePlan {
                    engine: EngineId { gpu: 1, idx: 0 }, // wrong GPU
                    cmds: vec![Command::Copy {
                        src: Addr::new(NodeId::Gpu(0), 0),
                        dst: Addr::new(NodeId::Gpu(1), 0),
                        len: 128,
                    }],
                    batched_control: false,
                }],
            }],
        };
        assert!(std::panic::catch_unwind(|| plan.validate(&topo)).is_err());
    }
}
