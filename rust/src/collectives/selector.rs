//! Size-range → best-variant policy (the paper's Tables 2 and 3).
//!
//! [`select_variant`] is the static policy a runtime would ship (§6's
//! "runtime can pick the command in the regions where it provides
//! benefits"); [`calibrate`] derives the same table empirically from a
//! sweep, which is how the benches regenerate Tables 2/3.

use crate::util::bytes::{GB, KB, MB};

use super::{CollectiveKind, Strategy, Variant};

/// Static best-implementation policy.
///
/// All-gather (Table 2):          All-to-all (Table 3):
/// - [1KB, 256KB): b2b+prelaunch  - [1KB, 64KB): b2b+prelaunch
/// - [256KB, 1MB): bcst+prelaunch - [64KB, 4MB): swap+prelaunch
/// - [1MB, 512MB): pcpy+prelaunch - [4MB, 1GB): pcpy+prelaunch
/// - ≥512MB:       pcpy           - ≥1GB:       pcpy
pub fn select_variant(kind: CollectiveKind, size: u64) -> Variant {
    match kind {
        CollectiveKind::AllGather => {
            if size < 256 * KB {
                Variant::new(Strategy::B2b, true)
            } else if size < MB {
                Variant::new(Strategy::Bcst, true)
            } else if size < 512 * MB {
                Variant::new(Strategy::Pcpy, true)
            } else {
                Variant::new(Strategy::Pcpy, false)
            }
        }
        CollectiveKind::AllToAll => {
            if size < 64 * KB {
                Variant::new(Strategy::B2b, true)
            } else if size < 4 * MB {
                Variant::new(Strategy::Swap, true)
            } else if size < GB {
                Variant::new(Strategy::Pcpy, true)
            } else {
                Variant::new(Strategy::Pcpy, false)
            }
        }
    }
}

/// A measured (size, variant, latency) point from a sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub size: u64,
    pub variant: Variant,
    pub latency_ns: u64,
}

/// Empirically derive the best variant per size from sweep data
/// (regenerates Tables 2/3 from measurements).
///
/// Single pass over the points (the old version re-filtered the full list
/// per distinct size, O(n²)): per size the running argmin is kept with a
/// strict `<` comparison, which preserves the historical tie-break — the
/// earliest point in input order wins among equal latencies.
pub fn calibrate(points: &[SweepPoint]) -> Vec<(u64, Variant)> {
    use std::collections::HashMap;
    let mut best: HashMap<u64, (u64, Variant)> = HashMap::with_capacity(points.len());
    for p in points {
        let e = best.entry(p.size).or_insert((p.latency_ns, p.variant));
        if p.latency_ns < e.0 {
            *e = (p.latency_ns, p.variant);
        }
    }
    let mut out: Vec<(u64, Variant)> = best.into_iter().map(|(s, (_, v))| (s, v)).collect();
    out.sort_unstable_by_key(|&(s, _)| s);
    out
}

/// Collapse a per-size best list into contiguous ranges (table rows).
pub fn ranges(best: &[(u64, Variant)]) -> Vec<(u64, u64, Variant)> {
    let mut out: Vec<(u64, u64, Variant)> = Vec::new();
    for &(size, v) in best {
        match out.last_mut() {
            Some((_, hi, lv)) if *lv == v => *hi = size,
            _ => out.push((size, size, v)),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_rows() {
        let k = CollectiveKind::AllGather;
        assert_eq!(
            select_variant(k, 4 * KB),
            Variant::new(Strategy::B2b, true)
        );
        assert_eq!(
            select_variant(k, 512 * KB),
            Variant::new(Strategy::Bcst, true)
        );
        assert_eq!(
            select_variant(k, 32 * MB),
            Variant::new(Strategy::Pcpy, true)
        );
        assert_eq!(
            select_variant(k, GB),
            Variant::new(Strategy::Pcpy, false)
        );
    }

    #[test]
    fn table3_rows() {
        let k = CollectiveKind::AllToAll;
        assert_eq!(select_variant(k, 4 * KB), Variant::new(Strategy::B2b, true));
        assert_eq!(
            select_variant(k, MB),
            Variant::new(Strategy::Swap, true)
        );
        assert_eq!(
            select_variant(k, 64 * MB),
            Variant::new(Strategy::Pcpy, true)
        );
        assert_eq!(
            select_variant(k, 2 * GB),
            Variant::new(Strategy::Pcpy, false)
        );
    }

    #[test]
    fn selected_variants_are_applicable() {
        for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
            for size in crate::util::bytes::size_sweep(KB, 4 * GB, 2) {
                assert!(select_variant(kind, size).strategy.applicable(kind));
            }
        }
    }

    #[test]
    fn calibrate_picks_argmin_and_ranges_collapse() {
        let v1 = Variant::new(Strategy::B2b, true);
        let v2 = Variant::new(Strategy::Pcpy, true);
        let pts = vec![
            SweepPoint { size: 1024, variant: v1, latency_ns: 10 },
            SweepPoint { size: 1024, variant: v2, latency_ns: 20 },
            SweepPoint { size: 2048, variant: v1, latency_ns: 15 },
            SweepPoint { size: 2048, variant: v2, latency_ns: 18 },
            SweepPoint { size: 4096, variant: v1, latency_ns: 30 },
            SweepPoint { size: 4096, variant: v2, latency_ns: 25 },
        ];
        let best = calibrate(&pts);
        assert_eq!(best[0].1, v1);
        assert_eq!(best[2].1, v2);
        let r = ranges(&best);
        assert_eq!(r.len(), 2);
        assert_eq!(r[0], (1024, 2048, v1));
        assert_eq!(r[1], (4096, 4096, v2));
    }

    #[test]
    fn calibrate_tie_break_prefers_first_in_input_order() {
        let v1 = Variant::new(Strategy::B2b, true);
        let v2 = Variant::new(Strategy::Pcpy, true);
        let pts = vec![
            SweepPoint { size: 2048, variant: v2, latency_ns: 10 },
            SweepPoint { size: 2048, variant: v1, latency_ns: 10 },
            SweepPoint { size: 1024, variant: v1, latency_ns: 5 },
        ];
        // Sizes ascending; equal latencies keep the earlier input point.
        assert_eq!(calibrate(&pts), vec![(1024, v1), (2048, v2)]);
    }
}
