//! `bcst` — broadcast-command all-gather (paper §4.2, Fig. 9).
//!
//! Each broadcast command carries one source and TWO destinations, so a
//! rank's 7 peer transfers collapse to ⌈7/2⌉ = 4 commands on 4 engines
//! (3 broadcasts + 1 copy): half the commands, half the engines, half the
//! sync traffic, and the source chunk is read from HBM once per pair.

use crate::sim::command::{Addr, Command};
use crate::sim::engine::EngineId;
use crate::sim::topology::{NodeId, Topology};

use super::plan::{CollectivePlan, EnginePlan, RankPlan};
use super::CollectiveKind;

/// Build the broadcast-based AG plan (AG only; see `Strategy::applicable`).
pub fn plan(topo: &Topology, size: u64) -> CollectivePlan {
    let n = topo.num_gpus;
    let chunk = CollectivePlan::chunk(size, n);
    assert!(chunk > 0, "size {size} too small for {n} GPUs");
    let mut ranks = Vec::new();
    for g in 0..n {
        let src = Addr::new(NodeId::Gpu(g), g as u64 * chunk);
        let peers = topo.peers(g);
        let mut engines = Vec::new();
        let mut eidx = 0u8;
        let mut it = peers.chunks(2);
        for pair in &mut it {
            let cmd = if pair.len() == 2 {
                Command::Bcst {
                    src,
                    dst0: Addr::new(NodeId::Gpu(pair[0]), g as u64 * chunk),
                    dst1: Addr::new(NodeId::Gpu(pair[1]), g as u64 * chunk),
                    len: chunk,
                }
            } else {
                Command::Copy {
                    src,
                    dst: Addr::new(NodeId::Gpu(pair[0]), g as u64 * chunk),
                    len: chunk,
                }
            };
            engines.push(EnginePlan {
                engine: EngineId { gpu: g, idx: eidx },
                cmds: vec![cmd],
                batched_control: false,
            });
            eidx += 1;
        }
        ranks.push(RankPlan { gpu: g, engines });
    }
    let p = CollectivePlan {
        kind: CollectiveKind::AllGather,
        size,
        ranks,
    };
    p.validate(topo);
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn halves_commands_and_engines() {
        let topo = Topology::mi300x_platform();
        let p = plan(&topo, 8192);
        // 7 peers → 3 bcst + 1 copy per rank.
        assert_eq!(p.total_engines(), 8 * 4);
        assert_eq!(p.total_data_cmds(), 8 * 4);
        let r0 = &p.ranks[0];
        let bcsts = r0
            .engines
            .iter()
            .filter(|e| matches!(e.cmds[0], Command::Bcst { .. }))
            .count();
        let copies = r0
            .engines
            .iter()
            .filter(|e| matches!(e.cmds[0], Command::Copy { .. }))
            .count();
        assert_eq!((bcsts, copies), (3, 1));
    }

    #[test]
    fn covers_all_peers_exactly_once() {
        let topo = Topology::mi300x_platform();
        let p = plan(&topo, 8192);
        for r in &p.ranks {
            let mut dsts = Vec::new();
            for e in &r.engines {
                match &e.cmds[0] {
                    Command::Bcst { dst0, dst1, .. } => {
                        dsts.push(dst0.node);
                        dsts.push(dst1.node);
                    }
                    Command::Copy { dst, .. } => dsts.push(dst.node),
                    c => panic!("unexpected {c:?}"),
                }
            }
            dsts.sort();
            let expect: Vec<_> = topo
                .peers(r.gpu)
                .into_iter()
                .map(NodeId::Gpu)
                .collect();
            assert_eq!(dsts, expect);
        }
    }

    #[test]
    fn even_peer_count_uses_only_bcst() {
        // 5 GPUs → 4 peers → 2 bcst, 0 copies.
        let topo = Topology::custom(5, 8, 64.0, 64.0);
        let p = plan(&topo, 5 * 1024);
        for r in &p.ranks {
            assert_eq!(r.engines.len(), 2);
            assert!(r
                .engines
                .iter()
                .all(|e| matches!(e.cmds[0], Command::Bcst { .. })));
        }
    }
}
