//! `swap` — swap-command all-to-all (paper §4.3, Fig. 10).
//!
//! An in-place AA is a set of pairwise exchanges: rank g's chunk j swaps
//! with rank j's chunk g. One DMA `swap` command performs an exchange that
//! would otherwise need three copies and a temporary buffer. Each of the
//! n(n-1)/2 pairs is issued by exactly one rank; issuers are balanced so
//! every rank drives ⌊(n-1)/2⌋ or ⌈(n-1)/2⌉ swaps.

use crate::sim::command::{Addr, Command};
use crate::sim::engine::EngineId;
use crate::sim::topology::{NodeId, Topology};

use super::plan::{CollectivePlan, EnginePlan, RankPlan};
use super::CollectiveKind;

/// Which rank issues the swap for pair (a, b)? Balanced ring rule:
/// rank `a` issues for peers at ring distance 1..=⌊(n-1)/2⌋ ahead, and for
/// the antipode (even n) the lower rank issues.
pub fn issuer(a: u8, b: u8, n: u8) -> u8 {
    assert!(a != b && a < n && b < n);
    let d = (b + n - a) % n; // ring distance a → b
    let half = (n - 1) / 2;
    if d <= half {
        a
    } else if n % 2 == 0 && d == n / 2 {
        a.min(b)
    } else {
        b
    }
}

/// Build the swap-based in-place AA plan (AA only).
pub fn plan(topo: &Topology, size: u64) -> CollectivePlan {
    let n = topo.num_gpus;
    let chunk = CollectivePlan::chunk(size, n);
    assert!(chunk > 0, "size {size} too small for {n} GPUs");
    let mut ranks: Vec<RankPlan> = (0..n)
        .map(|g| RankPlan {
            gpu: g,
            engines: Vec::new(),
        })
        .collect();
    for a in 0..n {
        for b in (a + 1)..n {
            let iss = issuer(a, b, n);
            let r = &mut ranks[iss as usize];
            let eidx = r.engines.len() as u8;
            r.engines.push(EnginePlan {
                engine: EngineId { gpu: iss, idx: eidx },
                cmds: vec![Command::Swap {
                    a: Addr::new(NodeId::Gpu(a), b as u64 * chunk),
                    b: Addr::new(NodeId::Gpu(b), a as u64 * chunk),
                    len: chunk,
                }],
                batched_control: false,
            });
        }
    }
    let p = CollectivePlan {
        kind: CollectiveKind::AllToAll,
        size,
        ranks,
    };
    p.validate(topo);
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issuers_are_balanced() {
        let n = 8u8;
        let mut counts = vec![0usize; n as usize];
        for a in 0..n {
            for b in (a + 1)..n {
                counts[issuer(a, b, n) as usize] += 1;
            }
        }
        // 28 swaps over 8 ranks: 3 or 4 each.
        assert_eq!(counts.iter().sum::<usize>(), 28);
        assert!(counts.iter().all(|&c| c == 3 || c == 4), "{counts:?}");
    }

    #[test]
    fn every_pair_swapped_once() {
        let topo = Topology::mi300x_platform();
        let p = plan(&topo, 8192);
        assert_eq!(p.total_data_cmds(), 28);
        let mut pairs = std::collections::HashSet::new();
        for r in &p.ranks {
            for e in &r.engines {
                match e.cmds[0] {
                    Command::Swap { a, b, .. } => {
                        let (ga, gb) = match (a.node, b.node) {
                            (NodeId::Gpu(x), NodeId::Gpu(y)) => (x.min(y), x.max(y)),
                            _ => panic!("swap must be GPU-GPU"),
                        };
                        assert!(pairs.insert((ga, gb)), "duplicate pair");
                    }
                    _ => panic!("swap plan must use Swap"),
                }
            }
        }
        assert_eq!(pairs.len(), 28);
    }

    #[test]
    fn swap_offsets_transpose() {
        let topo = Topology::mi300x_platform();
        let size = 8 * 1024u64;
        let chunk = size / 8;
        let p = plan(&topo, size);
        for r in &p.ranks {
            for e in &r.engines {
                if let Command::Swap { a, b, len } = e.cmds[0] {
                    let (NodeId::Gpu(ga), NodeId::Gpu(gb)) = (a.node, b.node) else {
                        unreachable!()
                    };
                    assert_eq!(len, chunk);
                    assert_eq!(a.offset, gb as u64 * chunk);
                    assert_eq!(b.offset, ga as u64 * chunk);
                }
            }
        }
    }

    #[test]
    fn odd_gpu_count_balances_too() {
        let n = 5u8;
        let mut counts = vec![0usize; n as usize];
        for a in 0..n {
            for b in (a + 1)..n {
                counts[issuer(a, b, n) as usize] += 1;
            }
        }
        assert_eq!(counts.iter().sum::<usize>(), 10);
        assert!(counts.iter().all(|&c| c == 2), "{counts:?}");
    }
}
