//! Cross-episode plan cache (§Perf pass).
//!
//! Planning a collective is a pure function of the collective kind, the
//! base strategy, the byte size and the *shape* of the topology (GPU and
//! engine counts — no planner consults link bandwidths), so repeated
//! episodes at the same point — selector calibration sweeps, figure
//! generators, the serving path's per-batch-shape sizing — used to rebuild
//! the identical `Vec<Command>` lists every call. The cache builds each
//! plan once and hands out [`Arc`] clones; the executor reads through the
//! `Arc`, so replay costs two reference-count bumps instead of a planner
//! walk. The hierarchical `cluster::hier` layer keeps a sibling cache of
//! its rebased node scripts keyed the same way plus the node coordinates.
//!
//! Caching is semantically invisible: planners are deterministic, plans
//! are immutable once built, and `tests/determinism.rs` pins cache-hit
//! episodes to fresh-build episodes bit for bit.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::sim::Topology;

use super::exec::build_plan;
use super::plan::CollectivePlan;
use super::{CollectiveKind, Variant};

/// Shape fingerprint of a topology: everything a planner reads from it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WorldShape {
    pub num_gpus: u8,
    pub engines_per_gpu: u8,
}

impl WorldShape {
    /// Fingerprint `topo` (bandwidths deliberately excluded — plans carry
    /// addresses and engine placements, never link speeds).
    pub fn of(topo: &Topology) -> Self {
        WorldShape {
            num_gpus: topo.num_gpus,
            engines_per_gpu: topo.engines_per_gpu,
        }
    }
}

/// Cache key: (kind, variant, size, world shape). The variant's prelaunch
/// flag is part of the key for uniformity even though planners only read
/// the strategy — keying on the full variant keeps the key aligned with
/// the call sites and costs one extra bool.
///
/// Schedule audit (PR 4): the cluster layer's inter schedules
/// (`Sequential`/`Pipelined`/`Overlapped`) do NOT appear here by design —
/// flat single-node plans have no inter leg, no caller threads a schedule
/// into [`build_plan`], and triggers are applied at queue time. The
/// schedule-sensitive cache is `cluster::hier`'s rounds cache, whose
/// `RoundsKey` carries the full `ClusterChoice` (variant AND schedule);
/// its poison test proves an `Overlapped` lookup can never be served a
/// `Sequential` build.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub kind: CollectiveKind,
    pub variant: Variant,
    pub size: u64,
    pub shape: WorldShape,
}

/// Runaway guard: property tests draw random sizes, and an unbounded map
/// would slowly pin every plan ever built. Past this many entries the
/// cache is dropped wholesale (episodes after a flush rebuild on miss —
/// correctness is unaffected).
const CACHE_CAP: usize = 4096;

static PLANS: OnceLock<Mutex<HashMap<PlanKey, Arc<CollectivePlan>>>> = OnceLock::new();
static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);

fn table() -> &'static Mutex<HashMap<PlanKey, Arc<CollectivePlan>>> {
    PLANS.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Shared skeleton for the crate's cross-episode caches (this flat plan
/// cache and `cluster::hier`'s rounds cache): double-checked lookup with
/// the build running OUTSIDE the lock (planning can be slow and must not
/// serialize concurrent test threads), flush-at-cap as a runaway guard,
/// first-insert-wins on a build race so every caller shares one
/// allocation. Returns the value and whether the first lookup hit.
pub(crate) fn get_or_build<K: Eq + Hash, V>(
    table: &Mutex<HashMap<K, Arc<V>>>,
    cap: usize,
    key: K,
    build: impl FnOnce() -> V,
) -> (Arc<V>, bool) {
    if let Some(v) = table.lock().unwrap().get(&key) {
        return (Arc::clone(v), true);
    }
    let v = Arc::new(build());
    let mut t = table.lock().unwrap();
    if t.len() >= cap {
        t.clear();
    }
    (Arc::clone(t.entry(key).or_insert(v)), false)
}

/// Plan `variant` for `kind` at `size` bytes on `topo`, served from the
/// cross-episode cache. Identical to [`build_plan`] output by
/// construction (the builder is deterministic).
pub fn cached_plan(
    kind: CollectiveKind,
    variant: Variant,
    topo: &Topology,
    size: u64,
) -> Arc<CollectivePlan> {
    let key = PlanKey {
        kind,
        variant,
        size,
        shape: WorldShape::of(topo),
    };
    let (plan, hit) =
        get_or_build(table(), CACHE_CAP, key, || build_plan(kind, variant, topo, size));
    let counter = if hit { &HITS } else { &MISSES };
    counter.fetch_add(1, Ordering::Relaxed);
    plan
}

/// Lifetime (hit, miss) counters — benches report them, tests assert the
/// replay path actually hits.
pub fn stats() -> (u64, u64) {
    (HITS.load(Ordering::Relaxed), MISSES.load(Ordering::Relaxed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::Strategy;
    use crate::util::bytes::KB;

    #[test]
    fn hit_returns_shared_plan_identical_to_fresh_build() {
        let topo = Topology::mi300x_platform();
        let v = Variant::new(Strategy::Pcpy, true);
        let a = cached_plan(CollectiveKind::AllGather, v, &topo, 8 * KB);
        let b = cached_plan(CollectiveKind::AllGather, v, &topo, 8 * KB);
        assert!(Arc::ptr_eq(&a, &b), "repeat lookups must share one plan");
        let fresh = build_plan(CollectiveKind::AllGather, v, &topo, 8 * KB);
        assert_eq!(a.total_data_cmds(), fresh.total_data_cmds());
        assert_eq!(a.total_engines(), fresh.total_engines());
        assert_eq!(a.size, fresh.size);
        let (h, _) = stats();
        assert!(h >= 1);
    }

    #[test]
    fn distinct_shapes_do_not_collide() {
        let big = Topology::mi300x_platform();
        let small = Topology::custom(4, 16, 64.0, 64.0);
        let v = Variant::new(Strategy::Pcpy, false);
        let a = cached_plan(CollectiveKind::AllToAll, v, &big, 16 * KB);
        let b = cached_plan(CollectiveKind::AllToAll, v, &small, 16 * KB);
        assert_eq!(a.ranks.len(), 8);
        assert_eq!(b.ranks.len(), 4);
    }
}
