//! DMA-Latte collectives: the paper's operator-level contribution (§4–5.2).
//!
//! All-gather and all-to-all offloaded entirely to sDMA engines, in five
//! implementations:
//!
//! | variant     | feature (paper)                  | section |
//! |-------------|----------------------------------|---------|
//! | `pcpy`      | parallel copies, 1 engine/peer   | §4.1    |
//! | `bcst`      | broadcast command (1 src, 2 dst) | §4.2    |
//! | `swap`      | swap command (in-place exchange) | §4.3    |
//! | `b2b`       | back-to-back overlap, 1 engine   | §4.4    |
//! | `prelaunch` | poll-gated pre-scheduled streams | §4.5    |
//!
//! `prelaunch` composes with each of the others, giving the eight
//! configurations of Figs. 13/14. [`selector`] encodes the best-per-size
//! policy of Tables 2/3.

pub mod b2b;
pub mod bcst;
pub mod cache;
pub mod exec;
pub mod moe;
pub mod pcpy;
pub mod plan;
pub mod reduce_scatter;
pub mod selector;
pub mod swap;
pub mod verify;

pub use exec::{run_collective, CollectiveResult, CollectiveRunner, RunOptions};
pub use plan::{CollectivePlan, EnginePlan, RankPlan};
pub use selector::select_variant;

/// Which collective.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    /// Each GPU contributes a chunk; everyone ends with the concatenation.
    AllGather,
    /// Chunk (g, j) of GPU g's input becomes chunk g of GPU j's output
    /// (a distributed transpose).
    AllToAll,
}

impl CollectiveKind {
    /// Short name as used in figure labels.
    pub fn name(&self) -> &'static str {
        match self {
            CollectiveKind::AllGather => "allgather",
            CollectiveKind::AllToAll => "alltoall",
        }
    }
}

/// Base implementation strategy (before the prelaunch axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    Pcpy,
    Bcst,
    Swap,
    B2b,
}

impl Strategy {
    /// Short name as used in figure labels.
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Pcpy => "pcpy",
            Strategy::Bcst => "bcst",
            Strategy::Swap => "swap",
            Strategy::B2b => "b2b",
        }
    }

    /// Is this strategy applicable to `kind`? (`bcst` needs a shared source
    /// → AG only; `swap` needs a symmetric exchange → AA only.)
    pub fn applicable(&self, kind: CollectiveKind) -> bool {
        match (self, kind) {
            (Strategy::Bcst, CollectiveKind::AllToAll) => false,
            (Strategy::Swap, CollectiveKind::AllGather) => false,
            _ => true,
        }
    }
}

/// A full variant: strategy × prelaunch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Variant {
    pub strategy: Strategy,
    pub prelaunch: bool,
}

impl Variant {
    /// Construct.
    pub fn new(strategy: Strategy, prelaunch: bool) -> Self {
        Variant {
            strategy,
            prelaunch,
        }
    }

    /// Figure-label name, e.g. `prelaunch_b2b`.
    pub fn name(&self) -> String {
        if self.prelaunch {
            format!("prelaunch_{}", self.strategy.name())
        } else {
            self.strategy.name().to_string()
        }
    }

    /// All variants applicable to `kind`, in figure order.
    pub fn all_for(kind: CollectiveKind) -> Vec<Variant> {
        let mut v = Vec::new();
        for s in [Strategy::Pcpy, Strategy::Bcst, Strategy::Swap, Strategy::B2b] {
            if s.applicable(kind) {
                v.push(Variant::new(s, false));
                v.push(Variant::new(s, true));
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn applicability() {
        assert!(Strategy::Bcst.applicable(CollectiveKind::AllGather));
        assert!(!Strategy::Bcst.applicable(CollectiveKind::AllToAll));
        assert!(Strategy::Swap.applicable(CollectiveKind::AllToAll));
        assert!(!Strategy::Swap.applicable(CollectiveKind::AllGather));
        assert!(Strategy::Pcpy.applicable(CollectiveKind::AllGather));
        assert!(Strategy::B2b.applicable(CollectiveKind::AllToAll));
    }

    #[test]
    fn variant_names() {
        assert_eq!(Variant::new(Strategy::B2b, true).name(), "prelaunch_b2b");
        assert_eq!(Variant::new(Strategy::Pcpy, false).name(), "pcpy");
    }

    #[test]
    fn variants_per_kind() {
        // AG: pcpy, bcst, b2b × {direct, prelaunch} = 6
        assert_eq!(Variant::all_for(CollectiveKind::AllGather).len(), 6);
        // AA: pcpy, swap, b2b × {direct, prelaunch} = 6
        assert_eq!(Variant::all_for(CollectiveKind::AllToAll).len(), 6);
    }
}
