//! `b2b` — back-to-back overlap collective (paper §4.4, Fig. 11).
//!
//! All of a rank's copies are placed on a SINGLE engine as one batched
//! stream with a single sync command. The engine's issue pipeline overlaps
//! consecutive copies (loads of copy k+1 issue while copy k drains), hiding
//! per-copy fixed costs, and the rank pays one doorbell + one wake + one
//! sync instead of seven of each.

use crate::sim::command::{Addr, Command};
use crate::sim::engine::EngineId;
use crate::sim::topology::{NodeId, Topology};

use super::plan::{aa_out_base, CollectivePlan, EnginePlan, RankPlan};
use super::CollectiveKind;

/// Build the b2b plan: one engine per rank, all copies back-to-back.
pub fn plan(kind: CollectiveKind, topo: &Topology, size: u64) -> CollectivePlan {
    let n = topo.num_gpus;
    let chunk = CollectivePlan::chunk(size, n);
    assert!(chunk > 0, "size {size} too small for {n} GPUs");
    let mut ranks = Vec::new();
    for g in 0..n {
        let mut cmds = Vec::new();
        for peer in topo.peers(g) {
            cmds.push(match kind {
                CollectiveKind::AllGather => Command::Copy {
                    src: Addr::new(NodeId::Gpu(g), g as u64 * chunk),
                    dst: Addr::new(NodeId::Gpu(peer), g as u64 * chunk),
                    len: chunk,
                },
                CollectiveKind::AllToAll => Command::Copy {
                    src: Addr::new(NodeId::Gpu(g), peer as u64 * chunk),
                    dst: Addr::new(NodeId::Gpu(peer), aa_out_base(size) + g as u64 * chunk),
                    len: chunk,
                },
            });
        }
        ranks.push(RankPlan {
            gpu: g,
            engines: vec![EnginePlan {
                engine: EngineId { gpu: g, idx: 0 },
                cmds,
                batched_control: true,
            }],
        });
    }
    let p = CollectivePlan { kind, size, ranks };
    p.validate(topo);
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_engine_per_rank() {
        let topo = Topology::mi300x_platform();
        let p = plan(CollectiveKind::AllGather, &topo, 8192);
        assert_eq!(p.total_engines(), 8);
        for r in &p.ranks {
            assert_eq!(r.engines.len(), 1);
            assert_eq!(r.engines[0].cmds.len(), 7);
            assert!(r.engines[0].batched_control);
        }
    }

    #[test]
    fn copies_are_hazard_free() {
        // b2b pipelining requires unique src/dst — verify no intra-stream
        // hazards in the generated plan.
        use crate::sim::command::hazard;
        let topo = Topology::mi300x_platform();
        for kind in [CollectiveKind::AllGather, CollectiveKind::AllToAll] {
            let p = plan(kind, &topo, 8192);
            for r in &p.ranks {
                let cmds = &r.engines[0].cmds;
                for i in 0..cmds.len() {
                    for j in (i + 1)..cmds.len() {
                        assert!(
                            !hazard(&cmds[i], &cmds[j]),
                            "hazard between {i} and {j} in {kind:?}"
                        );
                    }
                }
            }
        }
    }
}
