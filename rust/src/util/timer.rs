//! Wall-clock micro-bench helper (criterion is not vendored offline).
//!
//! Every `rust/benches/*` binary uses [`bench`] for hot-path measurements:
//! warmup, N timed iterations, mean/median/p99 in nanoseconds.

use std::time::Instant;

use crate::util::stats;

/// Result of a [`bench`] run (all values nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<40} {:>12} median  {:>12} mean  {:>12} p99  ({} iters)",
            self.name,
            crate::util::bytes::fmt_ns(self.median_ns),
            crate::util::bytes::fmt_ns(self.mean_ns),
            crate::util::bytes::fmt_ns(self.p99_ns),
            self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: stats::mean(&samples),
        median_ns: stats::median(&samples),
        p99_ns: stats::percentile(&samples, 99.0),
        min_ns: stats::min(&samples),
    }
}

/// Prevent the optimizer from discarding a value (stable-Rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop-ish", 2, 16, || {
            black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.iters, 16);
        assert!(r.mean_ns >= 0.0);
        assert!(r.min_ns <= r.median_ns);
        assert!(r.median_ns <= r.p99_ns + 1e-9);
    }
}
