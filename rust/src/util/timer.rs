//! Wall-clock micro-bench helper (criterion is not vendored offline).
//!
//! Every `rust/benches/*` binary uses [`bench`] for hot-path measurements:
//! warmup, N timed iterations, mean/median/p95/p99 in nanoseconds.
//! [`bench_json`] serializes before/after comparison rows into the
//! machine-readable `BENCH_*.json` trajectory files.

use std::time::Instant;

use crate::util::stats;

/// Result of a [`bench`] run (all values nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<40} {:>12} median  {:>12} mean  {:>12} p95  {:>12} p99  ({} iters)",
            self.name,
            crate::util::bytes::fmt_ns(self.median_ns),
            crate::util::bytes::fmt_ns(self.mean_ns),
            crate::util::bytes::fmt_ns(self.p95_ns),
            crate::util::bytes::fmt_ns(self.p99_ns),
            self.iters
        )
    }

    /// JSON object for the machine-readable `BENCH_*.json` trajectory
    /// files (`serde` is not vendored; the schema is flat numbers only).
    pub fn json(&self) -> String {
        format!(
            "{{\"name\": {}, \"iters\": {}, \"mean_ns\": {:.1}, \"median_ns\": {:.1}, \
             \"p95_ns\": {:.1}, \"p99_ns\": {:.1}, \"min_ns\": {:.1}}}",
            json_str(&self.name),
            self.iters,
            self.mean_ns,
            self.median_ns,
            self.p95_ns,
            self.p99_ns,
            self.min_ns
        )
    }
}

/// One before/after row of a `BENCH_*.json` trajectory file: the same hot
/// path measured through the legacy (pre-optimization) code path and the
/// optimized one, in the same process on the same machine.
#[derive(Debug, Clone)]
pub struct BenchComparison {
    /// Stable row identifier (greppable in CI).
    pub path: String,
    /// Legacy code-path measurement; `None` for rows that only exist in
    /// the optimized form (reported without a speedup).
    pub before: Option<BenchResult>,
    pub after: BenchResult,
}

impl BenchComparison {
    /// Median-over-median speedup; `None` without a baseline.
    pub fn speedup(&self) -> Option<f64> {
        self.before
            .as_ref()
            .map(|b| b.median_ns / self.after.median_ns)
    }

    /// JSON object for this row.
    pub fn json(&self) -> String {
        let mut s = format!("{{\"path\": {}", json_str(&self.path));
        if let Some(b) = &self.before {
            s.push_str(&format!(", \"before\": {}", b.json()));
        }
        s.push_str(&format!(", \"after\": {}", self.after.json()));
        // A zero-duration median would make the ratio non-finite and the
        // document unparseable; drop the field instead.
        if let Some(sp) = self.speedup().filter(|sp| sp.is_finite()) {
            s.push_str(&format!(", \"speedup_median\": {sp:.2}"));
        }
        s.push('}');
        s
    }
}

/// Assemble a full `BENCH_*.json` document: bench name, free-form string
/// metadata, and the comparison rows. Parseable by [`crate::util::json`].
pub fn bench_json(bench: &str, meta: &[(&str, String)], rows: &[BenchComparison]) -> String {
    let mut s = format!("{{\n  \"bench\": {}", json_str(bench));
    for (k, v) in meta {
        s.push_str(&format!(",\n  {}: {}", json_str(k), json_str(v)));
    }
    s.push_str(",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str("    ");
        s.push_str(&r.json());
        if i + 1 < rows.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ]\n}\n");
    s
}

/// Minimal JSON string escape (quotes, backslashes, control chars).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: stats::mean(&samples),
        median_ns: stats::median(&samples),
        p95_ns: stats::percentile_nearest_rank(&samples, 95.0),
        p99_ns: stats::percentile_nearest_rank(&samples, 99.0),
        min_ns: stats::min(&samples),
    }
}

/// Prevent the optimizer from discarding a value (stable-Rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let r = bench("noop-ish", 2, 16, || {
            black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.iters, 16);
        assert!(r.mean_ns >= 0.0);
        assert!(r.min_ns <= r.median_ns);
        assert!(r.median_ns <= r.p95_ns + 1e-9);
        assert!(r.p95_ns <= r.p99_ns + 1e-9);
    }

    #[test]
    fn bench_json_round_trips_through_the_parser() {
        let a = bench("after", 1, 4, || {
            black_box((0..10_000).sum::<u64>());
        });
        let b = bench("before", 1, 4, || {
            black_box((0..100_000).sum::<u64>());
        });
        let doc = bench_json(
            "perf_hotpath",
            &[("pr", "PR3".to_string()), ("mode", "smoke".to_string())],
            &[
                BenchComparison {
                    path: "collective_episode".to_string(),
                    before: Some(b),
                    after: a.clone(),
                },
                BenchComparison {
                    path: "baseline_free_row".to_string(),
                    before: None,
                    after: a,
                },
            ],
        );
        let j = crate::util::json::Json::parse(&doc).expect("emitted JSON must parse");
        assert_eq!(j.get("bench").unwrap().str(), Some("perf_hotpath"));
        assert_eq!(j.get("pr").unwrap().str(), Some("PR3"));
        let rows = j.get("rows").unwrap().arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(
            rows[0].get("path").unwrap().str(),
            Some("collective_episode")
        );
        assert!(rows[0].get("speedup_median").unwrap().num().is_some());
        assert!(rows[0].get("before").unwrap().get("median_ns").is_some());
        assert!(rows[1].get("before").is_none());
        assert!(rows[1].get("after").unwrap().get("p95_ns").is_some());
    }

    #[test]
    fn json_str_escapes() {
        assert_eq!(json_str("a\"b\\c\n"), "\"a\\\"b\\\\c\\n\"");
    }
}
