//! Summary statistics used throughout the evaluation harness:
//! geomean (the paper reports geomean speedups), percentiles, mean/stddev —
//! plus the bounded-memory streaming accumulators behind `ServeMetrics`
//! ([`LatHist`], [`Reservoir`]) so million-request serving episodes do not
//! keep a per-request `Vec` alive.

/// Geometric mean of positive values. Returns NaN for an empty slice.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean requires positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

/// Arithmetic mean. NaN for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1). 0 for fewer than 2 samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Percentile via linear interpolation on sorted data, `p` in `[0,100]`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Percentile via the nearest-rank method (the SLO-reporting convention:
/// the value reported is always an observed sample, never interpolated).
///
/// `rank = ceil(p/100 * n)`, clamped to `[1, n]`; returns `sorted[rank-1]`.
/// NaN for empty input. Shared by `ServeMetrics` (TTFT / per-token
/// percentiles) and the bench timer's p95/p99.
pub fn percentile_nearest_rank(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    let rank = ((p / 100.0) * n as f64).ceil() as usize;
    v[rank.clamp(1, n) - 1]
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// min of a slice (NaN-free input assumed).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// max of a slice.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Exact-phase sample cap for [`LatHist`] / [`Reservoir`] defaults. Every
/// serving episode below this many samples per series reports the same
/// bit-exact numbers as the pre-streaming unbounded vectors did.
pub const LATHIST_DEFAULT_CAP: usize = 65_536;
/// Log-bucket growth factor. The sketch's worst-case relative error is
/// `sqrt(gamma) - 1` ≈ 0.995 % — just under the documented 1 % bound.
pub const LATHIST_GAMMA: f64 = 1.02;
/// Smallest bucketed value (1 ns — latencies below that underflow to min).
pub const LATHIST_MIN: f64 = 1.0;
/// Hard bucket-count ceiling: `ln(1e17) / ln(1.02)` ≈ 1976 buckets span
/// 1 ns .. ~3 years, so 2048 bounds the sketch at ~16 KiB regardless of
/// input range.
pub const LATHIST_MAX_BUCKETS: usize = 2048;

/// Bounded-memory latency accumulator: **exact** nearest-rank percentiles
/// while at most `cap` samples have been pushed, a log-bucketed sketch with
/// a ≤ 1 % relative-error bound beyond that.
///
/// Below the cap the accumulator is just a `Vec<f64>` in push order —
/// `percentile` delegates to [`percentile_nearest_rank`], `mean` performs
/// the same left-to-right summation as [`mean`], and [`std::ops::Index`] /
/// `iter` expose the raw samples — so every existing caller sees
/// bit-identical numbers. When sample `cap + 1` arrives the exact buffer is
/// folded into γ = 1.02 log buckets and dropped; from then on memory is
/// O(`LATHIST_MAX_BUCKETS`) and percentiles come from a counting walk whose
/// answer lands in the bucket containing the true nearest-rank sample
/// (bucket counts are exact), hence relative error ≤ √γ − 1 for values
/// ≥ 1 ns. Values below 1 ns (or NaN) land in an underflow bucket reported
/// as the running minimum.
#[derive(Debug, Clone, PartialEq)]
pub struct LatHist {
    /// Raw samples in push order; drained (and left empty) once spilled.
    exact: Vec<f64>,
    cap: usize,
    /// Lazily-sized log buckets; empty until the exact phase spills.
    buckets: Vec<u64>,
    underflow: u64,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LatHist {
    fn default() -> Self {
        LatHist::with_cap(LATHIST_DEFAULT_CAP)
    }
}

impl LatHist {
    /// Accumulator holding up to `cap` exact samples before sketching.
    pub fn with_cap(cap: usize) -> LatHist {
        LatHist {
            exact: Vec::new(),
            cap,
            buckets: Vec::new(),
            underflow: 0,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one sample.
    pub fn push(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if self.count <= self.cap as u64 {
            self.exact.push(v);
        } else {
            if !self.exact.is_empty() {
                let drained = std::mem::take(&mut self.exact);
                for x in drained {
                    self.bucket_add(x);
                }
            }
            self.bucket_add(v);
        }
    }

    fn bucket_add(&mut self, v: f64) {
        // `!(v >= ..)` also routes NaN to the underflow bucket.
        if !(v >= LATHIST_MIN) {
            self.underflow += 1;
            return;
        }
        let idx = ((v / LATHIST_MIN).ln() / LATHIST_GAMMA.ln()).floor() as usize;
        let idx = idx.min(LATHIST_MAX_BUCKETS - 1);
        if self.buckets.len() <= idx {
            self.buckets.resize(idx + 1, 0);
        }
        self.buckets[idx] += 1;
    }

    /// Total samples pushed (not the resident count).
    pub fn len(&self) -> usize {
        self.count as usize
    }

    /// True iff no sample has been pushed.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// True once the exact phase has been folded into the sketch.
    pub fn spilled(&self) -> bool {
        self.count > self.cap as u64
    }

    /// The exact-phase samples in push order (empty after spilling).
    pub fn iter(&self) -> std::slice::Iter<'_, f64> {
        self.exact.iter()
    }

    /// Arithmetic mean of every sample ever pushed (exact in both phases;
    /// same summation order as [`mean`]). NaN when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum / self.count as f64
    }

    /// Nearest-rank percentile, `p` in `[0, 100]`. Exact below the cap,
    /// ≤ 1 % relative error above it. NaN when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        if !self.spilled() {
            return percentile_nearest_rank(&self.exact, p);
        }
        let n = self.count;
        let rank = (((p / 100.0) * n as f64).ceil() as u64).clamp(1, n);
        let mut acc = self.underflow;
        if rank <= acc {
            return self.min;
        }
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if rank <= acc {
                // Geometric bucket midpoint; clamping to the observed range
                // keeps the extremes exact.
                let rep = LATHIST_MIN * LATHIST_GAMMA.powi(i as i32) * LATHIST_GAMMA.sqrt();
                return rep.clamp(self.min, self.max);
            }
        }
        self.max
    }
}

impl From<Vec<f64>> for LatHist {
    fn from(xs: Vec<f64>) -> LatHist {
        let mut h = LatHist::default();
        for x in xs {
            h.push(x);
        }
        h
    }
}

impl std::ops::Index<usize> for LatHist {
    type Output = f64;
    /// Exact-phase sample by push index (panics after spilling, like
    /// indexing the drained `Vec` it replaced).
    fn index(&self, i: usize) -> &f64 {
        &self.exact[i]
    }
}

/// Default seed for [`Reservoir::default`]; instances that need replayable
/// samples should pass their own seed via [`Reservoir::with_cap`].
pub const RESERVOIR_DEFAULT_SEED: u64 = 0x5EED_0F5A_17C0_FFEE;

/// Seeded Algorithm-R reservoir sample: keeps every item in push order up
/// to `cap`, then replaces uniformly at random so the resident set stays a
/// uniform sample of everything seen, in O(cap) memory.
///
/// `len()` reports the **logical** count (items ever pushed) so callers
/// that previously sized a `Vec` keep working; `kept()` / `iter()` expose
/// the bounded sample.
#[derive(Debug, Clone, PartialEq)]
pub struct Reservoir<T> {
    kept: Vec<T>,
    cap: usize,
    seen: u64,
    state: u64,
}

impl<T> Default for Reservoir<T> {
    fn default() -> Self {
        Reservoir::with_cap(LATHIST_DEFAULT_CAP, RESERVOIR_DEFAULT_SEED)
    }
}

impl<T> Reservoir<T> {
    /// Reservoir keeping at most `cap` items, replacement driven by `seed`.
    pub fn with_cap(cap: usize, seed: u64) -> Reservoir<T> {
        Reservoir {
            kept: Vec::new(),
            cap,
            seen: 0,
            state: seed,
        }
    }

    /// splitmix64 — self-contained so the reservoir's stream never couples
    /// to any other consumer of [`crate::util::rng::Rng`].
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Offer one item to the sample.
    pub fn push(&mut self, item: T) {
        self.seen += 1;
        if self.kept.len() < self.cap {
            self.kept.push(item);
        } else if self.cap > 0 {
            // Modulo bias is ~2^-40 at the caps used here — irrelevant for
            // a diagnostic sample, and it keeps the replacement stream to
            // one splitmix64 step per item.
            let j = self.next_u64() % self.seen;
            if (j as usize) < self.cap {
                self.kept[j as usize] = item;
            }
        }
    }

    /// Items ever offered (logical length, not the resident count).
    pub fn len(&self) -> usize {
        self.seen as usize
    }

    /// True iff nothing has been offered.
    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    /// The resident sample (push order until the cap is hit).
    pub fn kept(&self) -> &[T] {
        &self.kept
    }

    /// Iterate the resident sample.
    pub fn iter(&self) -> std::slice::Iter<'_, T> {
        self.kept.iter()
    }
}

impl<T> From<Vec<T>> for Reservoir<T> {
    fn from(xs: Vec<T>) -> Reservoir<T> {
        let mut r = Reservoir::default();
        for x in xs {
            r.push(x);
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_empty_is_nan() {
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn mean_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(median(&xs), 3.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0];
        assert!((percentile(&xs, 50.0) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn nearest_rank_known_distributions() {
        // 1..=10: p50 -> ceil(5.0) = rank 5 -> 5; p95/p99 -> rank 10 -> 10.
        let xs: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(percentile_nearest_rank(&xs, 50.0), 5.0);
        assert_eq!(percentile_nearest_rank(&xs, 95.0), 10.0);
        assert_eq!(percentile_nearest_rank(&xs, 99.0), 10.0);
        // The classic worked example: {15,20,35,40,50}, p30 -> rank 2 -> 20.
        let ys = [15.0, 20.0, 35.0, 40.0, 50.0];
        assert_eq!(percentile_nearest_rank(&ys, 30.0), 20.0);
        assert_eq!(percentile_nearest_rank(&ys, 100.0), 50.0);
        // p0 clamps to rank 1 (the minimum), not an out-of-range index.
        assert_eq!(percentile_nearest_rank(&ys, 0.0), 15.0);
    }

    #[test]
    fn nearest_rank_empty_and_singleton() {
        assert!(percentile_nearest_rank(&[], 50.0).is_nan());
        assert_eq!(percentile_nearest_rank(&[7.5], 99.0), 7.5);
        assert_eq!(percentile_nearest_rank(&[7.5], 1.0), 7.5);
    }

    #[test]
    fn nearest_rank_always_returns_a_sample() {
        let xs = [3.0, 1.0, 4.0, 1.5, 9.0, 2.6];
        for p in [1.0, 10.0, 33.0, 50.0, 66.0, 90.0, 95.0, 99.0, 100.0] {
            let v = percentile_nearest_rank(&xs, p);
            assert!(xs.contains(&v), "p{p} gave {v}, not an observed sample");
        }
    }

    #[test]
    fn min_max() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 3.0);
    }

    #[test]
    fn lathist_exact_phase_is_bit_identical_to_vec_stats() {
        let xs: Vec<f64> = (0..200).map(|i| ((i * 37) % 101) as f64 * 1e6 + 0.25).collect();
        let h: LatHist = xs.clone().into();
        assert!(!h.spilled());
        assert_eq!(h.len(), xs.len());
        for p in [0.0, 1.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            assert_eq!(h.percentile(p), percentile_nearest_rank(&xs, p), "p{p}");
        }
        assert_eq!(h.mean(), mean(&xs));
        assert_eq!(h[0], xs[0]);
        assert_eq!(h.iter().copied().collect::<Vec<_>>(), xs);
    }

    #[test]
    fn lathist_empty_and_singleton() {
        let h = LatHist::default();
        assert!(h.is_empty());
        assert!(h.percentile(99.0).is_nan());
        assert!(h.mean().is_nan());
        let mut h = LatHist::with_cap(1);
        h.push(42.0);
        assert_eq!(h.percentile(50.0), 42.0);
        assert_eq!(h.percentile(99.9), 42.0);
        assert_eq!(h.mean(), 42.0);
    }

    #[test]
    fn lathist_spill_bounds_memory_and_keeps_extremes() {
        let mut h = LatHist::with_cap(16);
        for i in 1..=1000u64 {
            h.push(i as f64 * 1e3);
        }
        assert!(h.spilled());
        assert_eq!(h.len(), 1000);
        assert_eq!(h.iter().len(), 0, "exact buffer must drain on spill");
        assert!(h.buckets.len() <= LATHIST_MAX_BUCKETS);
        // p0/p100 clamp to the exact observed range.
        assert_eq!(h.percentile(0.0), 1e3);
        assert_eq!(h.percentile(100.0), 1e6);
        // The mean is exact in both phases.
        assert!((h.mean() - 500.5e3).abs() < 1e-6);
    }

    #[test]
    fn lathist_underflow_reports_min() {
        let mut h = LatHist::with_cap(2);
        for v in [0.25, 0.5, 2e6, 3e6, 4e6] {
            h.push(v);
        }
        assert!(h.spilled());
        // Ranks 1-2 sit in the underflow bucket -> reported as the min.
        assert_eq!(h.percentile(1.0), 0.25);
        assert_eq!(h.percentile(100.0), 4e6);
    }

    #[test]
    fn lathist_sketch_error_bound_property() {
        // Satellite: pinned <= 1 % relative error past the cap, over random
        // log-uniform latency distributions spanning ns..minutes.
        use crate::util::proptest::{run as prop_run, Config};
        prop_run(
            "lathist_sketch_error_bound",
            Config { cases: 24, ..Default::default() },
            |rng| {
                let n = 1500 + rng.below(1500) as usize;
                let mut h = LatHist::with_cap(32);
                let mut all = Vec::with_capacity(n);
                for _ in 0..n {
                    // log-uniform over [1e2, 1e11) ns.
                    let v = 10f64.powf(2.0 + rng.f64() * 9.0);
                    h.push(v);
                    all.push(v);
                }
                assert!(h.spilled());
                for p in [1.0, 25.0, 50.0, 90.0, 95.0, 99.0, 99.9] {
                    let approx = h.percentile(p);
                    let exact = percentile_nearest_rank(&all, p);
                    let rel = (approx - exact).abs() / exact;
                    assert!(
                        rel <= 0.01,
                        "p{p}: approx {approx} vs exact {exact} (rel err {rel:.4})"
                    );
                }
            },
        );
    }

    #[test]
    fn reservoir_below_cap_keeps_push_order() {
        let mut r: Reservoir<u64> = Reservoir::with_cap(8, 9);
        for i in 0..5 {
            r.push(i);
        }
        assert_eq!(r.len(), 5);
        assert_eq!(r.kept(), &[0, 1, 2, 3, 4]);
    }

    #[test]
    fn reservoir_is_bounded_deterministic_and_samples_input() {
        let mk = || {
            let mut r: Reservoir<u64> = Reservoir::with_cap(32, 1234);
            for i in 0..5000 {
                r.push(i);
            }
            r
        };
        let a = mk();
        let b = mk();
        assert_eq!(a, b, "same seed must keep the same sample");
        assert_eq!(a.len(), 5000);
        assert_eq!(a.kept().len(), 32);
        assert!(a.iter().all(|&x| x < 5000));
        // Replacement actually happened: the sample is not just 0..32.
        assert!(a.iter().any(|&x| x >= 32));
    }
}
