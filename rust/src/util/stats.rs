//! Summary statistics used throughout the evaluation harness:
//! geomean (the paper reports geomean speedups), percentiles, mean/stddev.

/// Geometric mean of positive values. Returns NaN for an empty slice.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean requires positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

/// Arithmetic mean. NaN for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1). 0 for fewer than 2 samples.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Percentile via linear interpolation on sorted data, `p` in `[0,100]`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Percentile via the nearest-rank method (the SLO-reporting convention:
/// the value reported is always an observed sample, never interpolated).
///
/// `rank = ceil(p/100 * n)`, clamped to `[1, n]`; returns `sorted[rank-1]`.
/// NaN for empty input. Shared by `ServeMetrics` (TTFT / per-token
/// percentiles) and the bench timer's p95/p99.
pub fn percentile_nearest_rank(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    let rank = ((p / 100.0) * n as f64).ceil() as usize;
    v[rank.clamp(1, n) - 1]
}

/// Median (p50).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// min of a slice (NaN-free input assumed).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// max of a slice.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_empty_is_nan() {
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn mean_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(median(&xs), 3.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0];
        assert!((percentile(&xs, 50.0) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn nearest_rank_known_distributions() {
        // 1..=10: p50 -> ceil(5.0) = rank 5 -> 5; p95/p99 -> rank 10 -> 10.
        let xs: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(percentile_nearest_rank(&xs, 50.0), 5.0);
        assert_eq!(percentile_nearest_rank(&xs, 95.0), 10.0);
        assert_eq!(percentile_nearest_rank(&xs, 99.0), 10.0);
        // The classic worked example: {15,20,35,40,50}, p30 -> rank 2 -> 20.
        let ys = [15.0, 20.0, 35.0, 40.0, 50.0];
        assert_eq!(percentile_nearest_rank(&ys, 30.0), 20.0);
        assert_eq!(percentile_nearest_rank(&ys, 100.0), 50.0);
        // p0 clamps to rank 1 (the minimum), not an out-of-range index.
        assert_eq!(percentile_nearest_rank(&ys, 0.0), 15.0);
    }

    #[test]
    fn nearest_rank_empty_and_singleton() {
        assert!(percentile_nearest_rank(&[], 50.0).is_nan());
        assert_eq!(percentile_nearest_rank(&[7.5], 99.0), 7.5);
        assert_eq!(percentile_nearest_rank(&[7.5], 1.0), 7.5);
    }

    #[test]
    fn nearest_rank_always_returns_a_sample() {
        let xs = [3.0, 1.0, 4.0, 1.5, 9.0, 2.6];
        for p in [1.0, 10.0, 33.0, 50.0, 66.0, 90.0, 95.0, 99.0, 100.0] {
            let v = percentile_nearest_rank(&xs, p);
            assert!(xs.contains(&v), "p{p} gave {v}, not an observed sample");
        }
    }

    #[test]
    fn min_max() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(min(&xs), 1.0);
        assert_eq!(max(&xs), 3.0);
    }
}
