//! Deterministic pseudo-random number generation.
//!
//! xoshiro256** seeded via splitmix64 — the standard, tiny, high-quality
//! combination. Deterministic across platforms, which the property-test
//! runner ([`crate::util::proptest`]) relies on for reproducible failures.

/// splitmix64 step; used to expand a single `u64` seed into xoshiro state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // 128-bit multiply keeps the distribution unbiased.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len() - 1)]
    }

    /// Fill a byte buffer with pseudo-random data.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let w = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&w[..rem.len()]);
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn below_covers_all_residues() {
        let mut r = Rng::new(3);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            seen[r.below(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn fill_bytes_deterministic_and_unaligned() {
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        let mut ba = [0u8; 13];
        let mut bb = [0u8; 13];
        a.fill_bytes(&mut ba);
        b.fill_bytes(&mut bb);
        assert_eq!(ba, bb);
        assert!(ba.iter().any(|&x| x != 0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
