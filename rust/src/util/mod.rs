//! In-house utility substrates.
//!
//! The offline vendor set lacks `rand`, `proptest`, `criterion`, `clap` and
//! `serde`, so the small pieces of each that this project needs are built
//! here from scratch (see DESIGN.md §8 Known deviations).

pub mod bytes;
pub mod csv;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;

/// True when `DMA_LATTE_BENCH_SMOKE` is set (to anything but `0`): the
/// bench binaries shrink their sweeps to small sizes / few iterations so CI
/// can smoke-run every bench and figure path on each change without paying
/// for the full tables.
pub fn bench_smoke() -> bool {
    std::env::var_os("DMA_LATTE_BENCH_SMOKE").is_some_and(|v| v != "0")
}
