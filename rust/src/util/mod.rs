//! In-house utility substrates.
//!
//! The offline vendor set lacks `rand`, `proptest`, `criterion`, `clap` and
//! `serde`, so the small pieces of each that this project needs are built
//! here from scratch (see DESIGN.md §8 Known deviations).

pub mod bytes;
pub mod csv;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;
