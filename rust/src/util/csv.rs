//! Tiny CSV writer: every bench emits its figure data under `results/` so
//! EXPERIMENTS.md numbers can be regenerated and re-plotted.

use std::fs;
use std::io::Write as _;
use std::path::Path;

/// Accumulates rows then writes a CSV file (creating parent dirs).
#[derive(Debug, Default)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

fn escape(field: &str) -> String {
    if field.contains(',') || field.contains('"') || field.contains('\n') {
        format!("\"{}\"", field.replace('"', "\"\""))
    } else {
        field.to_string()
    }
}

impl Csv {
    /// New CSV with header columns.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Csv {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a data row.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        self.rows.push(cells.into_iter().map(Into::into).collect());
        self
    }

    /// Render to CSV text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(
            &self
                .header
                .iter()
                .map(|c| escape(c))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| escape(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write to `path`, creating parent directories.
    pub fn write(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut f = fs::File::create(path)?;
        f.write_all(self.render().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_rows() {
        let mut c = Csv::new(vec!["size", "us"]);
        c.row(vec!["1024", "3.5"]);
        assert_eq!(c.render(), "size,us\n1024,3.5\n");
    }

    #[test]
    fn escapes_fields() {
        let mut c = Csv::new(vec!["a"]);
        c.row(vec!["x,y"]);
        c.row(vec!["he said \"hi\""]);
        let s = c.render();
        assert!(s.contains("\"x,y\""));
        assert!(s.contains("\"he said \"\"hi\"\"\""));
    }

    #[test]
    fn writes_file() {
        let p = std::env::temp_dir().join("dma_latte_csv_test/out.csv");
        let mut c = Csv::new(vec!["k"]);
        c.row(vec!["v"]);
        c.write(&p).unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "k\nv\n");
    }
}
