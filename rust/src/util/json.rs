//! Minimal JSON parser (serde is not in the offline vendor set). Supports
//! the full JSON grammar; used to read `artifacts/meta.json` and
//! `artifacts/golden.json`.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element access.
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    /// As f64.
    pub fn num(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// As u64 (must be a non-negative integer).
    pub fn u64(&self) -> Option<u64> {
        self.num().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as u64)
    }

    /// As string slice.
    pub fn str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As array slice.
    pub fn arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or("bad \\u escape")?,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8")?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny"}, "d": null, "e": true}"#)
            .unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().num(), Some(2.5));
        assert_eq!(j.get("a").unwrap().idx(2).unwrap().num(), Some(-300.0));
        assert_eq!(j.get("b").unwrap().get("c").unwrap().str(), Some("x\ny"));
        assert_eq!(j.get("d"), Some(&Json::Null));
        assert_eq!(j.get("e"), Some(&Json::Bool(true)));
    }

    #[test]
    fn u64_accessor() {
        let j = Json::parse("[16384, 2.5]").unwrap();
        assert_eq!(j.idx(0).unwrap().u64(), Some(16384));
        assert_eq!(j.idx(1).unwrap().u64(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.str(), Some("Aé"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }
}
