//! proptest-lite: a seeded property-test runner.
//!
//! The real `proptest` crate is not in the offline vendor set (DESIGN.md §8),
//! so this provides the part we rely on: run a property over many random
//! cases, and on failure report the *case seed* so the exact case replays
//! deterministically (`DMA_LATTE_PROP_SEED=<seed>` reruns just that case).

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases (default 64; raise for cheap properties).
    pub cases: u64,
    /// Base seed; each case uses `base_seed + case_index`.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 64,
            base_seed: 0xD_31A1_A77E,
        }
    }
}

/// Run `prop` on `cases` seeded RNGs; panic with the replay seed on failure.
///
/// The property receives a fresh deterministic [`Rng`] per case and should
/// draw its inputs from it, asserting internally.
pub fn run<F: FnMut(&mut Rng)>(name: &str, cfg: Config, mut prop: F) {
    // Replay mode: run exactly one case with the given seed.
    if let Ok(s) = std::env::var("DMA_LATTE_PROP_SEED") {
        if let Ok(seed) = s.parse::<u64>() {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
            return;
        }
    }
    for i in 0..cfg.cases {
        let seed = cfg.base_seed.wrapping_add(i);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {i} (replay with \
                 DMA_LATTE_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Shorthand with the default config.
pub fn check<F: FnMut(&mut Rng)>(name: &str, prop: F) {
    run(name, Config::default(), prop);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("sum-commutes", |rng| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn reports_replay_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            run(
                "always-fails",
                Config {
                    cases: 3,
                    base_seed: 123,
                },
                |_rng| panic!("boom"),
            );
        });
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| format!("{err:?}"));
        assert!(msg.contains("DMA_LATTE_PROP_SEED=123"), "{msg}");
    }

    #[test]
    fn cases_get_distinct_rngs() {
        let mut seen = std::collections::HashSet::new();
        run(
            "distinct",
            Config {
                cases: 16,
                base_seed: 7,
            },
            |rng| {
                seen.insert(rng.next_u64());
            },
        );
        assert!(seen.len() >= 15);
    }
}
