//! Minimal ASCII table printer for figure/table output (the paper's rows are
//! reproduced as aligned text tables by every bench binary).

/// A simple column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; pads/truncates to the header width.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let mut r: Vec<String> = cells.into_iter().map(Into::into).collect();
        r.resize(self.header.len(), String::new());
        self.rows.push(r);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no data rows were added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a string with column alignment and a separator rule.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = width[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", c, w = width[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["size", "speedup"]);
        t.row(vec!["1K", "0.22"]);
        t.row(vec!["512K", "0.61"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("size"));
        assert!(lines[2].starts_with("1K"));
        // columns aligned: "speedup" column starts at same offset everywhere
        let col = lines[0].find("speedup").unwrap();
        assert_eq!(&lines[2][col..col + 4], "0.22");
    }

    #[test]
    fn pads_short_rows() {
        let mut t = Table::new(vec!["a", "b", "c"]);
        t.row(vec!["1"]);
        assert!(t.render().contains('1'));
        assert_eq!(t.len(), 1);
    }
}
