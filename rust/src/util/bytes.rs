//! Human-friendly byte-size parsing and formatting ("4KB".."4GB"), used by
//! the CLI sweeps and the figure/table printers. Binary units (KiB semantics)
//! to match collective-benchmark convention, printed with the paper's K/M/G
//! labels.

pub const KB: u64 = 1024;
pub const MB: u64 = 1024 * KB;
pub const GB: u64 = 1024 * MB;

/// Format a byte count the way collective benchmarks (and the paper's x-axes)
/// do: `1K`, `512K`, `4M`, `1G`, falling back to raw bytes below 1K.
pub fn fmt_size(bytes: u64) -> String {
    if bytes >= GB && bytes % GB == 0 {
        format!("{}G", bytes / GB)
    } else if bytes >= MB && bytes % MB == 0 {
        format!("{}M", bytes / MB)
    } else if bytes >= KB && bytes % KB == 0 {
        format!("{}K", bytes / KB)
    } else {
        format!("{bytes}B")
    }
}

/// Parse `"4K"`, `"4KB"`, `"32M"`, `"1G"`, `"123"` (raw bytes). Case-insensitive.
pub fn parse_size(s: &str) -> Result<u64, String> {
    let t = s.trim().to_ascii_uppercase();
    let t = t.strip_suffix('B').unwrap_or(&t);
    let (num, mult) = if let Some(n) = t.strip_suffix('K') {
        (n, KB)
    } else if let Some(n) = t.strip_suffix('M') {
        (n, MB)
    } else if let Some(n) = t.strip_suffix('G') {
        (n, GB)
    } else {
        (t, 1)
    };
    let v: f64 = num
        .trim()
        .parse()
        .map_err(|_| format!("bad size: {s:?}"))?;
    if v < 0.0 {
        return Err(format!("negative size: {s:?}"));
    }
    Ok((v * mult as f64).round() as u64)
}

/// Geometric sweep of sizes `[lo, hi]` multiplying by `factor` (usually 2).
pub fn size_sweep(lo: u64, hi: u64, factor: u64) -> Vec<u64> {
    assert!(factor >= 2 && lo > 0 && lo <= hi);
    let mut v = Vec::new();
    let mut s = lo;
    while s <= hi {
        v.push(s);
        match s.checked_mul(factor) {
            Some(n) => s = n,
            None => break,
        }
    }
    v
}

/// Format a duration given in nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        for s in ["1K", "4K", "512K", "1M", "32M", "1G", "4G"] {
            assert_eq!(fmt_size(parse_size(s).unwrap()), s);
        }
    }

    #[test]
    fn parses_suffixed_b() {
        assert_eq!(parse_size("4KB").unwrap(), 4 * KB);
        assert_eq!(parse_size("2mb").unwrap(), 2 * MB);
        assert_eq!(parse_size("100").unwrap(), 100);
    }

    #[test]
    fn parse_fractional() {
        assert_eq!(parse_size("0.5K").unwrap(), 512);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_size("abc").is_err());
        assert!(parse_size("-4K").is_err());
    }

    #[test]
    fn sweep_covers_range() {
        let v = size_sweep(KB, 4 * GB, 2);
        assert_eq!(v.first(), Some(&KB));
        assert_eq!(v.last(), Some(&(4 * GB)));
        assert_eq!(v.len(), 23);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert_eq!(fmt_ns(2.5e6), "2.500ms");
        assert_eq!(fmt_ns(3.2e9), "3.200s");
    }
}
