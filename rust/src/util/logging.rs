//! Leveled stderr logging with an env-controlled threshold
//! (`DMA_LATTE_LOG=debug|info|warn|error`, default `info`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Log severity, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

impl Level {
    fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" | "warning" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }
    fn tag(self) -> &'static str {
        match self {
            Level::Debug => "DEBUG",
            Level::Info => "INFO",
            Level::Warn => "WARN",
            Level::Error => "ERROR",
        }
    }
}

static THRESHOLD: AtomicU8 = AtomicU8::new(u8::MAX);
static INIT: OnceLock<()> = OnceLock::new();

fn threshold() -> u8 {
    INIT.get_or_init(|| {
        let lvl = std::env::var("DMA_LATTE_LOG")
            .ok()
            .and_then(|s| Level::parse(&s))
            .unwrap_or(Level::Info);
        THRESHOLD.store(lvl as u8, Ordering::Relaxed);
    });
    THRESHOLD.load(Ordering::Relaxed)
}

/// Override the log threshold programmatically (tests, CLI `-v`).
pub fn set_level(lvl: Level) {
    INIT.get_or_init(|| ());
    THRESHOLD.store(lvl as u8, Ordering::Relaxed);
}

/// True when `lvl` would currently be emitted.
pub fn enabled(lvl: Level) -> bool {
    (lvl as u8) >= threshold()
}

/// Core log entry point; prefer the `log_*!` macros.
pub fn log(lvl: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(lvl) {
        eprintln!("[{:5}] {}: {}", lvl.tag(), module, msg);
    }
}

/// Log at DEBUG.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}
/// Log at INFO.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}
/// Log at WARN.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}
/// Log at ERROR.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn threshold_filters() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(Level::Debug);
        assert!(enabled(Level::Info));
    }
}
