//! KV block geometry and simulated-memory addressing.

use crate::models::ModelConfig;
use crate::sim::topology::NodeId;
use crate::sim::Addr;

/// vLLM's default block size in tokens.
pub const DEFAULT_BLOCK_TOKENS: u32 = 16;

/// Geometry of the paged KV cache for one model.
#[derive(Debug, Clone)]
pub struct BlockLayout {
    /// Tokens per block.
    pub block_tokens: u32,
    /// Bytes of one block (all layers contiguous).
    pub block_bytes: u64,
    /// Base offset of the GPU KV pool in simulated GPU memory.
    pub gpu_pool_base: u64,
    /// Base offset of the CPU KV tier in simulated CPU memory.
    pub cpu_pool_base: u64,
}

impl BlockLayout {
    /// Layout for `model` with `block_tokens` tokens per block.
    pub fn new(model: &ModelConfig, block_tokens: u32) -> Self {
        BlockLayout {
            block_tokens,
            block_bytes: model.kv_block_bytes(block_tokens),
            gpu_pool_base: 0,
            cpu_pool_base: 0,
        }
    }

    /// Number of blocks needed for `tokens` tokens (ceil).
    pub fn blocks_for(&self, tokens: u64) -> u64 {
        tokens.div_ceil(self.block_tokens as u64)
    }

    /// Simulated address of GPU block `idx` on `gpu`.
    pub fn gpu_block_addr(&self, gpu: u8, idx: u64) -> Addr {
        Addr::new(NodeId::Gpu(gpu), self.gpu_pool_base + idx * self.block_bytes)
    }

    /// Simulated address of CPU block `idx`.
    pub fn cpu_block_addr(&self, idx: u64) -> Addr {
        Addr::new(NodeId::Cpu, self.cpu_pool_base + idx * self.block_bytes)
    }

    /// Synthesize `n` disjoint CPU→GPU block copies onto `gpu`.
    ///
    /// Fetch cost in the DES depends only on the copy **count and sizes**
    /// (engines are assigned round-robin by copy index; all blocks are
    /// `block_bytes`), never on which pool slots are involved — so the
    /// admission path can carry a bare block count
    /// (`AdmitAction::Fetch::fetch_blocks`) and materialize equal-shape
    /// copies here only when a fetch is actually simulated.
    pub fn synth_copies(&self, gpu: u8, n: u64) -> Vec<crate::kvcache::fetch::CopySpec> {
        (0..n)
            .map(|i| (self.cpu_block_addr(i), self.gpu_block_addr(gpu, i), self.block_bytes))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::{LLAMA31_8B, QWEN25_0_5B};

    #[test]
    fn block_bytes_match_models() {
        let l = BlockLayout::new(&LLAMA31_8B, 16);
        assert_eq!(l.block_bytes, 2 * 1024 * 1024);
        let q = BlockLayout::new(&QWEN25_0_5B, 16);
        assert_eq!(q.block_bytes, 192 * 1024);
    }

    #[test]
    fn blocks_for_rounds_up() {
        let l = BlockLayout::new(&QWEN25_0_5B, 16);
        assert_eq!(l.blocks_for(4096), 256);
        assert_eq!(l.blocks_for(4097), 257);
        assert_eq!(l.blocks_for(1), 1);
        assert_eq!(l.blocks_for(0), 0);
    }

    #[test]
    fn synth_copies_are_disjoint_block_sized_pairs() {
        let l = BlockLayout::new(&QWEN25_0_5B, 16);
        let copies = l.synth_copies(2, 4);
        assert_eq!(copies.len(), 4);
        for (i, (src, dst, bytes)) in copies.iter().enumerate() {
            assert_eq!(*src, l.cpu_block_addr(i as u64));
            assert_eq!(*dst, l.gpu_block_addr(2, i as u64));
            assert_eq!(*bytes, l.block_bytes);
        }
        assert!(l.synth_copies(0, 0).is_empty());
    }

    #[test]
    fn addresses_are_disjoint() {
        let l = BlockLayout::new(&QWEN25_0_5B, 16);
        let a0 = l.gpu_block_addr(0, 0);
        let a1 = l.gpu_block_addr(0, 1);
        assert_eq!(a1.offset - a0.offset, l.block_bytes);
        assert_eq!(l.cpu_block_addr(3).node, NodeId::Cpu);
    }
}
