//! GPU KV block pool: fixed-capacity free-list allocator with per-request
//! block tables (the vLLM BlockManager role).

use std::collections::HashMap;

/// Physical block index in the GPU pool.
pub type BlockId = u64;

/// Request identifier (allocator key).
pub type ReqId = u64;

/// Fixed-capacity block allocator.
#[derive(Debug)]
pub struct BlockAllocator {
    capacity: u64,
    free: Vec<BlockId>,
    /// Per-request block table: logical order (block 0 = first 16 tokens).
    tables: HashMap<ReqId, Vec<BlockId>>,
}

/// Allocation failure: pool exhausted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OutOfBlocks {
    pub requested: u64,
    pub available: u64,
}

impl std::fmt::Display for OutOfBlocks {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "out of KV blocks: requested {}, available {}",
            self.requested, self.available
        )
    }
}
impl std::error::Error for OutOfBlocks {}

impl BlockAllocator {
    /// Pool with `capacity` blocks (all free).
    pub fn new(capacity: u64) -> Self {
        BlockAllocator {
            capacity,
            free: (0..capacity).rev().collect(),
            tables: HashMap::new(),
        }
    }

    /// Free block count.
    pub fn available(&self) -> u64 {
        self.free.len() as u64
    }

    /// Total capacity.
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Allocate `n` blocks for `req`, appending to its table. All-or-nothing.
    pub fn alloc(&mut self, req: ReqId, n: u64) -> Result<&[BlockId], OutOfBlocks> {
        if (self.free.len() as u64) < n {
            return Err(OutOfBlocks {
                requested: n,
                available: self.free.len() as u64,
            });
        }
        let table = self.tables.entry(req).or_default();
        let start = table.len();
        for _ in 0..n {
            table.push(self.free.pop().unwrap());
        }
        Ok(&table[start..])
    }

    /// Block table of a request.
    pub fn table(&self, req: ReqId) -> Option<&[BlockId]> {
        self.tables.get(&req).map(|t| t.as_slice())
    }

    /// Release all blocks of `req` back to the pool.
    pub fn release(&mut self, req: ReqId) {
        if let Some(table) = self.tables.remove(&req) {
            self.free.extend(table);
        }
    }

    /// Invariant check: no block is both free and allocated; no block is
    /// allocated twice; counts add up. (Used by property tests.)
    pub fn check_invariants(&self) {
        let mut seen = std::collections::HashSet::new();
        for &b in &self.free {
            assert!(b < self.capacity, "free block {b} out of range");
            assert!(seen.insert(b), "block {b} double-free");
        }
        for (req, table) in &self.tables {
            for &b in table {
                assert!(b < self.capacity, "req {req} block {b} out of range");
                assert!(seen.insert(b), "block {b} double-allocated");
            }
        }
        assert_eq!(seen.len() as u64, self.capacity, "blocks leaked");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let mut a = BlockAllocator::new(10);
        let t = a.alloc(1, 4).unwrap().to_vec();
        assert_eq!(t.len(), 4);
        assert_eq!(a.available(), 6);
        a.check_invariants();
        a.release(1);
        assert_eq!(a.available(), 10);
        a.check_invariants();
    }

    #[test]
    fn all_or_nothing() {
        let mut a = BlockAllocator::new(4);
        a.alloc(1, 3).unwrap();
        let err = a.alloc(2, 2).unwrap_err();
        assert_eq!(err.requested, 2);
        assert_eq!(err.available, 1);
        // Failed alloc must not leak blocks.
        assert_eq!(a.available(), 1);
        a.check_invariants();
    }

    #[test]
    fn append_grows_table() {
        let mut a = BlockAllocator::new(8);
        a.alloc(7, 2).unwrap();
        a.alloc(7, 3).unwrap();
        assert_eq!(a.table(7).unwrap().len(), 5);
        a.check_invariants();
    }

    #[test]
    fn release_unknown_is_noop() {
        let mut a = BlockAllocator::new(2);
        a.release(99);
        assert_eq!(a.available(), 2);
    }
}
