//! KV fetch engines — the paper's §5.3 comparison set.
//!
//! | impl          | host API               | engines | syncs      |
//! |---------------|------------------------|---------|------------|
//! | `DmaBaseline` | `hipMemcpyAsync` ×N    | many    | 1 per copy |
//! | `DmaB2b`      | `hipMemcpyBatchAsync`  | few     | 1 per chain|
//! | `Kernel`      | one gather kernel      | 0 (CUs) | 1          |
//!
//! `DmaB2b` applies the paper's policy: chains of back-to-back copies on a
//! single engine with one trailing sync, switching to multi-engine fan-out
//! past an empirically-chosen 4 MB threshold (§5.3.1).

pub mod dma_b2b;
pub mod dma_baseline;
pub mod kernel;

use crate::sim::{Addr, Sim};

/// Which fetch implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FetchImpl {
    DmaBaseline,
    DmaB2b,
    Kernel,
}

impl FetchImpl {
    /// Label used in figures.
    pub fn name(&self) -> &'static str {
        match self {
            FetchImpl::DmaBaseline => "dma_baseline",
            FetchImpl::DmaB2b => "dma_b2b",
            FetchImpl::Kernel => "kernel",
        }
    }
}

/// One host-to-device copy: (cpu src, gpu dst, bytes).
pub type CopySpec = (Addr, Addr, u64);

/// Measured outcome of a fetch.
#[derive(Debug, Clone, Copy, Default)]
pub struct FetchOutcome {
    /// CPU time the issuing thread was busy (API calls, doorbells, waits
    /// between issues) — this blocks the serving scheduler.
    pub host_ns: u64,
    /// Start → all blocks resident + completion observed.
    pub total_ns: u64,
    /// GPU CU time consumed (kernel fetch only) — contends with model
    /// compute.
    pub gpu_cu_ns: u64,
    /// DMA engines engaged.
    pub engines_used: usize,
    /// Number of host API calls made.
    pub api_calls: usize,
}

/// Run a fetch of `copies` with the chosen implementation on `sim`
/// (persistent across calls: memory, engines and the clock carry over).
pub fn run_fetch(sim: &mut Sim, imp: FetchImpl, copies: &[CopySpec]) -> FetchOutcome {
    if copies.is_empty() {
        return FetchOutcome::default();
    }
    match imp {
        FetchImpl::DmaBaseline => dma_baseline::run(sim, copies),
        FetchImpl::DmaB2b => dma_b2b::run(sim, copies),
        FetchImpl::Kernel => kernel::run(sim, copies),
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::sim::topology::NodeId;

    /// Build N host→gpu0 copies of `len` bytes each, disjoint ranges.
    pub fn mk_copies(n: u64, len: u64) -> Vec<CopySpec> {
        (0..n)
            .map(|i| {
                (
                    Addr::new(NodeId::Cpu, i * len),
                    Addr::new(NodeId::Gpu(0), i * len),
                    len,
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::mk_copies;
    use super::*;
    use crate::sim::SimConfig;

    /// All three implementations move the same bytes (functional parity).
    #[test]
    fn functional_parity() {
        use crate::sim::topology::NodeId;
        let copies = mk_copies(8, 4096);
        let mut want = Vec::new();
        for imp in [FetchImpl::DmaBaseline, FetchImpl::DmaB2b, FetchImpl::Kernel] {
            let mut sim = Sim::new(SimConfig::mi300x().functional());
            for (i, (src, _, len)) in copies.iter().enumerate() {
                sim.memory
                    .poke(src.node, src.offset, &vec![i as u8 + 1; *len as usize]);
            }
            let out = run_fetch(&mut sim, imp, &copies);
            assert!(out.total_ns > 0);
            let got: Vec<Vec<u8>> = copies
                .iter()
                .map(|(_, dst, len)| sim.memory.peek(NodeId::Gpu(0), dst.offset, *len))
                .collect();
            if want.is_empty() {
                want = got;
            } else {
                assert_eq!(want, got, "{} differs", imp.name());
            }
        }
        assert_eq!(want[3][0], 4);
    }

    /// The paper's §5.3.3 relationships: b2b cuts host time by ≥10× vs
    /// per-copy API; kernel total is lowest but burns CU time.
    #[test]
    fn cost_relationships() {
        let copies = mk_copies(256, 192 * 1024); // Qwen-0.5B-ish, 4096 tokens
        let mut outs = Vec::new();
        for imp in [FetchImpl::DmaBaseline, FetchImpl::DmaB2b, FetchImpl::Kernel] {
            let mut sim = Sim::new(SimConfig::mi300x());
            outs.push(run_fetch(&mut sim, imp, &copies));
        }
        let (base, b2b, kern) = (outs[0], outs[1], outs[2]);
        assert!(
            base.host_ns > 10 * b2b.host_ns,
            "host: base {} vs b2b {}",
            base.host_ns,
            b2b.host_ns
        );
        assert!(b2b.total_ns < base.total_ns);
        assert_eq!(base.api_calls, 256);
        assert!(b2b.api_calls <= 16);
        assert_eq!(base.gpu_cu_ns, 0);
        assert!(kern.gpu_cu_ns > 0);
        // Kernel launch path is the cheapest on the host by far…
        assert!(kern.host_ns < b2b.host_ns);
        // …and its end-to-end time is in the same band as b2b DMA (the
        // paper: kernel TTFT ≈11% lower on average; DMA wins link
        // efficiency at wire-bound sizes).
        let ratio = kern.total_ns as f64 / b2b.total_ns as f64;
        assert!((0.7..1.3).contains(&ratio), "kern/b2b = {ratio}");
    }
}
