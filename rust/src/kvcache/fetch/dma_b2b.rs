//! Optimized DMA KV fetch: `hipMemcpyBatchAsync` + back-to-back chains
//! (the paper's contribution at workload level, §5.3.1).
//!
//! All independent copies are conveyed in one batch API call; the runtime
//! directs them to a single engine back-to-back with a single trailing
//! sync. Past the 4 MB empirical threshold it fans out to more engines —
//! each engine still runs one b2b chain with one sync.

use crate::sim::command::{AtomicOp, Command};
use crate::sim::host::{ApiKind, HostOp};
use crate::sim::{EngineId, Sim};

use super::{CopySpec, FetchOutcome};

/// Fan-out threshold: chains above this size split across engines (§5.3.1).
pub const B2B_THRESHOLD_BYTES: u64 = 4 * 1024 * 1024;

/// Max engines the batched runtime will fan out to.
const MAX_FANOUT: usize = 8;

/// Partition `copies` into per-engine chains per the b2b policy.
pub fn plan_chains(copies: &[CopySpec]) -> Vec<Vec<CopySpec>> {
    let total: u64 = copies.iter().map(|c| c.2).sum();
    if total <= B2B_THRESHOLD_BYTES {
        return vec![copies.to_vec()];
    }
    // Fan out into roughly equal chains, at most MAX_FANOUT.
    let chains_wanted = ((total / B2B_THRESHOLD_BYTES) as usize + 1).min(MAX_FANOUT);
    let per = copies.len().div_ceil(chains_wanted);
    copies.chunks(per.max(1)).map(|c| c.to_vec()).collect()
}

/// Run the b2b fetch.
pub fn run(sim: &mut Sim, copies: &[CopySpec]) -> FetchOutcome {
    // Engines live on whichever endpoint is a GPU (fetch: dst; save: src).
    let gpu_idx = match (copies[0].1.node, copies[0].0.node) {
        (crate::sim::topology::NodeId::Gpu(g), _) => g,
        (_, crate::sim::topology::NodeId::Gpu(g)) => g,
        _ => panic!("at least one endpoint must be a GPU"),
    };
    let chains = plan_chains(copies);
    let mut script = vec![HostOp::Mark { name: "fetch_start" }];
    let mut signals = Vec::new();
    for (ci, chain) in chains.iter().enumerate() {
        let sig = sim.alloc_signal(0);
        signals.push(sig);
        let engine = EngineId {
            gpu: gpu_idx,
            idx: (ci % sim.cfg.topology.engines_per_gpu as usize) as u8,
        };
        let mut cmds: Vec<Command> = chain
            .iter()
            .map(|&(src, dst, len)| Command::Copy { src, dst, len })
            .collect();
        cmds.push(Command::Atomic {
            signal: sig,
            op: AtomicOp::Add(1),
        });
        script.push(HostOp::CreateCommands {
            engine,
            cmds,
            api: ApiKind::HipBatched,
        });
        script.push(HostOp::RingDoorbell { engine });
    }
    script.push(HostOp::Mark { name: "issued" });
    for sig in &signals {
        script.push(HostOp::WaitSignal {
            signal: *sig,
            at_least: 1,
        });
    }
    script.push(HostOp::Mark { name: "fetch_end" });

    let engines_before = sim.engines_used();
    let start_t = sim.time;
    let host = sim.add_host(script, start_t);
    let out = sim.run();
    assert!(out.deadlocked.is_empty(), "b2b fetch deadlocked");
    let h = sim.host(host);
    let s = h.mark("fetch_start").unwrap();
    FetchOutcome {
        host_ns: h.mark("issued").unwrap() - s,
        total_ns: h.mark("fetch_end").unwrap() - s,
        gpu_cu_ns: 0,
        engines_used: sim.engines_used().saturating_sub(engines_before).max(1),
        api_calls: chains.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::fetch::testutil::mk_copies;
    use crate::sim::SimConfig;
    use crate::util::bytes::{KB, MB};

    #[test]
    fn small_batch_uses_one_engine_one_sync() {
        let copies = mk_copies(64, 32 * KB); // 2MB total < threshold
        assert_eq!(plan_chains(&copies).len(), 1);
        let mut sim = Sim::new(SimConfig::mi300x());
        let out = run(&mut sim, &copies);
        assert_eq!(out.engines_used, 1);
        assert_eq!(out.api_calls, 1);
    }

    #[test]
    fn large_batch_fans_out() {
        let copies = mk_copies(256, 2 * MB); // 512MB total
        let chains = plan_chains(&copies);
        assert!(chains.len() > 1 && chains.len() <= MAX_FANOUT);
        // All copies preserved.
        let n: usize = chains.iter().map(|c| c.len()).sum();
        assert_eq!(n, 256);
        let mut sim = Sim::new(SimConfig::mi300x());
        let out = run(&mut sim, &copies);
        assert_eq!(out.engines_used, chains.len());
    }

    #[test]
    fn host_time_is_one_batch_call() {
        let mut sim = Sim::new(SimConfig::mi300x());
        let copies = mk_copies(256, 8 * KB); // 2MB, single chain
        let out = run(&mut sim, &copies);
        let lat = &sim.cfg.latency;
        let expect = lat.t_hip_batch_base
            + 256.0 * lat.t_hip_batch_per_copy
            + lat.t_doorbell;
        assert!((out.host_ns as f64) < 1.1 * expect, "host {}", out.host_ns);
    }

    #[test]
    fn beats_baseline_end_to_end_for_small_blocks() {
        let copies = mk_copies(256, 192 * KB);
        let mut s1 = Sim::new(SimConfig::mi300x());
        let base = crate::kvcache::fetch::dma_baseline::run(&mut s1, &copies);
        let mut s2 = Sim::new(SimConfig::mi300x());
        let b2b = run(&mut s2, &copies);
        assert!(
            (b2b.total_ns as f64) < 0.6 * base.total_ns as f64,
            "b2b {} vs base {}",
            b2b.total_ns,
            base.total_ns
        );
    }
}
