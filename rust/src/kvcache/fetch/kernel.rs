//! Kernel-based KV fetch: a single GPU kernel gathers all dispersed blocks
//! with load/store instructions, one workgroup per block (§5.3.1's third
//! comparator, as in prior work [28]).
//!
//! Lowest launch overhead (one kernel vs many API calls) → best TTFT at
//! the operator level (the paper measures it 11% faster than DMA fetch),
//! but the CUs it occupies contend with model compute, which is exactly
//! the contention DMA offload exists to avoid (§2.4). The L1 Pallas
//! `kv_gather` kernel is the real-compute analogue of this path.

use crate::sim::Sim;

use super::{CopySpec, FetchOutcome};

/// Run the kernel fetch (analytic timing + functional byte movement).
pub fn run(sim: &mut Sim, copies: &[CopySpec]) -> FetchOutcome {
    let lat = sim.cfg.latency.clone();
    let total_bytes: u64 = copies.iter().map(|c| c.2).sum();
    // CU-driven PCIe transfer at kernel link efficiency; one workgroup per
    // block keeps all links busy, no per-block fixed cost.
    let link_bw = {
        let topo = &sim.cfg.topology;
        let l = topo.link_index(copies[0].0.node, copies[0].1.node);
        topo.link(l).bw_bytes_per_ns
    };
    let wire_ns = total_bytes as f64 / (link_bw * lat.cu_link_efficiency);
    let host_ns = lat.t_kernel_launch;
    let gpu_ns = wire_ns + 2_000.0; // kernel ramp-up/drain
    // Functional effects + traffic accounting.
    for &(src, dst, len) in copies {
        sim.memory
            .dma_copy(src.node, src.offset, dst.node, dst.offset, len);
    }
    FetchOutcome {
        host_ns: host_ns as u64,
        total_ns: (host_ns + gpu_ns) as u64,
        gpu_cu_ns: gpu_ns as u64,
        engines_used: 0,
        api_calls: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::fetch::testutil::mk_copies;
    use crate::sim::SimConfig;

    #[test]
    fn single_launch_wire_bound() {
        let mut sim = Sim::new(SimConfig::mi300x());
        let copies = mk_copies(256, 192 * 1024); // 48MB
        let out = run(&mut sim, &copies);
        assert_eq!(out.api_calls, 1);
        assert_eq!(out.engines_used, 0);
        // 48MB / (64 B/ns × cu_link_eff) of CU time.
        let expect =
            48.0 * 1024.0 * 1024.0 / (64.0 * sim.cfg.latency.cu_link_efficiency);
        assert!((out.gpu_cu_ns as f64 - expect).abs() / expect < 0.05);
        assert!(out.host_ns < 20_000);
    }

    #[test]
    fn moves_bytes() {
        let mut sim = Sim::new(SimConfig::mi300x().functional());
        let copies = mk_copies(2, 64);
        sim.memory.poke(copies[1].0.node, copies[1].0.offset, &[9u8; 64]);
        run(&mut sim, &copies);
        assert_eq!(
            sim.memory.peek(copies[1].1.node, copies[1].1.offset, 64),
            vec![9u8; 64]
        );
    }
}
