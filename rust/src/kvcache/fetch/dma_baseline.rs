//! Baseline DMA KV fetch: one `hipMemcpyAsync` per KV block (the vLLM
//! KV-offload connector's behaviour the paper starts from, §5.3.1).
//! Each call pays full API setup/teardown, lands on a stream mapped
//! round-robin over the GPU's sDMA engines, and carries its own
//! completion signal that the host later observes.

use crate::sim::command::{AtomicOp, Command};
use crate::sim::host::{ApiKind, HostOp};
use crate::sim::{EngineId, Sim};

use super::{CopySpec, FetchOutcome};

/// Engines the HIP runtime spreads per-copy streams across.
const FANOUT_ENGINES: u8 = 16;

/// Run the baseline fetch.
pub fn run(sim: &mut Sim, copies: &[CopySpec]) -> FetchOutcome {
    // The engines live on whichever endpoint is a GPU (dst for fetch,
    // src for save — the sDMA engine handles both directions, §2.2).
    let gpu_idx = match (copies[0].1.node, copies[0].0.node) {
        (crate::sim::topology::NodeId::Gpu(g), _) => g,
        (_, crate::sim::topology::NodeId::Gpu(g)) => g,
        _ => panic!("at least one endpoint must be a GPU"),
    };
    let engines = FANOUT_ENGINES.min(sim.cfg.topology.engines_per_gpu);
    let mut script = vec![HostOp::Mark { name: "fetch_start" }];
    let mut signals = Vec::new();
    for (i, &(src, dst, len)) in copies.iter().enumerate() {
        let sig = sim.alloc_signal(0);
        signals.push(sig);
        let engine = EngineId {
            gpu: gpu_idx,
            idx: (i % engines as usize) as u8,
        };
        script.push(HostOp::CreateCommands {
            engine,
            cmds: vec![
                Command::Copy { src, dst, len },
                Command::Atomic {
                    signal: sig,
                    op: AtomicOp::Add(1),
                },
            ],
            api: ApiKind::HipPerCopy,
        });
        script.push(HostOp::RingDoorbell { engine });
    }
    script.push(HostOp::Mark { name: "issued" });
    for sig in &signals {
        script.push(HostOp::WaitSignal {
            signal: *sig,
            at_least: 1,
        });
    }
    script.push(HostOp::Mark { name: "fetch_end" });

    let engines_before = sim.engines_used();
    let start_t = sim.time;
    let host = sim.add_host(script, start_t);
    let out = sim.run();
    assert!(out.deadlocked.is_empty(), "baseline fetch deadlocked");
    let h = sim.host(host);
    let s = h.mark("fetch_start").unwrap();
    FetchOutcome {
        host_ns: h.mark("issued").unwrap() - s,
        total_ns: h.mark("fetch_end").unwrap() - s,
        gpu_cu_ns: 0,
        engines_used: sim.engines_used().saturating_sub(engines_before).max(1),
        api_calls: copies.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::fetch::testutil::mk_copies;
    use crate::sim::SimConfig;

    #[test]
    fn per_copy_api_dominates_host_time() {
        let mut sim = Sim::new(SimConfig::mi300x());
        let copies = mk_copies(64, 8 * 1024);
        let out = run(&mut sim, &copies);
        // ≥ 64 × (api + doorbell) of host time.
        let per_copy =
            sim.cfg.latency.t_hip_api_per_copy + sim.cfg.latency.t_doorbell;
        assert!(out.host_ns as f64 >= 0.95 * 64.0 * per_copy);
        assert!(out.total_ns >= out.host_ns);
        assert_eq!(out.api_calls, 64);
    }

    #[test]
    fn spreads_over_engines() {
        let mut sim = Sim::new(SimConfig::mi300x());
        let out = run(&mut sim, &mk_copies(64, 8 * 1024));
        assert_eq!(out.engines_used, 16);
    }

    #[test]
    fn sequential_fetches_on_one_sim_accumulate_time() {
        let mut sim = Sim::new(SimConfig::mi300x());
        let a = run(&mut sim, &mk_copies(4, 1024));
        let t_mid = sim.time;
        let b = run(&mut sim, &mk_copies(4, 1024));
        assert!(sim.time > t_mid);
        // Same workload → similar cost both times.
        let rel = (a.total_ns as f64 - b.total_ns as f64).abs() / a.total_ns as f64;
        assert!(rel < 0.2, "a={} b={}", a.total_ns, b.total_ns);
    }
}
