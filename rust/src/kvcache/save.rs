//! KV save path: GPU → CPU block transfers (the "save" half of the
//! paper's §5.3 KV save/fetch workload; same mechanics as fetch with the
//! direction reversed — the paper's footnote 1 uses "save" to avoid
//! confusion with DMA offloads).
//!
//! Reuses the fetch engines' host-API cost model: baseline issues one
//! `hipMemcpyAsync` per block on the device-to-host direction; the
//! optimized path batches all blocks into b2b chains. Saves are typically
//! fire-and-forget (decode continues while KV drains to CPU), so the
//! interesting metric is host time + D2H link occupancy.

use crate::sim::Sim;

use super::fetch::{dma_b2b, dma_baseline, CopySpec, FetchImpl, FetchOutcome};

/// Plan save copies for a request's blocks: (gpu src, cpu dst, len).
pub fn plan_save(
    layout: &super::BlockLayout,
    gpu: u8,
    gpu_blocks: &[u64],
    cpu_blocks: &[u64],
) -> Vec<CopySpec> {
    assert_eq!(gpu_blocks.len(), cpu_blocks.len());
    gpu_blocks
        .iter()
        .zip(cpu_blocks)
        .map(|(&g, &c)| {
            (
                layout.gpu_block_addr(gpu, g),
                layout.cpu_block_addr(c),
                layout.block_bytes,
            )
        })
        .collect()
}

/// Run a save with the chosen implementation (kernel saves are not used
/// by the paper — CUs are busy decoding — so only the DMA impls apply).
pub fn run_save(sim: &mut Sim, imp: FetchImpl, copies: &[CopySpec]) -> FetchOutcome {
    if copies.is_empty() {
        return FetchOutcome::default();
    }
    match imp {
        FetchImpl::DmaBaseline => dma_baseline::run(sim, copies),
        FetchImpl::DmaB2b | FetchImpl::Kernel => dma_b2b::run(sim, copies),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::BlockLayout;
    use crate::models::zoo::QWEN25_0_5B;
    use crate::sim::topology::NodeId;
    use crate::sim::SimConfig;

    fn layout() -> BlockLayout {
        BlockLayout::new(&QWEN25_0_5B, 16)
    }

    #[test]
    fn save_moves_bytes_gpu_to_cpu() {
        let l = layout();
        let gpu_blocks: Vec<u64> = (0..8).collect();
        let cpu_blocks: Vec<u64> = (100..108).collect();
        let copies = plan_save(&l, 0, &gpu_blocks, &cpu_blocks);
        let mut sim = Sim::new(SimConfig::mi300x().functional());
        for (src, _, len) in &copies {
            sim.memory
                .poke(src.node, src.offset, &vec![7u8; *len as usize]);
        }
        let out = run_save(&mut sim, FetchImpl::DmaB2b, &copies);
        assert!(out.total_ns > 0);
        for (_, dst, len) in &copies {
            assert_eq!(dst.node, NodeId::Cpu);
            assert_eq!(
                sim.memory.peek(NodeId::Cpu, dst.offset, *len),
                vec![7u8; *len as usize]
            );
        }
    }

    #[test]
    fn batched_save_cheaper_on_host_than_per_copy() {
        let l = layout();
        let gpu_blocks: Vec<u64> = (0..256).collect();
        let cpu_blocks: Vec<u64> = (0..256).collect();
        let copies = plan_save(&l, 0, &gpu_blocks, &cpu_blocks);
        let mut s1 = Sim::new(SimConfig::mi300x());
        let base = run_save(&mut s1, FetchImpl::DmaBaseline, &copies);
        let mut s2 = Sim::new(SimConfig::mi300x());
        let b2b = run_save(&mut s2, FetchImpl::DmaB2b, &copies);
        assert!(base.host_ns > 10 * b2b.host_ns);
    }

    #[test]
    fn save_then_fetch_roundtrip() {
        // Save a request's KV to CPU, then fetch it back to different GPU
        // blocks: bytes identical (the CPU tier round-trips).
        use crate::kvcache::fetch::run_fetch;
        let l = layout();
        let mut sim = Sim::new(SimConfig::mi300x().functional());
        let gpu_src: Vec<u64> = (0..4).collect();
        let cpu: Vec<u64> = (10..14).collect();
        let gpu_dst: Vec<u64> = (20..24).collect();
        for &g in &gpu_src {
            let a = l.gpu_block_addr(0, g);
            sim.memory
                .poke(a.node, a.offset, &vec![g as u8 + 1; l.block_bytes as usize]);
        }
        let saves = plan_save(&l, 0, &gpu_src, &cpu);
        run_save(&mut sim, FetchImpl::DmaB2b, &saves);
        let fetches: Vec<_> = cpu
            .iter()
            .zip(&gpu_dst)
            .map(|(&c, &g)| (l.cpu_block_addr(c), l.gpu_block_addr(0, g), l.block_bytes))
            .collect();
        run_fetch(&mut sim, FetchImpl::DmaB2b, &fetches);
        for (i, &g) in gpu_dst.iter().enumerate() {
            let a = l.gpu_block_addr(0, g);
            let got = sim.memory.peek(a.node, a.offset, l.block_bytes);
            assert!(got.iter().all(|&b| b == i as u8 + 1));
        }
    }
}
