//! CPU-memory KV tier: content-addressed block sequences with LRU eviction
//! (the "KV cache save/fetch to/from CPU memory" side of §5.3; the role of
//! the vLLM KV-offload connector's CPU backend [28]).

use std::collections::HashMap;

/// Key identifying a cached prefix (in real vLLM: hash of token prefix;
/// here: request/prompt id).
pub type CacheKey = u64;

/// One cached entry: which CPU blocks hold the prefix's KV.
#[derive(Debug, Clone)]
pub struct CpuEntry {
    pub key: CacheKey,
    pub cpu_blocks: Vec<u64>,
    pub tokens: u64,
    /// LRU stamp.
    last_used: u64,
}

/// CPU KV store with block-granular capacity and LRU eviction.
#[derive(Debug)]
pub struct CpuStore {
    capacity_blocks: u64,
    used_blocks: u64,
    entries: HashMap<CacheKey, CpuEntry>,
    free: Vec<u64>,
    next_block: u64,
    clock: u64,
    /// Eviction counter (metrics).
    pub evictions: u64,
}

impl CpuStore {
    /// Store with `capacity_blocks` CPU blocks.
    pub fn new(capacity_blocks: u64) -> Self {
        CpuStore {
            capacity_blocks,
            used_blocks: 0,
            entries: HashMap::new(),
            free: Vec::new(),
            next_block: 0,
            clock: 0,
            evictions: 0,
        }
    }

    /// Blocks currently used.
    pub fn used(&self) -> u64 {
        self.used_blocks
    }

    /// Look up a cached prefix; bumps LRU on hit.
    pub fn lookup(&mut self, key: CacheKey) -> Option<&CpuEntry> {
        self.clock += 1;
        let clock = self.clock;
        if let Some(e) = self.entries.get_mut(&key) {
            e.last_used = clock;
            Some(&*e)
        } else {
            None
        }
    }

    /// Save `n_blocks` of KV (covering `tokens` tokens) under `key`,
    /// evicting LRU entries as needed. Returns the CPU block ids, or None
    /// when the prefix cannot fit even after evicting everything else.
    pub fn save(&mut self, key: CacheKey, n_blocks: u64, tokens: u64) -> Option<Vec<u64>> {
        if n_blocks > self.capacity_blocks {
            return None;
        }
        // Refreshing an existing key: release its old blocks first.
        self.remove(key);
        while self.capacity_blocks - self.used_blocks < n_blocks {
            let lru = self
                .entries
                .values()
                .min_by_key(|e| e.last_used)?
                .key;
            self.remove(lru);
            self.evictions += 1;
        }
        let mut blocks = Vec::with_capacity(n_blocks as usize);
        for _ in 0..n_blocks {
            blocks.push(self.free.pop().unwrap_or_else(|| {
                let b = self.next_block;
                self.next_block += 1;
                b
            }));
        }
        self.used_blocks += n_blocks;
        self.clock += 1;
        self.entries.insert(
            key,
            CpuEntry {
                key,
                cpu_blocks: blocks.clone(),
                tokens,
                last_used: self.clock,
            },
        );
        Some(blocks)
    }

    /// Drop an entry, freeing its blocks.
    pub fn remove(&mut self, key: CacheKey) {
        if let Some(e) = self.entries.remove(&key) {
            self.used_blocks -= e.cpu_blocks.len() as u64;
            self.free.extend(e.cpu_blocks);
        }
    }

    /// Number of entries resident.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_lookup_roundtrip() {
        let mut s = CpuStore::new(100);
        let blocks = s.save(1, 10, 160).unwrap();
        assert_eq!(blocks.len(), 10);
        let e = s.lookup(1).unwrap();
        assert_eq!(e.tokens, 160);
        assert_eq!(s.used(), 10);
        assert!(s.lookup(2).is_none());
    }

    #[test]
    fn lru_eviction() {
        let mut s = CpuStore::new(20);
        s.save(1, 10, 160).unwrap();
        s.save(2, 10, 160).unwrap();
        s.lookup(1); // 1 is now MRU
        s.save(3, 10, 160).unwrap(); // must evict 2
        assert!(s.lookup(2).is_none());
        assert!(s.lookup(1).is_some());
        assert!(s.lookup(3).is_some());
        assert_eq!(s.evictions, 1);
        assert_eq!(s.used(), 20);
    }

    #[test]
    fn oversized_prefix_rejected() {
        let mut s = CpuStore::new(5);
        assert!(s.save(1, 6, 96).is_none());
        assert_eq!(s.used(), 0);
    }

    #[test]
    fn resave_replaces() {
        let mut s = CpuStore::new(10);
        s.save(1, 4, 64).unwrap();
        s.save(1, 6, 96).unwrap();
        assert_eq!(s.used(), 6);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn block_ids_never_alias() {
        let mut s = CpuStore::new(30);
        let a = s.save(1, 10, 160).unwrap();
        let b = s.save(2, 10, 160).unwrap();
        for x in &a {
            assert!(!b.contains(x));
        }
    }
}
