//! Paged KV cache with a CPU offload tier (paper §2.1.2, §5.3).
//!
//! vLLM-style PagedAttention layout: the KV cache is split into fixed-size
//! blocks of 16 tokens; blocks are non-contiguous in memory. Following the
//! optimized layout of the vLLM KV-offload connector [28] that the paper
//! assumes, a block stores **all layers contiguously**, so one block is one
//! transfer (e.g. 2 MiB for Llama-3.1-8B, 192 KiB for Qwen2.5-0.5B).
//!
//! - [`allocator`]: GPU block pool.
//! - [`cpu_store`]: CPU-memory KV tier with LRU eviction.
//! - [`layout`]: block geometry + simulated-memory addressing.
//! - [`fetch`]: the three KV-fetch implementations the paper compares —
//!   per-copy DMA (`hipMemcpyAsync` baseline), batched-b2b DMA (the
//!   contribution), and a CU gather kernel.
//! - [`migrate`]: cross-node KV migration for disaggregated prefill/decode
//!   serving — DMA save/fetch legs fused with the cluster NIC link, with a
//!   layer-pipelined streaming schedule vs a blocking bulk transfer.

pub mod allocator;
pub mod cpu_store;
pub mod fetch;
pub mod layout;
pub mod migrate;
pub mod save;

pub use allocator::BlockAllocator;
pub use cpu_store::CpuStore;
pub use layout::{BlockLayout, DEFAULT_BLOCK_TOKENS};
pub use migrate::{MigrateOutcome, MigrateSchedule, MigrateSpec, Migrator};
