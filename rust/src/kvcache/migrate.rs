//! Cross-node KV migration: prefill node → decode node over DMA + NIC.
//!
//! Disaggregated serving splits a request across two machines: a prefill
//! node builds the KV cache, a decode node consumes it. The cache has to
//! physically move, and this module lowers that movement onto the pieces
//! the repo already models — the paper's b2b DMA save/fetch plans on each
//! node's PCIe link ([`run_save`] / [`run_fetch`]) fused with the cluster
//! NIC link model ([`NicModel`]: posts and payloads serialize on the
//! sender port, propagation pipelines — same contract as the hierarchical
//! collectives' inter-node exchange in `cluster::hier`).
//!
//! Two schedules:
//!
//! - [`MigrateSchedule::Blocking`] — the full cache drains to the prefill
//!   node's CPU staging tier, crosses the NIC as one bulk scatter-gather
//!   write, and is fetched onto the decode GPU; decode starts only after
//!   the last byte lands ([`MigrateOutcome::first_ready_ns`] ==
//!   [`MigrateOutcome::total_ns`]).
//! - [`MigrateSchedule::LayerPipelined`] — the headline optimization. KV
//!   blocks store all layers contiguously, so the migration slices each
//!   block by layer range and streams layer-granular chunks: chunk `k`'s
//!   D2H save overlaps chunk `k-1`'s NIC flight overlaps chunk `k-2`'s
//!   H2D fetch. Decode can start step 0 as soon as chunk 0 (layer 0) is
//!   resident. Per-chunk posts cost extra (`t_post_per_msg` each), but the
//!   1 MiB chunk floor keeps each chunk's wire time ~45× the post cost,
//!   and both PCIe legs (64 B/ns) outrun the NIC (50 B/ns), so the NIC
//!   stays the pipeline bottleneck and the streamed total never exceeds
//!   the blocking total (asserted across the model zoo in tests and per
//!   sweep cell in `benches/disagg.rs`).
//!
//! Both schedules move real bytes when the sims are functional: the CPU
//! staging ranges are relayed from the prefill sim's memory into the
//! decode sim's memory chunk-by-chunk (the NIC hop), so the migrated
//! cache is byte-verified against the single-node save/fetch reference
//! (`tests/prop_migrate.rs`).

use crate::cluster::topology::NicModel;
use crate::sim::{Addr, Sim, SimConfig};

use super::fetch::{run_fetch, CopySpec, FetchImpl, FetchOutcome};
use super::save::run_save;
use super::BlockLayout;

/// Chunk-size floor for the pipelined schedule. Below this the per-chunk
/// NIC post and b2b sync overheads stop amortizing and streaming could
/// lose to the bulk transfer; at 1 MiB the payload (~20 µs on the wire)
/// dwarfs the 450 ns post.
pub const MIN_CHUNK_BYTES: u64 = 1024 * 1024;

/// How the KV cache crosses the node boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MigrateSchedule {
    /// Bulk transfer: save all → one NIC write → fetch all.
    Blocking,
    /// Stream layer-granular chunks; decode starts when layer 0 lands.
    LayerPipelined,
}

impl MigrateSchedule {
    /// Label used in figures and bench rows.
    pub fn name(&self) -> &'static str {
        match self {
            MigrateSchedule::Blocking => "blocking",
            MigrateSchedule::LayerPipelined => "layer_pipelined",
        }
    }
}

/// One migration: which blocks move, between which simulated devices.
#[derive(Debug)]
pub struct MigrateSpec<'a> {
    /// Shared block geometry (identical on both nodes).
    pub layout: &'a BlockLayout,
    /// Model layer count — the chunk-granularity ceiling.
    pub layers: u32,
    /// DMA implementation for both PCIe legs.
    pub imp: FetchImpl,
    /// NIC link between the two nodes.
    pub nic: &'a NicModel,
    /// Local GPU holding the source blocks on the prefill node.
    pub src_gpu: u8,
    /// Local GPU receiving the blocks on the decode node.
    pub dst_gpu: u8,
    /// GPU block ids on the prefill node.
    pub src_blocks: &'a [u64],
    /// CPU staging slots (bounce buffers; same ids on both nodes).
    pub staging_blocks: &'a [u64],
    /// GPU block ids on the decode node.
    pub dst_blocks: &'a [u64],
}

/// Modeled outcome of one migration (all times relative to its start).
#[derive(Debug, Clone, Copy, Default)]
pub struct MigrateOutcome {
    /// Total KV bytes moved.
    pub bytes: u64,
    /// Chunks streamed (1 for blocking).
    pub chunks: usize,
    /// RDMA work requests posted (one scatter-gather write per chunk).
    pub nic_msgs: usize,
    /// Last byte resident on the decode GPU.
    pub total_ns: u64,
    /// First chunk (layer 0) resident on the decode GPU — the earliest
    /// decode step 0 can begin. Equals `total_ns` for blocking.
    pub first_ready_ns: u64,
    /// First use of the sender NIC port.
    pub nic_open_ns: u64,
    /// Last release of the sender NIC port.
    pub nic_close_ns: u64,
    /// Port-occupied time (posts + payloads; excludes idle gaps while
    /// waiting on the save leg).
    pub nic_busy_ns: u64,
    /// Summed D2H save leg time (prefill-side PCIe occupancy).
    pub save_ns: u64,
    /// Summed H2D fetch leg time (decode-side PCIe occupancy).
    pub fetch_ns: u64,
    /// Summed host-thread time issuing both legs' DMA batches.
    pub host_ns: u64,
}

/// Chunks the pipelined schedule streams for a given shape (1 for
/// blocking). Capped by the layer count (slicing granularity), the block
/// count (so streamed posts never exceed a per-block bulk plan), and the
/// [`MIN_CHUNK_BYTES`] floor.
pub fn chunk_count(
    schedule: MigrateSchedule,
    layers: u32,
    n_blocks: u64,
    block_bytes: u64,
) -> usize {
    if n_blocks == 0 {
        return 0;
    }
    match schedule {
        MigrateSchedule::Blocking => 1,
        MigrateSchedule::LayerPipelined => {
            let by_bytes = (n_blocks * block_bytes / MIN_CHUNK_BYTES).max(1);
            (layers as u64).min(n_blocks).min(by_bytes).max(1) as usize
        }
    }
}

/// Split `layers` into `chunks` contiguous ranges, sizes differing ≤ 1.
fn layer_ranges(layers: u32, chunks: usize) -> Vec<(u32, u32)> {
    let chunks = chunks as u32;
    let base = layers / chunks;
    let extra = layers % chunks;
    let mut ranges = Vec::with_capacity(chunks as usize);
    let mut lo = 0;
    for c in 0..chunks {
        let hi = lo + base + u32::from(c < extra);
        ranges.push((lo, hi));
        lo = hi;
    }
    ranges
}

fn at(a: Addr, off: u64) -> Addr {
    Addr::new(a.node, a.offset + off)
}

/// Persistent pair of per-node simulators: `save_sim` models the prefill
/// node's DMA subsystem, `fetch_sim` the decode node's. Reuse across
/// migrations follows the engine's `fetch_sim` pattern (memory, engines
/// and clock carry over; outcomes are per-episode durations).
pub struct Migrator {
    /// Prefill-node DES (D2H save leg).
    pub save_sim: Sim,
    /// Decode-node DES (H2D fetch leg).
    pub fetch_sim: Sim,
}

impl Migrator {
    /// Timing-only pair (no byte movement — the serving hot path).
    pub fn new() -> Self {
        Migrator {
            save_sim: Sim::new(SimConfig::mi300x()),
            fetch_sim: Sim::new(SimConfig::mi300x()),
        }
    }

    /// Byte-moving pair for functional verification.
    pub fn functional() -> Self {
        Migrator {
            save_sim: Sim::new(SimConfig::mi300x().functional()),
            fetch_sim: Sim::new(SimConfig::mi300x().functional()),
        }
    }

    /// Run one migration under `schedule`.
    pub fn run(&mut self, spec: &MigrateSpec<'_>, schedule: MigrateSchedule) -> MigrateOutcome {
        let n = spec.src_blocks.len();
        assert_eq!(n, spec.staging_blocks.len());
        assert_eq!(n, spec.dst_blocks.len());
        if n == 0 {
            return MigrateOutcome::default();
        }
        let bb = spec.layout.block_bytes;
        // The chunker slices blocks by layer range; the layout invariant
        // (all layers contiguous, equal size) makes the split exact.
        assert_eq!(
            bb % spec.layers as u64,
            0,
            "layers must tile the KV block evenly"
        );
        let layer_bytes = bb / spec.layers as u64;
        let chunks = chunk_count(schedule, spec.layers, n as u64, bb);
        let ranges = layer_ranges(spec.layers, chunks);

        let mut out = MigrateOutcome {
            bytes: n as u64 * bb,
            chunks,
            nic_msgs: chunks,
            ..Default::default()
        };
        // Three pipeline frontiers: the prefill PCIe leg, the NIC port,
        // the decode PCIe leg. Each chunk flows save → port → fetch;
        // chunks serialize within a leg, legs overlap across chunks.
        let mut save_done = 0u64;
        let mut port = 0.0f64;
        let mut nic_open = f64::MAX;
        let mut nic_busy = 0.0f64;
        let mut fetch_free = 0u64;
        for (ci, &(lo, hi)) in ranges.iter().enumerate() {
            let off = lo as u64 * layer_bytes;
            let len = (hi - lo) as u64 * layer_bytes;
            let saves: Vec<CopySpec> = spec
                .src_blocks
                .iter()
                .zip(spec.staging_blocks)
                .map(|(&g, &c)| {
                    (
                        at(spec.layout.gpu_block_addr(spec.src_gpu, g), off),
                        at(spec.layout.cpu_block_addr(c), off),
                        len,
                    )
                })
                .collect();
            let s = run_save(&mut self.save_sim, spec.imp, &saves);
            save_done += s.total_ns;
            out.save_ns += s.total_ns;
            out.host_ns += s.host_ns;
            self.relay(spec, off, len);
            // One scatter-gather RDMA write per chunk, port-serialized:
            // the post and payload occupy the sender port, the one-way
            // latency pipelines behind it.
            let start = port.max(save_done as f64);
            nic_open = nic_open.min(start);
            let occ = spec.nic.t_post_per_msg + spec.nic.payload_ns(len * n as u64);
            port = start + occ;
            nic_busy += occ;
            let arrive = port + spec.nic.t_latency;
            let fetches: Vec<CopySpec> = spec
                .staging_blocks
                .iter()
                .zip(spec.dst_blocks)
                .map(|(&c, &g)| {
                    (
                        at(spec.layout.cpu_block_addr(c), off),
                        at(spec.layout.gpu_block_addr(spec.dst_gpu, g), off),
                        len,
                    )
                })
                .collect();
            let f = run_fetch(&mut self.fetch_sim, spec.imp, &fetches);
            out.fetch_ns += f.total_ns;
            out.host_ns += f.host_ns;
            let fstart = (arrive.ceil() as u64).max(fetch_free);
            fetch_free = fstart + f.total_ns;
            if ci == 0 {
                out.first_ready_ns = fetch_free;
            }
        }
        out.total_ns = fetch_free;
        out.nic_open_ns = nic_open.ceil() as u64;
        out.nic_close_ns = port.ceil() as u64;
        out.nic_busy_ns = nic_busy.ceil() as u64;
        out
    }

    /// Pure cost of migrating `n_blocks` blocks (synthesized ids — the
    /// DES outcome depends only on copy counts and sizes, like
    /// [`BlockLayout::synth_copies`]). The engine memoizes this per
    /// `(schedule, n_blocks)`.
    pub fn cost(
        &mut self,
        layout: &BlockLayout,
        layers: u32,
        imp: FetchImpl,
        nic: &NicModel,
        n_blocks: u64,
        schedule: MigrateSchedule,
    ) -> MigrateOutcome {
        let ids: Vec<u64> = (0..n_blocks).collect();
        let spec = MigrateSpec {
            layout,
            layers,
            imp,
            nic,
            src_gpu: 0,
            dst_gpu: 0,
            src_blocks: &ids,
            staging_blocks: &ids,
            dst_blocks: &ids,
        };
        self.run(&spec, schedule)
    }

    /// The NIC hop for functional runs: relay the just-saved CPU staging
    /// ranges from the prefill sim's memory into the decode sim's.
    fn relay(&mut self, spec: &MigrateSpec<'_>, off: u64, len: u64) {
        if !self.save_sim.memory.is_functional() || !self.fetch_sim.memory.is_functional() {
            return;
        }
        for &c in spec.staging_blocks {
            let a = at(spec.layout.cpu_block_addr(c), off);
            let bytes = self.save_sim.memory.peek(a.node, a.offset, len);
            self.fetch_sim.memory.poke(a.node, a.offset, &bytes);
        }
    }
}

impl Default for Migrator {
    fn default() -> Self {
        Self::new()
    }
}

/// Aggregate save/fetch leg outcome view (used by power accounting).
pub fn leg_outcomes(out: &MigrateOutcome) -> (FetchOutcome, FetchOutcome) {
    (
        FetchOutcome {
            total_ns: out.save_ns,
            ..Default::default()
        },
        FetchOutcome {
            total_ns: out.fetch_ns,
            ..Default::default()
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::{ALL_MODELS, LLAMA31_8B, QWEN25_0_5B};
    use crate::util::bytes::MB;

    fn mig(
        model: &crate::models::ModelConfig,
        n_blocks: u64,
        schedule: MigrateSchedule,
    ) -> MigrateOutcome {
        let layout = BlockLayout::new(model, 16);
        let mut m = Migrator::new();
        m.cost(
            &layout,
            model.layers,
            FetchImpl::DmaB2b,
            &NicModel::default(),
            n_blocks,
            schedule,
        )
    }

    #[test]
    fn chunk_count_caps() {
        // Blocking is always one bulk transfer.
        assert_eq!(chunk_count(MigrateSchedule::Blocking, 24, 256, 192 * 1024), 1);
        // Pipelined: layer cap (Qwen-0.5B, big prompt: 48 MiB / 1 MiB
        // floor would allow 48, layers cap at 24).
        assert_eq!(
            chunk_count(MigrateSchedule::LayerPipelined, 24, 256, 192 * 1024),
            24
        );
        // Byte floor: 2 blocks × 192 KiB < 1 MiB → single chunk.
        assert_eq!(
            chunk_count(MigrateSchedule::LayerPipelined, 24, 2, 192 * 1024),
            1
        );
        // Block cap: 4 blocks of 2 MiB could fill 8 chunks; capped at 4.
        assert_eq!(
            chunk_count(MigrateSchedule::LayerPipelined, 32, 4, 2 * MB),
            4
        );
        assert_eq!(chunk_count(MigrateSchedule::LayerPipelined, 24, 0, 192 * 1024), 0);
    }

    #[test]
    fn layer_ranges_tile_exactly() {
        for (layers, chunks) in [(24u32, 24usize), (24, 5), (32, 1), (7, 3)] {
            let r = layer_ranges(layers, chunks);
            assert_eq!(r.len(), chunks);
            assert_eq!(r[0].0, 0);
            assert_eq!(r.last().unwrap().1, layers);
            for w in r.windows(2) {
                assert_eq!(w[0].1, w[1].0);
                assert!(w[0].1 > w[0].0);
            }
        }
    }

    /// The acceptance bound at the modeled-migration level: streaming is
    /// never slower than the bulk transfer, on any model or prompt size.
    #[test]
    fn pipelined_never_slower_than_blocking_across_zoo() {
        for model in ALL_MODELS {
            for n_blocks in [1u64, 4, 16, 64, 256] {
                let b = mig(model, n_blocks, MigrateSchedule::Blocking);
                let p = mig(model, n_blocks, MigrateSchedule::LayerPipelined);
                assert_eq!(b.bytes, p.bytes);
                assert!(
                    p.total_ns <= b.total_ns,
                    "{} n={n_blocks}: pipelined {} > blocking {}",
                    model.name,
                    p.total_ns,
                    b.total_ns
                );
                assert!(p.first_ready_ns <= p.total_ns);
                assert_eq!(b.first_ready_ns, b.total_ns);
                if p.chunks > 1 {
                    // The point of the optimization: layer 0 lands (and
                    // decode can start) well before the bulk transfer
                    // would have delivered anything.
                    assert!(
                        p.first_ready_ns < b.total_ns,
                        "{} n={n_blocks}: no first-token win",
                        model.name
                    );
                }
            }
        }
    }

    #[test]
    fn big_prompt_first_token_wins_by_2x() {
        // Qwen-0.5B, 4096-token prompt: 256 blocks, 24 chunks. Layer 0 is
        // on the decode GPU while the bulk path is still draining D2H.
        let b = mig(&QWEN25_0_5B, 256, MigrateSchedule::Blocking);
        let p = mig(&QWEN25_0_5B, 256, MigrateSchedule::LayerPipelined);
        assert_eq!(p.chunks, 24);
        assert!(2 * p.first_ready_ns < b.total_ns);
    }

    #[test]
    fn single_chunk_degenerates_to_blocking() {
        // Below the byte floor the pipelined plan IS the blocking plan:
        // same copies, same single scatter-gather write, same times.
        let b = mig(&QWEN25_0_5B, 2, MigrateSchedule::Blocking);
        let p = mig(&QWEN25_0_5B, 2, MigrateSchedule::LayerPipelined);
        assert_eq!(p.chunks, 1);
        assert_eq!(p.total_ns, b.total_ns);
        assert_eq!(p.first_ready_ns, b.first_ready_ns);
        assert_eq!(p.nic_busy_ns, b.nic_busy_ns);
    }

    #[test]
    fn port_accounting_is_consistent() {
        let p = mig(&LLAMA31_8B, 64, MigrateSchedule::LayerPipelined);
        assert!(p.nic_open_ns < p.nic_close_ns);
        assert!(p.nic_busy_ns <= p.nic_close_ns - p.nic_open_ns);
        assert!(p.nic_close_ns < p.total_ns); // fetch leg extends past port close
        assert_eq!(p.nic_msgs, p.chunks);
    }

    #[test]
    fn migrated_bytes_match_source() {
        // Functional migration: bytes poked on the prefill GPU arrive
        // bit-identical on the decode GPU, per block, via CPU staging and
        // the relayed NIC hop.
        let layout = BlockLayout::new(&QWEN25_0_5B, 16);
        let mut m = Migrator::functional();
        let src: Vec<u64> = (0..4).collect();
        let staging: Vec<u64> = (10..14).collect();
        let dst: Vec<u64> = (20..24).collect();
        for &g in &src {
            let a = layout.gpu_block_addr(1, g);
            m.save_sim
                .memory
                .poke(a.node, a.offset, &vec![g as u8 + 1; layout.block_bytes as usize]);
        }
        let spec = MigrateSpec {
            layout: &layout,
            layers: QWEN25_0_5B.layers,
            imp: FetchImpl::DmaB2b,
            nic: &NicModel::default(),
            src_gpu: 1,
            dst_gpu: 3,
            src_blocks: &src,
            staging_blocks: &staging,
            dst_blocks: &dst,
        };
        let out = m.run(&spec, MigrateSchedule::LayerPipelined);
        assert!(out.total_ns > 0);
        for (i, &g) in dst.iter().enumerate() {
            let a = layout.gpu_block_addr(3, g);
            let got = m.fetch_sim.memory.peek(a.node, a.offset, layout.block_bytes);
            assert!(got.iter().all(|&b| b == i as u8 + 1), "block {g} corrupted");
        }
    }
}
