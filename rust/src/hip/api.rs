//! The HIP-like user API over the simulator: streams, `memcpy_async`,
//! `memcpy_batch_async`, `stream_synchronize` — §6's proposed surface.
//!
//! Each stream maps to one sDMA engine queue (HIP semantics: ordered
//! within a stream, unordered across streams). The batch call applies the
//! [`super::heuristics`] planner, so users get broadcast fusion, swap
//! attributes and the b2b/fan-out decision transparently.

use crate::sim::command::{Addr, AtomicOp, Command};
use crate::sim::host::{ApiKind, HostOp};
use crate::sim::{EngineId, Sim, SignalId};

pub use super::heuristics::{BatchEntry, CopyType, HeuristicsConfig};

/// Stream handle (maps to an engine of the destination GPU).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamId(pub u8);

/// Pending work handle: signal + expected count.
#[derive(Debug, Clone, Copy)]
pub struct Pending {
    signal: SignalId,
    expect: i64,
}

/// HIP-like runtime over a simulator instance.
pub struct HipRuntime {
    pub sim: Sim,
    pub cfg: HeuristicsConfig,
    gpu: u8,
    /// Stats for tests/benches.
    pub api_calls: u64,
    pub commands_issued: u64,
}

impl HipRuntime {
    /// Runtime driving `gpu`'s engines on `sim`.
    pub fn new(sim: Sim, gpu: u8) -> Self {
        HipRuntime {
            sim,
            cfg: HeuristicsConfig::default(),
            gpu,
            api_calls: 0,
            commands_issued: 0,
        }
    }

    fn engine(&self, idx: usize) -> EngineId {
        EngineId {
            gpu: self.gpu,
            idx: (idx % self.sim.cfg.topology.engines_per_gpu as usize) as u8,
        }
    }

    /// `hipMemcpyAsync`: one copy on one stream; returns a handle to wait
    /// on. Pays the full per-call setup/teardown cost.
    pub fn memcpy_async(&mut self, dst: Addr, src: Addr, len: u64, stream: StreamId) -> Pending {
        let sig = self.sim.alloc_signal(0);
        let engine = self.engine(stream.0 as usize);
        let start = self.sim.time;
        self.sim.add_host(
            vec![
                HostOp::CreateCommands {
                    engine,
                    cmds: vec![
                        Command::Copy { src, dst, len },
                        Command::Atomic {
                            signal: sig,
                            op: AtomicOp::Add(1),
                        },
                    ],
                    api: ApiKind::HipPerCopy,
                },
                HostOp::RingDoorbell { engine },
            ],
            start,
        );
        self.api_calls += 1;
        self.commands_issued += 1;
        Pending {
            signal: sig,
            expect: 1,
        }
    }

    /// `hipMemcpyBatchAsync`: a batch of copies (+attributes). The runtime
    /// plans broadcast fusion, swap lowering and fan-out, issues one
    /// prologue/epilogue, and returns a single completion handle.
    pub fn memcpy_batch_async(&mut self, entries: &[BatchEntry]) -> Pending {
        let plan = super::heuristics::plan_batch(entries, &self.cfg);
        let sig = self.sim.alloc_signal(0);
        let expect = plan.chains.len() as i64;
        let start = self.sim.time;
        let mut script = Vec::new();
        for (ci, chain) in plan.chains.iter().enumerate() {
            let engine = self.engine(ci);
            let mut cmds = chain.clone();
            self.commands_issued += cmds.len() as u64;
            cmds.push(Command::Atomic {
                signal: sig,
                op: AtomicOp::Add(1),
            });
            script.push(HostOp::CreateCommands {
                engine,
                cmds,
                api: ApiKind::HipBatched,
            });
            script.push(HostOp::RingDoorbell { engine });
        }
        self.sim.add_host(script, start);
        self.api_calls += 1;
        Pending {
            signal: sig,
            expect,
        }
    }

    /// `hipStreamSynchronize`-style wait: drive the sim until the pending
    /// work completed; returns completion time (sim ns).
    pub fn synchronize(&mut self, pending: Pending) -> u64 {
        let sig = pending.signal;
        let expect = pending.expect;
        let start = self.sim.time;
        self.sim.add_host(
            vec![HostOp::WaitSignal {
                signal: sig,
                at_least: expect,
            }],
            start,
        );
        let out = self.sim.run();
        assert!(
            out.deadlocked.is_empty(),
            "synchronize deadlocked: {:?}",
            out.deadlocked
        );
        self.sim.time
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::topology::NodeId;
    use crate::sim::SimConfig;
    use crate::util::bytes::KB;

    fn rt() -> HipRuntime {
        HipRuntime::new(Sim::new(SimConfig::mi300x().functional()), 0)
    }

    #[test]
    fn memcpy_async_roundtrip() {
        let mut rt = rt();
        rt.sim.memory.poke(NodeId::Gpu(0), 0, &[3u8; 1024]);
        let p = rt.memcpy_async(
            Addr::new(NodeId::Gpu(1), 0),
            Addr::new(NodeId::Gpu(0), 0),
            1024,
            StreamId(0),
        );
        rt.synchronize(p);
        assert_eq!(rt.sim.memory.peek(NodeId::Gpu(1), 0, 1024), vec![3u8; 1024]);
        assert_eq!(rt.api_calls, 1);
    }

    #[test]
    fn batch_semantics_equal_individual_copies() {
        // Same byte movement either way; batch uses far fewer API calls.
        let entries: Vec<BatchEntry> = (0..10u64)
            .map(|i| BatchEntry {
                src: Addr::new(NodeId::Cpu, i * 4096),
                dst: Addr::new(NodeId::Gpu(0), i * 4096),
                len: 4096,
                ty: CopyType::Copy,
            })
            .collect();
        let mut fill = vec![0u8; 40960];
        for (i, b) in fill.iter_mut().enumerate() {
            *b = (i % 251) as u8;
        }

        let mut a = rt();
        a.sim.memory.poke(NodeId::Cpu, 0, &fill);
        let ps: Vec<_> = entries
            .iter()
            .map(|e| a.memcpy_async(e.dst, e.src, e.len, StreamId(0)))
            .collect();
        for p in ps {
            a.synchronize(p);
        }

        let mut b = rt();
        b.sim.memory.poke(NodeId::Cpu, 0, &fill);
        let p = b.memcpy_batch_async(&entries);
        b.synchronize(p);

        assert_eq!(
            a.sim.memory.peek(NodeId::Gpu(0), 0, 40960),
            b.sim.memory.peek(NodeId::Gpu(0), 0, 40960)
        );
        assert_eq!(a.api_calls, 10);
        assert_eq!(b.api_calls, 1);
    }

    #[test]
    fn batch_is_faster_for_latency_bound_sets() {
        let entries: Vec<BatchEntry> = (0..64u64)
            .map(|i| BatchEntry {
                src: Addr::new(NodeId::Cpu, i * 8192),
                dst: Addr::new(NodeId::Gpu(0), i * 8192),
                len: 8 * KB,
                ty: CopyType::Copy,
            })
            .collect();
        let mut a = rt();
        let ps: Vec<_> = entries
            .iter()
            .map(|e| a.memcpy_async(e.dst, e.src, e.len, StreamId(0)))
            .collect();
        let t_single = {
            for p in ps {
                a.synchronize(p);
            }
            a.sim.time
        };
        let mut b = rt();
        let p = b.memcpy_batch_async(&entries);
        let t_batch = b.synchronize(p);
        assert!(
            (t_batch as f64) < 0.5 * t_single as f64,
            "batch {t_batch} vs per-copy {t_single}"
        );
    }

    #[test]
    fn broadcast_inference_transparent_and_correct() {
        let mut rt = rt();
        rt.sim.memory.poke(NodeId::Gpu(0), 0, &[9u8; 2048]);
        let entries = vec![
            BatchEntry {
                src: Addr::new(NodeId::Gpu(0), 0),
                dst: Addr::new(NodeId::Gpu(1), 0),
                len: 2048,
                ty: CopyType::Copy,
            },
            BatchEntry {
                src: Addr::new(NodeId::Gpu(0), 0),
                dst: Addr::new(NodeId::Gpu(2), 512),
                len: 2048,
                ty: CopyType::Copy,
            },
        ];
        let p = rt.memcpy_batch_async(&entries);
        rt.synchronize(p);
        assert_eq!(rt.sim.memory.peek(NodeId::Gpu(1), 0, 2048), vec![9u8; 2048]);
        assert_eq!(rt.sim.memory.peek(NodeId::Gpu(2), 512, 2048), vec![9u8; 2048]);
        // One bcst command, not two copies.
        assert_eq!(rt.commands_issued, 1);
        // Source read once (memory-traffic benefit).
        assert_eq!(rt.sim.memory.reads(NodeId::Gpu(0)), 2048);
    }

    #[test]
    fn swap_attribute_end_to_end() {
        let mut rt = rt();
        rt.sim.memory.poke(NodeId::Gpu(0), 0, &[1u8; 256]);
        rt.sim.memory.poke(NodeId::Gpu(1), 0, &[2u8; 256]);
        let p = rt.memcpy_batch_async(&[BatchEntry {
            src: Addr::new(NodeId::Gpu(0), 0),
            dst: Addr::new(NodeId::Gpu(1), 0),
            len: 256,
            ty: CopyType::Swap,
        }]);
        rt.synchronize(p);
        assert_eq!(rt.sim.memory.peek(NodeId::Gpu(0), 0, 256), vec![2u8; 256]);
        assert_eq!(rt.sim.memory.peek(NodeId::Gpu(1), 0, 256), vec![1u8; 256]);
    }
}
