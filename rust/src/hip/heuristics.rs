//! Runtime planning heuristics for the batch copy API (paper §6).

use std::collections::HashMap;

use crate::sim::command::{Addr, Command};
use crate::sim::topology::NodeId;

/// User-visible copy type attribute (the §6 `attributes` field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CopyType {
    /// Plain copy (default).
    Copy,
    /// Explicit in-place exchange request.
    Swap,
}

/// One entry of a `memcpy_batch_async` call.
#[derive(Debug, Clone, Copy)]
pub struct BatchEntry {
    pub src: Addr,
    pub dst: Addr,
    pub len: u64,
    pub ty: CopyType,
}

/// Tunables of the runtime planner.
#[derive(Debug, Clone)]
pub struct HeuristicsConfig {
    /// Below this total size, the whole batch goes b2b on one engine
    /// (the paper's empirically-chosen 4MB, §5.3.1).
    pub b2b_threshold_bytes: u64,
    /// Max engines a batch may fan out to.
    pub max_fanout: usize,
    /// Infer `bcst` commands from (src, len) duplicates.
    pub infer_broadcast: bool,
}

impl Default for HeuristicsConfig {
    fn default() -> Self {
        HeuristicsConfig {
            b2b_threshold_bytes: 4 * 1024 * 1024,
            max_fanout: 8,
            infer_broadcast: true,
        }
    }
}

/// Planned batch: per-engine-slot command chains (engine indices are
/// relative; the API layer maps them onto a GPU's engines).
#[derive(Debug)]
pub struct BatchPlan {
    pub chains: Vec<Vec<Command>>,
    /// How many entries were fused into broadcasts.
    pub broadcasts_inferred: usize,
    /// Entries expressed as swap commands.
    pub swaps: usize,
}

/// Lower batch entries to DMA commands, fusing broadcast pairs.
fn lower_entries(entries: &[BatchEntry], cfg: &HeuristicsConfig) -> (Vec<Command>, usize, usize) {
    let mut cmds = Vec::new();
    let mut swaps = 0;
    let mut bcasts = 0;
    // Group copy entries by (src, len) for broadcast inference.
    let mut groups: HashMap<(NodeId, u64, u64), Vec<&BatchEntry>> = HashMap::new();
    let mut order: Vec<(NodeId, u64, u64)> = Vec::new();
    for e in entries {
        match e.ty {
            CopyType::Swap => {
                swaps += 1;
                cmds.push(Command::Swap {
                    a: e.src,
                    b: e.dst,
                    len: e.len,
                });
            }
            CopyType::Copy => {
                let key = (e.src.node, e.src.offset, e.len);
                if !groups.contains_key(&key) {
                    order.push(key);
                }
                groups.entry(key).or_default().push(e);
            }
        }
    }
    for key in order {
        let group = &groups[&key];
        let mut it = group.iter().peekable();
        while let Some(a) = it.next() {
            if cfg.infer_broadcast {
                if let Some(b) = it.peek() {
                    // Same source & size, two destinations ⇒ bcst.
                    let b = **b;
                    it.next();
                    bcasts += 1;
                    cmds.push(Command::Bcst {
                        src: a.src,
                        dst0: a.dst,
                        dst1: b.dst,
                        len: a.len,
                    });
                    continue;
                }
            }
            cmds.push(Command::Copy {
                src: a.src,
                dst: a.dst,
                len: a.len,
            });
        }
    }
    (cmds, bcasts, swaps)
}

/// Plan a batch: lower entries, then pick the fan-out degree.
pub fn plan_batch(entries: &[BatchEntry], cfg: &HeuristicsConfig) -> BatchPlan {
    let (cmds, broadcasts_inferred, swaps) = lower_entries(entries, cfg);
    let total: u64 = entries.iter().map(|e| e.len).sum();
    let chains = if total <= cfg.b2b_threshold_bytes || cmds.len() <= 1 {
        // Latency-bound: back-to-back on a single engine, one sync.
        vec![cmds]
    } else {
        // Bandwidth-bound: fan out, topology-aware — spread by destination
        // node so chains hit distinct links where possible.
        let n = ((total / cfg.b2b_threshold_bytes) as usize + 1)
            .min(cfg.max_fanout)
            .max(1);
        let mut chains: Vec<Vec<Command>> = vec![Vec::new(); n];
        for (i, c) in cmds.into_iter().enumerate() {
            let slot = match c.writes().first().map(|(a, _)| a.node) {
                Some(NodeId::Gpu(g)) => (g as usize) % n,
                _ => i % n,
            };
            chains[slot].push(c);
        }
        chains.retain(|c| !c.is_empty());
        chains
    };
    BatchPlan {
        chains,
        broadcasts_inferred,
        swaps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::{KB, MB};

    fn entry(src_off: u64, dst_gpu: u8, dst_off: u64, len: u64) -> BatchEntry {
        BatchEntry {
            src: Addr::new(NodeId::Gpu(0), src_off),
            dst: Addr::new(NodeId::Gpu(dst_gpu), dst_off),
            len,
            ty: CopyType::Copy,
        }
    }

    #[test]
    fn infers_broadcast_pairs() {
        // Two copies from the same (src, len) to different GPUs fuse.
        let entries = vec![entry(0, 1, 0, 4 * KB), entry(0, 2, 0, 4 * KB)];
        let plan = plan_batch(&entries, &HeuristicsConfig::default());
        assert_eq!(plan.broadcasts_inferred, 1);
        assert_eq!(plan.chains[0].len(), 1);
        assert!(matches!(plan.chains[0][0], Command::Bcst { .. }));
    }

    #[test]
    fn odd_group_leaves_one_copy() {
        let entries = vec![
            entry(0, 1, 0, KB),
            entry(0, 2, 0, KB),
            entry(0, 3, 0, KB),
        ];
        let plan = plan_batch(&entries, &HeuristicsConfig::default());
        assert_eq!(plan.broadcasts_inferred, 1);
        assert_eq!(plan.chains[0].len(), 2); // bcst + copy
    }

    #[test]
    fn different_sources_do_not_fuse() {
        let entries = vec![entry(0, 1, 0, KB), entry(8192, 2, 0, KB)];
        let plan = plan_batch(&entries, &HeuristicsConfig::default());
        assert_eq!(plan.broadcasts_inferred, 0);
    }

    #[test]
    fn inference_can_be_disabled() {
        let entries = vec![entry(0, 1, 0, KB), entry(0, 2, 0, KB)];
        let cfg = HeuristicsConfig {
            infer_broadcast: false,
            ..Default::default()
        };
        let plan = plan_batch(&entries, &cfg);
        assert_eq!(plan.broadcasts_inferred, 0);
        assert_eq!(plan.chains[0].len(), 2);
    }

    #[test]
    fn swap_attribute_lowers_to_swap() {
        let entries = vec![BatchEntry {
            src: Addr::new(NodeId::Gpu(0), 0),
            dst: Addr::new(NodeId::Gpu(1), 0),
            len: KB,
            ty: CopyType::Swap,
        }];
        let plan = plan_batch(&entries, &HeuristicsConfig::default());
        assert_eq!(plan.swaps, 1);
        assert!(matches!(plan.chains[0][0], Command::Swap { .. }));
    }

    #[test]
    fn small_batch_single_chain_large_fans_out() {
        let small: Vec<_> = (0..16).map(|i| entry(i * 8192, 1, i * 8192, 4 * KB)).collect();
        assert_eq!(plan_batch(&small, &HeuristicsConfig::default()).chains.len(), 1);
        let large: Vec<_> = (0..16)
            .map(|i| entry(i << 24, (1 + i % 7) as u8, i << 24, 8 * MB))
            .collect();
        let plan = plan_batch(&large, &HeuristicsConfig::default());
        assert!(plan.chains.len() > 1);
        // Every command survives the split.
        let n: usize = plan.chains.iter().map(|c| c.len()).sum();
        assert_eq!(n, 16);
    }

    #[test]
    fn topology_aware_spread() {
        // Large batch to 7 distinct GPUs: chains should target distinct
        // destination groups (no chain mixes all GPUs).
        let entries: Vec<_> = (0..14)
            .map(|i| entry(i << 24, (1 + i % 7) as u8, 0, 8 * MB))
            .collect();
        let plan = plan_batch(&entries, &HeuristicsConfig::default());
        for chain in &plan.chains {
            let mut dsts: Vec<_> = chain
                .iter()
                .flat_map(|c| c.writes())
                .map(|(a, _)| a.node)
                .collect();
            dsts.dedup();
            assert!(dsts.len() <= 2, "chain mixes many destinations: {dsts:?}");
        }
    }
}
