//! §6 "Runtime Innovations" as code: a HIP-like runtime facade over the
//! DMA simulator that exposes the paper's proposed API surface —
//! `memcpy_async` (today's single-copy call), `memcpy_batch_async` (the
//! batch API of [8]/[24]) — and implements, *transparently to the user*,
//! the runtime-side heuristics the paper proposes:
//!
//! - **shared prologue/epilogue** for batches (amortized setup/teardown);
//! - **broadcast inference**: same source + size, ≥2 destinations ⇒ one
//!   `bcst` command instead of two copies;
//! - **swap via attributes**: an explicit per-entry `CopyType::Swap`
//!   (safe inference is impossible — §6);
//! - **fan-out heuristic**: latency-bound batches go back-to-back on one
//!   engine with a single sync; larger batches fan out across engines;
//! - **topology-aware engine selection** by destination node.

pub mod api;
pub mod heuristics;

pub use api::{BatchEntry, CopyType, HipRuntime, StreamId};
pub use heuristics::{plan_batch, BatchPlan, HeuristicsConfig};
