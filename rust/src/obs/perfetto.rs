//! Chrome `trace_event` JSON writer (Perfetto / `chrome://tracing`).
//!
//! Emits the object-format document `{"displayTimeUnit":"ns",
//! "traceEvents":[...]}` with:
//!
//! - two `"M"` (metadata) events per distinct track — a `process_name`
//!   for its pid and a `thread_name` for its (pid, tid) — so the UI
//!   groups tracks by node and labels every resource;
//! - one `"X"` (complete) event per span. `ts`/`dur` are microseconds;
//!   they are written with three decimals, so integer-ns instants
//!   round-trip exactly (`(ts_us * 1000).round() == start_ns`).
//!
//! The output parses with [`crate::util::json`] (schema-checked plus
//! golden-tested in `tests/obs_trace.rs`).

use super::span::ObsTrace;

/// Minimal JSON string escape (quotes, backslashes, control chars).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Nanoseconds → trace_event microseconds with exact ns resolution.
fn us(ns: u64) -> String {
    format!("{}.{:03}", ns / 1000, ns % 1000)
}

/// Serialize `trace` as a Chrome trace_event JSON document.
pub fn write_chrome_trace(trace: &ObsTrace) -> String {
    let mut out = String::from("{\"displayTimeUnit\":\"ns\",\"traceEvents\":[\n");
    let mut first = true;
    let mut push = |out: &mut String, ev: String| {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str("  ");
        out.push_str(&ev);
    };
    for tr in trace.tracks() {
        push(
            &mut out,
            format!(
                "{{\"ph\":\"M\",\"pid\":{},\"name\":\"process_name\",\
                 \"args\":{{\"name\":\"{}\"}}}}",
                tr.pid(),
                esc(&tr.process_label())
            ),
        );
        push(
            &mut out,
            format!(
                "{{\"ph\":\"M\",\"pid\":{},\"tid\":{},\"name\":\"thread_name\",\
                 \"args\":{{\"name\":\"{}\"}}}}",
                tr.pid(),
                tr.tid(),
                esc(&tr.label())
            ),
        );
    }
    for s in &trace.spans {
        let parent = s
            .parent
            .map(|p| p.to_string())
            .unwrap_or_else(|| "null".to_string());
        push(
            &mut out,
            format!(
                "{{\"ph\":\"X\",\"name\":\"{}\",\"cat\":\"{}\",\"pid\":{},\"tid\":{},\
                 \"ts\":{},\"dur\":{},\
                 \"args\":{{\"kind\":\"{}\",\"id\":{},\"parent\":{}}}}}",
                esc(&s.name),
                s.kind.name(),
                s.track.pid(),
                s.track.tid(),
                us(s.start_ns),
                us(s.dur_ns()),
                s.kind.name(),
                s.id,
                parent
            ),
        );
    }
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::{SpanKind, Track};
    use crate::util::json::Json;

    #[test]
    fn us_has_exact_ns_resolution() {
        assert_eq!(us(0), "0.000");
        assert_eq!(us(1), "0.001");
        assert_eq!(us(999), "0.999");
        assert_eq!(us(1000), "1.000");
        assert_eq!(us(1_234_567), "1234.567");
    }

    #[test]
    fn document_parses_and_counts_match() {
        let mut t = ObsTrace::default();
        let r = t.push(None, "root".into(), SpanKind::Root, Track::Episode, 0, 100);
        t.push(
            Some(r),
            "copy \"q\"".into(),
            SpanKind::Copy,
            Track::Dma {
                node: 0,
                gpu: 1,
                engine: 2,
            },
            10,
            60,
        );
        let doc = write_chrome_trace(&t);
        let j = Json::parse(&doc).expect("emitted trace must parse");
        assert_eq!(j.get("displayTimeUnit").unwrap().str(), Some("ns"));
        let evs = j.get("traceEvents").unwrap().arr().unwrap();
        // 2 distinct tracks → 4 M events, plus 2 X events.
        assert_eq!(evs.len(), 6);
        let xs: Vec<_> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().str() == Some("X"))
            .collect();
        assert_eq!(xs.len(), 2);
        // ns-exact round trip through the µs encoding.
        let copy = xs[1];
        let ts = copy.get("ts").unwrap().num().unwrap();
        let dur = copy.get("dur").unwrap().num().unwrap();
        assert_eq!((ts * 1000.0).round() as u64, 10);
        assert_eq!((dur * 1000.0).round() as u64, 50);
        assert_eq!(copy.get("args").unwrap().get("parent").unwrap().u64(), Some(0));
        assert_eq!(copy.get("name").unwrap().str(), Some("copy \"q\""));
    }

    #[test]
    fn empty_trace_is_still_valid_json() {
        let doc = write_chrome_trace(&ObsTrace::default());
        let j = Json::parse(&doc).unwrap();
        assert_eq!(j.get("traceEvents").unwrap().arr().unwrap().len(), 0);
    }
}
