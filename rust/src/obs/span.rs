//! Span model: one interval tree per traced episode, with stable IDs and a
//! track (simulated resource) per span. The track determines the
//! Perfetto process/thread placement ([`Track::pid`] / [`Track::tid`]) and
//! whether overlapping spans on it indicate a model bug
//! ([`Track::exclusive`]).

/// Stable span identifier, assigned in push order (monotonic within one
/// [`ObsTrace`]). At push time a parent's ID is always smaller than its
/// child's; closing an episode may re-parent earlier spans under a
/// later-pushed measure window, so don't rely on ordering after that.
pub type SpanId = u32;

/// What a span represents — drives the critical-path component mapping
/// ([`crate::obs::critical::component_of`]) and the Perfetto `args.kind`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Episode root (whole collective / whole serving run), structural.
    Root,
    /// Measured latency window (attribution denominator), structural.
    Measure,
    /// One serving request's arrival → completion window, structural.
    Request,
    /// One intra-node round grouping, structural.
    Round,
    /// CPU command creation + enqueue (paper Fig. 6 Control).
    Control,
    /// Doorbell → engine wake/fetch (Fig. 6 Schedule).
    Schedule,
    /// DMA decode + setup + data movement (Fig. 6 Copy).
    Copy,
    /// Completion atomics + host observe (Fig. 6 Sync).
    Sync,
    /// Bus-occupancy sub-window of a Copy (engine data path busy).
    Wire,
    /// CU reduction pass (hierarchical RS/AR folds).
    CuReduce,
    /// NIC port occupancy (post + payload serialization).
    Nic,
    /// NIC message in flight (propagation; pipelines across messages).
    NicFlight,
    /// Serving-step GEMM compute.
    Gemm,
    /// Collective time the serving engine could not hide behind compute.
    ExposedComm,
    /// Framework / runtime API time on the scheduler host.
    HostApi,
}

impl SpanKind {
    /// Short stable name (Perfetto `args.kind`).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Root => "root",
            SpanKind::Measure => "measure",
            SpanKind::Request => "request",
            SpanKind::Round => "round",
            SpanKind::Control => "control",
            SpanKind::Schedule => "schedule",
            SpanKind::Copy => "copy",
            SpanKind::Sync => "sync",
            SpanKind::Wire => "wire",
            SpanKind::CuReduce => "cu-reduce",
            SpanKind::Nic => "nic",
            SpanKind::NicFlight => "nic-flight",
            SpanKind::Gemm => "gemm",
            SpanKind::ExposedComm => "exposed-comm",
            SpanKind::HostApi => "host-api",
        }
    }
}

/// The simulated resource a span occupies — one Perfetto track each.
///
/// Process grouping: pid 0 holds the episode/measure tracks, pid 1 the
/// serving-engine tracks, pid `10 + k` the per-node cluster tracks of node
/// `k` (so multi-node timelines group by node in the Perfetto UI).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Track {
    /// Episode root + measure windows.
    Episode,
    /// Serving scheduler host (admission, framework API).
    SchedHost,
    /// Serving GPU compute (step GEMMs).
    Gpu,
    /// Serving collective-communication track (exposed remainders).
    Comm,
    /// Serving PCIe/fetch track (KV-cache DMA).
    Pcie,
    /// Per-request lifetime spans.
    Requests,
    /// Per-rank host thread of node `node`, GPU `gpu` (command creation).
    RankHost { node: u8, gpu: u8 },
    /// Node-level host thread (trigger writes, completion observes).
    NodeHost { node: u8 },
    /// DMA engine front-end + copy track.
    Dma { node: u8, gpu: u8, engine: u8 },
    /// DMA engine wire (bus-occupancy) track — exclusive by construction.
    DmaWire { node: u8, gpu: u8, engine: u8 },
    /// CU reduction track of node `node`.
    Cu { node: u8 },
    /// NIC port of node `node` — exclusive (posts+payloads serialize).
    Nic { node: u8 },
    /// NIC in-flight track of the *destination* node (flights pipeline, so
    /// overlap here is expected).
    NicFlight { node: u8 },
}

impl Track {
    /// Perfetto process id.
    pub fn pid(self) -> u64 {
        match self {
            Track::Episode => 0,
            Track::SchedHost | Track::Gpu | Track::Comm | Track::Pcie | Track::Requests => 1,
            Track::RankHost { node, .. }
            | Track::NodeHost { node }
            | Track::Dma { node, .. }
            | Track::DmaWire { node, .. }
            | Track::Cu { node }
            | Track::Nic { node }
            | Track::NicFlight { node } => 10 + node as u64,
        }
    }

    /// Perfetto thread id (unique within the track's pid).
    pub fn tid(self) -> u64 {
        match self {
            Track::Episode => 0,
            Track::SchedHost => 1,
            Track::Gpu => 2,
            Track::Comm => 3,
            Track::Pcie => 4,
            Track::Requests => 5,
            Track::NodeHost { .. } => 1,
            Track::Cu { .. } => 2,
            Track::Nic { .. } => 3,
            Track::NicFlight { .. } => 4,
            Track::RankHost { gpu, .. } => 10 + gpu as u64,
            Track::Dma { gpu, engine, .. } => 1000 + gpu as u64 * 100 + engine as u64 * 2,
            Track::DmaWire { gpu, engine, .. } => 1001 + gpu as u64 * 100 + engine as u64 * 2,
        }
    }

    /// Human name for the Perfetto `thread_name` metadata event.
    pub fn label(self) -> String {
        match self {
            Track::Episode => "episode".into(),
            Track::SchedHost => "sched.host".into(),
            Track::Gpu => "gpu.compute".into(),
            Track::Comm => "comm.exposed".into(),
            Track::Pcie => "pcie.fetch".into(),
            Track::Requests => "requests".into(),
            Track::RankHost { node, gpu } => format!("node{node}.gpu{gpu}.host"),
            Track::NodeHost { node } => format!("node{node}.host"),
            Track::Dma { node, gpu, engine } => format!("node{node}.gpu{gpu}.sdma{engine}"),
            Track::DmaWire { node, gpu, engine } => {
                format!("node{node}.gpu{gpu}.sdma{engine}.wire")
            }
            Track::Cu { node } => format!("node{node}.cu"),
            Track::Nic { node } => format!("node{node}.nic"),
            Track::NicFlight { node } => format!("node{node}.nic.flight"),
        }
    }

    /// Human name for the Perfetto `process_name` metadata event.
    pub fn process_label(self) -> String {
        match self.pid() {
            0 => "episodes".into(),
            1 => "serving".into(),
            p => format!("node{}", p - 10),
        }
    }

    /// Tracks on which overlapping spans would indicate a broken model:
    /// the NIC port serializes posts+payloads, and an engine's data path
    /// chains through `data_free_at`. (Hosts, CUs and flight tracks
    /// legitimately carry concurrent work.)
    pub fn exclusive(self) -> bool {
        matches!(self, Track::Nic { .. } | Track::DmaWire { .. })
    }
}

/// One recorded span on the absolute episode timeline (ns).
#[derive(Debug, Clone)]
pub struct Span {
    pub id: SpanId,
    pub parent: Option<SpanId>,
    pub name: String,
    pub kind: SpanKind,
    pub track: Track,
    pub start_ns: u64,
    pub end_ns: u64,
}

impl Span {
    /// Span duration in ns.
    pub fn dur_ns(&self) -> u64 {
        self.end_ns - self.start_ns
    }
}

/// A completed trace: flat span list with tree structure via parent IDs.
#[derive(Debug, Clone, Default)]
pub struct ObsTrace {
    pub spans: Vec<Span>,
}

impl ObsTrace {
    /// Append a span; IDs are assigned in push order so `parent < id`
    /// always holds (debug-asserted).
    pub fn push(
        &mut self,
        parent: Option<SpanId>,
        name: String,
        kind: SpanKind,
        track: Track,
        start_ns: u64,
        end_ns: u64,
    ) -> SpanId {
        let id = self.spans.len() as SpanId;
        debug_assert!(end_ns >= start_ns, "span '{name}' ends before it starts");
        debug_assert!(parent.map_or(true, |p| p < id), "parent must precede child");
        self.spans.push(Span {
            id,
            parent,
            name,
            kind,
            track,
            start_ns,
            end_ns,
        });
        id
    }

    /// Rewrite a structural span's interval once it is known (episode
    /// roots and measure windows are opened before their extent exists).
    pub fn set_interval(&mut self, id: SpanId, start_ns: u64, end_ns: u64) {
        debug_assert!(end_ns >= start_ns);
        let s = &mut self.spans[id as usize];
        s.start_ns = start_ns;
        s.end_ns = end_ns;
    }

    /// Distinct tracks in first-seen order (Perfetto metadata emission).
    pub fn tracks(&self) -> Vec<Track> {
        let mut seen = Vec::new();
        for s in &self.spans {
            if !seen.contains(&s.track) {
                seen.push(s.track);
            }
        }
        seen
    }

    /// All spans on `track`, in recorded order.
    pub fn on_track(&self, track: Track) -> impl Iterator<Item = &Span> {
        self.spans.iter().filter(move |s| s.track == track)
    }

    /// Latest span end (0 for an empty trace).
    pub fn max_end_ns(&self) -> u64 {
        self.spans.iter().map(|s| s.end_ns).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_assigns_monotonic_ids() {
        let mut t = ObsTrace::default();
        let a = t.push(None, "root".into(), SpanKind::Root, Track::Episode, 0, 0);
        let b = t.push(
            Some(a),
            "copy".into(),
            SpanKind::Copy,
            Track::Dma {
                node: 0,
                gpu: 1,
                engine: 0,
            },
            5,
            9,
        );
        assert_eq!((a, b), (0, 1));
        assert_eq!(t.spans[b as usize].parent, Some(a));
        assert_eq!(t.spans[b as usize].dur_ns(), 4);
    }

    #[test]
    fn track_ids_are_unique_per_pid() {
        let node_tracks = [
            Track::NodeHost { node: 2 },
            Track::Cu { node: 2 },
            Track::Nic { node: 2 },
            Track::NicFlight { node: 2 },
            Track::RankHost { node: 2, gpu: 0 },
            Track::RankHost { node: 2, gpu: 7 },
            Track::Dma {
                node: 2,
                gpu: 0,
                engine: 0,
            },
            Track::DmaWire {
                node: 2,
                gpu: 0,
                engine: 0,
            },
            Track::Dma {
                node: 2,
                gpu: 3,
                engine: 1,
            },
        ];
        let mut tids: Vec<u64> = node_tracks.iter().map(|t| t.tid()).collect();
        tids.sort_unstable();
        tids.dedup();
        assert_eq!(tids.len(), node_tracks.len(), "tid collision within pid");
        assert!(node_tracks.iter().all(|t| t.pid() == 12));
    }

    #[test]
    fn exclusivity_flags() {
        assert!(Track::Nic { node: 0 }.exclusive());
        assert!(Track::DmaWire {
            node: 0,
            gpu: 0,
            engine: 0
        }
        .exclusive());
        assert!(!Track::NicFlight { node: 0 }.exclusive());
        assert!(!Track::Cu { node: 0 }.exclusive());
        assert!(!Track::Dma {
            node: 0,
            gpu: 0,
            engine: 0
        }
        .exclusive());
    }

    #[test]
    fn tracks_first_seen_order() {
        let mut t = ObsTrace::default();
        t.push(None, "r".into(), SpanKind::Root, Track::Episode, 0, 10);
        t.push(None, "n".into(), SpanKind::Nic, Track::Nic { node: 1 }, 0, 5);
        t.push(None, "n2".into(), SpanKind::Nic, Track::Nic { node: 1 }, 5, 9);
        assert_eq!(t.tracks(), vec![Track::Episode, Track::Nic { node: 1 }]);
        assert_eq!(t.on_track(Track::Nic { node: 1 }).count(), 2);
        assert_eq!(t.max_end_ns(), 10);
    }
}
