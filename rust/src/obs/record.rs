//! Scoped span recorder.
//!
//! Tracing is opt-in per call tree: [`start`] installs a thread-local
//! recorder, the instrumented layers emit spans only when one is active
//! (checked once per episode via [`active`], never per DES event), and
//! [`finish`] removes it and returns the completed [`ObsTrace`]. No
//! function signature in the `sim`/`cluster`/`coordinator` layers changes,
//! and with no recorder installed every instrumentation site reduces to
//! one thread-local load — the determinism/bit-identity guarantees of the
//! untraced paths are untouched.
//!
//! ## Episode protocol
//!
//! The first layer to call [`Recorder::open_episode`] becomes the episode
//! *owner* (an all-reduce owns the episode its reduce-scatter joins).
//! Every emitting layer parents its spans to the episode root; a layer
//! that measured a latency window appends a [`SpanKind::Measure`] child
//! via [`Recorder::measure`] — the attribution denominators. Only the
//! owner calls [`Recorder::close_episode`], which re-parents contained
//! spans under their measure window and sizes the root. Sequential phase
//! compositions call [`Recorder::rebase_to_end`] between phases so each
//! phase's private `t0`-anchored timeline lands after the previous one.

use std::cell::RefCell;

use super::span::{ObsTrace, SpanId, SpanKind, Track};

/// Open-episode bookkeeping handed to the owner.
#[derive(Debug, Clone, Copy)]
pub struct Episode {
    /// The episode's root span.
    pub root: SpanId,
    /// Absolute ns at which the episode opened (offset at open time).
    pub base_ns: u64,
}

/// Thread-local trace builder; see the module docs for the protocol.
#[derive(Debug, Default)]
pub struct Recorder {
    /// The trace under construction.
    pub trace: ObsTrace,
    /// Offset added to every emitted span (phase stacking).
    pub offset_ns: u64,
    episode: Option<Episode>,
    /// End of the last measure window — windows never overlap.
    frontier_ns: u64,
}

impl Recorder {
    /// Open (or join) the current episode. Returns the episode and whether
    /// the caller is the owner (responsible for closing it).
    pub fn open_episode(&mut self, name: &str) -> (Episode, bool) {
        if let Some(ep) = self.episode {
            return (ep, false);
        }
        let base = self.offset_ns;
        let root = self
            .trace
            .push(None, name.to_string(), SpanKind::Root, Track::Episode, base, base);
        let ep = Episode { root, base_ns: base };
        self.episode = Some(ep);
        self.frontier_ns = base;
        (ep, true)
    }

    /// Emit one span at `offset + [start, end)`, parented to the episode
    /// root (or free-standing when no episode is open).
    pub fn span(
        &mut self,
        name: String,
        kind: SpanKind,
        track: Track,
        start_ns: u64,
        end_ns: u64,
    ) -> SpanId {
        let parent = self.episode.map(|e| e.root);
        self.trace.push(
            parent,
            name,
            kind,
            track,
            self.offset_ns + start_ns,
            self.offset_ns + end_ns,
        )
    }

    /// Append a measure (latency) window `offset + [start, end)` — one
    /// attribution denominator. If the proposed window would overlap the
    /// previous one it is shifted right, preserving its width, so the
    /// windows always partition cleanly (their widths are what must sum to
    /// the composite latency).
    pub fn measure(&mut self, name: &str, start_ns: u64, end_ns: u64) -> SpanId {
        let width = end_ns - start_ns;
        let start = (self.offset_ns + start_ns).max(self.frontier_ns);
        let end = start + width;
        self.frontier_ns = end;
        let parent = self.episode.map(|e| e.root);
        self.trace
            .push(parent, name.to_string(), SpanKind::Measure, Track::Episode, start, end)
    }

    /// Close the episode: size the root over everything recorded since it
    /// opened, and re-parent each root-child contained in a measure window
    /// to that window (building the root → measure → span hierarchy).
    pub fn close_episode(&mut self) {
        let Some(ep) = self.episode.take() else {
            return;
        };
        let root_end = self
            .trace
            .spans
            .iter()
            .skip(ep.root as usize)
            .map(|s| s.end_ns)
            .max()
            .unwrap_or(ep.base_ns);
        self.trace.set_interval(ep.root, ep.base_ns, root_end);
        let measures: Vec<(SpanId, u64, u64)> = self
            .trace
            .spans
            .iter()
            .filter(|s| s.kind == SpanKind::Measure && s.parent == Some(ep.root))
            .map(|s| (s.id, s.start_ns, s.end_ns))
            .collect();
        for s in &mut self.trace.spans {
            if s.parent != Some(ep.root) || s.kind == SpanKind::Measure {
                continue;
            }
            if let Some(&(m, _, _)) = measures
                .iter()
                .find(|&&(_, ms, me)| s.start_ns >= ms && s.end_ns <= me)
            {
                s.parent = Some(m);
            }
        }
        self.offset_ns = self.trace.max_end_ns();
    }

    /// Advance the emission offset past everything recorded so far: the
    /// next phase's `t0`-anchored spans stack strictly after this phase's
    /// (sequential all-reduce composing reduce-scatter then all-gather).
    pub fn rebase_to_end(&mut self) {
        self.offset_ns = self.offset_ns.max(self.trace.max_end_ns());
    }
}

thread_local! {
    static RECORDER: RefCell<Option<Recorder>> = const { RefCell::new(None) };
}

/// Install a fresh recorder on this thread (replacing any active one).
pub fn start() {
    RECORDER.with(|r| *r.borrow_mut() = Some(Recorder::default()));
}

/// True when a recorder is installed — THE zero-cost gate: instrumented
/// layers check this once per episode and skip all span work when false.
pub fn active() -> bool {
    RECORDER.with(|r| r.borrow().is_some())
}

/// Remove the recorder and return its trace (None when none is active).
pub fn finish() -> Option<ObsTrace> {
    RECORDER.with(|r| r.borrow_mut().take()).map(|rec| rec.trace)
}

/// Run `f` against the active recorder (no-op returning None when
/// inactive). Never nest `with` calls — the recorder is RefCell-borrowed
/// for the duration of `f`.
pub fn with<R>(f: impl FnOnce(&mut Recorder) -> R) -> Option<R> {
    RECORDER.with(|r| r.borrow_mut().as_mut().map(f))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inactive_is_a_noop() {
        assert!(!active());
        assert!(with(|_| ()).is_none());
        assert!(finish().is_none());
    }

    #[test]
    fn episode_open_join_close() {
        start();
        let (ep, owned) = with(|r| r.open_episode("collective:allreduce")).unwrap();
        assert!(owned);
        let (ep2, owned2) = with(|r| r.open_episode("collective:reduce-scatter")).unwrap();
        assert!(!owned2, "second open joins, never owns");
        assert_eq!(ep.root, ep2.root);
        with(|r| {
            r.span(
                "copy".into(),
                SpanKind::Copy,
                Track::Dma {
                    node: 0,
                    gpu: 0,
                    engine: 0,
                },
                100,
                300,
            );
            r.measure("measure", 50, 400);
            r.close_episode();
        });
        let t = finish().unwrap();
        assert!(!active());
        // Root sized over everything; copy re-parented under the measure.
        let root = &t.spans[ep.root as usize];
        assert_eq!((root.start_ns, root.end_ns), (0, 450));
        let copy = t.spans.iter().find(|s| s.kind == SpanKind::Copy).unwrap();
        let m = t.spans.iter().find(|s| s.kind == SpanKind::Measure).unwrap();
        assert_eq!(copy.parent, Some(m.id));
        assert_eq!(m.parent, Some(root.id));
    }

    #[test]
    fn measures_never_overlap_and_keep_width() {
        start();
        with(|r| {
            r.open_episode("e");
            r.measure("a", 0, 100);
            // Proposed [60, 160) overlaps [0, 100) → shifted to [100, 200).
            r.measure("b", 60, 160);
            r.close_episode();
        });
        let t = finish().unwrap();
        let ms: Vec<_> = t
            .spans
            .iter()
            .filter(|s| s.kind == SpanKind::Measure)
            .collect();
        assert_eq!(ms.len(), 2);
        assert_eq!((ms[0].start_ns, ms[0].end_ns), (0, 100));
        assert_eq!((ms[1].start_ns, ms[1].end_ns), (100, 200));
    }

    #[test]
    fn rebase_stacks_phases() {
        start();
        with(|r| {
            r.open_episode("ar");
            r.span(
                "rs-copy".into(),
                SpanKind::Copy,
                Track::Dma {
                    node: 0,
                    gpu: 0,
                    engine: 0,
                },
                0,
                500,
            );
            r.measure("rs", 0, 500);
            r.rebase_to_end();
            // Phase 2 re-anchors at its own t0=0; lands at 500 absolute.
            r.span(
                "ag-copy".into(),
                SpanKind::Copy,
                Track::Dma {
                    node: 0,
                    gpu: 0,
                    engine: 0,
                },
                0,
                300,
            );
            r.measure("ag", 0, 300);
            r.close_episode();
        });
        let t = finish().unwrap();
        let ag = t.spans.iter().find(|s| s.name == "ag-copy").unwrap();
        assert_eq!((ag.start_ns, ag.end_ns), (500, 800));
        let widths: u64 = t
            .spans
            .iter()
            .filter(|s| s.kind == SpanKind::Measure)
            .map(|s| s.dur_ns())
            .sum();
        assert_eq!(widths, 800);
    }
}
