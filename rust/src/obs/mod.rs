//! Cross-layer tracing and critical-path attribution.
//!
//! One span hierarchy threads through the whole stack — serving request →
//! engine step → cluster collective → per-phase legs (intra DMA rounds, CU
//! reductions, NIC exchanges, fused AG chunks) → the single-node
//! [`crate::sim::trace`] DMA phases:
//!
//! - [`span`] — the span/track model (stable parent/child IDs, one track
//!   per simulated resource: DMA engines, engine wire, CUs, NIC ports,
//!   hosts, serving GPU/comm/PCIe).
//! - [`record`] — the thread-local scoped recorder and the episode
//!   open/join/close protocol. Zero-cost when inactive: instrumented
//!   layers check [`record::active`] once per episode.
//! - [`perfetto`] — Chrome `trace_event` JSON writer
//!   (Perfetto / `chrome://tracing` loadable, `dma-latte trace`).
//! - [`critical`] — interval-partition attribution whose nine components
//!   (control / schedule / copy / sync / cu-reduce / nic / exposed-comm /
//!   gemm / idle) provably sum to the measured end-to-end latency.
//!
//! Typical use (what `dma-latte trace` does):
//!
//! ```
//! use dma_latte::cluster::{self, ClusterTopology};
//! use dma_latte::collectives::CollectiveKind;
//! use dma_latte::obs::{critical, perfetto, record};
//!
//! let cluster_topo = ClusterTopology::mi300x(2);
//! let choice = cluster::select_cluster(CollectiveKind::AllGather, &cluster_topo, 16 << 10);
//! record::start();
//! let res = cluster::run_hier(
//!     CollectiveKind::AllGather,
//!     choice,
//!     &cluster_topo,
//!     16 << 10,
//!     &cluster::HierRunOptions { trace: true, ..Default::default() },
//! );
//! let trace = record::finish().unwrap();
//! let attr = critical::attribute(&trace);
//! assert_eq!(attr.total(), res.latency_ns);
//! let json = perfetto::write_chrome_trace(&trace);
//! assert!(json.contains("traceEvents"));
//! ```

pub mod critical;
pub mod perfetto;
pub mod record;
pub mod span;

pub use critical::{attribute, Attribution, Component, COMPONENTS};
pub use perfetto::write_chrome_trace;
pub use span::{ObsTrace, Span, SpanId, SpanKind, Track};

use crate::sim::trace::{Phase, Trace};

/// Lift one per-node DES trace ([`crate::sim::trace::Trace`]) into the
/// recorder: Fig. 6 phase spans land on the node's per-resource tracks
/// (host command creation on the rank host, engine wake/copy/fence on the
/// engine track, host observes on the node host, wire sub-spans on the
/// exclusive wire track). Sim timestamps are already absolute within the
/// episode timeline, so they pass through the recorder's offset untouched.
pub fn lift_sim_trace(rec: &mut record::Recorder, node: u8, trace: &Trace) {
    for s in &trace.spans {
        let (kind, track) = match (s.phase, s.engine) {
            (Phase::Control, Some(e)) => (SpanKind::Control, Track::RankHost { node, gpu: e.gpu }),
            (Phase::Control, None) => (SpanKind::Control, Track::NodeHost { node }),
            (Phase::Schedule, Some(e)) => (
                SpanKind::Schedule,
                Track::Dma {
                    node,
                    gpu: e.gpu,
                    engine: e.idx,
                },
            ),
            (Phase::Schedule, None) => (SpanKind::Schedule, Track::NodeHost { node }),
            (Phase::Copy, Some(e)) => (
                SpanKind::Copy,
                Track::Dma {
                    node,
                    gpu: e.gpu,
                    engine: e.idx,
                },
            ),
            (Phase::Copy, None) => (SpanKind::Copy, Track::NodeHost { node }),
            (Phase::Sync, Some(e)) => (
                SpanKind::Sync,
                Track::Dma {
                    node,
                    gpu: e.gpu,
                    engine: e.idx,
                },
            ),
            (Phase::Sync, None) => (SpanKind::Sync, Track::NodeHost { node }),
        };
        let name = match kind {
            SpanKind::Copy => format!("copy#{}", s.cmd_seq),
            _ => kind.name().to_string(),
        };
        rec.span(name, kind, track, s.start, s.end);
    }
    for w in &trace.wire {
        rec.span(
            format!("wire#{}", w.cmd_seq),
            SpanKind::Wire,
            Track::DmaWire {
                node,
                gpu: w.engine.gpu,
                engine: w.engine.idx,
            },
            w.start,
            w.end,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::EngineId;

    #[test]
    fn lift_maps_phases_to_tracks() {
        let mut t = Trace::default();
        let e = EngineId { gpu: 3, idx: 1 };
        t.record(Some(e), 0, Phase::Control, 0, 10);
        t.record(Some(e), 0, Phase::Schedule, 10, 12);
        t.record(Some(e), 0, Phase::Copy, 12, 40);
        t.record(None, 0, Phase::Sync, 40, 45);
        t.record_wire(e, 0, 20, 40);
        let mut rec = record::Recorder::default();
        rec.offset_ns = 100;
        lift_sim_trace(&mut rec, 2, &t);
        let spans = &rec.trace.spans;
        assert_eq!(spans.len(), 5);
        assert_eq!(spans[0].track, Track::RankHost { node: 2, gpu: 3 });
        assert_eq!(
            spans[2].track,
            Track::Dma {
                node: 2,
                gpu: 3,
                engine: 1
            }
        );
        assert_eq!(spans[3].track, Track::NodeHost { node: 2 });
        assert_eq!(
            spans[4].track,
            Track::DmaWire {
                node: 2,
                gpu: 3,
                engine: 1
            }
        );
        // Offset applied to lifted spans.
        assert_eq!((spans[0].start_ns, spans[0].end_ns), (100, 110));
        assert_eq!(spans[4].kind, SpanKind::Wire);
    }
}
