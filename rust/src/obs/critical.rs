//! Critical-path attribution: partition every measured latency window into
//! nine components that provably sum to the end-to-end latency.
//!
//! The algorithm is an interval-partition sweep, not a span-duration sum:
//! spans legitimately overlap (a Copy span covers its Wire sub-span; a
//! pipelined NIC leg overlaps the intra rounds), so summing durations
//! over-counts. Instead, every elementary time segment of each
//! [`SpanKind::Measure`](super::span::SpanKind::Measure) window is
//! assigned to exactly one component — the highest-priority component
//! with a span active over that segment, or [`Component::Idle`] when none
//! is. A partition of the window sums to the window width by
//! construction (integer ns, no rounding), which
//! [`attribute`] asserts.

use super::span::{ObsTrace, SpanKind};

/// Attribution components, in display order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Component {
    /// CPU command creation / framework API time.
    Control,
    /// Doorbell → engine wake/fetch.
    Schedule,
    /// DMA decode/setup/data movement (incl. the PCIe fetch track).
    Copy,
    /// Completion atomics + host observe.
    Sync,
    /// CU reduction passes.
    CuReduce,
    /// NIC port occupancy + message flight.
    Nic,
    /// Collective time the serving engine could not hide.
    ExposedComm,
    /// Serving-step GEMM compute.
    Gemm,
    /// No component active (trigger gaps, barrier waits).
    Idle,
}

/// All components in display order ([`Attribution::parts`] indexing).
pub const COMPONENTS: [Component; 9] = [
    Component::Control,
    Component::Schedule,
    Component::Copy,
    Component::Sync,
    Component::CuReduce,
    Component::Nic,
    Component::ExposedComm,
    Component::Gemm,
    Component::Idle,
];

impl Component {
    /// Short stable name (table headers, CSV columns, CI greps).
    pub fn name(self) -> &'static str {
        match self {
            Component::Control => "control",
            Component::Schedule => "schedule",
            Component::Copy => "copy",
            Component::Sync => "sync",
            Component::CuReduce => "cu-reduce",
            Component::Nic => "nic",
            Component::ExposedComm => "exposed-comm",
            Component::Gemm => "gemm",
            Component::Idle => "idle",
        }
    }

    /// Index into [`Attribution::parts`].
    pub fn index(self) -> usize {
        COMPONENTS.iter().position(|&c| c == self).unwrap()
    }

    /// Sweep priority (lower rank wins a contended segment): compute
    /// first — a segment where the GEMM runs is compute-bound no matter
    /// what else overlaps — then data movement, then reduction/NIC, then
    /// control-plane phases.
    fn rank(self) -> u8 {
        match self {
            Component::Gemm => 0,
            Component::ExposedComm => 1,
            Component::Copy => 2,
            Component::CuReduce => 3,
            Component::Nic => 4,
            Component::Schedule => 5,
            Component::Sync => 6,
            Component::Control => 7,
            Component::Idle => 8,
        }
    }
}

/// Component a span kind contributes to (None for structural kinds —
/// roots, measures, requests and rounds shape the tree, not the sweep).
pub fn component_of(kind: SpanKind) -> Option<Component> {
    match kind {
        SpanKind::Control | SpanKind::HostApi => Some(Component::Control),
        SpanKind::Schedule => Some(Component::Schedule),
        SpanKind::Copy | SpanKind::Wire => Some(Component::Copy),
        SpanKind::Sync => Some(Component::Sync),
        SpanKind::CuReduce => Some(Component::CuReduce),
        SpanKind::Nic | SpanKind::NicFlight => Some(Component::Nic),
        SpanKind::Gemm => Some(Component::Gemm),
        SpanKind::ExposedComm => Some(Component::ExposedComm),
        SpanKind::Root | SpanKind::Measure | SpanKind::Request | SpanKind::Round => None,
    }
}

/// Result of [`attribute`]: per-component ns over the measured windows.
#[derive(Debug, Clone, Default)]
pub struct Attribution {
    /// Per-component ns in [`COMPONENTS`] order.
    pub parts: [u64; 9],
    /// Total measured window width — equals `parts.sum()` exactly.
    pub window_ns: u64,
}

impl Attribution {
    /// Sum of all components (== `window_ns` == end-to-end latency).
    pub fn total(&self) -> u64 {
        self.parts.iter().sum()
    }

    /// Component value by name-safe accessor.
    pub fn get(&self, c: Component) -> u64 {
        self.parts[c.index()]
    }

    /// Render the attribution as an aligned two-column table with
    /// percentages of the measured window.
    pub fn render(&self) -> String {
        let mut t = crate::util::table::Table::new(vec!["component", "ns", "pct"]);
        for c in COMPONENTS {
            let v = self.get(c);
            let pct = if self.window_ns == 0 {
                0.0
            } else {
                100.0 * v as f64 / self.window_ns as f64
            };
            t.row(vec![c.name().to_string(), v.to_string(), format!("{pct:.1}%")]);
        }
        t.row(vec![
            "total".to_string(),
            self.total().to_string(),
            "100.0%".to_string(),
        ]);
        t.render()
    }
}

/// Attribute every measure window of `trace`; see the module docs.
///
/// Panics if the measure windows overlap (the recorder's frontier makes
/// that impossible for recorder-built traces) or if the partition does not
/// sum to the window width (internal invariant).
pub fn attribute(trace: &ObsTrace) -> Attribution {
    let mut windows: Vec<(u64, u64)> = trace
        .spans
        .iter()
        .filter(|s| s.kind == SpanKind::Measure)
        .map(|s| (s.start_ns, s.end_ns))
        .collect();
    windows.sort_unstable();
    for w in windows.windows(2) {
        assert!(
            w[1].0 >= w[0].1,
            "measure windows overlap: {:?} vs {:?}",
            w[0],
            w[1]
        );
    }
    let mut out = Attribution::default();
    for (ws, we) in windows {
        sweep_window(trace, ws, we, &mut out.parts);
        out.window_ns += we - ws;
    }
    assert_eq!(
        out.total(),
        out.window_ns,
        "attribution must partition the measured windows exactly"
    );
    out
}

/// Sweep one window: event list over clipped component spans, each
/// elementary segment charged to the highest-priority active component.
fn sweep_window(trace: &ObsTrace, ws: u64, we: u64, parts: &mut [u64; 9]) {
    // (time, component display index, +1/-1), clipped to [ws, we].
    let mut evs: Vec<(u64, usize, i64)> = Vec::new();
    for s in &trace.spans {
        let Some(c) = component_of(s.kind) else {
            continue;
        };
        let (a, b) = (s.start_ns.max(ws), s.end_ns.min(we));
        if a < b {
            evs.push((a, c.index(), 1));
            evs.push((b, c.index(), -1));
        }
    }
    evs.sort_unstable();
    let mut counts = [0i64; 9];
    let mut t = ws;
    let mut i = 0;
    while t < we {
        while i < evs.len() && evs[i].0 <= t {
            counts[evs[i].1] += evs[i].2;
            i += 1;
        }
        let next = if i < evs.len() { evs[i].0.min(we) } else { we };
        let winner = COMPONENTS
            .iter()
            .copied()
            .filter(|c| *c != Component::Idle && counts[c.index()] > 0)
            .min_by_key(|c| c.rank())
            .unwrap_or(Component::Idle);
        parts[winner.index()] += next - t;
        t = next;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::Track;

    fn dma() -> Track {
        Track::Dma {
            node: 0,
            gpu: 0,
            engine: 0,
        }
    }

    #[test]
    fn partition_sums_to_window_with_gaps_and_overlap() {
        let mut t = ObsTrace::default();
        t.push(None, "m".into(), SpanKind::Measure, Track::Episode, 0, 100);
        // Copy [10,40) overlapping Sync [30,60); gap [60,80); Control [80,90).
        t.push(None, "c".into(), SpanKind::Copy, dma(), 10, 40);
        t.push(None, "s".into(), SpanKind::Sync, dma(), 30, 60);
        t.push(None, "ctl".into(), SpanKind::Control, Track::NodeHost { node: 0 }, 80, 90);
        let a = attribute(&t);
        assert_eq!(a.total(), 100);
        assert_eq!(a.get(Component::Copy), 30); // [10,40) — copy outranks sync
        assert_eq!(a.get(Component::Sync), 20); // [40,60)
        assert_eq!(a.get(Component::Control), 10); // [80,90)
        assert_eq!(a.get(Component::Idle), 40); // [0,10) + [60,80) + [90,100)
    }

    #[test]
    fn spans_outside_the_window_are_clipped() {
        let mut t = ObsTrace::default();
        t.push(None, "m".into(), SpanKind::Measure, Track::Episode, 50, 150);
        t.push(None, "c".into(), SpanKind::Copy, dma(), 0, 100);
        let a = attribute(&t);
        assert_eq!(a.get(Component::Copy), 50);
        assert_eq!(a.get(Component::Idle), 50);
        assert_eq!(a.total(), 100);
    }

    #[test]
    fn two_windows_accumulate() {
        let mut t = ObsTrace::default();
        t.push(None, "m1".into(), SpanKind::Measure, Track::Episode, 0, 50);
        t.push(None, "m2".into(), SpanKind::Measure, Track::Episode, 50, 120);
        t.push(None, "c".into(), SpanKind::Copy, dma(), 0, 120);
        let a = attribute(&t);
        assert_eq!(a.get(Component::Copy), 120);
        assert_eq!(a.total(), 120);
        assert_eq!(a.window_ns, 120);
    }

    #[test]
    #[should_panic(expected = "overlap")]
    fn overlapping_windows_rejected() {
        let mut t = ObsTrace::default();
        t.push(None, "m1".into(), SpanKind::Measure, Track::Episode, 0, 60);
        t.push(None, "m2".into(), SpanKind::Measure, Track::Episode, 40, 100);
        attribute(&t);
    }

    #[test]
    fn gemm_outranks_everything() {
        let mut t = ObsTrace::default();
        t.push(None, "m".into(), SpanKind::Measure, Track::Episode, 0, 10);
        t.push(None, "g".into(), SpanKind::Gemm, Track::Gpu, 0, 10);
        t.push(None, "x".into(), SpanKind::ExposedComm, Track::Comm, 0, 10);
        t.push(None, "c".into(), SpanKind::Copy, dma(), 0, 10);
        let a = attribute(&t);
        assert_eq!(a.get(Component::Gemm), 10);
        assert_eq!(a.total(), 10);
    }

    #[test]
    fn render_lists_all_components() {
        let mut t = ObsTrace::default();
        t.push(None, "m".into(), SpanKind::Measure, Track::Episode, 0, 10);
        let a = attribute(&t);
        let s = a.render();
        for c in COMPONENTS {
            assert!(s.contains(c.name()), "missing {}", c.name());
        }
        assert!(s.contains("total"));
    }
}
