//! [`ModelBackend`] implementation over the PJRT executor: owns the paged
//! KV pool (host-side mirror of GPU HBM) and per-slot block tables, so the
//! threaded server can serve real batched requests through the compiled
//! model.

use crate::coordinator::server::ModelBackend;

use super::error::Result;

use super::executor::Executor;

/// PJRT-backed model with a paged KV pool.
pub struct PjrtBackend {
    exe: Executor,
    /// Paged pool `[NB, BS, L, 2, KVH, D]` flattened.
    pool: Vec<f32>,
    /// Per-batch-slot block tables `[B, MB]`.
    tables: Vec<i32>,
    /// Per-slot context length.
    pos: Vec<i32>,
    /// Next free physical block (simple bump allocator per serve run).
    next_block: usize,
    kv_row: usize,
    block_row: usize,
}

impl PjrtBackend {
    /// Wrap a loaded executor.
    pub fn new(exe: Executor) -> Self {
        let d = &exe.meta.dims;
        let kv_row = d.layers * 2 * d.kv_heads * d.head_dim;
        let block_row = d.block_size * kv_row;
        let pool = vec![0f32; d.num_blocks * block_row];
        let tables = vec![0i32; d.batch * d.max_blocks];
        let pos = vec![0i32; d.batch];
        PjrtBackend {
            exe,
            pool,
            tables,
            pos,
            next_block: 0,
            kv_row,
            block_row,
        }
    }

    /// Load artifacts and build the backend.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(Self::new(Executor::load(dir)?))
    }

    /// Model dims.
    pub fn dims(&self) -> &super::meta::ModelDims {
        &self.exe.meta.dims
    }

    /// Reset pool/tables between serve runs.
    pub fn reset(&mut self) {
        self.pool.fill(0.0);
        self.tables.fill(0);
        self.pos.fill(0);
        self.next_block = 0;
    }

    /// Write one token's KV row into slot `slot` at position `p`,
    /// allocating blocks lazily.
    fn write_kv(&mut self, slot: usize, p: usize, kv_row: &[f32]) {
        let d = self.exe.meta.dims.clone();
        let logical = p / d.block_size;
        let within = p % d.block_size;
        let tptr = slot * d.max_blocks + logical;
        if within == 0 {
            // Allocate a fresh physical block for this logical block.
            self.tables[tptr] = (self.next_block % d.num_blocks) as i32;
            self.next_block += 1;
        }
        let phys = self.tables[tptr] as usize;
        let base = phys * self.block_row + within * self.kv_row;
        self.pool[base..base + self.kv_row].copy_from_slice(kv_row);
    }

    /// Prefill a prompt into slot `slot`; returns the first token.
    fn prefill_into_slot(&mut self, slot: usize, prompt: &[u32]) -> i32 {
        let d = self.exe.meta.dims.clone();
        let mut toks: Vec<i32> = prompt
            .iter()
            .map(|&t| (t as usize % d.vocab) as i32)
            .collect();
        toks.resize(d.prefill_len, 0);
        let (logits, kv) = self.exe.prefill(&toks).expect("prefill failed");
        // kv: [T, L, 2, KVH, D] — page into the pool.
        for p in 0..d.prefill_len {
            let row = &kv[p * self.kv_row..(p + 1) * self.kv_row];
            let row = row.to_vec();
            self.write_kv(slot, p, &row);
        }
        self.pos[slot] = d.prefill_len as i32;
        Executor::argmax(&logits)
    }
}

impl ModelBackend for PjrtBackend {
    fn prefill(&mut self, prompt: &[u32]) -> u32 {
        // Slot assignment: round-robin over the artifact batch width.
        let slot = 0;
        self.prefill_into_slot(slot, prompt) as u32
    }

    fn decode(&mut self, last_tokens: &[u32]) -> Vec<u32> {
        let d = self.exe.meta.dims.clone();
        let b = d.batch;
        // The compiled step has fixed batch B: tile/truncate the live batch.
        let mut token = vec![0i32; b];
        for (i, &t) in last_tokens.iter().take(b).enumerate() {
            token[i] = (t as usize % d.vocab) as i32;
        }
        let pos = self.pos.clone();
        let (logits, new_kv) = self
            .exe
            .decode_step(&token, &pos, &self.pool, &self.tables)
            .expect("decode failed");
        // Write each slot's new KV row and advance.
        let kv_per_seq = self.kv_row;
        for slot in 0..b.min(last_tokens.len()) {
            let row = new_kv[slot * kv_per_seq..(slot + 1) * kv_per_seq].to_vec();
            let p = self.pos[slot] as usize;
            if p < d.max_blocks * d.block_size {
                self.write_kv(slot, p, &row);
                self.pos[slot] += 1;
            }
        }
        (0..last_tokens.len())
            .map(|i| {
                let slot = i.min(b - 1);
                Executor::argmax(&logits[slot * d.vocab..(slot + 1) * d.vocab]) as u32
            })
            .collect()
    }

    fn kv_bytes_per_token(&self) -> u64 {
        (self.kv_row * 4) as u64
    }
}
