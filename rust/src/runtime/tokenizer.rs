//! Toy byte-level tokenizer for the real serving path: deterministic,
//! reversible, vocabulary-bounded. Serving benchmarks use synthetic token
//! streams; this gives the end-to-end example a real text→tokens→text
//! loop without shipping a BPE model.
//!
//! Scheme: bytes map to ids 0..256; frequent ASCII bigrams get merged ids
//! 256..256+N via a fixed merge table (a miniature, deterministic "BPE").

/// Fixed bigram merge table (most common English bigrams).
const MERGES: &[&[u8; 2]] = &[
    b"th", b"he", b"in", b"er", b"an", b"re", b"on", b"at", b"en", b"nd",
    b"ti", b"es", b"or", b"te", b"of", b"ed", b"is", b"it", b"al", b"ar",
    b"st", b"to", b"nt", b"ng", b"se", b"ha", b"as", b"ou", b"io", b"le",
];

/// Byte-level tokenizer with fixed bigram merges.
#[derive(Debug, Default)]
pub struct Tokenizer;

impl Tokenizer {
    /// Vocabulary size (bytes + merges).
    pub fn vocab(&self) -> u32 {
        256 + MERGES.len() as u32
    }

    /// Encode text to token ids (greedy left-to-right bigram merge).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let b = text.as_bytes();
        let mut out = Vec::with_capacity(b.len());
        let mut i = 0;
        while i < b.len() {
            if i + 1 < b.len() {
                if let Some(m) = MERGES
                    .iter()
                    .position(|mm| mm[0] == b[i] && mm[1] == b[i + 1])
                {
                    out.push(256 + m as u32);
                    i += 2;
                    continue;
                }
            }
            out.push(b[i] as u32);
            i += 1;
        }
        out
    }

    /// Decode token ids back to text (lossy only for invalid UTF-8).
    pub fn decode(&self, tokens: &[u32]) -> String {
        let mut bytes = Vec::with_capacity(tokens.len() * 2);
        for &t in tokens {
            if t < 256 {
                bytes.push(t as u8);
            } else if let Some(m) = MERGES.get((t - 256) as usize) {
                bytes.extend_from_slice(&m[..]);
            }
            // Unknown ids (model samples beyond vocab) are dropped.
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let tk = Tokenizer;
        for s in [
            "the rain in spain",
            "DMA engines overlap copies",
            "hello, world! 123",
        ] {
            assert_eq!(tk.decode(&tk.encode(s)), s);
        }
    }

    #[test]
    fn merges_compress() {
        let tk = Tokenizer;
        let toks = tk.encode("the");
        // "th" merges, "e" stays: 2 tokens, not 3.
        assert_eq!(toks.len(), 2);
        assert!(toks[0] >= 256);
    }

    #[test]
    fn roundtrip_utf8() {
        let tk = Tokenizer;
        let s = "héllo ≥ wörld";
        assert_eq!(tk.decode(&tk.encode(s)), s);
    }

    #[test]
    fn unknown_ids_dropped() {
        let tk = Tokenizer;
        assert_eq!(tk.decode(&[72, 105, 9999]), "Hi");
    }

    #[test]
    fn vocab_bound() {
        let tk = Tokenizer;
        assert!(tk.encode("any text at all").iter().all(|&t| t < tk.vocab()));
    }
}
