//! Model executor handle for the AOT-compiled HLO artifacts.
//!
//! The real implementation compiles the artifacts with a PJRT CPU client
//! (`xla::HloModuleProto::from_text_file` → compile → execute, parameter
//! buffers uploaded once). The `xla` bindings and `anyhow` are not in the
//! offline vendor set, so this build ships an **offline stub**: all of the
//! artifact/metadata/parameter plumbing ([`super::meta`], [`super::params`])
//! stays real and tested, while [`Executor::load`] reports the missing
//! backend instead of compiling. The API surface matches the PJRT version
//! exactly, so [`super::backend::PjrtBackend`] and the serving stack compile
//! and the swap back to a vendored `xla` is a one-file change (see the seed
//! commit for the original implementation).

use super::error::Result;
use super::meta::ArtifactMeta;

/// Compiled model runtime.
///
/// Offline build: cannot be constructed ([`Executor::load`] always errors),
/// but carries the full artifact metadata type so downstream code
/// type-checks against the real interface.
#[non_exhaustive]
pub struct Executor {
    pub meta: ArtifactMeta,
}

impl Executor {
    /// Load artifacts from `dir`, regenerate the weights, upload them.
    ///
    /// Offline stub: parses and validates the artifact metadata (so a bad
    /// artifacts directory is still reported precisely), then reports that
    /// the PJRT backend is unavailable.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let meta = ArtifactMeta::load(&dir)?;
        Err(crate::rt_error!(
            "PJRT backend not available in the offline build (artifacts at {} parsed OK: \
             {} params; vendor the `xla` bindings to execute)",
            dir.as_ref().display(),
            meta.params.len()
        ))
    }

    /// Decode step: `token[B]`, `pos[B]`, `pool`, `block_tables[B,MB]` →
    /// (logits `[B,V]`, new_kv `[B,L,2,KVH,D]`).
    pub fn decode_step(
        &self,
        _token: &[i32],
        _pos: &[i32],
        _pool: &[f32],
        _block_tables: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        unreachable!("offline Executor cannot be constructed")
    }

    /// Prefill: `tokens[1,T]` → (logits `[1,V]`, kv `[T,L,2,KVH,D]`).
    pub fn prefill(&self, _tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>)> {
        unreachable!("offline Executor cannot be constructed")
    }

    /// Pallas KV gather: `pool[NB,256]`, `idx[MB]` → `[MB,256]`.
    pub fn kv_gather(&self, _pool: &[f32], _idx: &[i32]) -> Result<Vec<f32>> {
        unreachable!("offline Executor cannot be constructed")
    }

    /// Argmax over a logits row (greedy sampling).
    pub fn argmax(logits: &[f32]) -> i32 {
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_greedy() {
        assert_eq!(Executor::argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(Executor::argmax(&[]), 0);
    }

    #[test]
    fn load_reports_missing_artifacts() {
        let e = Executor::load("/nonexistent/artifacts").unwrap_err();
        assert!(e.to_string().contains("meta.json"), "{e}");
    }
}
