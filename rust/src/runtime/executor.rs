//! PJRT executor: compile the HLO artifacts once, keep parameter buffers
//! device-resident, execute per step with only the small dynamic inputs
//! re-uploaded (the L3 hot-path discipline: no Python, no re-compilation,
//! no weight re-upload).

use anyhow::{anyhow, Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::meta::ArtifactMeta;
use super::params::gen_tensor;

/// Compiled model runtime.
pub struct Executor {
    pub client: PjRtClient,
    pub meta: ArtifactMeta,
    decode: PjRtLoadedExecutable,
    prefill: PjRtLoadedExecutable,
    kv_gather: PjRtLoadedExecutable,
    /// Device-resident parameter buffers (uploaded once).
    param_bufs: Vec<PjRtBuffer>,
}

fn compile(client: &PjRtClient, path: &std::path::Path) -> Result<PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
        .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
}

impl Executor {
    /// Load artifacts from `dir`, regenerate the weights, upload them.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let meta = ArtifactMeta::load(&dir).context("artifact metadata")?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let decode = compile(&client, &meta.hlo_path("decode_step"))?;
        let prefill = compile(&client, &meta.hlo_path("prefill"))?;
        let kv_gather = compile(&client, &meta.hlo_path("kv_gather"))?;
        let seed = meta.dims.param_seed;
        let mut param_bufs = Vec::with_capacity(meta.params.len());
        for p in &meta.params {
            let host = gen_tensor(seed, p.offset, p.numel(), p.scale);
            let buf = client
                .buffer_from_host_buffer::<f32>(&host, &p.shape, None)
                .map_err(|e| anyhow!("uploading {}: {e:?}", p.name))?;
            param_bufs.push(buf);
        }
        crate::log_info!(
            "executor ready: {} params ({:.1}M) on {}",
            meta.params.len(),
            meta.num_params() as f64 / 1e6,
            client.platform_name()
        );
        Ok(Executor {
            client,
            meta,
            decode,
            prefill,
            kv_gather,
            param_bufs,
        })
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, dims, None)
            .map_err(|e| anyhow!("upload f32: {e:?}"))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<i32>(data, dims, None)
            .map_err(|e| anyhow!("upload i32: {e:?}"))
    }

    fn run(
        &self,
        exe: &PjRtLoadedExecutable,
        extra: Vec<PjRtBuffer>,
        with_params: bool,
    ) -> Result<Vec<Literal>> {
        let mut args: Vec<&PjRtBuffer> = Vec::new();
        if with_params {
            args.extend(self.param_bufs.iter());
        }
        args.extend(extra.iter());
        let out = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output: {e:?}"))?;
        // Artifacts are lowered with return_tuple=True.
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    }

    /// Decode step: `token[B]`, `pos[B]`, `pool`, `block_tables[B,MB]` →
    /// (logits `[B,V]`, new_kv `[B,L,2,KVH,D]`).
    pub fn decode_step(
        &self,
        token: &[i32],
        pos: &[i32],
        pool: &[f32],
        block_tables: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let d = &self.meta.dims;
        anyhow::ensure!(token.len() == d.batch, "token batch mismatch");
        let pool_dims = [d.num_blocks, d.block_size, d.layers, 2, d.kv_heads, d.head_dim];
        let extra = vec![
            self.upload_i32(token, &[d.batch])?,
            self.upload_i32(pos, &[d.batch])?,
            self.upload_f32(pool, &pool_dims)?,
            self.upload_i32(block_tables, &[d.batch, d.max_blocks])?,
        ];
        let outs = self.run(&self.decode, extra, true)?;
        anyhow::ensure!(outs.len() == 2, "decode_step must return 2 outputs");
        Ok((
            outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            outs[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
        ))
    }

    /// Prefill: `tokens[1,T]` → (logits `[1,V]`, kv `[T,L,2,KVH,D]`).
    pub fn prefill(&self, tokens: &[i32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let d = &self.meta.dims;
        anyhow::ensure!(tokens.len() == d.prefill_len, "prefill length mismatch");
        let extra = vec![self.upload_i32(tokens, &[1, d.prefill_len])?];
        let outs = self.run(&self.prefill, extra, true)?;
        anyhow::ensure!(outs.len() == 2, "prefill must return 2 outputs");
        Ok((
            outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            outs[1].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
        ))
    }

    /// Pallas KV gather: `pool[NB,256]`, `idx[MB]` → `[MB,256]`.
    pub fn kv_gather(&self, pool: &[f32], idx: &[i32]) -> Result<Vec<f32>> {
        let d = &self.meta.dims;
        let extra = vec![
            self.upload_f32(pool, &[d.num_blocks, 256])?,
            self.upload_i32(idx, &[d.max_blocks])?,
        ];
        let outs = self.run(&self.kv_gather, extra, false)?;
        outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    /// Argmax over a logits row (greedy sampling).
    pub fn argmax(logits: &[f32]) -> i32 {
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }
}
