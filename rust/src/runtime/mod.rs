//! PJRT runtime: loads the AOT-compiled HLO artifacts (built once by
//! `make artifacts` from the JAX/Pallas layers) and executes them on the
//! request path. Python is never invoked at runtime.
//!
//! - [`meta`]: parses `artifacts/meta.json` / `golden.json`.
//! - [`params`]: regenerates the model weights counter-based (bit-identical
//!   to `python/compile/model.py`), avoiding a 220 MB params file.
//! - [`executor`]: PJRT CPU client — `HloModuleProto::from_text_file` →
//!   compile → execute, with parameter buffers uploaded once and reused.
//!   (Offline builds ship an API-identical stub; the `xla` bindings are not
//!   in the vendor set. See `executor.rs` module docs.)
//! - [`error`]: dependency-free `Result`/`Context` (`anyhow` stand-in).
//! - [`backend`]: [`crate::coordinator::server::ModelBackend`] over the
//!   compiled prefill/decode executables + a paged KV pool.

pub mod backend;
pub mod error;
pub mod executor;
pub mod meta;
pub mod params;
pub mod tokenizer;

pub use backend::PjrtBackend;
pub use error::{Result, RuntimeError};
pub use executor::Executor;
pub use meta::ArtifactMeta;
