//! Cross-language deterministic parameter generation.
//!
//! Mirrors `python/compile/model.py::counter_uniform`: value *i* of a
//! tensor at manifest offset *off* is derived from `splitmix64(seed + off
//! + i)`, mapped to a uniform in [-1, 1) and scaled. The AOT goldens carry
//! probe values to assert bit-identity across languages.

/// splitmix64 of a single counter (matches the numpy vectorized version).
pub fn splitmix64(x: u64) -> u64 {
    let x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Uniform f32 in [-1, 1) from counter `seed + offset + i`.
pub fn counter_uniform(seed: u64, offset: u64, i: u64) -> f32 {
    let bits = splitmix64(seed.wrapping_add(offset).wrapping_add(i));
    let u = (bits >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    (u * 2.0 - 1.0) as f32
}

/// Generate a full tensor: `scale == 0` means norm weights (all ones).
pub fn gen_tensor(seed: u64, offset: u64, numel: u64, scale: f32) -> Vec<f32> {
    if scale == 0.0 {
        return vec![1.0; numel as usize];
    }
    (0..numel)
        .map(|i| counter_uniform(seed, offset, i) * scale)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Cross-checked with the numpy implementation.
        assert_eq!(splitmix64(0), 0xE220A8397B1DCDAF);
        assert_eq!(splitmix64(1), 0x910A2DEC89025CC1);
    }

    #[test]
    fn uniform_bounded_and_deterministic() {
        for i in 0..1000 {
            let v = counter_uniform(42, 0, i);
            assert!((-1.0..1.0).contains(&v));
            assert_eq!(v, counter_uniform(42, 0, i));
        }
    }

    #[test]
    fn norm_weights_are_ones() {
        assert_eq!(gen_tensor(42, 0, 4, 0.0), vec![1.0; 4]);
    }

    #[test]
    fn scale_applies() {
        let t = gen_tensor(42, 100, 64, 0.5);
        assert!(t.iter().all(|v| v.abs() < 0.5));
        assert!(t.iter().any(|v| v.abs() > 0.05));
    }
}
