//! Artifact metadata: model config, parameter manifest and golden vectors
//! written by `python/compile/aot.py`.

use std::path::{Path, PathBuf};

use super::error::{Context, Result};
use crate::rt_error;
use crate::util::json::Json;

/// One parameter tensor's manifest entry.
#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub scale: f32,
    pub offset: u64,
}

impl ParamEntry {
    /// Element count.
    pub fn numel(&self) -> u64 {
        self.shape.iter().product::<usize>() as u64
    }
}

/// Model dimensions baked into the artifacts.
#[derive(Debug, Clone)]
pub struct ModelDims {
    pub vocab: usize,
    pub d_model: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub block_size: usize,
    pub max_blocks: usize,
    pub num_blocks: usize,
    pub batch: usize,
    pub prefill_len: usize,
    pub param_seed: u64,
}

/// Parsed `meta.json` (+ paths).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub dir: PathBuf,
    pub dims: ModelDims,
    pub params: Vec<ParamEntry>,
}

fn req_u64(j: &Json, k: &str) -> Result<u64> {
    j.get(k)
        .and_then(Json::u64)
        .ok_or_else(|| rt_error!("missing field {k}"))
}

impl ArtifactMeta {
    /// Load `meta.json` from the artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {}/meta.json — run `make artifacts`", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| rt_error!("meta.json: {e}"))?;
        let cfg = j.get("config").ok_or_else(|| rt_error!("missing config"))?;
        let dims = ModelDims {
            vocab: req_u64(cfg, "vocab")? as usize,
            d_model: req_u64(cfg, "d_model")? as usize,
            layers: req_u64(cfg, "layers")? as usize,
            heads: req_u64(cfg, "heads")? as usize,
            kv_heads: req_u64(cfg, "kv_heads")? as usize,
            head_dim: req_u64(cfg, "head_dim")? as usize,
            block_size: req_u64(cfg, "block_size")? as usize,
            max_blocks: req_u64(cfg, "max_blocks")? as usize,
            num_blocks: req_u64(cfg, "num_blocks")? as usize,
            batch: req_u64(cfg, "batch")? as usize,
            prefill_len: req_u64(cfg, "prefill_len")? as usize,
            param_seed: req_u64(cfg, "param_seed")?,
        };
        let params = j
            .get("param_manifest")
            .and_then(Json::arr)
            .ok_or_else(|| rt_error!("missing param_manifest"))?
            .iter()
            .map(|e| -> Result<ParamEntry> {
                Ok(ParamEntry {
                    name: e
                        .get("name")
                        .and_then(Json::str)
                        .ok_or_else(|| rt_error!("param name"))?
                        .to_string(),
                    shape: e
                        .get("shape")
                        .and_then(Json::arr)
                        .ok_or_else(|| rt_error!("param shape"))?
                        .iter()
                        .map(|d| d.u64().unwrap_or(0) as usize)
                        .collect(),
                    scale: e
                        .get("scale")
                        .and_then(Json::num)
                        .ok_or_else(|| rt_error!("param scale"))? as f32,
                    offset: req_u64(e, "offset")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ArtifactMeta { dir, dims, params })
    }

    /// Path of one HLO artifact.
    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Load the golden vectors.
    pub fn goldens(&self) -> Result<Json> {
        let text = std::fs::read_to_string(self.dir.join("golden.json"))?;
        Json::parse(&text).map_err(|e| rt_error!("golden.json: {e}"))
    }

    /// Total parameter count.
    pub fn num_params(&self) -> u64 {
        self.params.iter().map(ParamEntry::numel).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_meta_when_built() {
        let dir = artifacts_dir();
        if !dir.join("meta.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = ArtifactMeta::load(&dir).unwrap();
        assert_eq!(m.dims.vocab, 16384);
        assert_eq!(m.dims.layers, 10);
        // embed + 10×8 + ln_f + unembed
        assert_eq!(m.params.len(), 1 + 10 * 8 + 2);
        assert!(m.num_params() > 40_000_000);
        // Manifest offsets dense & monotone.
        for w in m.params.windows(2) {
            assert_eq!(w[1].offset, w[0].offset + w[0].numel());
        }
        assert!(m.hlo_path("decode_step").exists());
    }
}
