//! Minimal error plumbing for the runtime layer (`anyhow` is not in the
//! offline vendor set, DESIGN.md §8): a message-carrying error, a `Result`
//! alias, a `Context` extension trait mirroring the `anyhow::Context`
//! surface this crate uses, and the [`crate::rt_error!`] constructor macro.

use std::fmt;

/// Runtime-layer error: a human-readable message chain.
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl RuntimeError {
    /// Construct from any message.
    pub fn msg(m: impl Into<String>) -> Self {
        RuntimeError(m.into())
    }
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        RuntimeError(e.to_string())
    }
}

impl From<String> for RuntimeError {
    fn from(s: String) -> Self {
        RuntimeError(s)
    }
}

impl From<&str> for RuntimeError {
    fn from(s: &str) -> Self {
        RuntimeError(s.to_string())
    }
}

/// Runtime-layer result.
pub type Result<T> = std::result::Result<T, RuntimeError>;

/// `anyhow::Context`-style message chaining on any displayable error.
pub trait Context<T> {
    /// Wrap the error with a fixed message.
    fn context(self, msg: impl Into<String>) -> Result<T>;
    /// Wrap the error with a lazily-built message.
    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| RuntimeError(format!("{}: {e}", msg.into())))
    }

    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.map_err(|e| RuntimeError(format!("{}: {e}", f().into())))
    }
}

/// Construct a [`RuntimeError`] with `format!` syntax (the offline stand-in
/// for `anyhow!`).
#[macro_export]
macro_rules! rt_error {
    ($($arg:tt)*) => {
        $crate::runtime::error::RuntimeError(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_chains_messages() {
        let r: std::result::Result<(), &str> = Err("inner");
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let r: std::result::Result<(), &str> = Err("inner");
        let e = r.with_context(|| format!("outer {}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "outer 2: inner");
    }

    #[test]
    fn macro_formats() {
        let e = crate::rt_error!("missing field {}", "vocab");
        assert_eq!(e.to_string(), "missing field vocab");
    }

    #[test]
    fn io_error_converts() {
        fn f() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(f().is_err());
    }
}
