//! Functional per-node memories + traffic accounting.
//!
//! Every data-moving DMA command in the simulator actually moves bytes, so
//! collective implementations are verified end-to-end (AG = concatenation,
//! AA = transpose — `collectives::verify`). Traffic counters feed the power
//! model (`sim::power`): `bcst` reads its source once for two destinations,
//! which is exactly the memory-traffic saving the paper credits for its
//! 5–10% power win (§5.2.9).

use std::collections::HashMap;

use super::topology::NodeId;

/// Byte-addressable memory for every node, plus read/write counters.
#[derive(Debug, Default)]
pub struct MemorySystem {
    mem: HashMap<NodeId, Vec<u8>>,
    /// Functional byte movement can be disabled for timing-only sweeps
    /// (multi-GB collectives would otherwise allocate multi-GB buffers).
    functional: bool,
    read_bytes: HashMap<NodeId, u64>,
    write_bytes: HashMap<NodeId, u64>,
}

impl MemorySystem {
    /// `functional = true` enables real byte movement (tests, examples);
    /// `false` keeps only traffic accounting (large timing sweeps).
    pub fn new(functional: bool) -> Self {
        MemorySystem {
            functional,
            ..Default::default()
        }
    }

    /// Whether byte movement is enabled.
    pub fn is_functional(&self) -> bool {
        self.functional
    }

    /// Drop all node memories and traffic counters, keeping the
    /// functional/timing-only mode ([`crate::sim::Sim::reset`]). Releases
    /// the per-node byte buffers — a reused simulator must not pin every
    /// episode's buffers at once.
    pub fn reset(&mut self) {
        self.mem.clear();
        self.read_bytes.clear();
        self.write_bytes.clear();
    }

    /// Ensure `node`'s memory is at least `size` bytes (functional mode).
    pub fn ensure(&mut self, node: NodeId, size: u64) {
        if self.functional {
            let m = self.mem.entry(node).or_default();
            if (m.len() as u64) < size {
                m.resize(size as usize, 0);
            }
        }
    }

    /// Write raw bytes (host-side initialization; not counted as DMA traffic).
    pub fn poke(&mut self, node: NodeId, offset: u64, data: &[u8]) {
        if !self.functional {
            return;
        }
        self.ensure(node, offset + data.len() as u64);
        let m = self.mem.get_mut(&node).unwrap();
        m[offset as usize..offset as usize + data.len()].copy_from_slice(data);
    }

    /// Read raw bytes (verification; not counted as DMA traffic).
    /// Untouched memory reads as zeros, like freshly-mapped pages.
    pub fn peek(&self, node: NodeId, offset: u64, len: u64) -> Vec<u8> {
        let mut out = vec![0u8; len as usize];
        if !self.functional {
            return out;
        }
        if let Some(m) = self.mem.get(&node) {
            let end = ((offset + len) as usize).min(m.len());
            if (offset as usize) < end {
                let n = end - offset as usize;
                out[..n].copy_from_slice(&m[offset as usize..end]);
            }
        }
        out
    }

    /// DMA copy: src(node,offset) → dst(node,offset), counting traffic.
    pub fn dma_copy(
        &mut self,
        src: NodeId,
        src_off: u64,
        dst: NodeId,
        dst_off: u64,
        len: u64,
    ) {
        *self.read_bytes.entry(src).or_default() += len;
        *self.write_bytes.entry(dst).or_default() += len;
        if !self.functional {
            return;
        }
        let data = self.peek(src, src_off, len);
        self.ensure(dst, dst_off + len);
        let m = self.mem.get_mut(&dst).unwrap();
        m[dst_off as usize..(dst_off + len) as usize].copy_from_slice(&data);
    }

    /// DMA broadcast: one source read, two destination writes (§4.2).
    pub fn dma_bcst(
        &mut self,
        src: NodeId,
        src_off: u64,
        dst0: (NodeId, u64),
        dst1: (NodeId, u64),
        len: u64,
    ) {
        // Single source read — this is bcst's memory-traffic advantage.
        *self.read_bytes.entry(src).or_default() += len;
        *self.write_bytes.entry(dst0.0).or_default() += len;
        *self.write_bytes.entry(dst1.0).or_default() += len;
        if !self.functional {
            return;
        }
        let data = self.peek(src, src_off, len);
        for (dn, off) in [dst0, dst1] {
            self.ensure(dn, off + len);
            let m = self.mem.get_mut(&dn).unwrap();
            m[off as usize..(off + len) as usize].copy_from_slice(&data);
        }
    }

    /// DMA swap: exchange two ranges in place (§4.3): two reads, two writes,
    /// no temporary buffer.
    pub fn dma_swap(&mut self, a: (NodeId, u64), b: (NodeId, u64), len: u64) {
        *self.read_bytes.entry(a.0).or_default() += len;
        *self.read_bytes.entry(b.0).or_default() += len;
        *self.write_bytes.entry(a.0).or_default() += len;
        *self.write_bytes.entry(b.0).or_default() += len;
        if !self.functional {
            return;
        }
        let da = self.peek(a.0, a.1, len);
        let db = self.peek(b.0, b.1, len);
        self.poke(a.0, a.1, &db);
        self.poke(b.0, b.1, &da);
    }

    /// Bytes DMA-read from `node` so far.
    pub fn reads(&self, node: NodeId) -> u64 {
        self.read_bytes.get(&node).copied().unwrap_or(0)
    }

    /// Bytes DMA-written to `node` so far.
    pub fn writes(&self, node: NodeId) -> u64 {
        self.write_bytes.get(&node).copied().unwrap_or(0)
    }

    /// Total DMA traffic (reads + writes) across all nodes.
    pub fn total_traffic(&self) -> u64 {
        self.read_bytes.values().sum::<u64>() + self.write_bytes.values().sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const G0: NodeId = NodeId::Gpu(0);
    const G1: NodeId = NodeId::Gpu(1);
    const G2: NodeId = NodeId::Gpu(2);

    #[test]
    fn copy_moves_bytes_and_counts() {
        let mut m = MemorySystem::new(true);
        m.poke(G0, 0, &[1, 2, 3, 4]);
        m.dma_copy(G0, 0, G1, 8, 4);
        assert_eq!(m.peek(G1, 8, 4), vec![1, 2, 3, 4]);
        assert_eq!(m.reads(G0), 4);
        assert_eq!(m.writes(G1), 4);
    }

    #[test]
    fn bcst_reads_once_writes_twice() {
        let mut m = MemorySystem::new(true);
        m.poke(G0, 0, &[7; 16]);
        m.dma_bcst(G0, 0, (G1, 0), (G2, 32), 16);
        assert_eq!(m.peek(G1, 0, 16), vec![7; 16]);
        assert_eq!(m.peek(G2, 32, 16), vec![7; 16]);
        assert_eq!(m.reads(G0), 16); // ONE read
        assert_eq!(m.writes(G1) + m.writes(G2), 32);
    }

    #[test]
    fn swap_exchanges_in_place() {
        let mut m = MemorySystem::new(true);
        m.poke(G0, 0, &[1; 8]);
        m.poke(G1, 0, &[2; 8]);
        m.dma_swap((G0, 0), (G1, 0), 8);
        assert_eq!(m.peek(G0, 0, 8), vec![2; 8]);
        assert_eq!(m.peek(G1, 0, 8), vec![1; 8]);
        assert_eq!(m.total_traffic(), 32);
    }

    #[test]
    fn reset_clears_data_and_counters_keeps_mode() {
        let mut m = MemorySystem::new(true);
        m.poke(G0, 0, &[5; 8]);
        m.dma_copy(G0, 0, G1, 0, 8);
        m.reset();
        assert!(m.is_functional());
        assert_eq!(m.total_traffic(), 0);
        assert_eq!(m.peek(G1, 0, 8), vec![0; 8]);
    }

    #[test]
    fn non_functional_counts_but_skips_data() {
        let mut m = MemorySystem::new(false);
        m.dma_copy(G0, 0, G1, 0, 1 << 30); // no allocation happens
        assert_eq!(m.reads(G0), 1 << 30);
        assert_eq!(m.peek(G1, 0, 4), vec![0; 4]);
    }
}
