//! Event heap for the DES: min-ordered by (time, sequence number) so
//! same-time events fire in insertion order (deterministic replay).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::clock::SimTime;
use super::command::AtomicOp;
use super::engine::EngineId;
use super::host::HostId;
use super::signal::SignalId;

/// Events driving the simulation forward.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// A host program resumes at its next op.
    HostResume(HostId),
    /// Doorbell for an engine was rung (commands became visible).
    Doorbell(EngineId),
    /// Engine finished waking/fetching and can process commands.
    EngineReady(EngineId),
    /// Engine front-end free; try to issue the next command.
    EngineAdvance(EngineId),
    /// A signal value mutates at this instant; wakes host waiters and
    /// engine pollers whose condition now holds. (Signal values change at
    /// the *event's* time, never earlier, preserving global time order.)
    SignalUpdate { signal: SignalId, op: AtomicOp },
}

#[derive(Debug, PartialEq, Eq)]
struct Entry {
    at: SimTime,
    seq: u64,
    ev: Event,
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic min-heap of timestamped events.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Entry>>,
    seq: u64,
}

impl EventQueue {
    /// Schedule `ev` at absolute time `at`.
    pub fn push(&mut self, at: SimTime, ev: Event) {
        self.seq += 1;
        self.heap.push(Reverse(Entry {
            at,
            seq: self.seq,
            ev,
        }));
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<(SimTime, Event)> {
        self.heap.pop().map(|Reverse(e)| (e.at, e.ev))
    }

    /// Earliest scheduled time, if any.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|Reverse(e)| e.at)
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no events remain.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wake(i: u32) -> Event {
        Event::SignalUpdate {
            signal: SignalId(i),
            op: AtomicOp::Add(1),
        }
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::default();
        q.push(30, wake(0));
        q.push(10, wake(1));
        q.push(20, wake(2));
        assert_eq!(q.pop().unwrap().0, 10);
        assert_eq!(q.pop().unwrap().0, 20);
        assert_eq!(q.pop().unwrap().0, 30);
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::default();
        q.push(5, wake(1));
        q.push(5, wake(2));
        match q.pop().unwrap().1 {
            Event::SignalUpdate { signal, .. } => assert_eq!(signal, SignalId(1)),
            _ => unreachable!(),
        }
        assert_eq!(q.len(), 1);
        assert!(!q.is_empty());
        assert_eq!(q.peek_time(), Some(5));
    }
}
