//! Event heap for the DES: min-ordered by (time, sequence number) so
//! same-time events fire in insertion order (deterministic replay).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::clock::SimTime;
use super::command::AtomicOp;
use super::engine::EngineId;
use super::host::HostId;
use super::signal::SignalId;

/// Events driving the simulation forward.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Event {
    /// A host program resumes at its next op.
    HostResume(HostId),
    /// Doorbell for an engine was rung (commands became visible).
    Doorbell(EngineId),
    /// Engine finished waking/fetching and can process commands.
    EngineReady(EngineId),
    /// Engine front-end free; try to issue the next command.
    EngineAdvance(EngineId),
    /// A signal value mutates at this instant; wakes host waiters and
    /// engine pollers whose condition now holds. (Signal values change at
    /// the *event's* time, never earlier, preserving global time order.)
    SignalUpdate { signal: SignalId, op: AtomicOp },
}

#[derive(Debug, PartialEq, Eq)]
struct Entry {
    at: SimTime,
    seq: u64,
    ev: Event,
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic min-heap of timestamped events.
///
/// A one-entry `front` slot sits ahead of the binary heap as a fast path
/// for the DES's dominant access pattern: a dispatched handler pushes the
/// very next event (same or near-same time) which the main loop immediately
/// pops. In that pattern both the push and the pop are O(1) — one slot
/// store plus one comparison — instead of two O(log n) heap operations.
/// Ordering is unchanged: `pop` always compares the slot against the heap
/// top under the full `(time, seq)` order, so replay stays deterministic.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<Entry>>,
    /// Fast-path slot; NOT guaranteed to hold the global minimum — `pop`
    /// compares it against the heap top.
    front: Option<Entry>,
    seq: u64,
}

impl EventQueue {
    /// Schedule `ev` at absolute time `at`.
    pub fn push(&mut self, at: SimTime, ev: Event) {
        self.seq += 1;
        let e = Entry {
            at,
            seq: self.seq,
            ev,
        };
        match &self.front {
            None => self.front = Some(e),
            Some(f) if (e.at, e.seq) < (f.at, f.seq) => {
                let old = self.front.replace(e).unwrap();
                self.heap.push(Reverse(old));
            }
            Some(_) => self.heap.push(Reverse(e)),
        }
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<(SimTime, Event)> {
        let front_first = match (&self.front, self.heap.peek()) {
            (Some(_), None) => true,
            // seq is unique, so the order is strict — no tie possible.
            (Some(f), Some(Reverse(h))) => (f.at, f.seq) < (h.at, h.seq),
            (None, _) => false,
        };
        if front_first {
            self.front.take().map(|e| (e.at, e.ev))
        } else {
            self.heap.pop().map(|Reverse(e)| (e.at, e.ev))
        }
    }

    /// Earliest scheduled time, if any.
    pub fn peek_time(&self) -> Option<SimTime> {
        let h = self.heap.peek().map(|Reverse(e)| e.at);
        let f = self.front.as_ref().map(|e| e.at);
        match (f, h) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (x, y) => x.or(y),
        }
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len() + usize::from(self.front.is_some())
    }

    /// True when no events remain.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty() && self.front.is_none()
    }

    /// Drop all pending events and restart the deterministic sequence
    /// numbering, keeping the heap's allocation ([`crate::sim::Sim::reset`]).
    pub fn clear(&mut self) {
        self.heap.clear();
        self.front = None;
        self.seq = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wake(i: u32) -> Event {
        Event::SignalUpdate {
            signal: SignalId(i),
            op: AtomicOp::Add(1),
        }
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::default();
        q.push(30, wake(0));
        q.push(10, wake(1));
        q.push(20, wake(2));
        assert_eq!(q.pop().unwrap().0, 10);
        assert_eq!(q.pop().unwrap().0, 20);
        assert_eq!(q.pop().unwrap().0, 30);
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::default();
        q.push(5, wake(1));
        q.push(5, wake(2));
        match q.pop().unwrap().1 {
            Event::SignalUpdate { signal, .. } => assert_eq!(signal, SignalId(1)),
            _ => unreachable!(),
        }
        assert_eq!(q.len(), 1);
        assert!(!q.is_empty());
        assert_eq!(q.peek_time(), Some(5));
    }

    /// The push-then-pop-at-head pattern must pop in exactly the same
    /// order a plain heap would, including same-time insertion ties.
    #[test]
    fn front_slot_preserves_order() {
        let mut q = EventQueue::default();
        q.push(10, wake(0));
        q.push(5, wake(1)); // displaces the front slot
        q.push(20, wake(2));
        assert_eq!(q.peek_time(), Some(5));
        assert_eq!(q.len(), 3);
        let order: Vec<u64> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![5, 10, 20]);

        // Interleave pushes and pops; ties at t=7 keep insertion order.
        q.push(7, wake(3));
        q.push(7, wake(4));
        match q.pop().unwrap().1 {
            Event::SignalUpdate { signal, .. } => assert_eq!(signal, SignalId(3)),
            _ => unreachable!(),
        }
        q.push(6, wake(5));
        assert_eq!(q.pop().unwrap().0, 6);
        assert_eq!(q.pop().unwrap().0, 7);
        assert!(q.pop().is_none());
    }

    #[test]
    fn clear_restarts_sequence() {
        let mut q = EventQueue::default();
        q.push(5, wake(0));
        q.push(5, wake(1));
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        // Post-clear ties break exactly as in a fresh queue.
        q.push(3, wake(2));
        q.push(3, wake(3));
        match q.pop().unwrap().1 {
            Event::SignalUpdate { signal, .. } => assert_eq!(signal, SignalId(2)),
            _ => unreachable!(),
        }
    }
}
