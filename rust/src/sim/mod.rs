//! Discrete-event simulator (DES) of the AMD Instinct MI300X Infinity
//! Platform DMA subsystem — the substrate this reproduction substitutes for
//! the paper's real hardware (DESIGN.md §1).
//!
//! The simulator is *functional* (DMA commands actually move bytes between
//! per-device memories, so collectives can be verified for correctness) and
//! *timed* (a calibrated phase model — control / schedule / copy / sync —
//! reproduces the latency composition the paper measures in Fig. 7).
//!
//! Actors:
//! - **Hosts** ([`host`]): CPU-side rank threads executing scripts of
//!   [`host::HostOp`]s — create DMA commands, ring doorbells, wait on
//!   signals. API cost depends on the call style (raw ROCt vs
//!   `hipMemcpyAsync` vs `hipMemcpyBatchAsync`).
//! - **Engines** ([`engine`]): sDMA engines fetching commands from their
//!   queues, decoding and executing them. Consecutive data-move commands
//!   pipeline ("back-to-back overlap", §4.4) unless a data hazard forces
//!   serialization; `Atomic` acts as a completion fence; `Poll` parks the
//!   engine until a signal condition holds (§4.5 prelaunch).
//! - **Links** ([`topology`]): directed xGMI / PCIe links with FIFO
//!   bandwidth occupancy.

pub mod clock;
pub mod command;
pub mod engine;
pub mod event;
pub mod host;
pub mod latency;
pub mod memory;
pub mod power;
pub mod signal;
pub mod topology;
pub mod trace;

mod core;

pub use self::core::{Sim, SimConfig, SimOutcome};
pub use clock::SimTime;
pub use command::{Addr, AtomicOp, Command, PollCond};
pub use engine::EngineId;
pub use host::{ApiKind, HostId, HostOp};
pub use latency::LatencyModel;
pub use signal::SignalId;
pub use topology::{NodeId, Topology};
