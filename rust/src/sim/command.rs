//! DMA command set of the MI300X sDMA engines as used by the paper:
//! vanilla `Copy`, the two novel data-move commands `Bcst` (§4.2) and
//! `Swap` (§4.3), the `Poll` command that enables prelaunch (§4.5),
//! `Atomic` signal updates for synchronization, and `Timestamp` (the
//! instrumentation command used for the Fig. 7 benchmarking methodology).

use super::signal::SignalId;
use super::topology::NodeId;

/// A (node, offset) memory address.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Addr {
    pub node: NodeId,
    pub offset: u64,
}

impl Addr {
    /// Convenience constructor.
    pub fn new(node: NodeId, offset: u64) -> Self {
        Addr { node, offset }
    }
}

/// Condition for the `Poll` command.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PollCond {
    /// Proceed once `signal >= value`.
    Gte(i64),
    /// Proceed once `signal == value`.
    Eq(i64),
}

impl PollCond {
    /// Evaluate against a current signal value.
    pub fn satisfied(&self, v: i64) -> bool {
        match *self {
            PollCond::Gte(t) => v >= t,
            PollCond::Eq(t) => v == t,
        }
    }
}

/// Atomic op for the sync phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AtomicOp {
    /// `signal += delta` (delta may be negative, i.e. decrement).
    Add(i64),
    /// `signal = value`.
    Set(i64),
}

/// One sDMA queue entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Command {
    /// Vanilla copy: single source → single destination.
    Copy { src: Addr, dst: Addr, len: u64 },
    /// Broadcast: single source → two destinations, source read once.
    Bcst {
        src: Addr,
        dst0: Addr,
        dst1: Addr,
        len: u64,
    },
    /// Swap the contents of two ranges in place (no temporary buffer).
    Swap { a: Addr, b: Addr, len: u64 },
    /// Park the engine until `cond` holds on `signal` (prelaunch trigger /
    /// dependency gate).
    Poll { signal: SignalId, cond: PollCond },
    /// Atomic signal update; acts as a completion fence for all prior
    /// data-move commands on the same engine.
    Atomic { signal: SignalId, op: AtomicOp },
    /// Record the engine-local time into trace slot `slot` (benchmarking).
    Timestamp { slot: u32 },
}

impl Command {
    /// Bytes this command moves over links (swap moves `len` both ways).
    pub fn wire_bytes(&self) -> u64 {
        match *self {
            Command::Copy { len, .. } => len,
            Command::Bcst { len, .. } => 2 * len,
            Command::Swap { len, .. } => 2 * len,
            _ => 0,
        }
    }

    /// Is this a data-move command (participates in b2b pipelining and
    /// hazard analysis)?
    pub fn is_data_move(&self) -> bool {
        matches!(
            self,
            Command::Copy { .. } | Command::Bcst { .. } | Command::Swap { .. }
        )
    }

    /// Ranges this command reads: (addr, len).
    pub fn reads(&self) -> Vec<(Addr, u64)> {
        match *self {
            Command::Copy { src, len, .. } => vec![(src, len)],
            Command::Bcst { src, len, .. } => vec![(src, len)],
            Command::Swap { a, b, len } => vec![(a, len), (b, len)],
            _ => vec![],
        }
    }

    /// Ranges this command writes: (addr, len).
    pub fn writes(&self) -> Vec<(Addr, u64)> {
        match *self {
            Command::Copy { dst, len, .. } => vec![(dst, len)],
            Command::Bcst {
                dst0, dst1, len, ..
            } => vec![(dst0, len), (dst1, len)],
            Command::Swap { a, b, len } => vec![(a, len), (b, len)],
            _ => vec![],
        }
    }
}

/// Do two (addr, len) ranges overlap?
pub fn ranges_overlap(a: (Addr, u64), b: (Addr, u64)) -> bool {
    a.0.node == b.0.node && a.0.offset < b.0.offset + b.1 && b.0.offset < a.0.offset + a.1
}

/// Allocation-free range extraction for the hot-path hazard check:
/// fills `buf` and returns (n_reads, n_writes) where reads occupy
/// `buf[..n_reads]` and writes `buf[2..2 + n_writes]`.
#[inline]
fn ranges_into(cmd: &Command, buf: &mut [(Addr, u64); 4]) -> (usize, usize) {
    match *cmd {
        Command::Copy { src, dst, len } => {
            buf[0] = (src, len);
            buf[2] = (dst, len);
            (1, 1)
        }
        Command::Bcst {
            src,
            dst0,
            dst1,
            len,
        } => {
            buf[0] = (src, len);
            buf[2] = (dst0, len);
            buf[3] = (dst1, len);
            (1, 2)
        }
        Command::Swap { a, b, len } => {
            buf[0] = (a, len);
            buf[1] = (b, len);
            buf[2] = (a, len);
            buf[3] = (b, len);
            (2, 2)
        }
        _ => (0, 0),
    }
}

/// Data hazard between two data-move commands: RAW, WAR or WAW on any range.
/// The b2b overlap feature (§4.4) may only pipeline hazard-free commands.
/// (Hot path: runs per in-flight transfer per issued command — no allocs.)
pub fn hazard(first: &Command, second: &Command) -> bool {
    let mut fb = [(Addr::new(crate::sim::topology::NodeId::Cpu, 0), 0); 4];
    let mut sb = fb;
    let (fr, fw) = ranges_into(first, &mut fb);
    let (sr, sw) = ranges_into(second, &mut sb);
    // RAW: second reads what first writes.
    for w in &fb[2..2 + fw] {
        for r in &sb[..sr] {
            if ranges_overlap(*w, *r) {
                return true;
            }
        }
    }
    // WAR: second writes what first reads; WAW: both write.
    for sw_r in &sb[2..2 + sw] {
        for fr_r in fb[..fr].iter().chain(fb[2..2 + fw].iter()) {
            if ranges_overlap(*sw_r, *fr_r) {
                return true;
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::topology::NodeId::*;

    fn copy(src: u64, dst: u64, len: u64) -> Command {
        Command::Copy {
            src: Addr::new(Gpu(0), src),
            dst: Addr::new(Gpu(1), dst),
            len,
        }
    }

    #[test]
    fn wire_bytes_by_kind() {
        assert_eq!(copy(0, 0, 100).wire_bytes(), 100);
        let b = Command::Bcst {
            src: Addr::new(Gpu(0), 0),
            dst0: Addr::new(Gpu(1), 0),
            dst1: Addr::new(Gpu(2), 0),
            len: 10,
        };
        assert_eq!(b.wire_bytes(), 20);
        let s = Command::Swap {
            a: Addr::new(Gpu(0), 0),
            b: Addr::new(Gpu(1), 0),
            len: 8,
        };
        assert_eq!(s.wire_bytes(), 16);
    }

    #[test]
    fn overlap_detection() {
        let a = (Addr::new(Gpu(0), 0), 10u64);
        let b = (Addr::new(Gpu(0), 9), 5u64);
        let c = (Addr::new(Gpu(0), 10), 5u64);
        let d = (Addr::new(Gpu(1), 0), 100u64);
        assert!(ranges_overlap(a, b));
        assert!(!ranges_overlap(a, c)); // adjacent, not overlapping
        assert!(!ranges_overlap(a, d)); // different node
    }

    #[test]
    fn hazards() {
        // Independent copies: no hazard (b2b can pipeline them).
        assert!(!hazard(&copy(0, 0, 64), &copy(64, 64, 64)));
        // RAW: second reads the first's destination.
        let w = copy(0, 100, 64);
        let r = Command::Copy {
            src: Addr::new(Gpu(1), 100),
            dst: Addr::new(Gpu(2), 0),
            len: 64,
        };
        assert!(hazard(&w, &r));
        // WAW: same destination.
        assert!(hazard(&copy(0, 0, 64), &copy(128, 32, 64)));
    }

    #[test]
    fn poll_conditions() {
        assert!(PollCond::Gte(3).satisfied(3));
        assert!(PollCond::Gte(3).satisfied(9));
        assert!(!PollCond::Gte(3).satisfied(2));
        assert!(PollCond::Eq(0).satisfied(0));
        assert!(!PollCond::Eq(0).satisfied(1));
    }
}
