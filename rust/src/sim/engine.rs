//! sDMA engine state.
//!
//! Each engine owns one system-memory request queue. The host writes
//! commands into the queue ([`EngineState::pending`]) and rings the doorbell;
//! the engine wakes, fetches, then issues commands in order. The engine
//! front-end (decode) and data path are separate resources: the next
//! command's decode overlaps the previous command's data phase — this *is*
//! the back-to-back overlap feature of §4.4 — but data phases serialize
//! through the engine, and a data hazard (or an `Atomic` fence) forces the
//! issue to wait for prior completions.

use std::collections::VecDeque;

use super::clock::SimTime;
use super::command::Command;
use super::signal::SignalId;
use super::command::PollCond;

/// Engine handle: (gpu, engine index on that gpu).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EngineId {
    pub gpu: u8,
    pub idx: u8,
}

impl std::fmt::Display for EngineId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "gpu{}.sdma{}", self.gpu, self.idx)
    }
}

/// Execution state of one engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineRunState {
    /// Nothing fetched, waiting for a doorbell.
    Idle,
    /// Doorbell received, waking/fetching.
    Waking,
    /// Actively issuing commands.
    Running,
    /// Parked on a `Poll` command.
    Polling { signal: SignalId, cond: PollCond },
}

/// An in-flight data transfer (for fences and hazard waits).
#[derive(Debug, Clone)]
pub struct Inflight {
    pub cmd_seq: u64,
    pub done_at: SimTime,
    /// The command, kept for hazard range checks.
    pub cmd: Command,
}

/// Full per-engine simulation state.
#[derive(Debug)]
pub struct EngineState {
    pub id: EngineId,
    /// Commands written by the host but not yet made visible by a doorbell.
    pub pending: Vec<Command>,
    /// Fetched commands awaiting issue.
    pub fetched: VecDeque<Command>,
    pub run_state: EngineRunState,
    /// When the engine front-end (decode) is next free.
    pub issue_free_at: SimTime,
    /// When the engine data path is next free (data phases serialize).
    pub data_free_at: SimTime,
    /// Transfers issued but not yet completed.
    pub inflight: Vec<Inflight>,
    /// Completion time of the last data command issued (fence target).
    pub last_data_done: SimTime,
    /// Monotone per-engine command counter (trace key).
    pub cmd_seq: u64,
    /// Accumulated busy nanoseconds (power accounting).
    pub busy_ns: u64,
    /// Total commands executed (metrics).
    pub commands_executed: u64,
    /// Fault injection: if set, the engine stops issuing at this time.
    pub stall_at: Option<SimTime>,
}

impl EngineState {
    /// Fresh idle engine.
    pub fn new(id: EngineId) -> Self {
        EngineState {
            id,
            pending: Vec::new(),
            fetched: VecDeque::new(),
            run_state: EngineRunState::Idle,
            issue_free_at: 0,
            data_free_at: 0,
            inflight: Vec::new(),
            last_data_done: 0,
            cmd_seq: 0,
            busy_ns: 0,
            commands_executed: 0,
            stall_at: None,
        }
    }

    /// Drop completed in-flight entries at time `now`.
    pub fn retire_inflight(&mut self, now: SimTime) {
        self.inflight.retain(|f| f.done_at > now);
    }

    /// Earliest time `cmd` may start its data phase given hazards with
    /// in-flight transfers (returns `now` when hazard-free).
    pub fn hazard_clear_at(&self, cmd: &Command, now: SimTime) -> SimTime {
        let mut t = now;
        for f in &self.inflight {
            if f.done_at > t && super::command::hazard(&f.cmd, cmd) {
                t = f.done_at;
            }
        }
        t
    }

    /// True if the engine has nothing left to do.
    pub fn quiescent(&self) -> bool {
        self.pending.is_empty() && self.fetched.is_empty() && self.inflight.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::command::Addr;
    use crate::sim::topology::NodeId;

    fn mkcopy(dst_off: u64) -> Command {
        Command::Copy {
            src: Addr::new(NodeId::Gpu(0), 0),
            dst: Addr::new(NodeId::Gpu(1), dst_off),
            len: 64,
        }
    }

    #[test]
    fn hazard_clear_waits_for_conflict() {
        let mut e = EngineState::new(EngineId { gpu: 0, idx: 0 });
        e.inflight.push(Inflight {
            cmd_seq: 0,
            done_at: 100,
            cmd: mkcopy(0),
        });
        // A copy whose source is the in-flight copy's destination must wait.
        let dependent = Command::Copy {
            src: Addr::new(NodeId::Gpu(1), 0),
            dst: Addr::new(NodeId::Gpu(2), 0),
            len: 64,
        };
        assert_eq!(e.hazard_clear_at(&dependent, 10), 100);
        // An unrelated copy does not wait.
        let indep = Command::Copy {
            src: Addr::new(NodeId::Gpu(0), 4096),
            dst: Addr::new(NodeId::Gpu(2), 4096),
            len: 64,
        };
        assert_eq!(e.hazard_clear_at(&indep, 10), 10);
    }

    #[test]
    fn retire_drops_done() {
        let mut e = EngineState::new(EngineId { gpu: 0, idx: 0 });
        for t in [50, 150] {
            e.inflight.push(Inflight {
                cmd_seq: 0,
                done_at: t,
                cmd: mkcopy(t),
            });
        }
        e.retire_inflight(100);
        assert_eq!(e.inflight.len(), 1);
        assert!(!e.quiescent());
    }
}
