//! sDMA engine state.
//!
//! Each engine owns one system-memory request queue. The host writes
//! commands into the queue ([`EngineState::pending`]) and rings the doorbell;
//! the engine wakes, fetches, then issues commands in order. The engine
//! front-end (decode) and data path are separate resources: the next
//! command's decode overlaps the previous command's data phase — this *is*
//! the back-to-back overlap feature of §4.4 — but data phases serialize
//! through the engine, and a data hazard (or an `Atomic` fence) forces the
//! issue to wait for prior completions.

use std::collections::VecDeque;

use super::clock::SimTime;
use super::command::Command;
use super::signal::SignalId;
use super::command::PollCond;

/// Engine handle: (gpu, engine index on that gpu).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EngineId {
    pub gpu: u8,
    pub idx: u8,
}

impl std::fmt::Display for EngineId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "gpu{}.sdma{}", self.gpu, self.idx)
    }
}

/// Execution state of one engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineRunState {
    /// Nothing fetched, waiting for a doorbell.
    Idle,
    /// Doorbell received, waking/fetching.
    Waking,
    /// Actively issuing commands.
    Running,
    /// Parked on a `Poll` command.
    Polling { signal: SignalId, cond: PollCond },
}

/// An in-flight data transfer (for fences and hazard waits).
#[derive(Debug, Clone)]
pub struct Inflight {
    pub cmd_seq: u64,
    pub done_at: SimTime,
    /// The command, kept for hazard range checks.
    pub cmd: Command,
}

/// Full per-engine simulation state.
#[derive(Debug)]
pub struct EngineState {
    pub id: EngineId,
    /// Commands written by the host but not yet made visible by a doorbell.
    pub pending: Vec<Command>,
    /// Fetched commands awaiting issue.
    pub fetched: VecDeque<Command>,
    pub run_state: EngineRunState,
    /// When the engine front-end (decode) is next free.
    pub issue_free_at: SimTime,
    /// When the engine data path is next free (data phases serialize).
    pub data_free_at: SimTime,
    /// Transfers issued but not yet completed, ordered by `done_at`
    /// (data phases serialize through the engine, so completion times are
    /// non-decreasing in issue order — [`EngineState::note_inflight`]
    /// asserts it). Retirement drains from the front instead of scanning.
    pub inflight: VecDeque<Inflight>,
    /// Completion time of the last data command issued (fence target).
    pub last_data_done: SimTime,
    /// Monotone per-engine command counter (trace key).
    pub cmd_seq: u64,
    /// Accumulated busy nanoseconds (power accounting).
    pub busy_ns: u64,
    /// Total commands executed (metrics).
    pub commands_executed: u64,
    /// Fault injection: if set, the engine stops issuing at this time.
    pub stall_at: Option<SimTime>,
}

impl EngineState {
    /// Fresh idle engine.
    pub fn new(id: EngineId) -> Self {
        EngineState {
            id,
            pending: Vec::new(),
            fetched: VecDeque::new(),
            run_state: EngineRunState::Idle,
            issue_free_at: 0,
            data_free_at: 0,
            inflight: VecDeque::new(),
            last_data_done: 0,
            cmd_seq: 0,
            busy_ns: 0,
            commands_executed: 0,
            stall_at: None,
        }
    }

    /// Return the engine to its freshly-constructed state, keeping the
    /// queue/inflight allocations for reuse ([`crate::sim::Sim::reset`]).
    pub fn reset(&mut self) {
        self.pending.clear();
        self.fetched.clear();
        self.run_state = EngineRunState::Idle;
        self.issue_free_at = 0;
        self.data_free_at = 0;
        self.inflight.clear();
        self.last_data_done = 0;
        self.cmd_seq = 0;
        self.busy_ns = 0;
        self.commands_executed = 0;
        self.stall_at = None;
    }

    /// Record an issued transfer. Completion times are non-decreasing in
    /// issue order (the data path serializes), which is what lets
    /// [`EngineState::retire_inflight`] drain from the front.
    pub fn note_inflight(&mut self, f: Inflight) {
        debug_assert!(
            self.inflight.back().map_or(true, |b| b.done_at <= f.done_at),
            "inflight completion times must be non-decreasing"
        );
        self.inflight.push_back(f);
    }

    /// Drop completed in-flight entries at time `now`: a front-drain over
    /// the done-time-sorted deque, O(retired) instead of the old
    /// full-`retain` scan per issued command (§Perf pass).
    pub fn retire_inflight(&mut self, now: SimTime) {
        while self.inflight.front().is_some_and(|f| f.done_at <= now) {
            self.inflight.pop_front();
        }
    }

    /// Earliest time `cmd` may start its data phase given hazards with
    /// in-flight transfers (returns `now` when hazard-free).
    pub fn hazard_clear_at(&self, cmd: &Command, now: SimTime) -> SimTime {
        let mut t = now;
        for f in &self.inflight {
            if f.done_at > t && super::command::hazard(&f.cmd, cmd) {
                t = f.done_at;
            }
        }
        t
    }

    /// True if the engine has nothing left to do.
    pub fn quiescent(&self) -> bool {
        self.pending.is_empty() && self.fetched.is_empty() && self.inflight.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::command::Addr;
    use crate::sim::topology::NodeId;

    fn mkcopy(dst_off: u64) -> Command {
        Command::Copy {
            src: Addr::new(NodeId::Gpu(0), 0),
            dst: Addr::new(NodeId::Gpu(1), dst_off),
            len: 64,
        }
    }

    #[test]
    fn hazard_clear_waits_for_conflict() {
        let mut e = EngineState::new(EngineId { gpu: 0, idx: 0 });
        e.note_inflight(Inflight {
            cmd_seq: 0,
            done_at: 100,
            cmd: mkcopy(0),
        });
        // A copy whose source is the in-flight copy's destination must wait.
        let dependent = Command::Copy {
            src: Addr::new(NodeId::Gpu(1), 0),
            dst: Addr::new(NodeId::Gpu(2), 0),
            len: 64,
        };
        assert_eq!(e.hazard_clear_at(&dependent, 10), 100);
        // An unrelated copy does not wait.
        let indep = Command::Copy {
            src: Addr::new(NodeId::Gpu(0), 4096),
            dst: Addr::new(NodeId::Gpu(2), 4096),
            len: 64,
        };
        assert_eq!(e.hazard_clear_at(&indep, 10), 10);
    }

    #[test]
    fn retire_drops_done() {
        let mut e = EngineState::new(EngineId { gpu: 0, idx: 0 });
        for t in [50, 150] {
            e.note_inflight(Inflight {
                cmd_seq: 0,
                done_at: t,
                cmd: mkcopy(t),
            });
        }
        e.retire_inflight(100);
        assert_eq!(e.inflight.len(), 1);
        assert_eq!(e.inflight.front().unwrap().done_at, 150);
        assert!(!e.quiescent());
    }

    #[test]
    fn reset_restores_fresh_state() {
        let mut e = EngineState::new(EngineId { gpu: 0, idx: 3 });
        e.pending.push(mkcopy(0));
        e.fetched.push_back(mkcopy(64));
        e.run_state = EngineRunState::Running;
        e.issue_free_at = 10;
        e.data_free_at = 20;
        e.note_inflight(Inflight {
            cmd_seq: 1,
            done_at: 30,
            cmd: mkcopy(128),
        });
        e.last_data_done = 30;
        e.cmd_seq = 2;
        e.busy_ns = 40;
        e.commands_executed = 2;
        e.stall_at = Some(99);
        e.reset();
        let fresh = EngineState::new(EngineId { gpu: 0, idx: 3 });
        assert!(e.quiescent());
        assert_eq!(e.run_state, fresh.run_state);
        assert_eq!(
            (e.issue_free_at, e.data_free_at, e.last_data_done),
            (0, 0, 0)
        );
        assert_eq!((e.cmd_seq, e.busy_ns, e.commands_executed), (0, 0, 0));
        assert_eq!(e.stall_at, None);
    }
}
