//! Calibrated latency constants — the quantitative heart of the simulator.
//!
//! Every constant corresponds to a phase of a DMA offload identified by the
//! paper's Fig. 6/7 benchmarking (control → schedule → copy → sync) or to a
//! host API cost (§5.3.1, §6). Defaults are calibrated so that the *shape*
//! claims of the paper emerge (see `rust/tests/calibration.rs`):
//!
//! - non-copy phases ≈ 60% of a 4KB copy, < 20% above 1MB (Fig. 7);
//! - pcpy AG ≈ 4.5× slower than RCCL geomean below 32MB, ~15% faster above;
//! - bcst/swap ≈ 1.7× over pcpy (≤4MB); b2b ≈ 2.5–2.7× over pcpy (<1MB);
//! - prelaunch ≈ 1.9×/1.5×/1.2× on pcpy/bcst/b2b respectively.

/// All tunable latency constants, nanoseconds unless noted.
#[derive(Debug, Clone)]
pub struct LatencyModel {
    // ---- control phase (host, per command; raw ROCt queue access) ----
    /// Host cost to create + enqueue one DMA command individually.
    pub t_control_per_cmd: f64,
    /// Host cost per command when commands are built as one batch
    /// (shared prologue/epilogue, §6 Copy Batching).
    pub t_control_per_cmd_batched: f64,

    // ---- schedule phase ----
    /// Host doorbell ring (MMIO write over PCIe).
    pub t_doorbell: f64,
    /// Engine wake + fetch of queue entries after a doorbell.
    pub t_engine_wake: f64,

    // ---- copy phase ----
    /// Engine front-end per-command issue/decode time. This is also the b2b
    /// pipelining gap: the next command's decode overlaps the previous
    /// command's data phase.
    pub t_issue: f64,
    /// Remaining fixed copy cost: address translation + first-byte latency.
    pub t_copy_fixed: f64,
    /// Payload efficiency of DMA transfers on a link (fraction of raw BW).
    /// DMA moves little metadata → high efficiency (paper §5.2.4).
    pub dma_link_efficiency: f64,
    /// A single sDMA engine's data-path bandwidth (bytes/ns). A broadcast
    /// pushing 2× payload through one engine, or a b2b chain of copies,
    /// serializes here even when the target links differ — this is why
    /// `pcpy`'s parallel engines win back the bandwidth-bound regime
    /// (paper §5.2.5/5.2.7).
    pub engine_data_bw: f64,
    /// Duplex boost for `swap`: reads and writes stream in both directions
    /// concurrently, so a swap's 2× payload costs less than 2× one-way time.
    pub swap_duplex_factor: f64,

    // ---- sync phase ----
    /// Engine executes the atomic signal update.
    pub t_atomic: f64,
    /// Host observes one completed signal (per-signal, serial on host).
    pub t_host_observe: f64,

    // ---- poll / prelaunch ----
    /// Engine re-check latency when the poll condition is already met.
    pub t_poll_check: f64,
    /// Engine wake latency after the polled signal is written.
    pub t_poll_wake: f64,
    /// Host memory write that triggers prelaunched commands.
    pub t_trigger_write: f64,

    // ---- HIP-level API costs (serving path, §5.3.1) ----
    /// Full per-call cost of one `hipMemcpyAsync` (API entry, dependency
    /// resolution, coherency setup, teardown). The paper's §6 calls out this
    /// per-copy setup/teardown as the overhead batch APIs amortize.
    pub t_hip_api_per_copy: f64,
    /// Base cost of one `hipMemcpyBatchAsync` call.
    pub t_hip_batch_base: f64,
    /// Incremental per-entry cost inside a batch call.
    pub t_hip_batch_per_copy: f64,

    // ---- GPU kernel path (kernel-based KV fetch comparator) ----
    /// Kernel launch latency (single kernel fetches all blocks).
    pub t_kernel_launch: f64,
    /// CU-driven copy link efficiency (kernels move payload + control).
    pub cu_link_efficiency: f64,
}

impl Default for LatencyModel {
    fn default() -> Self {
        LatencyModel {
            t_control_per_cmd: 250.0,
            t_control_per_cmd_batched: 120.0,
            t_doorbell: 1_600.0,
            t_engine_wake: 1_100.0,
            t_issue: 220.0,
            t_copy_fixed: 2_600.0,
            dma_link_efficiency: 0.97,
            engine_data_bw: 64.0,
            swap_duplex_factor: 1.5,
            t_atomic: 900.0,
            t_host_observe: 850.0,
            t_poll_check: 150.0,
            t_poll_wake: 400.0,
            t_trigger_write: 250.0,
            t_hip_api_per_copy: 5_800.0,
            t_hip_batch_base: 9_000.0,
            t_hip_batch_per_copy: 200.0,
            t_kernel_launch: 9_000.0,
            cu_link_efficiency: 0.97,
        }
    }
}

impl LatencyModel {
    /// Data-phase duration for `len` bytes over a link with raw bandwidth
    /// `bw` bytes/ns: fixed cost + payload time at DMA efficiency.
    pub fn copy_data_ns(&self, len: u64, bw_bytes_per_ns: f64) -> f64 {
        self.t_copy_fixed + len as f64 / (bw_bytes_per_ns * self.dma_link_efficiency)
    }

    /// Host-side cost of creating `n` commands with the given API style.
    pub fn control_ns(&self, n: usize, batched: bool) -> f64 {
        if batched {
            self.t_control_per_cmd_batched * n as f64
        } else {
            self.t_control_per_cmd * n as f64
        }
    }

    /// Single-engine data-path time for a command moving `total_bytes`
    /// (2× payload for bcst/swap); swap streams duplex.
    pub fn engine_path_ns(&self, total_bytes: u64, duplex: bool) -> f64 {
        let bw = if duplex {
            self.engine_data_bw * self.swap_duplex_factor
        } else {
            self.engine_data_bw
        };
        total_bytes as f64 / bw
    }

    /// Single-copy end-to-end estimate (control + schedule + copy + sync) —
    /// the analytic counterpart of the Fig. 7 microbenchmark; used by unit
    /// tests to cross-check the DES. Control covers the two queue entries a
    /// single offload needs: the copy command and its sync (atomic) command.
    pub fn single_copy_estimate_ns(&self, len: u64, bw_bytes_per_ns: f64) -> f64 {
        2.0 * self.t_control_per_cmd
            + self.t_doorbell
            + self.t_engine_wake
            + self.t_issue
            + self.copy_data_ns(len, bw_bytes_per_ns)
            + self.t_atomic
            + self.t_host_observe
    }

    /// Fraction of a single copy spent outside the copy phase (Fig. 7's
    /// headline: up to ~60% at 4KB, <20% above 1MB).
    pub fn non_copy_fraction(&self, len: u64, bw_bytes_per_ns: f64) -> f64 {
        let total = self.single_copy_estimate_ns(len, bw_bytes_per_ns);
        let copy = self.t_issue + self.copy_data_ns(len, bw_bytes_per_ns);
        (total - copy) / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::{KB, MB};

    #[test]
    fn fig7_noncopy_shape() {
        let m = LatencyModel::default();
        let bw = 64.0; // xGMI bytes/ns
        let f4k = m.non_copy_fraction(4 * KB, bw);
        let f2m = m.non_copy_fraction(2 * MB, bw);
        assert!(
            (0.5..=0.68).contains(&f4k),
            "4KB non-copy fraction {f4k:.2} outside paper band"
        );
        assert!(f2m < 0.20, "2MB non-copy fraction {f2m:.2} should be <20%");
        // Monotone decrease with size.
        let mut prev = 1.0;
        for s in [4 * KB, 16 * KB, 64 * KB, 256 * KB, MB, 2 * MB] {
            let f = m.non_copy_fraction(s, bw);
            assert!(f <= prev + 1e-9);
            prev = f;
        }
    }

    #[test]
    fn phase_ordering_matches_paper() {
        // copy > schedule ~ sync >> control (paper §3.2.3) at small sizes.
        let m = LatencyModel::default();
        let copy = m.t_issue + m.copy_data_ns(4 * KB, 64.0);
        let schedule = m.t_doorbell + m.t_engine_wake;
        let sync = m.t_atomic + m.t_host_observe;
        let control = m.t_control_per_cmd;
        assert!(copy > schedule);
        assert!((schedule / sync) > 0.6 && (schedule / sync) < 2.5);
        assert!(control < 0.5 * sync);
    }

    #[test]
    fn batching_amortizes_control() {
        let m = LatencyModel::default();
        assert!(m.control_ns(7, true) < 0.6 * m.control_ns(7, false));
    }
}
