//! Host (CPU) side of a DMA offload: rank programs as scripts of ops.
//!
//! Each collective rank / serving thread is a `HostProgram` — a straight-line
//! script of [`HostOp`]s executed against a host-time cursor. `WaitSignal`
//! blocks the program; the sim core resumes it when the signal lands.
//! API styles ([`ApiKind`]) carry the paper's cost split: raw ROCt queue
//! writes (collective prototypes, §5.2.1), `hipMemcpyAsync` per-copy calls
//! (baseline KV fetch, §5.3.1), and `hipMemcpyBatchAsync` batch calls
//! (optimized KV fetch, §6).

use super::command::Command;
use super::engine::EngineId;
use super::signal::SignalId;

/// Host program handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct HostId(pub u32);

/// How commands are conveyed to the runtime (determines control cost).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ApiKind {
    /// User-level ROCt queue writes (the paper's collective prototypes).
    Raw,
    /// Raw queue writes built as one batch (shared prologue/epilogue).
    RawBatched,
    /// One full `hipMemcpyAsync` per copy (heavy: dependency resolution,
    /// coherency setup/teardown per call).
    HipPerCopy,
    /// One `hipMemcpyBatchAsync` for many copies.
    HipBatched,
}

/// One step of a host program.
#[derive(Debug, Clone)]
pub enum HostOp {
    /// Create `cmds` in `engine`'s queue (visible only after a doorbell).
    CreateCommands {
        engine: EngineId,
        cmds: Vec<Command>,
        api: ApiKind,
    },
    /// Ring `engine`'s doorbell: make written commands visible + wake it.
    RingDoorbell { engine: EngineId },
    /// Block until `signal >= at_least`, then pay the observe cost.
    WaitSignal { signal: SignalId, at_least: i64 },
    /// Host store to a signal (prelaunch trigger, §4.5).
    SetSignal { signal: SignalId, value: i64 },
    /// Spend fixed host time (models framework overhead around offloads).
    Delay { ns: u64 },
    /// Advance the host cursor to absolute time `at` (no-op if already
    /// past). Used by the cluster layer to align intra-node phases with
    /// inter-node NIC arrivals.
    DelayUntil { at: u64 },
    /// Record the current host time under `name` (measurement marker).
    Mark { name: &'static str },
}

/// Host program execution state.
#[derive(Debug)]
pub struct HostProgram {
    pub id: HostId,
    pub script: Vec<HostOp>,
    pub pc: usize,
    /// Host-local clock (the program's own time cursor).
    pub now: u64,
    /// Set when blocked on a signal.
    pub waiting: Option<(SignalId, i64)>,
    /// Marker name → host time.
    pub marks: Vec<(&'static str, u64)>,
    /// Completed?
    pub done: bool,
}

impl HostProgram {
    /// New program starting at host time `start`.
    pub fn new(id: HostId, script: Vec<HostOp>, start: u64) -> Self {
        HostProgram {
            id,
            script,
            pc: 0,
            now: start,
            waiting: None,
            marks: Vec::new(),
            done: false,
        }
    }

    /// Time recorded for marker `name` (first occurrence).
    pub fn mark(&self, name: &str) -> Option<u64> {
        self.marks.iter().find(|(n, _)| *n == name).map(|&(_, t)| t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn marks_lookup() {
        let mut p = HostProgram::new(HostId(0), vec![], 0);
        p.marks.push(("start", 10));
        p.marks.push(("end", 99));
        assert_eq!(p.mark("start"), Some(10));
        assert_eq!(p.mark("end"), Some(99));
        assert_eq!(p.mark("nope"), None);
    }
}
