//! Per-command phase tracing — the simulator's equivalent of the paper's
//! timestamp-instrumented ROCt microbenchmark (§3.2.1), used to regenerate
//! the Fig. 7 latency breakdown.

use super::clock::SimTime;
use super::engine::EngineId;

/// The four phases of a DMA offload (paper Fig. 6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// CPU creates + enqueues commands.
    Control,
    /// Doorbell ring → engine wake/fetch.
    Schedule,
    /// Decode + address translation + data movement.
    Copy,
    /// Atomic signal update + host observe.
    Sync,
}

/// One recorded span.
#[derive(Debug, Clone)]
pub struct Span {
    pub engine: Option<EngineId>,
    pub cmd_seq: u64,
    pub phase: Phase,
    pub start: SimTime,
    pub end: SimTime,
}

/// The wire (data-movement) portion of a Copy span: from the moment the
/// engine starts pushing bytes (`data_start`) to completion. The Copy span
/// itself starts at decode, so its prefix is decode + setup, not bus time.
/// The observability layer ([`crate::obs`]) uses these to render a
/// per-engine exclusive "wire" track — consecutive wire spans on one engine
/// never overlap because the engine's data path is serialized.
#[derive(Debug, Clone)]
pub struct WireSpan {
    pub engine: EngineId,
    pub cmd_seq: u64,
    pub start: SimTime,
    pub end: SimTime,
}

/// Phase-span recorder (enabled per `SimConfig::trace`).
#[derive(Debug, Default)]
pub struct Trace {
    pub spans: Vec<Span>,
    /// Timestamp-command slots (engine-recorded times).
    pub stamps: Vec<(u32, SimTime)>,
    /// Wire sub-spans of data moves (subset of the Copy spans' windows).
    pub wire: Vec<WireSpan>,
}

impl Trace {
    /// Drop all recorded spans, stamps and wire spans, keeping the
    /// allocations ([`crate::sim::Sim::reset`]).
    pub fn clear(&mut self) {
        self.spans.clear();
        self.stamps.clear();
        self.wire.clear();
    }

    /// Record the wire (bus-occupancy) window of a data move.
    pub fn record_wire(&mut self, engine: EngineId, cmd_seq: u64, start: SimTime, end: SimTime) {
        debug_assert!(end >= start);
        self.wire.push(WireSpan {
            engine,
            cmd_seq,
            start,
            end,
        });
    }

    /// Record a phase span.
    pub fn record(
        &mut self,
        engine: Option<EngineId>,
        cmd_seq: u64,
        phase: Phase,
        start: SimTime,
        end: SimTime,
    ) {
        debug_assert!(end >= start);
        self.spans.push(Span {
            engine,
            cmd_seq,
            phase,
            start,
            end,
        });
    }

    /// Total duration recorded for `phase` (summed over spans).
    pub fn phase_total(&self, phase: Phase) -> SimTime {
        self.spans
            .iter()
            .filter(|s| s.phase == phase)
            .map(|s| s.end - s.start)
            .sum()
    }

    /// Per-phase totals in Fig. 6 order: [control, schedule, copy, sync].
    pub fn breakdown(&self) -> [SimTime; 4] {
        [
            self.phase_total(Phase::Control),
            self.phase_total(Phase::Schedule),
            self.phase_total(Phase::Copy),
            self.phase_total(Phase::Sync),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_by_phase() {
        let mut t = Trace::default();
        t.record(None, 0, Phase::Control, 0, 10);
        t.record(None, 0, Phase::Copy, 10, 110);
        t.record(None, 1, Phase::Copy, 50, 100);
        assert_eq!(t.phase_total(Phase::Control), 10);
        assert_eq!(t.phase_total(Phase::Copy), 150);
        assert_eq!(t.breakdown(), [10, 0, 150, 0]);
    }

    #[test]
    fn clear_drops_wire_spans() {
        let mut t = Trace::default();
        t.record(None, 0, Phase::Copy, 0, 10);
        t.record_wire(EngineId { gpu: 0, idx: 0 }, 0, 4, 10);
        assert_eq!(t.wire.len(), 1);
        t.clear();
        assert!(t.spans.is_empty());
        assert!(t.wire.is_empty());
    }
}
