//! Platform topology: nodes (8 GPUs + CPU), directed links, engines.
//!
//! Mirrors the MI300X Infinity Platform (paper §2.2): every GPU pair is
//! connected by an AMD Infinity Fabric (xGMI) link at 64 GB/s per direction;
//! each GPU connects to the CPU over PCIe Gen 5 at 64 GB/s per direction;
//! each GPU carries 16 sDMA engines on its IO dies.

use std::collections::HashMap;
use std::sync::Arc;

/// A device that owns memory: the host CPU or one of the GPUs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum NodeId {
    /// Host CPU (DRAM).
    Cpu,
    /// GPU by platform index.
    Gpu(u8),
}

impl std::fmt::Display for NodeId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NodeId::Cpu => write!(f, "cpu"),
            NodeId::Gpu(g) => write!(f, "gpu{g}"),
        }
    }
}

/// Kind of interconnect a link uses (affects bandwidth + payload efficiency).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    /// GPU↔GPU Infinity Fabric.
    Xgmi,
    /// GPU↔CPU PCIe Gen 5.
    Pcie,
}

/// Dense link index (see [`Topology::link_index`]).
pub type LinkIdx = usize;

/// A directed link between two nodes.
#[derive(Debug, Clone)]
pub struct Link {
    pub src: NodeId,
    pub dst: NodeId,
    pub kind: LinkKind,
    /// Raw bandwidth in bytes/ns (= GB/s / 1.0, since 1 GB/s ≈ 1 byte/ns
    /// with GB = 10^9; we use the paper's 64 GB/s marketing figure).
    pub bw_bytes_per_ns: f64,
}

/// Static platform description.
///
/// The link tables are immutable after construction and shared behind
/// [`Arc`], so cloning a `Topology` (which every DES episode used to pay
/// for) is two reference-count bumps — the §Perf pass relies on this to
/// make `SimConfig` effectively free to clone per episode.
#[derive(Debug, Clone)]
pub struct Topology {
    pub num_gpus: u8,
    pub engines_per_gpu: u8,
    links: Arc<[Link]>,
    index: Arc<HashMap<(NodeId, NodeId), LinkIdx>>,
}

impl Topology {
    /// The paper's system: 8 fully-connected MI300X GPUs, 16 sDMA engines
    /// each, xGMI 64 GB/s/dir between every GPU pair, PCIe Gen5 64 GB/s/dir
    /// between every GPU and the CPU.
    pub fn mi300x_platform() -> Self {
        Self::custom(8, 16, 64.0, 64.0)
    }

    /// Build a custom full-connect topology (used by property tests to vary
    /// GPU counts). Bandwidths in GB/s per direction.
    pub fn custom(num_gpus: u8, engines_per_gpu: u8, xgmi_gbps: f64, pcie_gbps: f64) -> Self {
        assert!(num_gpus >= 1, "need at least one GPU");
        assert!(engines_per_gpu >= 1);
        let mut links = Vec::new();
        let mut index = HashMap::new();
        let add = |links: &mut Vec<Link>,
                       index: &mut HashMap<(NodeId, NodeId), LinkIdx>,
                       src: NodeId,
                       dst: NodeId,
                       kind: LinkKind,
                       gbps: f64| {
            index.insert((src, dst), links.len());
            links.push(Link {
                src,
                dst,
                kind,
                bw_bytes_per_ns: gbps, // 1 GB/s == 1 byte/ns
            });
        };
        for i in 0..num_gpus {
            for j in 0..num_gpus {
                if i != j {
                    add(
                        &mut links,
                        &mut index,
                        NodeId::Gpu(i),
                        NodeId::Gpu(j),
                        LinkKind::Xgmi,
                        xgmi_gbps,
                    );
                }
            }
            add(
                &mut links,
                &mut index,
                NodeId::Gpu(i),
                NodeId::Cpu,
                LinkKind::Pcie,
                pcie_gbps,
            );
            add(
                &mut links,
                &mut index,
                NodeId::Cpu,
                NodeId::Gpu(i),
                LinkKind::Pcie,
                pcie_gbps,
            );
        }
        Topology {
            num_gpus,
            engines_per_gpu,
            links: links.into(),
            index: Arc::new(index),
        }
    }

    /// Directed link from `src` to `dst`, or `None` if the pair is not
    /// connected (same node, unknown node — or, in a cluster, a cross-node
    /// pair: the `cluster` layer routes those over NIC links instead).
    pub fn try_link_index(&self, src: NodeId, dst: NodeId) -> Option<LinkIdx> {
        self.index.get(&(src, dst)).copied()
    }

    /// Directed link from `src` to `dst`. Panicking convenience wrapper
    /// around [`Topology::try_link_index`] for callers that know the pair
    /// is intra-node connected.
    pub fn link_index(&self, src: NodeId, dst: NodeId) -> LinkIdx {
        self.try_link_index(src, dst)
            .unwrap_or_else(|| panic!("no link {src} -> {dst}"))
    }

    /// Link metadata by dense index.
    pub fn link(&self, idx: LinkIdx) -> &Link {
        &self.links[idx]
    }

    /// Total number of directed links.
    pub fn num_links(&self) -> usize {
        self.links.len()
    }

    /// All GPU peers of `gpu` (everything but itself).
    pub fn peers(&self, gpu: u8) -> Vec<u8> {
        (0..self.num_gpus).filter(|&p| p != gpu).collect()
    }

    /// Aggregate per-GPU outbound GPU↔GPU bandwidth in bytes/ns
    /// (7 × 64 = 448 GB/s on the paper's platform).
    pub fn gpu_fanout_bw(&self) -> f64 {
        let n = self.num_gpus as f64;
        if n < 2.0 {
            return 0.0;
        }
        let l = self.link_index(NodeId::Gpu(0), NodeId::Gpu(1));
        (n - 1.0) * self.links[l].bw_bytes_per_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mi300x_shape() {
        let t = Topology::mi300x_platform();
        assert_eq!(t.num_gpus, 8);
        assert_eq!(t.engines_per_gpu, 16);
        // 8*7 xGMI + 2*8 PCIe = 72 directed links
        assert_eq!(t.num_links(), 72);
        assert_eq!(t.peers(3).len(), 7);
        assert!(!t.peers(3).contains(&3));
        // 448 GB/s fan-out (paper §2.2)
        assert!((t.gpu_fanout_bw() - 448.0).abs() < 1e-9);
    }

    #[test]
    fn links_are_directed_and_typed() {
        let t = Topology::mi300x_platform();
        let ab = t.link_index(NodeId::Gpu(0), NodeId::Gpu(1));
        let ba = t.link_index(NodeId::Gpu(1), NodeId::Gpu(0));
        assert_ne!(ab, ba);
        assert_eq!(t.link(ab).kind, LinkKind::Xgmi);
        let up = t.link_index(NodeId::Gpu(0), NodeId::Cpu);
        assert_eq!(t.link(up).kind, LinkKind::Pcie);
    }

    #[test]
    #[should_panic]
    fn self_link_panics() {
        let t = Topology::mi300x_platform();
        t.link_index(NodeId::Gpu(0), NodeId::Gpu(0));
    }

    #[test]
    fn try_link_index_is_total() {
        let t = Topology::mi300x_platform();
        assert!(t.try_link_index(NodeId::Gpu(0), NodeId::Gpu(1)).is_some());
        assert!(t.try_link_index(NodeId::Gpu(0), NodeId::Gpu(0)).is_none());
        assert!(t.try_link_index(NodeId::Gpu(200), NodeId::Cpu).is_none());
        assert_eq!(
            t.try_link_index(NodeId::Gpu(2), NodeId::Cpu),
            Some(t.link_index(NodeId::Gpu(2), NodeId::Cpu))
        );
    }

    #[test]
    fn clone_shares_link_tables() {
        let t = Topology::mi300x_platform();
        let u = t.clone();
        assert!(Arc::ptr_eq(&t.links, &u.links));
        assert!(Arc::ptr_eq(&t.index, &u.index));
        assert_eq!(t.num_links(), u.num_links());
    }

    #[test]
    fn custom_counts() {
        let t = Topology::custom(4, 8, 50.0, 32.0);
        assert_eq!(t.num_links(), 4 * 3 + 8);
        assert!((t.gpu_fanout_bw() - 150.0).abs() < 1e-9);
    }
}
