//! HSA-style 64-bit completion signals (paper §2.2, §7).
//!
//! DMA engines notify the CPU (and, via `Poll`, other engines) through
//! atomic updates to 64-bit memory locations. Hosts wait on a signal
//! reaching a target value; engines park on a `Poll` condition.

/// Signal handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SignalId(pub u32);

/// Signal table: current values (waiters are managed by the sim core so the
/// table itself stays trivially borrowable).
#[derive(Debug, Default)]
pub struct SignalTable {
    values: Vec<i64>,
}

impl SignalTable {
    /// Allocate a new signal with initial value.
    pub fn alloc(&mut self, init: i64) -> SignalId {
        self.values.push(init);
        SignalId(self.values.len() as u32 - 1)
    }

    /// Current value.
    pub fn get(&self, id: SignalId) -> i64 {
        self.values[id.0 as usize]
    }

    /// Set to an absolute value; returns the new value.
    pub fn set(&mut self, id: SignalId, v: i64) -> i64 {
        self.values[id.0 as usize] = v;
        v
    }

    /// Add (may be negative); returns the new value.
    pub fn add(&mut self, id: SignalId, delta: i64) -> i64 {
        let v = &mut self.values[id.0 as usize];
        *v += delta;
        *v
    }

    /// Drop every signal, keeping the table's allocation. A table reset
    /// this way re-allocates the same deterministic id sequence (0, 1, …)
    /// as a fresh one ([`crate::sim::Sim::reset`]).
    pub fn reset(&mut self) {
        self.values.clear();
    }

    /// Number of allocated signals.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True if no signal has been allocated.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_set_add() {
        let mut t = SignalTable::default();
        let a = t.alloc(0);
        let b = t.alloc(5);
        assert_eq!(t.get(a), 0);
        assert_eq!(t.add(a, 3), 3);
        assert_eq!(t.add(a, -1), 2);
        assert_eq!(t.set(b, 10), 10);
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn reset_restarts_id_sequence() {
        let mut t = SignalTable::default();
        let a = t.alloc(1);
        let _ = t.alloc(2);
        t.reset();
        assert!(t.is_empty());
        let a2 = t.alloc(7);
        assert_eq!(a, a2);
        assert_eq!(t.get(a2), 7);
    }
}
