//! The DES core: owns all actors and processes events in time order.
//!
//! See `sim/mod.rs` for the actor overview. The core enforces the semantics
//! the paper's features rely on:
//!
//! - **b2b overlap (§4.4)**: an engine's front-end decodes command *k+1*
//!   while command *k*'s data drains; data phases serialize through the
//!   engine's data path; hazards (and `Atomic` fences) block the pipeline.
//! - **prelaunch (§4.5)**: `Poll` parks an engine until a host (or another
//!   engine) writes the trigger signal; command creation/doorbell costs were
//!   paid earlier, off the measured critical path.
//! - **signals**: values mutate at event time only, so no actor ever
//!   observes a "future" value.

use std::collections::HashMap;

use super::clock::{ns, SimTime};
use super::command::{AtomicOp, Command};
use super::engine::{EngineId, EngineRunState, EngineState, Inflight};
use super::event::{Event, EventQueue};
use super::host::{ApiKind, HostId, HostOp, HostProgram};
use super::latency::LatencyModel;
use super::memory::MemorySystem;
use super::signal::{SignalId, SignalTable};
use super::topology::Topology;
use super::trace::{Phase, Trace};

/// Simulator construction parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub topology: Topology,
    pub latency: LatencyModel,
    /// Move bytes for real (tests/examples) or only account traffic (sweeps).
    pub functional: bool,
    /// Record per-command phase spans (Fig. 7 reproduction).
    pub trace: bool,
}

impl SimConfig {
    /// The paper's platform with default calibration.
    pub fn mi300x() -> Self {
        SimConfig {
            topology: Topology::mi300x_platform(),
            latency: LatencyModel::default(),
            functional: false,
            trace: false,
        }
    }

    /// Enable functional byte movement.
    pub fn functional(mut self) -> Self {
        self.functional = true;
        self
    }

    /// Enable phase tracing.
    pub fn traced(mut self) -> Self {
        self.trace = true;
        self
    }
}

/// Result of driving a simulation to completion.
#[derive(Debug)]
pub struct SimOutcome {
    /// Time of the last processed event.
    pub makespan: SimTime,
    /// Hosts that never completed (blocked forever — deadlock/fault).
    pub deadlocked: Vec<HostId>,
    /// Events processed (perf counter; see EXPERIMENTS.md §Perf).
    pub events_processed: u64,
}

/// Local-copy bandwidth used when src and dst are the same node
/// (intra-GPU HBM-to-HBM move; ~3.9 TB/s effective on MI300X-class HBM).
const LOCAL_COPY_BW_BYTES_PER_NS: f64 = 3900.0;

/// The simulator.
pub struct Sim {
    pub cfg: SimConfig,
    pub time: SimTime,
    events: EventQueue,
    hosts: Vec<HostProgram>,
    engines: Vec<EngineState>,
    /// Per-link FIFO reservation horizon.
    link_free: Vec<SimTime>,
    pub signals: SignalTable,
    sig_host_waiters: HashMap<SignalId, Vec<HostId>>,
    sig_engine_pollers: HashMap<SignalId, Vec<EngineId>>,
    pub memory: MemorySystem,
    pub trace: Trace,
    /// Doorbell ring time per engine (schedule-phase trace).
    doorbell_at: Vec<Option<SimTime>>,
    /// Total bytes moved over links.
    pub link_bytes: u64,
    events_processed: u64,
}

impl Sim {
    /// Build a simulator.
    pub fn new(cfg: SimConfig) -> Self {
        let n_eng = cfg.topology.num_gpus as usize * cfg.topology.engines_per_gpu as usize;
        let engines = (0..n_eng)
            .map(|i| {
                EngineState::new(EngineId {
                    gpu: (i / cfg.topology.engines_per_gpu as usize) as u8,
                    idx: (i % cfg.topology.engines_per_gpu as usize) as u8,
                })
            })
            .collect();
        let functional = cfg.functional;
        let n_links = cfg.topology.num_links();
        Sim {
            time: 0,
            events: EventQueue::default(),
            hosts: Vec::new(),
            engines,
            link_free: vec![0; n_links],
            signals: SignalTable::default(),
            sig_host_waiters: HashMap::new(),
            sig_engine_pollers: HashMap::new(),
            memory: MemorySystem::new(functional),
            trace: Trace::default(),
            doorbell_at: vec![None; n_eng],
            link_bytes: 0,
            events_processed: 0,
            cfg,
        }
    }

    /// Return the simulator to the state `Sim::new(cfg)` would produce,
    /// keeping the engine array, event heap, signal table and link vector
    /// allocations. An episode run on a reset simulator is bit-identical
    /// to one run on a fresh simulator (signal/host ids restart at 0, the
    /// event sequence restarts, every clock returns to 0) — this is what
    /// lets sweeps and the serving engine reuse ONE simulator instead of
    /// rebuilding state, heap and signal tables every episode (§Perf pass).
    pub fn reset(&mut self) {
        self.time = 0;
        self.events.clear();
        self.hosts.clear();
        for e in &mut self.engines {
            e.reset();
        }
        self.link_free.fill(0);
        self.signals.reset();
        self.sig_host_waiters.clear();
        self.sig_engine_pollers.clear();
        self.memory.reset();
        self.trace.clear();
        self.doorbell_at.fill(None);
        self.link_bytes = 0;
        self.events_processed = 0;
    }

    fn eidx(&self, id: EngineId) -> usize {
        id.gpu as usize * self.cfg.topology.engines_per_gpu as usize + id.idx as usize
    }

    /// Engine state by id (tests, metrics).
    pub fn engine(&self, id: EngineId) -> &EngineState {
        &self.engines[self.eidx(id)]
    }

    /// Mutable engine state (fault injection: set `stall_at`).
    pub fn engine_mut(&mut self, id: EngineId) -> &mut EngineState {
        let i = self.eidx(id);
        &mut self.engines[i]
    }

    /// Allocate a fresh signal.
    pub fn alloc_signal(&mut self, init: i64) -> SignalId {
        self.signals.alloc(init)
    }

    /// Register a host program starting at absolute time `start`.
    pub fn add_host(&mut self, script: Vec<HostOp>, start: SimTime) -> HostId {
        let id = HostId(self.hosts.len() as u32);
        self.hosts.push(HostProgram::new(id, script, start));
        self.events.push(start, Event::HostResume(id));
        id
    }

    /// Host program state (marks, completion).
    pub fn host(&self, id: HostId) -> &HostProgram {
        &self.hosts[id.0 as usize]
    }

    /// Sum of busy nanoseconds over all engines (power accounting).
    pub fn total_engine_busy_ns(&self) -> u64 {
        self.engines.iter().map(|e| e.busy_ns).sum()
    }

    /// Number of engines that executed at least one command.
    pub fn engines_used(&self) -> usize {
        self.engines.iter().filter(|e| e.commands_executed > 0).count()
    }

    /// Build a power-model activity summary for a window of `duration_ns`.
    pub fn activity(&self, duration_ns: f64) -> super::power::Activity {
        super::power::Activity {
            duration_ns,
            engine_busy_ns: self.total_engine_busy_ns() as f64,
            engines_used: self.engines_used(),
            cu_busy_ns: 0.0,
            hbm_bytes: self.memory.total_traffic() as f64,
            link_bytes: self.link_bytes as f64,
            nic_bytes: 0.0,
        }
    }

    /// Run until no events remain. Returns makespan + deadlock report.
    pub fn run(&mut self) -> SimOutcome {
        while let Some((t, ev)) = self.events.pop() {
            debug_assert!(t >= self.time, "time went backwards: {t} < {}", self.time);
            self.time = t;
            self.events_processed += 1;
            self.dispatch(t, ev);
        }
        let deadlocked = self
            .hosts
            .iter()
            .filter(|h| !h.done)
            .map(|h| h.id)
            .collect();
        SimOutcome {
            makespan: self.time,
            deadlocked,
            events_processed: self.events_processed,
        }
    }

    fn dispatch(&mut self, t: SimTime, ev: Event) {
        match ev {
            Event::HostResume(h) => self.host_step(h, t),
            Event::Doorbell(e) => self.on_doorbell(e, t),
            Event::EngineReady(e) => {
                let i = self.eidx(e);
                self.engines[i].run_state = EngineRunState::Running;
                self.engines[i].issue_free_at = self.engines[i].issue_free_at.max(t);
                self.engine_advance(e, t);
            }
            Event::EngineAdvance(e) => self.engine_advance(e, t),
            Event::SignalUpdate { signal, op } => self.on_signal_update(signal, op, t),
        }
    }

    // ---------------- host execution ----------------

    fn host_step(&mut self, hid: HostId, event_t: SimTime) {
        // Resume semantics: if the host was waiting, the signal landed at
        // `event_t`; pay the observe cost.
        {
            let lat_observe = self.cfg.latency.t_host_observe;
            let h = &mut self.hosts[hid.0 as usize];
            if let Some((sig, at_least)) = h.waiting {
                let v = self.signals.get(sig);
                if v < at_least {
                    return; // spurious wake; still waiting
                }
                h.waiting = None;
                let start = h.now.max(event_t);
                h.now = start + ns(lat_observe);
                if self.cfg.trace {
                    self.trace
                        .record(None, 0, Phase::Sync, start, h.now);
                }
                h.pc += 1;
            } else {
                h.now = h.now.max(event_t);
            }
        }

        loop {
            let pc = self.hosts[hid.0 as usize].pc;
            if pc >= self.hosts[hid.0 as usize].script.len() {
                self.hosts[hid.0 as usize].done = true;
                return;
            }
            // Each op executes exactly once (pc strictly advances), so the
            // one op with a heap payload — CreateCommands — MOVES its
            // command vector into the engine queue instead of cloning it
            // per execution; the remaining ops are cheap to clone (§Perf
            // pass: this was the last per-command allocation on the host
            // hot path).
            if let HostOp::CreateCommands { engine, cmds, api } =
                &mut self.hosts[hid.0 as usize].script[pc]
            {
                let (engine, api) = (*engine, *api);
                let cmds = std::mem::take(cmds);
                let n_data = cmds.iter().filter(|c| c.is_data_move()).count();
                let cost = self.api_control_cost(&api, n_data, cmds.len());
                let h = &mut self.hosts[hid.0 as usize];
                let start = h.now;
                h.now += cost;
                let end = h.now;
                if self.cfg.trace {
                    self.trace.record(Some(engine), 0, Phase::Control, start, end);
                }
                let i = self.eidx(engine);
                let e = &mut self.engines[i];
                if e.pending.is_empty() {
                    e.pending = cmds; // adopt the script's buffer wholesale
                } else {
                    e.pending.extend(cmds);
                }
                self.hosts[hid.0 as usize].pc += 1;
                continue;
            }
            let op = self.hosts[hid.0 as usize].script[pc].clone();
            match op {
                // Handled by the move-out fast path above; kept for match
                // exhaustiveness only.
                HostOp::CreateCommands { .. } => unreachable!(),
                HostOp::RingDoorbell { engine } => {
                    let h = &mut self.hosts[hid.0 as usize];
                    h.now += ns(self.cfg.latency.t_doorbell);
                    let at = h.now;
                    self.events.push(at, Event::Doorbell(engine));
                }
                HostOp::WaitSignal { signal, at_least } => {
                    if self.signals.get(signal) >= at_least {
                        let lat = ns(self.cfg.latency.t_host_observe);
                        let h = &mut self.hosts[hid.0 as usize];
                        h.now += lat;
                    } else {
                        let h = &mut self.hosts[hid.0 as usize];
                        h.waiting = Some((signal, at_least));
                        self.sig_host_waiters.entry(signal).or_default().push(hid);
                        return;
                    }
                }
                HostOp::SetSignal { signal, value } => {
                    let h = &mut self.hosts[hid.0 as usize];
                    h.now += ns(self.cfg.latency.t_trigger_write);
                    let at = h.now;
                    self.events.push(
                        at,
                        Event::SignalUpdate {
                            signal,
                            op: AtomicOp::Set(value),
                        },
                    );
                }
                HostOp::Delay { ns: d } => {
                    self.hosts[hid.0 as usize].now += d;
                }
                HostOp::DelayUntil { at } => {
                    let h = &mut self.hosts[hid.0 as usize];
                    h.now = h.now.max(at);
                }
                HostOp::Mark { name } => {
                    let h = &mut self.hosts[hid.0 as usize];
                    let t = h.now;
                    h.marks.push((name, t));
                }
            }
            self.hosts[hid.0 as usize].pc += 1;
        }
    }

    /// Host cost of one CreateCommands op. Raw styles charge per queue
    /// entry (the ROCt prototypes build every packet); HIP styles charge
    /// per *API call* — `hipMemcpyAsync` is one flat setup/teardown per
    /// call, `hipMemcpyBatchAsync` a base plus a small per-copy increment
    /// (the trailing sync packet is part of the call, not an extra entry).
    fn api_control_cost(&self, api: &ApiKind, n_data_moves: usize, n_total: usize) -> SimTime {
        let l = &self.cfg.latency;
        let c = match api {
            ApiKind::Raw => l.t_control_per_cmd * n_total as f64,
            ApiKind::RawBatched => l.t_control_per_cmd_batched * n_total as f64,
            ApiKind::HipPerCopy => l.t_hip_api_per_copy,
            ApiKind::HipBatched => {
                l.t_hip_batch_base + l.t_hip_batch_per_copy * n_data_moves as f64
            }
        };
        ns(c)
    }

    // ---------------- engine execution ----------------

    fn on_doorbell(&mut self, eid: EngineId, t: SimTime) {
        let i = self.eidx(eid);
        let pending = std::mem::take(&mut self.engines[i].pending);
        self.engines[i].fetched.extend(pending);
        self.doorbell_at[i].get_or_insert(t);
        match self.engines[i].run_state {
            EngineRunState::Idle => {
                self.engines[i].run_state = EngineRunState::Waking;
                let wake = t + ns(self.cfg.latency.t_engine_wake);
                if self.cfg.trace {
                    self.trace.record(Some(eid), 0, Phase::Schedule, t, wake);
                }
                self.events.push(wake, Event::EngineReady(eid));
            }
            EngineRunState::Running => {
                let at = self.engines[i].issue_free_at.max(t);
                self.events.push(at, Event::EngineAdvance(eid));
            }
            // Waking: EngineReady already scheduled. Polling: commands queue
            // behind the poll; nothing to do until the signal lands.
            EngineRunState::Waking | EngineRunState::Polling { .. } => {}
        }
    }

    /// Issue at most one command, then reschedule.
    fn engine_advance(&mut self, eid: EngineId, t: SimTime) {
        let i = self.eidx(eid);
        if !matches!(self.engines[i].run_state, EngineRunState::Running) {
            return;
        }
        let now = self.engines[i].issue_free_at.max(t);
        // Fault injection: engine dies at stall_at.
        if let Some(s) = self.engines[i].stall_at {
            if now >= s {
                return;
            }
        }
        self.engines[i].retire_inflight(now);
        let Some(cmd) = self.engines[i].fetched.front().cloned() else {
            self.engines[i].run_state = EngineRunState::Idle;
            return;
        };
        match cmd {
            Command::Copy { .. } | Command::Bcst { .. } | Command::Swap { .. } => {
                self.issue_data_move(eid, cmd, now);
            }
            Command::Poll { signal, cond } => {
                if cond.satisfied(self.signals.get(signal)) {
                    let i = self.eidx(eid);
                    self.engines[i].fetched.pop_front();
                    let next = now + ns(self.cfg.latency.t_poll_check);
                    self.engines[i].issue_free_at = next;
                    self.engines[i].busy_ns += ns(self.cfg.latency.t_poll_check);
                    self.engines[i].commands_executed += 1;
                    self.events.push(next, Event::EngineAdvance(eid));
                } else {
                    let i = self.eidx(eid);
                    self.engines[i].run_state = EngineRunState::Polling { signal, cond };
                    self.sig_engine_pollers.entry(signal).or_default().push(eid);
                }
            }
            Command::Atomic { signal, op } => {
                let i = self.eidx(eid);
                self.engines[i].fetched.pop_front();
                // Completion fence: wait for all prior data commands.
                let fence = self.engines[i].last_data_done.max(now);
                let exec = fence + ns(self.cfg.latency.t_atomic);
                self.engines[i].issue_free_at = exec;
                self.engines[i].busy_ns += ns(self.cfg.latency.t_atomic);
                self.engines[i].commands_executed += 1;
                if self.cfg.trace {
                    self.trace.record(Some(eid), self.engines[i].cmd_seq, Phase::Sync, fence, exec);
                }
                self.events.push(exec, Event::SignalUpdate { signal, op });
                self.events.push(exec, Event::EngineAdvance(eid));
            }
            Command::Timestamp { slot } => {
                let i = self.eidx(eid);
                self.engines[i].fetched.pop_front();
                self.engines[i].commands_executed += 1;
                self.trace.stamps.push((slot, now));
                self.events.push(now, Event::EngineAdvance(eid));
            }
        }
    }

    /// Links a data-move command occupies: (link_idx, bytes) pairs; empty
    /// for same-node moves (handled at local-copy bandwidth).
    fn data_links(&self, cmd: &Command) -> Vec<(usize, u64)> {
        let topo = &self.cfg.topology;
        match *cmd {
            Command::Copy { src, dst, len } => {
                if src.node == dst.node {
                    vec![]
                } else {
                    vec![(topo.link_index(src.node, dst.node), len)]
                }
            }
            Command::Bcst {
                src,
                dst0,
                dst1,
                len,
            } => {
                let mut v = Vec::new();
                for d in [dst0, dst1] {
                    if d.node != src.node {
                        v.push((topo.link_index(src.node, d.node), len));
                    }
                }
                v
            }
            Command::Swap { a, b, len } => {
                if a.node == b.node {
                    vec![]
                } else {
                    vec![
                        (topo.link_index(a.node, b.node), len),
                        (topo.link_index(b.node, a.node), len),
                    ]
                }
            }
            _ => vec![],
        }
    }

    fn issue_data_move(&mut self, eid: EngineId, cmd: Command, now: SimTime) {
        let i = self.eidx(eid);
        // Hot path: copy out the handful of scalars used below instead of
        // cloning the whole LatencyModel per command (§Perf pass).
        let lat = &self.cfg.latency;
        let (t_issue, t_copy_fixed, link_eff) =
            (lat.t_issue, lat.t_copy_fixed, lat.dma_link_efficiency);
        let (engine_bw, swap_duplex) = (lat.engine_data_bw, lat.swap_duplex_factor);

        // Front-end decode.
        let decode_start = now;
        let decode_end = decode_start + ns(t_issue);

        // Per-command setup (address translation, load issue) runs on the
        // front-end and PIPELINES with the previous command's data phase —
        // this is the b2b overlap feature (§4.4). Hazards stall the setup.
        let hazard_t = self.engines[i].hazard_clear_at(&cmd, decode_end);
        let setup_done = hazard_t.max(decode_end) + ns(t_copy_fixed);

        // Wire phase serializes through the engine data path and the links.
        let links = self.data_links(&cmd);
        let link_avail = links
            .iter()
            .map(|&(l, _)| self.link_free[l])
            .max()
            .unwrap_or(0);
        let data_start = setup_done
            .max(self.engines[i].data_free_at)
            .max(link_avail);

        // Wire duration: slowest link leg (bcst/swap legs run in parallel),
        // floored by the engine's own data-path time — one engine pushing
        // 2× payload (bcst) cannot exceed its port bandwidth, which is what
        // hands the bandwidth-bound regime back to pcpy (§5.2.5).
        let wire = if links.is_empty() {
            let len = cmd.wire_bytes().max(1) / cmd.reads().len().max(1) as u64;
            ns(len as f64 / LOCAL_COPY_BW_BYTES_PER_NS)
        } else {
            let link_ns = links
                .iter()
                .map(|&(l, bytes)| {
                    let bw = self.cfg.topology.link(l).bw_bytes_per_ns;
                    ns(bytes as f64 / (bw * link_eff))
                })
                .max()
                .unwrap();
            let duplex = matches!(cmd, Command::Swap { .. });
            let eff_bw = if duplex { engine_bw * swap_duplex } else { engine_bw };
            let engine_ns = ns(cmd.wire_bytes() as f64 / eff_bw);
            link_ns.max(engine_ns)
        };
        let done = data_start + wire;

        // Reserve links (FIFO) + account wire traffic.
        for &(l, bytes) in &links {
            self.link_free[l] = done;
            self.link_bytes += bytes;
        }

        // Apply functional memory effects (issue order == dependency order;
        // hazardous commands were serialized above).
        match cmd {
            Command::Copy { src, dst, len } => {
                self.memory.dma_copy(src.node, src.offset, dst.node, dst.offset, len);
            }
            Command::Bcst {
                src,
                dst0,
                dst1,
                len,
            } => {
                self.memory.dma_bcst(
                    src.node,
                    src.offset,
                    (dst0.node, dst0.offset),
                    (dst1.node, dst1.offset),
                    len,
                );
            }
            Command::Swap { a, b, len } => {
                self.memory.dma_swap((a.node, a.offset), (b.node, b.offset), len);
            }
            _ => unreachable!(),
        }

        let e = &mut self.engines[i];
        e.fetched.pop_front();
        let seq = e.cmd_seq;
        e.cmd_seq += 1;
        e.commands_executed += 1;
        e.data_free_at = done;
        e.last_data_done = e.last_data_done.max(done);
        e.busy_ns += done - decode_start;
        e.note_inflight(Inflight {
            cmd_seq: seq,
            done_at: done,
            cmd,
        });
        // b2b: front-end freed at decode_end — the next command's decode
        // overlaps this command's data phase.
        e.issue_free_at = decode_end;
        if self.cfg.trace {
            self.trace
                .record(Some(eid), seq, Phase::Copy, decode_start, done);
            // Wire sub-span: bus occupancy only (Copy minus decode/setup),
            // consumed by the obs layer's per-engine exclusive wire track.
            self.trace.record_wire(eid, seq, data_start, done);
        }
        self.events.push(decode_end, Event::EngineAdvance(eid));
    }

    // ---------------- signals ----------------

    fn on_signal_update(&mut self, sig: SignalId, op: AtomicOp, t: SimTime) {
        let v = match op {
            AtomicOp::Add(d) => self.signals.add(sig, d),
            AtomicOp::Set(x) => self.signals.set(sig, x),
        };
        // Wake host waiters whose condition is now met.
        if let Some(waiters) = self.sig_host_waiters.get_mut(&sig) {
            let mut still = Vec::new();
            for hid in waiters.drain(..) {
                let h = &self.hosts[hid.0 as usize];
                match h.waiting {
                    Some((s, at_least)) if s == sig && v >= at_least => {
                        self.events.push(t, Event::HostResume(hid));
                    }
                    Some(_) => still.push(hid),
                    None => {}
                }
            }
            *waiters = still;
        }
        // Wake parked engines whose poll condition is now met.
        if let Some(pollers) = self.sig_engine_pollers.get_mut(&sig) {
            let mut still = Vec::new();
            for eid in pollers.drain(..) {
                let i = eid.gpu as usize * self.cfg.topology.engines_per_gpu as usize
                    + eid.idx as usize;
                match self.engines[i].run_state {
                    EngineRunState::Polling { signal, cond } if signal == sig => {
                        if cond.satisfied(v) {
                            self.engines[i].run_state = EngineRunState::Running;
                            // Pop the poll command itself.
                            self.engines[i].fetched.pop_front();
                            self.engines[i].commands_executed += 1;
                            let wake = t + ns(self.cfg.latency.t_poll_wake);
                            self.engines[i].issue_free_at =
                                self.engines[i].issue_free_at.max(wake);
                            self.events.push(wake, Event::EngineAdvance(eid));
                        } else {
                            still.push(eid);
                        }
                    }
                    _ => {}
                }
            }
            *pollers = still;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::command::{Addr, PollCond};
    use crate::sim::topology::NodeId;
    use crate::util::bytes::KB;

    fn eng(gpu: u8, idx: u8) -> EngineId {
        EngineId { gpu, idx }
    }

    /// One copy + atomic + host wait: the Fig. 6 phase pipeline end to end.
    #[test]
    fn single_copy_roundtrip() {
        let mut sim = Sim::new(SimConfig::mi300x().functional().traced());
        let sig = sim.alloc_signal(0);
        sim.memory.poke(NodeId::Gpu(0), 0, &[42u8; 4096]);
        let e = eng(0, 0);
        let cmds = vec![
            Command::Copy {
                src: Addr::new(NodeId::Gpu(0), 0),
                dst: Addr::new(NodeId::Gpu(1), 0),
                len: 4 * KB,
            },
            Command::Atomic {
                signal: sig,
                op: AtomicOp::Add(1),
            },
        ];
        sim.add_host(
            vec![
                HostOp::Mark { name: "start" },
                HostOp::CreateCommands {
                    engine: e,
                    cmds,
                    api: ApiKind::Raw,
                },
                HostOp::RingDoorbell { engine: e },
                HostOp::WaitSignal {
                    signal: sig,
                    at_least: 1,
                },
                HostOp::Mark { name: "end" },
            ],
            0,
        );
        let out = sim.run();
        assert!(out.deadlocked.is_empty());
        // Data arrived.
        assert_eq!(sim.memory.peek(NodeId::Gpu(1), 0, 4096), vec![42u8; 4096]);
        // Latency close to the analytic single-copy estimate.
        let h = sim.host(HostId(0));
        let elapsed = (h.mark("end").unwrap() - h.mark("start").unwrap()) as f64;
        let expect = sim.cfg.latency.single_copy_estimate_ns(4 * KB, 64.0);
        let rel = (elapsed - expect).abs() / expect;
        assert!(rel < 0.05, "elapsed {elapsed} vs estimate {expect}");
        // All four phases traced.
        let bd = sim.trace.breakdown();
        assert!(bd.iter().all(|&x| x > 0), "breakdown {bd:?}");
    }

    /// Two independent copies on ONE engine pipeline (b2b): the second
    /// copy's fixed cost is hidden, so total < 2 × single-copy data time.
    #[test]
    fn b2b_pipelines_independent_copies() {
        let len = 256 * KB;
        let run = |two_engines: bool| -> SimTime {
            let mut sim = Sim::new(SimConfig::mi300x());
            let sig = sim.alloc_signal(0);
            let mk = |peer: u8| Command::Copy {
                src: Addr::new(NodeId::Gpu(0), (peer as u64) << 32),
                dst: Addr::new(NodeId::Gpu(peer), 0),
                len,
            };
            let mut script = Vec::new();
            if two_engines {
                for (k, peer) in [1u8, 2u8].iter().enumerate() {
                    script.push(HostOp::CreateCommands {
                        engine: eng(0, k as u8),
                        cmds: vec![
                            mk(*peer),
                            Command::Atomic {
                                signal: sig,
                                op: AtomicOp::Add(1),
                            },
                        ],
                        api: ApiKind::Raw,
                    });
                    script.push(HostOp::RingDoorbell { engine: eng(0, k as u8) });
                }
            } else {
                script.push(HostOp::CreateCommands {
                    engine: eng(0, 0),
                    cmds: vec![
                        mk(1),
                        mk(2),
                        Command::Atomic {
                            signal: sig,
                            op: AtomicOp::Add(2),
                        },
                    ],
                    api: ApiKind::Raw,
                });
                script.push(HostOp::RingDoorbell { engine: eng(0, 0) });
            }
            script.push(HostOp::WaitSignal {
                signal: sig,
                at_least: 2,
            });
            sim.add_host(script, 0);
            let out = sim.run();
            assert!(out.deadlocked.is_empty());
            out.makespan
        };
        let one_engine = run(false);
        let two_engines = run(true);
        // Large copies: parallel engines win (two links in parallel).
        assert!(two_engines < one_engine);
        // But b2b on one engine avoids the second doorbell + wake: it must
        // be far better than fully serial (2× everything).
        let mut sim = Sim::new(SimConfig::mi300x());
        let est = sim.cfg.latency.single_copy_estimate_ns(len, 64.0);
        assert!((one_engine as f64) < 2.0 * est);
        let _ = &mut sim;
    }

    /// A RAW hazard forces serialization even on one engine.
    #[test]
    fn hazard_serializes() {
        let mut sim = Sim::new(SimConfig::mi300x().functional());
        let sig = sim.alloc_signal(0);
        sim.memory.poke(NodeId::Gpu(0), 0, &[7u8; 1024]);
        // copy1: gpu0[0..1k] -> gpu1[0..1k]; copy2 reads gpu1[0..1k] -> gpu2.
        let cmds = vec![
            Command::Copy {
                src: Addr::new(NodeId::Gpu(0), 0),
                dst: Addr::new(NodeId::Gpu(1), 0),
                len: 1024,
            },
            Command::Copy {
                src: Addr::new(NodeId::Gpu(1), 0),
                dst: Addr::new(NodeId::Gpu(2), 0),
                len: 1024,
            },
            Command::Atomic {
                signal: sig,
                op: AtomicOp::Add(1),
            },
        ];
        sim.add_host(
            vec![
                HostOp::CreateCommands {
                    engine: eng(1, 0),
                    cmds,
                    api: ApiKind::Raw,
                },
                HostOp::RingDoorbell { engine: eng(1, 0) },
                HostOp::WaitSignal {
                    signal: sig,
                    at_least: 1,
                },
            ],
            0,
        );
        sim.run();
        // Chained data visible at gpu2.
        assert_eq!(sim.memory.peek(NodeId::Gpu(2), 0, 1024), vec![7u8; 1024]);
    }

    /// Poll parks the engine until the host writes the trigger (prelaunch).
    #[test]
    fn poll_gates_execution() {
        let mut sim = Sim::new(SimConfig::mi300x().functional());
        let trigger = sim.alloc_signal(0);
        let done = sim.alloc_signal(0);
        sim.memory.poke(NodeId::Gpu(0), 0, &[9u8; 64]);
        let cmds = vec![
            Command::Poll {
                signal: trigger,
                cond: PollCond::Gte(1),
            },
            Command::Copy {
                src: Addr::new(NodeId::Gpu(0), 0),
                dst: Addr::new(NodeId::Gpu(1), 0),
                len: 64,
            },
            Command::Atomic {
                signal: done,
                op: AtomicOp::Add(1),
            },
        ];
        sim.add_host(
            vec![
                // Prelaunch: create + ring early.
                HostOp::CreateCommands {
                    engine: eng(0, 0),
                    cmds,
                    api: ApiKind::Raw,
                },
                HostOp::RingDoorbell { engine: eng(0, 0) },
                // Engine parks on the poll; fire the trigger much later.
                HostOp::Delay { ns: 50_000 },
                HostOp::Mark { name: "trigger" },
                HostOp::SetSignal {
                    signal: trigger,
                    value: 1,
                },
                HostOp::WaitSignal {
                    signal: done,
                    at_least: 1,
                },
                HostOp::Mark { name: "done" },
            ],
            0,
        );
        let out = sim.run();
        assert!(out.deadlocked.is_empty());
        let h = sim.host(HostId(0));
        let trigger_t = h.mark("trigger").unwrap();
        let done_t = h.mark("done").unwrap();
        // The copy executed only after the trigger, and quickly after:
        // the critical path excludes control + doorbell + wake.
        let crit = (done_t - trigger_t) as f64;
        let lat = &sim.cfg.latency;
        let upper = lat.t_trigger_write
            + lat.t_poll_wake
            + lat.t_issue
            + lat.copy_data_ns(64, 64.0)
            + lat.t_atomic
            + lat.t_host_observe
            + 500.0;
        assert!(crit < upper, "critical path {crit} vs bound {upper}");
        assert_eq!(sim.memory.peek(NodeId::Gpu(1), 0, 64), vec![9u8; 64]);
    }

    /// A host waiting on a signal nobody sets is reported as deadlocked.
    #[test]
    fn deadlock_detected() {
        let mut sim = Sim::new(SimConfig::mi300x());
        let sig = sim.alloc_signal(0);
        sim.add_host(
            vec![HostOp::WaitSignal {
                signal: sig,
                at_least: 1,
            }],
            0,
        );
        let out = sim.run();
        assert_eq!(out.deadlocked.len(), 1);
    }

    /// A reset simulator replays an episode bit-identically to a fresh one
    /// (same makespan, same event count, same signal ids, same bytes).
    #[test]
    fn reset_replays_identically() {
        let episode = |sim: &mut Sim| -> (SimTime, u64, u64) {
            let sig = sim.alloc_signal(0);
            assert_eq!(sig, SignalId(0), "signal ids must restart at 0");
            sim.memory.poke(NodeId::Gpu(0), 0, &[3u8; 4096]);
            sim.add_host(
                vec![
                    HostOp::CreateCommands {
                        engine: eng(0, 0),
                        cmds: vec![
                            Command::Copy {
                                src: Addr::new(NodeId::Gpu(0), 0),
                                dst: Addr::new(NodeId::Gpu(1), 0),
                                len: 4 * KB,
                            },
                            Command::Atomic {
                                signal: sig,
                                op: AtomicOp::Add(1),
                            },
                        ],
                        api: ApiKind::Raw,
                    },
                    HostOp::RingDoorbell { engine: eng(0, 0) },
                    HostOp::WaitSignal {
                        signal: sig,
                        at_least: 1,
                    },
                ],
                0,
            );
            let out = sim.run();
            assert!(out.deadlocked.is_empty());
            (out.makespan, out.events_processed, sim.link_bytes)
        };
        let mut fresh = Sim::new(SimConfig::mi300x().functional().traced());
        let want = episode(&mut fresh);
        let want_spans = fresh.trace.spans.len();

        let mut reused = Sim::new(SimConfig::mi300x().functional().traced());
        for _ in 0..3 {
            reused.reset();
            assert_eq!(episode(&mut reused), want);
            assert_eq!(reused.trace.spans.len(), want_spans);
            assert_eq!(
                reused.memory.peek(NodeId::Gpu(1), 0, 4096),
                vec![3u8; 4096]
            );
        }
    }

    /// Same-time events process deterministically; repeated runs agree.
    #[test]
    fn deterministic_replay() {
        let run_once = || {
            let mut sim = Sim::new(SimConfig::mi300x());
            let sig = sim.alloc_signal(0);
            for g in 0..4u8 {
                let cmds = vec![
                    Command::Copy {
                        src: Addr::new(NodeId::Gpu(g), 0),
                        dst: Addr::new(NodeId::Gpu((g + 1) % 4), 4096),
                        len: 64 * KB,
                    },
                    Command::Atomic {
                        signal: sig,
                        op: AtomicOp::Add(1),
                    },
                ];
                sim.add_host(
                    vec![
                        HostOp::CreateCommands {
                            engine: eng(g, 0),
                            cmds,
                            api: ApiKind::Raw,
                        },
                        HostOp::RingDoorbell { engine: eng(g, 0) },
                        HostOp::WaitSignal {
                            signal: sig,
                            at_least: 4,
                        },
                    ],
                    0,
                );
            }
            sim.run().makespan
        };
        assert_eq!(run_once(), run_once());
    }
}
