//! Simulated time: integer nanoseconds.

/// Simulation timestamp / duration in nanoseconds.
pub type SimTime = u64;

/// Convert a (possibly fractional) nanosecond count to [`SimTime`], rounding.
pub fn ns(x: f64) -> SimTime {
    debug_assert!(x >= 0.0, "negative duration {x}");
    x.round() as SimTime
}

/// Microseconds → [`SimTime`].
pub fn us(x: f64) -> SimTime {
    ns(x * 1e3)
}

/// Milliseconds → [`SimTime`].
pub fn ms(x: f64) -> SimTime {
    ns(x * 1e6)
}

/// [`SimTime`] → microseconds as f64 (for reporting).
pub fn to_us(t: SimTime) -> f64 {
    t as f64 / 1e3
}

/// [`SimTime`] → milliseconds as f64 (for reporting).
pub fn to_ms(t: SimTime) -> f64 {
    t as f64 / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(ns(1.4), 1);
        assert_eq!(ns(1.6), 2);
        assert_eq!(us(1.0), 1_000);
        assert_eq!(ms(2.0), 2_000_000);
        assert_eq!(to_us(1_500), 1.5);
        assert_eq!(to_ms(2_500_000), 2.5);
    }
}
