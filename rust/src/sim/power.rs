//! Component power model for the Fig. 15 reproduction.
//!
//! The paper measures total GPU power (XCD compute dies + IO dies + HBM,
//! §5.2.9) at 1 ms sampling while a collective runs. The deltas it reports
//! are driven by (a) CU occupancy — RCCL keeps CUs busy, DMA leaves XCDs
//! near idle (3.7× less XCD power); (b) engine count; (c) memory traffic —
//! `bcst` reads its source once for two destinations. This model converts
//! exactly those activity quantities, as accounted by the DES, into watts.

/// Activity summary for a window of `duration_ns`.
#[derive(Debug, Clone, Default)]
pub struct Activity {
    pub duration_ns: f64,
    /// Σ engine busy time (ns) across all engines used.
    pub engine_busy_ns: f64,
    /// Number of distinct DMA engines engaged.
    pub engines_used: usize,
    /// Σ CU busy time (ns) × CU count utilized, normalized to one XCD-GPU:
    /// `cu_busy_ns` = duration × cu_utilization for CU-driven collectives.
    pub cu_busy_ns: f64,
    /// HBM bytes read + written.
    pub hbm_bytes: f64,
    /// Bytes moved over links.
    pub link_bytes: f64,
    /// Bytes moved over the cross-node NIC (0 for intra-node collectives;
    /// charged by disaggregated KV migration, `kvcache::migrate`).
    pub nic_bytes: f64,
}

/// Per-component power constants (watts), MI300X-class magnitudes.
#[derive(Debug, Clone)]
pub struct PowerModel {
    /// Per-GPU idle floor (clocks, leakage, fans are excluded: GPU only).
    pub p_idle: f64,
    /// XCD power at full CU occupancy (all 8 XCDs busy).
    pub p_xcd_active: f64,
    /// XCD residual when only DMA runs (paper: 3.7× less XCD power).
    pub p_xcd_dma_residual: f64,
    /// IOD base when any DMA engine is active, per engine.
    pub p_iod_per_engine: f64,
    /// Link PHY power per GB/s of sustained traffic.
    pub p_link_per_gbps: f64,
    /// HBM power per GB/s of sustained traffic.
    pub p_hbm_per_gbps: f64,
    /// NIC (serdes + DMA over PCIe to the adapter) power per GB/s of
    /// sustained cross-node traffic. RDMA NICs burn noticeably more energy
    /// per byte than on-package links — ~0.45 W per GB/s keeps a saturated
    /// 400 Gb/s port in the ~20 W envelope of current adapters.
    pub p_nic_per_gbps: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel {
            p_idle: 140.0,
            p_xcd_active: 310.0,
            p_xcd_dma_residual: 58.0,
            p_iod_per_engine: 1.6,
            p_link_per_gbps: 0.11,
            p_hbm_per_gbps: 0.16,
            p_nic_per_gbps: 0.45,
        }
    }
}

/// Power sample (average watts over the activity window).
#[derive(Debug, Clone, Copy)]
pub struct PowerSample {
    pub xcd_w: f64,
    pub iod_w: f64,
    pub hbm_w: f64,
    pub idle_w: f64,
    /// NIC power from cross-node traffic; 0 unless `Activity::nic_bytes`
    /// was charged.
    pub nic_w: f64,
}

impl PowerSample {
    /// Total average power.
    pub fn total(&self) -> f64 {
        self.xcd_w + self.iod_w + self.hbm_w + self.idle_w + self.nic_w
    }
}

impl PowerModel {
    /// Average power over the window described by `a`.
    pub fn evaluate(&self, a: &Activity) -> PowerSample {
        assert!(a.duration_ns > 0.0, "empty activity window");
        let dur_s = a.duration_ns * 1e-9;
        // GB/s of sustained traffic over the window.
        let hbm_gbps = a.hbm_bytes / a.duration_ns; // bytes/ns == GB/s
        let link_gbps = a.link_bytes / a.duration_ns;
        let nic_gbps = a.nic_bytes / a.duration_ns;

        let cu_util = (a.cu_busy_ns / a.duration_ns).min(1.0);
        let dma_util = if a.engines_used > 0 {
            (a.engine_busy_ns / (a.duration_ns * a.engines_used.max(1) as f64)).min(1.0)
        } else {
            0.0
        };
        let xcd_w = if cu_util > 0.0 {
            self.p_xcd_active * cu_util
        } else if a.engines_used > 0 {
            self.p_xcd_dma_residual * dma_util.max(0.15)
        } else {
            0.0
        };
        let iod_w =
            a.engines_used as f64 * self.p_iod_per_engine * dma_util.max(if a.engines_used > 0 { 0.2 } else { 0.0 })
                + link_gbps * self.p_link_per_gbps;
        let hbm_w = hbm_gbps * self.p_hbm_per_gbps;
        let _ = dur_s;
        PowerSample {
            xcd_w,
            iod_w,
            hbm_w,
            idle_w: self.p_idle,
            nic_w: nic_gbps * self.p_nic_per_gbps,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn window(duration_ns: f64) -> Activity {
        Activity {
            duration_ns,
            ..Default::default()
        }
    }

    #[test]
    fn idle_floor() {
        let m = PowerModel::default();
        let s = m.evaluate(&window(1e6));
        assert!((s.total() - m.p_idle).abs() < 1e-9);
    }

    #[test]
    fn cu_collective_burns_more_xcd_than_dma() {
        let m = PowerModel::default();
        let mut cu = window(1e6);
        cu.cu_busy_ns = 0.9e6;
        cu.hbm_bytes = 400e6 * 0.9; // ~400 GB/s
        let mut dma = window(1e6);
        dma.engines_used = 7;
        dma.engine_busy_ns = 6.3e6; // 7 engines ~90% busy
        dma.hbm_bytes = 400e6 * 0.9;
        dma.link_bytes = 400e6 * 0.9;
        let s_cu = m.evaluate(&cu);
        let s_dma = m.evaluate(&dma);
        assert!(
            s_cu.xcd_w > 3.0 * s_dma.xcd_w,
            "XCD: cu={} dma={}",
            s_cu.xcd_w,
            s_dma.xcd_w
        );
        assert!(s_dma.total() < s_cu.total());
    }

    #[test]
    fn nic_traffic_is_charged_per_byte() {
        let m = PowerModel::default();
        // No NIC traffic → nic_w exactly 0, totals unchanged vs pre-NIC model.
        let quiet = m.evaluate(&window(1e6));
        assert_eq!(quiet.nic_w, 0.0);
        assert!((quiet.total() - m.p_idle).abs() < 1e-9);
        // 50 GB/s sustained (saturated 400 Gb/s port) lands in the ~20 W
        // adapter envelope and scales linearly with bytes.
        let mut mig = window(1e6);
        mig.nic_bytes = 50.0 * 1e6; // 50 bytes/ns over the window
        let s = m.evaluate(&mig);
        assert!((s.nic_w - 50.0 * m.p_nic_per_gbps).abs() < 1e-9);
        assert!(s.nic_w > 15.0 && s.nic_w < 30.0, "nic_w={}", s.nic_w);
        let mut half = window(1e6);
        half.nic_bytes = 25.0 * 1e6;
        assert!((m.evaluate(&half).nic_w * 2.0 - s.nic_w).abs() < 1e-9);
        assert!((s.total() - quiet.total() - s.nic_w).abs() < 1e-9);
    }

    #[test]
    fn traffic_scales_hbm_power() {
        let m = PowerModel::default();
        let mut lo = window(1e6);
        lo.hbm_bytes = 1e8;
        lo.engines_used = 1;
        lo.engine_busy_ns = 1e6;
        let mut hi = lo.clone();
        hi.hbm_bytes = 2e8;
        assert!(m.evaluate(&hi).hbm_w > m.evaluate(&lo).hbm_w);
    }
}
