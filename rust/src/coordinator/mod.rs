//! vLLM-like serving coordinator (L3 of the three-layer stack).
//!
//! A request flows: [`router`] → [`scheduler`] (admission + paged-KV block
//! accounting via `kvcache`) → KV fetch ([`kvcache::fetch`]) → continuous
//! batching ([`batcher`]) → decode steps. Two drivers share this machinery:
//!
//! - [`engine::VirtualEngine`] — virtual-time serving simulator on MI300X
//!   roofline timing; generates Figs. 16/17 and the §5.3.3 sweeps.
//! - [`server::Server`] — real threaded serving loop running the
//!   AOT-compiled JAX model through PJRT (`crate::runtime`); used by the
//!   end-to-end example with wall-clock metrics.
//!
//! Multi-node deployments (`ServeConfig::num_nodes > 1`) route per-step
//! collective sizing through the cluster-aware selector via [`comm`] and,
//! with `ServeConfig::comm_overlap` (the default), charge decode/prefill
//! only the **exposed** part of each step's all-reduces — the rest hides
//! behind per-layer compute ([`comm::CommCost`]); single-node deployments
//! keep the paper's flat behavior.
//!
//! Production-shaped load comes from [`workload`]: seeded arrival
//! processes (Poisson / bursty / diurnal trace), multi-tenant request
//! classes with per-class SLOs, and conversation replays — ingested
//! event-driven on the engine's virtual clock, with per-class percentile
//! breakdowns, SLO attainment, goodput and a queue-depth timeline in
//! [`metrics::ServeMetrics`].
//!
//! Disaggregated deployments ([`ServeConfig::disagg`], a
//! [`config::DisaggSpec`] of P prefill + D decode nodes) route prefill to
//! a dedicated node pool and charge each prefill→decode KV handoff as a
//! cross-node migration over the DMA/NIC path
//! ([`crate::kvcache::migrate`]) — layer-pipelined by default, so decode
//! starts as soon as the first KV chunk lands.
//!
//! Fault injection ([`ServeConfig::faults`] over
//! [`crate::cluster::faults`]) degrades the fleet the engine runs on;
//! [`config::DegradePolicy`] picks the reaction — re-select collectives
//! against the derated topology, drain sick nodes, shed best-effort
//! arrivals under SLO pressure, preempt running best-effort work — or
//! none of it (the degradation-blind baseline the figures compare
//! against). Healthy configs never materialize any of this.

pub mod batcher;
pub mod comm;
pub mod config;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod workload;

pub use comm::{CollectiveComm, CommCost};
pub use config::{DegradePolicy, DisaggSpec, ServeConfig};
pub use engine::VirtualEngine;
pub use metrics::{ClassStats, ServeMetrics, SloTarget};
pub use request::{Request, RequestState};
pub use workload::{ArrivalProcess, TenantClass, WorkloadSpec};
