//! Request router across engine replicas (the front half of a serving
//! deployment; reference: vllm-project/router). Supports round-robin and
//! least-outstanding routing with session stickiness for KV reuse.

use std::collections::HashMap;

use super::request::RequestId;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    /// Pick the replica with the fewest outstanding requests.
    LeastOutstanding,
}

/// Router over `n` replicas.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    outstanding: Vec<u64>,
    rr_next: usize,
    /// Session (prefix-cache) stickiness: session id → replica.
    sessions: HashMap<u64, usize>,
    assigned: HashMap<RequestId, usize>,
}

impl Router {
    /// Router with `replicas` backends.
    pub fn new(replicas: usize, policy: RoutePolicy) -> Self {
        assert!(replicas > 0);
        Router {
            policy,
            outstanding: vec![0; replicas],
            rr_next: 0,
            sessions: HashMap::new(),
            assigned: HashMap::new(),
        }
    }

    /// Number of replicas.
    pub fn replicas(&self) -> usize {
        self.outstanding.len()
    }

    /// Route a request; `session` pins repeat sessions to their replica
    /// (KV prefix reuse). Returns the replica index.
    pub fn route(&mut self, req: RequestId, session: Option<u64>) -> usize {
        if let Some(s) = session {
            if let Some(&r) = self.sessions.get(&s) {
                self.outstanding[r] += 1;
                self.assigned.insert(req, r);
                return r;
            }
        }
        let r = match self.policy {
            RoutePolicy::RoundRobin => {
                let r = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.outstanding.len();
                r
            }
            RoutePolicy::LeastOutstanding => self
                .outstanding
                .iter()
                .enumerate()
                .min_by_key(|&(_, &o)| o)
                .map(|(i, _)| i)
                .unwrap(),
        };
        if let Some(s) = session {
            self.sessions.insert(s, r);
        }
        self.outstanding[r] += 1;
        self.assigned.insert(req, r);
        r
    }

    /// Mark a request complete.
    pub fn complete(&mut self, req: RequestId) {
        if let Some(r) = self.assigned.remove(&req) {
            self.outstanding[r] = self.outstanding[r].saturating_sub(1);
        }
    }

    /// Outstanding per replica (metrics / tests).
    pub fn load(&self) -> &[u64] {
        &self.outstanding
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(3, RoutePolicy::RoundRobin);
        assert_eq!(r.route(0, None), 0);
        assert_eq!(r.route(1, None), 1);
        assert_eq!(r.route(2, None), 2);
        assert_eq!(r.route(3, None), 0);
    }

    #[test]
    fn least_outstanding_balances() {
        let mut r = Router::new(2, RoutePolicy::LeastOutstanding);
        let a = r.route(0, None);
        let b = r.route(1, None);
        assert_ne!(a, b);
        r.complete(0);
        // Replica `a` now has less load.
        assert_eq!(r.route(2, None), a);
    }

    #[test]
    fn sessions_stick() {
        let mut r = Router::new(4, RoutePolicy::LeastOutstanding);
        let first = r.route(0, Some(42));
        for i in 1..10 {
            assert_eq!(r.route(i, Some(42)), first);
        }
    }

    #[test]
    fn complete_decrements_once() {
        let mut r = Router::new(1, RoutePolicy::RoundRobin);
        r.route(0, None);
        r.complete(0);
        r.complete(0); // double-complete is a no-op
        assert_eq!(r.load(), &[0]);
    }
}
