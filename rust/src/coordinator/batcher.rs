//! Continuous batching policy: which queued requests to admit, given the
//! current decode batch and KV block budget (the vLLM scheduler's admission
//! half; block accounting itself lives in [`super::scheduler`]).

use crate::kvcache::BlockLayout;

use super::request::Request;

/// Admission decision for one scheduling round.
#[derive(Debug, Default)]
pub struct Admission {
    /// Indices (into the waiting queue) of requests to admit, in order.
    pub admit: Vec<usize>,
    /// Blocks the admissions will need.
    pub blocks_needed: u64,
}

/// Batching limits.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Max running requests.
    pub max_batch: usize,
    /// Max KV blocks admissions may claim per round (backpressure knob).
    pub max_blocks_per_round: u64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 64,
            max_blocks_per_round: u64::MAX,
        }
    }
}

/// Pick admissions FCFS under batch-slot and block-budget constraints.
///
/// `waiting` is any iterator over queued requests in FCFS order (the
/// scheduler passes a bounded borrow of its queue head — no per-round
/// snapshot clone).
pub fn plan_admissions<'a, I>(
    policy: &BatchPolicy,
    layout: &BlockLayout,
    waiting: I,
    running_now: usize,
    free_blocks: u64,
) -> Admission
where
    I: IntoIterator<Item = &'a Request>,
{
    let mut adm = Admission::default();
    let mut slots = policy.max_batch.saturating_sub(running_now);
    let mut budget = free_blocks.min(policy.max_blocks_per_round);
    for (i, req) in waiting.into_iter().enumerate() {
        if slots == 0 {
            break;
        }
        // Blocks for the full context (prompt + all tokens to generate).
        let need = layout.blocks_for(req.prompt_tokens + req.max_new_tokens);
        if need > budget {
            // FCFS head-of-line: stop rather than skip (prevents starvation).
            break;
        }
        adm.admit.push(i);
        adm.blocks_needed += need;
        budget -= need;
        slots -= 1;
    }
    adm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::QWEN25_0_5B;

    fn reqs(n: u64) -> Vec<Request> {
        (0..n).map(|i| Request::new(i, 4096, 64, 0)).collect()
    }

    fn layout() -> BlockLayout {
        BlockLayout::new(&QWEN25_0_5B, 16)
    }

    #[test]
    fn respects_batch_slots() {
        let p = BatchPolicy {
            max_batch: 4,
            ..Default::default()
        };
        let a = plan_admissions(&p, &layout(), &reqs(10), 2, u64::MAX);
        assert_eq!(a.admit, vec![0, 1]);
    }

    #[test]
    fn respects_block_budget() {
        let p = BatchPolicy::default();
        // Each request needs ceil(4160/16) = 260 blocks.
        let a = plan_admissions(&p, &layout(), &reqs(10), 0, 520);
        assert_eq!(a.admit.len(), 2);
        assert_eq!(a.blocks_needed, 520);
    }

    #[test]
    fn fcfs_no_skipping() {
        let mut rs = reqs(3);
        rs[0].prompt_tokens = 1 << 20; // huge head-of-line request
        let p = BatchPolicy::default();
        let a = plan_admissions(&p, &layout(), &rs, 0, 1000);
        // Head of line doesn't fit → nothing admitted (no starvation-prone
        // skip-ahead).
        assert!(a.admit.is_empty());
    }

    #[test]
    fn admits_all_when_unconstrained() {
        let p = BatchPolicy::default();
        let a = plan_admissions(&p, &layout(), &reqs(5), 0, u64::MAX);
        assert_eq!(a.admit.len(), 5);
    }
}
