//! Scheduler: owns the waiting queue, the running set, the KV block
//! allocator and the CPU tier; plans each serving round.
//!
//! Admission is **event-driven**: a round that admits nothing memoizes the
//! `(running, free-blocks)` state it was blocked under, and subsequent
//! rounds are skipped outright until a `submit` or `finish` event changes
//! that state — the per-step rebuild cost the pre-PR-7 engine paid on
//! every decode step disappears for stalled queues.

use std::collections::VecDeque;

use crate::kvcache::{BlockAllocator, BlockLayout, CpuStore};
use crate::util::rng::Rng;

use super::batcher::{plan_admissions, BatchPolicy};
use super::request::{Request, RequestId, RequestState};

/// What the engine must do for one admitted request.
#[derive(Debug)]
pub enum AdmitAction {
    /// CPU-cache hit: fetch `fetch_blocks` KV blocks (CPU → GPU), then
    /// decode. Only the count travels — fetch cost is address-independent
    /// (equal-sized blocks, engines assigned by copy index), so the engine
    /// synthesizes concrete copies via
    /// [`BlockLayout::synth_copies`](crate::kvcache::BlockLayout::synth_copies)
    /// only when it actually simulates the fetch. This drops three
    /// per-admission `Vec` allocations from the hot path.
    Fetch { req: Request, fetch_blocks: u64 },
    /// Miss: run prefill on the GPU, then decode.
    Prefill { req: Request },
}

/// Scheduler state.
pub struct Scheduler {
    pub layout: BlockLayout,
    pub alloc: BlockAllocator,
    pub cpu: CpuStore,
    pub policy: BatchPolicy,
    pub waiting: VecDeque<Request>,
    /// Synthetic hit-rate model (paper sweeps 50/70/100%).
    hit_rate: f64,
    /// Seed for the per-request hit draws (see [`Scheduler::hit_draw`]).
    seed: u64,
    /// GPU index this scheduler serves.
    pub gpu: u8,
    /// Counters.
    pub admitted: u64,
    pub hits: u64,
    pub misses: u64,
    pub rejected_oom: u64,
    /// Admission rounds skipped by the event-driven memo.
    pub planner_skips: u64,
    /// `(running_now, free_blocks)` the last fruitless round was blocked
    /// under; cleared by any `submit`/`finish` event.
    blocked_at: Option<(usize, u64)>,
}

impl Scheduler {
    /// Build a scheduler.
    pub fn new(
        layout: BlockLayout,
        gpu_blocks: u64,
        cpu_blocks: u64,
        policy: BatchPolicy,
        hit_rate: f64,
        seed: u64,
        gpu: u8,
    ) -> Self {
        Scheduler {
            layout,
            alloc: BlockAllocator::new(gpu_blocks),
            cpu: CpuStore::new(cpu_blocks),
            policy,
            waiting: VecDeque::new(),
            hit_rate,
            seed,
            gpu,
            admitted: 0,
            hits: 0,
            misses: 0,
            rejected_oom: 0,
            planner_skips: 0,
            blocked_at: None,
        }
    }

    /// Enqueue an incoming request (an arrival event: unblocks admission).
    pub fn submit(&mut self, req: Request) {
        self.blocked_at = None;
        self.waiting.push_back(req);
    }

    /// Number of requests not yet admitted.
    pub fn backlog(&self) -> usize {
        self.waiting.len()
    }

    /// Pre-populate the CPU tier with this request's full-context KV (the
    /// paper's 100%-hit methodology fills CPU memory with all tokens' KV).
    /// Keyed by `cache_key`, so conversation turns sharing a session key
    /// refresh one growing prefix entry instead of creating new ones.
    pub fn warm_cpu_cache(&mut self, req: &Request) {
        let blocks = self.layout.blocks_for(req.prompt_tokens);
        self.cpu.save(req.cache_key, blocks, req.prompt_tokens);
    }

    /// Synthetic hit draw for one request: a pure function of
    /// `(scheduler seed, request id)`, so the hit/miss outcome is
    /// independent of admission order, batching policy and backpressure
    /// state — replays stay deterministic under different
    /// [`BatchPolicy`] settings (a sequential stream would shift every
    /// draw after the first deferred admission).
    fn hit_draw(&self, id: RequestId) -> bool {
        Rng::new(self.seed ^ id.wrapping_mul(0x9E3779B97F4A7C15)).chance(self.hit_rate)
    }

    /// Plan admissions for this round; allocates GPU blocks and returns the
    /// per-request actions. `running_now` = current decode batch size.
    pub fn admit_round(&mut self, running_now: usize) -> Vec<AdmitAction> {
        if self.waiting.is_empty() {
            return Vec::new();
        }
        // Event-driven skip: a round that admitted nothing stays fruitless
        // until an arrival or a release changes the state it blocked under.
        let state = (running_now, self.alloc.available());
        if self.blocked_at == Some(state) {
            self.planner_skips += 1;
            return Vec::new();
        }
        // Admissions are a FCFS prefix bounded by batch slots, so only the
        // head of the queue needs planning (§Perf: cloning the whole
        // backlog made admission O(backlog²) at 2000 queued requests).
        let horizon = self
            .policy
            .max_batch
            .saturating_sub(running_now)
            .saturating_add(1);
        let adm = plan_admissions(
            &self.policy,
            &self.layout,
            self.waiting.iter().take(horizon),
            running_now,
            self.alloc.available(),
        );
        let mut actions = Vec::new();
        // Admissions are a FCFS prefix, so pop_front matches indices.
        for _ in 0..adm.admit.len() {
            let mut req = self.waiting.pop_front().unwrap();
            let need = self
                .layout
                .blocks_for(req.prompt_tokens + req.max_new_tokens);
            // The allocation is tracked per request id; admission only
            // needs to know it succeeded (no per-request copy of the
            // block list — addresses are synthesized at fetch time).
            if self.alloc.alloc(req.id, need).is_err() {
                self.rejected_oom += 1;
                self.waiting.push_front(req);
                break;
            }
            self.admitted += 1;
            let hit = self.cpu.lookup(req.cache_key).is_some() && self.hit_draw(req.id);
            if hit {
                self.hits += 1;
                req.state = RequestState::Fetching;
                let cpu_entry = self.cpu.lookup(req.cache_key).unwrap();
                let fetch_blocks = self
                    .layout
                    .blocks_for(req.prompt_tokens)
                    .min(cpu_entry.cpu_blocks.len() as u64);
                actions.push(AdmitAction::Fetch { req, fetch_blocks });
            } else {
                self.misses += 1;
                req.state = RequestState::Prefilling;
                actions.push(AdmitAction::Prefill { req });
            }
        }
        if actions.is_empty() {
            self.blocked_at = Some(state);
        }
        actions
    }

    /// Release a finished request's GPU blocks (a completion event:
    /// unblocks admission).
    pub fn finish(&mut self, id: RequestId) {
        self.blocked_at = None;
        self.alloc.release(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::QWEN25_0_5B;

    fn sched(hit_rate: f64) -> Scheduler {
        Scheduler::new(
            BlockLayout::new(&QWEN25_0_5B, 16),
            10_000,
            100_000,
            BatchPolicy::default(),
            hit_rate,
            7,
            0,
        )
    }

    fn submit_warm(s: &mut Scheduler, n: u64) {
        for i in 0..n {
            let r = Request::new(i, 4096, 32, 0);
            s.warm_cpu_cache(&r);
            s.submit(r);
        }
    }

    #[test]
    fn full_hit_rate_fetches() {
        let mut s = sched(1.0);
        submit_warm(&mut s, 4);
        let acts = s.admit_round(0);
        assert_eq!(acts.len(), 4);
        for a in &acts {
            match a {
                AdmitAction::Fetch { fetch_blocks, .. } => {
                    assert_eq!(*fetch_blocks, 256); // 4096/16
                }
                _ => panic!("expected fetch"),
            }
        }
        // The synthesized copies carry the layout's block size.
        let copies = s.layout.synth_copies(s.gpu, 256);
        assert_eq!(copies.len(), 256);
        assert_eq!(copies[0].2, s.layout.block_bytes);
        assert_eq!(s.hits, 4);
    }

    #[test]
    fn cold_cache_prefills() {
        let mut s = sched(1.0);
        for i in 0..3 {
            s.submit(Request::new(i, 4096, 32, 0)); // not warmed
        }
        let acts = s.admit_round(0);
        assert_eq!(acts.len(), 3);
        assert!(acts
            .iter()
            .all(|a| matches!(a, AdmitAction::Prefill { .. })));
        assert_eq!(s.misses, 3);
    }

    #[test]
    fn partial_hit_rate_mixes() {
        let mut s = sched(0.5);
        submit_warm(&mut s, 64);
        let acts = s.admit_round(0);
        let hits = acts
            .iter()
            .filter(|a| matches!(a, AdmitAction::Fetch { .. }))
            .count();
        assert!(hits > 10 && hits < 54, "hits={hits}");
    }

    /// Satellite fix: the hit/miss outcome per request is a pure function
    /// of `(seed, id)` — the same 64 requests admitted under a throttled
    /// `BatchPolicy` (many small rounds, interleaved releases) must
    /// produce exactly the hit set of one unconstrained round.
    #[test]
    fn hit_draws_are_independent_of_batch_policy() {
        let outcome = |policy: BatchPolicy, drain_between_rounds: bool| {
            let mut s = Scheduler::new(
                BlockLayout::new(&QWEN25_0_5B, 16),
                10_000,
                100_000,
                policy,
                0.5,
                7,
                0,
            );
            submit_warm(&mut s, 64);
            let mut hits = Vec::new();
            while s.backlog() > 0 {
                let acts = s.admit_round(0);
                assert!(!acts.is_empty(), "round must make progress");
                for a in acts {
                    let (id, hit) = match a {
                        AdmitAction::Fetch { req, .. } => (req.id, true),
                        AdmitAction::Prefill { req } => (req.id, false),
                    };
                    if hit {
                        hits.push(id);
                    }
                    if drain_between_rounds {
                        s.finish(id);
                    }
                }
            }
            hits
        };
        let one_round = outcome(BatchPolicy::default(), true);
        // Throttled: ≤ 2 admissions per round and a tight block budget, so
        // the backpressure path (deferred admissions) is exercised.
        let throttled = outcome(
            BatchPolicy {
                max_batch: 2,
                max_blocks_per_round: 600,
            },
            true,
        );
        assert!(!one_round.is_empty() && one_round.len() < 64);
        assert_eq!(one_round, throttled, "hit set must not depend on policy");
    }

    /// Event-driven admission: a blocked round is memoized and skipped
    /// until a submit/finish event changes the scheduler state.
    #[test]
    fn blocked_rounds_are_skipped_until_an_event() {
        let mut s = Scheduler::new(
            BlockLayout::new(&QWEN25_0_5B, 16),
            300, // only one request fits (needs 258)
            100_000,
            BatchPolicy::default(),
            1.0,
            7,
            0,
        );
        submit_warm(&mut s, 2);
        let first = s.admit_round(0);
        assert_eq!(first.len(), 1);
        let blocked_id = match &first[0] {
            AdmitAction::Fetch { req, .. } | AdmitAction::Prefill { req } => req.id,
        };
        // The second request cannot fit: the first fruitless round plans,
        // every following identical round is skipped outright.
        assert!(s.admit_round(1).is_empty());
        let skips_before = s.planner_skips;
        for _ in 0..5 {
            assert!(s.admit_round(1).is_empty());
        }
        assert_eq!(s.planner_skips, skips_before + 5);
        // A completion event invalidates the memo and admission resumes.
        s.finish(blocked_id);
        assert_eq!(s.admit_round(0).len(), 1);
        s.alloc.check_invariants();
    }

    /// Conversation turns share a session cache key: a follow-up turn hits
    /// the prefix its predecessor warmed even though its request id (and
    /// longer prompt) differ.
    #[test]
    fn session_cache_key_hits_across_turns() {
        let mut s = sched(1.0);
        let turn0 = Request::new(0, 1024, 16, 0).with_cache_key(500);
        s.warm_cpu_cache(&turn0);
        s.submit(turn0);
        let turn1 = Request::new(1, 2048, 16, 10).with_cache_key(500);
        s.warm_cpu_cache(&turn1); // refresh: now covers the longer prefix
        s.submit(turn1);
        let acts = s.admit_round(0);
        assert_eq!(acts.len(), 2);
        assert!(acts.iter().all(|a| matches!(a, AdmitAction::Fetch { .. })));
        assert_eq!(s.hits, 2);
    }

    #[test]
    fn oom_requeues_and_counts() {
        let mut s = Scheduler::new(
            BlockLayout::new(&QWEN25_0_5B, 16),
            300, // only one request fits (needs 258)
            100_000,
            BatchPolicy::default(),
            1.0,
            7,
            0,
        );
        submit_warm(&mut s, 2);
        let acts = s.admit_round(0);
        assert_eq!(acts.len(), 1);
        assert_eq!(s.backlog(), 1);
        s.alloc.check_invariants();
    }

    #[test]
    fn finish_releases_blocks() {
        let mut s = sched(1.0);
        submit_warm(&mut s, 1);
        let before = s.alloc.available();
        let acts = s.admit_round(0);
        let id = match &acts[0] {
            AdmitAction::Fetch { req, .. } => req.id,
            AdmitAction::Prefill { req } => req.id,
        };
        assert!(s.alloc.available() < before);
        s.finish(id);
        assert_eq!(s.alloc.available(), before);
    }
}
