//! Scheduler: owns the waiting queue, the running set, the KV block
//! allocator and the CPU tier; plans each serving round.

use std::collections::VecDeque;

use crate::kvcache::fetch::CopySpec;
use crate::kvcache::{BlockAllocator, BlockLayout, CpuStore};
use crate::util::rng::Rng;

use super::batcher::{plan_admissions, BatchPolicy};
use super::request::{Request, RequestId, RequestState};

/// What the engine must do for one admitted request.
#[derive(Debug)]
pub enum AdmitAction {
    /// CPU-cache hit: fetch these KV blocks (CPU → GPU), then decode.
    Fetch { req: Request, copies: Vec<CopySpec> },
    /// Miss: run prefill on the GPU, then decode.
    Prefill { req: Request },
}

/// Scheduler state.
pub struct Scheduler {
    pub layout: BlockLayout,
    pub alloc: BlockAllocator,
    pub cpu: CpuStore,
    pub policy: BatchPolicy,
    pub waiting: VecDeque<Request>,
    /// Synthetic hit-rate model (paper sweeps 50/70/100%).
    hit_rate: f64,
    rng: Rng,
    /// GPU index this scheduler serves.
    pub gpu: u8,
    /// Counters.
    pub admitted: u64,
    pub hits: u64,
    pub misses: u64,
    pub rejected_oom: u64,
}

impl Scheduler {
    /// Build a scheduler.
    pub fn new(
        layout: BlockLayout,
        gpu_blocks: u64,
        cpu_blocks: u64,
        policy: BatchPolicy,
        hit_rate: f64,
        seed: u64,
        gpu: u8,
    ) -> Self {
        Scheduler {
            layout,
            alloc: BlockAllocator::new(gpu_blocks),
            cpu: CpuStore::new(cpu_blocks),
            policy,
            waiting: VecDeque::new(),
            hit_rate,
            rng: Rng::new(seed),
            gpu,
            admitted: 0,
            hits: 0,
            misses: 0,
            rejected_oom: 0,
        }
    }

    /// Enqueue an incoming request.
    pub fn submit(&mut self, req: Request) {
        self.waiting.push_back(req);
    }

    /// Number of requests not yet admitted.
    pub fn backlog(&self) -> usize {
        self.waiting.len()
    }

    /// Pre-populate the CPU tier with this request's full-context KV (the
    /// paper's 100%-hit methodology fills CPU memory with all tokens' KV).
    pub fn warm_cpu_cache(&mut self, req: &Request) {
        let blocks = self.layout.blocks_for(req.prompt_tokens);
        self.cpu.save(req.id, blocks, req.prompt_tokens);
    }

    /// Plan admissions for this round; allocates GPU blocks and returns the
    /// per-request actions. `running_now` = current decode batch size.
    pub fn admit_round(&mut self, running_now: usize) -> Vec<AdmitAction> {
        // Admissions are a FCFS prefix bounded by batch slots, so only the
        // head of the queue needs snapshotting (§Perf: cloning the whole
        // backlog made admission O(backlog²) at 2000 queued requests).
        let horizon = self
            .policy
            .max_batch
            .saturating_sub(running_now)
            .saturating_add(1);
        let waiting_snapshot: Vec<Request> =
            self.waiting.iter().take(horizon).cloned().collect();
        let adm = plan_admissions(
            &self.policy,
            &self.layout,
            &waiting_snapshot,
            running_now,
            self.alloc.available(),
        );
        let mut actions = Vec::new();
        // Admissions are a FCFS prefix, so pop_front matches indices.
        for _ in 0..adm.admit.len() {
            let mut req = self.waiting.pop_front().unwrap();
            let need = self
                .layout
                .blocks_for(req.prompt_tokens + req.max_new_tokens);
            let gpu_blocks = match self.alloc.alloc(req.id, need) {
                Ok(b) => b.to_vec(),
                Err(_) => {
                    self.rejected_oom += 1;
                    self.waiting.push_front(req);
                    break;
                }
            };
            self.admitted += 1;
            let hit = {
                let cached = self.cpu.lookup(req.id).is_some();
                cached && self.rng.chance(self.hit_rate)
            };
            if hit {
                self.hits += 1;
                req.state = RequestState::Fetching;
                let cpu_entry = self.cpu.lookup(req.id).unwrap();
                let n_fetch = self
                    .layout
                    .blocks_for(req.prompt_tokens)
                    .min(cpu_entry.cpu_blocks.len() as u64);
                let cpu_blocks = cpu_entry.cpu_blocks.clone();
                let copies: Vec<CopySpec> = (0..n_fetch)
                    .map(|i| {
                        (
                            self.layout.cpu_block_addr(cpu_blocks[i as usize]),
                            self.layout.gpu_block_addr(self.gpu, gpu_blocks[i as usize]),
                            self.layout.block_bytes,
                        )
                    })
                    .collect();
                actions.push(AdmitAction::Fetch { req, copies });
            } else {
                self.misses += 1;
                req.state = RequestState::Prefilling;
                actions.push(AdmitAction::Prefill { req });
            }
        }
        actions
    }

    /// Release a finished request's GPU blocks.
    pub fn finish(&mut self, id: RequestId) {
        self.alloc.release(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::QWEN25_0_5B;

    fn sched(hit_rate: f64) -> Scheduler {
        Scheduler::new(
            BlockLayout::new(&QWEN25_0_5B, 16),
            10_000,
            100_000,
            BatchPolicy::default(),
            hit_rate,
            7,
            0,
        )
    }

    fn submit_warm(s: &mut Scheduler, n: u64) {
        for i in 0..n {
            let r = Request::new(i, 4096, 32, 0);
            s.warm_cpu_cache(&r);
            s.submit(r);
        }
    }

    #[test]
    fn full_hit_rate_fetches() {
        let mut s = sched(1.0);
        submit_warm(&mut s, 4);
        let acts = s.admit_round(0);
        assert_eq!(acts.len(), 4);
        for a in &acts {
            match a {
                AdmitAction::Fetch { copies, .. } => {
                    assert_eq!(copies.len(), 256); // 4096/16
                    assert_eq!(copies[0].2, s.layout.block_bytes);
                }
                _ => panic!("expected fetch"),
            }
        }
        assert_eq!(s.hits, 4);
    }

    #[test]
    fn cold_cache_prefills() {
        let mut s = sched(1.0);
        for i in 0..3 {
            s.submit(Request::new(i, 4096, 32, 0)); // not warmed
        }
        let acts = s.admit_round(0);
        assert_eq!(acts.len(), 3);
        assert!(acts
            .iter()
            .all(|a| matches!(a, AdmitAction::Prefill { .. })));
        assert_eq!(s.misses, 3);
    }

    #[test]
    fn partial_hit_rate_mixes() {
        let mut s = sched(0.5);
        submit_warm(&mut s, 64);
        let acts = s.admit_round(0);
        let hits = acts
            .iter()
            .filter(|a| matches!(a, AdmitAction::Fetch { .. }))
            .count();
        assert!(hits > 10 && hits < 54, "hits={hits}");
    }

    #[test]
    fn oom_requeues_and_counts() {
        let mut s = Scheduler::new(
            BlockLayout::new(&QWEN25_0_5B, 16),
            300, // only one request fits (needs 258)
            100_000,
            BatchPolicy::default(),
            1.0,
            7,
            0,
        );
        submit_warm(&mut s, 2);
        let acts = s.admit_round(0);
        assert_eq!(acts.len(), 1);
        assert_eq!(s.backlog(), 1);
        s.alloc.check_invariants();
    }

    #[test]
    fn finish_releases_blocks() {
        let mut s = sched(1.0);
        submit_warm(&mut s, 1);
        let before = s.alloc.available();
        let acts = s.admit_round(0);
        let id = match &acts[0] {
            AdmitAction::Fetch { req, .. } => req.id,
            AdmitAction::Prefill { req } => req.id,
        };
        assert!(s.alloc.available() < before);
        s.finish(id);
        assert_eq!(s.alloc.available(), before);
    }
}
