//! Real threaded serving loop (wall-clock): the end-to-end driver used by
//! `examples/llm_serving.rs`. One worker thread per replica runs continuous
//! batching over a [`ModelBackend`] (the PJRT executor in production, a
//! stub in tests), with KV save/fetch exercised functionally through the
//! DMA simulator's memory system.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::kvcache::fetch::{run_fetch, FetchImpl};
use crate::kvcache::BlockLayout;
use crate::sim::{Sim, SimConfig};

use super::batcher::BatchPolicy;
use super::metrics::ServeMetrics;
use super::request::{Request, RequestId};
use super::scheduler::{AdmitAction, Scheduler};

/// Model compute abstraction: the real implementation wraps the PJRT
/// executables compiled from the JAX model (see `crate::runtime`).
///
/// Not `Send`: PJRT handles are single-threaded, so the backend is
/// *constructed inside* the worker thread via the factory passed to
/// [`Server::start`].
pub trait ModelBackend: 'static {
    /// Prefill `prompt`, returning the first generated token.
    fn prefill(&mut self, prompt: &[u32]) -> u32;
    /// One decode step over the batch's last tokens; returns next tokens.
    fn decode(&mut self, last_tokens: &[u32]) -> Vec<u32>;
    /// KV bytes per token (for functional KV movement accounting).
    fn kv_bytes_per_token(&self) -> u64;
}

/// A completed request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: RequestId,
    pub tokens: Vec<u32>,
    pub ttft: std::time::Duration,
    pub total: std::time::Duration,
}

enum Msg {
    Submit { req: Request, prompt: Vec<u32> },
    Shutdown,
}

/// Server configuration (wall-clock path).
pub struct ServerConfig {
    pub layout: BlockLayout,
    pub fetch: FetchImpl,
    pub gpu_blocks: u64,
    pub cpu_blocks: u64,
    pub max_batch: usize,
}

/// One serving replica: a worker thread + submission channel.
pub struct Server {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<ServeMetrics>>,
    completions: Receiver<Completion>,
}

impl Server {
    /// Spawn the worker; `make_backend` runs on the worker thread (PJRT
    /// handles are not `Send`).
    pub fn start<B: ModelBackend, F>(cfg: ServerConfig, make_backend: F) -> Self
    where
        F: FnOnce() -> B + Send + 'static,
    {
        let (tx, rx) = channel::<Msg>();
        let (ctx, crx) = channel::<Completion>();
        let worker = std::thread::spawn(move || {
            let mut backend = make_backend();
            let mut sched = Scheduler::new(
                cfg.layout.clone(),
                cfg.gpu_blocks,
                cfg.cpu_blocks,
                BatchPolicy {
                    max_batch: cfg.max_batch,
                    ..Default::default()
                },
                1.0,
                7,
                0,
            );
            // Functional memory substrate for KV save/fetch.
            let mut kv_sim = Sim::new(SimConfig::mi300x().functional());
            let mut metrics = ServeMetrics::default();
            let t0 = Instant::now();
            struct Running {
                req: Request,
                prompt: Vec<u32>,
                out: Vec<u32>,
                started: Instant,
                first_tok: Option<Instant>,
            }
            let mut running: Vec<Running> = Vec::new();
            let mut prompts: std::collections::HashMap<RequestId, Vec<u32>> =
                std::collections::HashMap::new();
            let mut open = true;
            while open || !running.is_empty() || sched.backlog() > 0 {
                // Drain the submission channel (non-blocking when busy).
                loop {
                    let msg = if running.is_empty() && sched.backlog() == 0 && open {
                        rx.recv().ok()
                    } else {
                        match rx.try_recv() {
                            Ok(m) => Some(m),
                            Err(_) => None,
                        }
                    };
                    match msg {
                        Some(Msg::Submit { req, prompt }) => {
                            // Model the paper's save path: KV of the prompt
                            // already resident in CPU memory.
                            sched.warm_cpu_cache(&req);
                            prompts.insert(req.id, prompt);
                            sched.submit(req);
                        }
                        Some(Msg::Shutdown) => {
                            open = false;
                            break;
                        }
                        None => break,
                    }
                }
                // Admit.
                for act in sched.admit_round(running.len()) {
                    let started = Instant::now();
                    match act {
                        AdmitAction::Fetch { req, fetch_blocks } => {
                            metrics.cache_hits += 1;
                            metrics.fetch_bytes +=
                                fetch_blocks * cfg.layout.block_bytes;
                            // Functional DMA fetch through the simulator
                            // (equal-shape copies; see `synth_copies`).
                            let copies = cfg.layout.synth_copies(0, fetch_blocks);
                            run_fetch(&mut kv_sim, cfg.fetch, &copies);
                            let prompt = prompts.remove(&req.id).unwrap_or_default();
                            // With KV resident, the "prefill" is one step
                            // over the cached context.
                            let tok = backend.prefill(&prompt);
                            metrics.tokens_out += 1;
                            running.push(Running {
                                req,
                                prompt,
                                out: vec![tok],
                                started,
                                first_tok: Some(Instant::now()),
                            });
                        }
                        AdmitAction::Prefill { req } => {
                            metrics.cache_misses += 1;
                            let prompt = prompts.remove(&req.id).unwrap_or_default();
                            let tok = backend.prefill(&prompt);
                            metrics.tokens_out += 1;
                            running.push(Running {
                                req,
                                prompt,
                                out: vec![tok],
                                started,
                                first_tok: Some(Instant::now()),
                            });
                        }
                    }
                }
                // Complete any request already at quota (prefill token may
                // have satisfied max_new_tokens == 1).
                let now = Instant::now();
                let mut i = 0;
                while i < running.len() {
                    if running[i].out.len() as u64 >= running[i].req.max_new_tokens {
                        let r = running.swap_remove(i);
                        sched.finish(r.req.id);
                        metrics.finished += 1;
                        let ttft = r.first_tok.unwrap() - r.started;
                        metrics.ttft_ns.push(ttft.as_nanos() as f64);
                        let _ = ctx.send(Completion {
                            id: r.req.id,
                            tokens: r.out,
                            ttft,
                            total: now - r.started,
                        });
                        let _ = &r.prompt;
                    } else {
                        i += 1;
                    }
                }
                if running.is_empty() {
                    continue;
                }
                // One decode step for the batch.
                let last: Vec<u32> = running.iter().map(|r| *r.out.last().unwrap()).collect();
                let next = backend.decode(&last);
                let now = Instant::now();
                let mut i = 0;
                while i < running.len() {
                    running[i].out.push(next[i.min(next.len() - 1)]);
                    metrics.tokens_out += 1;
                    let done =
                        running[i].out.len() as u64 >= running[i].req.max_new_tokens;
                    if done {
                        let r = running.swap_remove(i);
                        sched.finish(r.req.id);
                        metrics.finished += 1;
                        let ttft = r.first_tok.unwrap() - r.started;
                        metrics.ttft_ns.push(ttft.as_nanos() as f64);
                        let _ = ctx.send(Completion {
                            id: r.req.id,
                            tokens: r.out,
                            ttft,
                            total: now - r.started,
                        });
                        let _ = &r.prompt;
                    } else {
                        i += 1;
                    }
                }
            }
            metrics.wall_ns = t0.elapsed().as_nanos() as u64;
            metrics
        });
        Server {
            tx,
            worker: Some(worker),
            completions: crx,
        }
    }

    /// Submit a request with its prompt tokens.
    pub fn submit(&self, req: Request, prompt: Vec<u32>) {
        self.tx
            .send(Msg::Submit { req, prompt })
            .expect("worker gone");
    }

    /// Receive the next completion (blocking).
    pub fn next_completion(&self) -> Option<Completion> {
        self.completions.recv().ok()
    }

    /// Stop accepting work and join, returning the run metrics.
    pub fn shutdown(mut self) -> ServeMetrics {
        let _ = self.tx.send(Msg::Shutdown);
        self.worker.take().unwrap().join().expect("worker panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::QWEN25_0_5B;

    /// Deterministic echo backend: emits prompt-length-derived tokens.
    struct EchoBackend;
    impl ModelBackend for EchoBackend {
        fn prefill(&mut self, prompt: &[u32]) -> u32 {
            prompt.len() as u32
        }
        fn decode(&mut self, last: &[u32]) -> Vec<u32> {
            last.iter().map(|&t| t + 1).collect()
        }
        fn kv_bytes_per_token(&self) -> u64 {
            QWEN25_0_5B.kv_bytes_per_token()
        }
    }

    fn server(fetch: FetchImpl) -> Server {
        Server::start(
            ServerConfig {
                layout: BlockLayout::new(&QWEN25_0_5B, 16),
                fetch,
                gpu_blocks: 1 << 16,
                cpu_blocks: 1 << 18,
                max_batch: 8,
            },
            || EchoBackend,
        )
    }

    #[test]
    fn serves_batched_requests_end_to_end() {
        let s = server(FetchImpl::DmaB2b);
        for i in 0..12u64 {
            s.submit(Request::new(i, 64, 4, 0), vec![7; 64]);
        }
        let mut seen = std::collections::HashSet::new();
        for _ in 0..12 {
            let c = s.next_completion().unwrap();
            assert_eq!(c.tokens.len(), 4);
            assert_eq!(c.tokens[0], 64); // echo of prompt length
            assert_eq!(c.tokens[1], 65); // decode increments
            seen.insert(c.id);
        }
        assert_eq!(seen.len(), 12);
        let m = s.shutdown();
        assert_eq!(m.finished, 12);
        assert_eq!(m.tokens_out, 12 * 4); // 1 prefill + 3 decode tokens each
        assert!(m.cache_hits + m.cache_misses == 12);
    }

    #[test]
    fn all_fetch_impls_serve() {
        for f in [FetchImpl::DmaBaseline, FetchImpl::DmaB2b, FetchImpl::Kernel] {
            let s = server(f);
            s.submit(Request::new(0, 32, 2, 0), vec![1; 32]);
            let c = s.next_completion().unwrap();
            assert_eq!(c.tokens.len(), 2);
            let m = s.shutdown();
            assert_eq!(m.finished, 1);
        }
    }
}
