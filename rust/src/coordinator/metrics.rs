//! Serving metrics: TTFT distribution, throughput, utilization counters.

use crate::util::stats;

/// Aggregated serving metrics (times in ns unless noted).
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub ttft_ns: Vec<f64>,
    pub finished: u64,
    pub tokens_out: u64,
    pub wall_ns: u64,
    /// Host (scheduler thread) busy time.
    pub host_busy_ns: u64,
    /// GPU busy time (decode + prefill + kernel-fetch CU time).
    pub gpu_busy_ns: u64,
    /// Total cross-node collective (TP all-reduce) time; 0 on single-node
    /// deployments (folded into the perf model there). Always equals
    /// `comm_exposed_ns + comm_hidden_ns`.
    pub comm_ns: u64,
    /// Collective time actually charged on the decode/prefill critical
    /// path — the part no compute window covers (all of `comm_ns` when
    /// overlap is disabled).
    pub comm_exposed_ns: u64,
    /// Collective time hidden behind per-layer compute by the
    /// chunk-granular overlap model (`coordinator::comm::CommCost`).
    pub comm_hidden_ns: u64,
    /// Total fetch bytes moved CPU→GPU.
    pub fetch_bytes: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl ServeMetrics {
    /// Output tokens per second.
    pub fn tps(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.tokens_out as f64 / (self.wall_ns as f64 / 1e9)
    }

    /// Mean TTFT in ms.
    pub fn ttft_mean_ms(&self) -> f64 {
        stats::mean(&self.ttft_ns) / 1e6
    }

    /// p99 TTFT in ms.
    pub fn ttft_p99_ms(&self) -> f64 {
        stats::percentile(&self.ttft_ns, 99.0) / 1e6
    }

    /// Fraction of collective time hidden behind compute (0 when no
    /// collectives ran).
    pub fn comm_hidden_frac(&self) -> f64 {
        if self.comm_ns == 0 {
            return 0.0;
        }
        self.comm_hidden_ns as f64 / self.comm_ns as f64
    }

    /// GPU utilization over the run.
    pub fn gpu_util(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.gpu_busy_ns as f64 / self.wall_ns as f64
    }

    /// One-line summary.
    pub fn summary(&self) -> String {
        format!(
            "{} reqs, {} tok, {:.1} tok/s, ttft mean {:.1}ms p99 {:.1}ms, gpu util {:.0}%",
            self.finished,
            self.tokens_out,
            self.tps(),
            self.ttft_mean_ms(),
            self.ttft_p99_ms(),
            self.gpu_util() * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tps_and_ttft() {
        let m = ServeMetrics {
            ttft_ns: vec![1e6, 2e6, 3e6],
            finished: 3,
            tokens_out: 300,
            wall_ns: 2_000_000_000,
            ..Default::default()
        };
        assert!((m.tps() - 150.0).abs() < 1e-9);
        assert!((m.ttft_mean_ms() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_is_zero() {
        let m = ServeMetrics::default();
        assert_eq!(m.tps(), 0.0);
        assert_eq!(m.gpu_util(), 0.0);
        assert_eq!(m.comm_hidden_frac(), 0.0);
    }

    #[test]
    fn comm_split_fraction() {
        let m = ServeMetrics {
            comm_ns: 100,
            comm_exposed_ns: 30,
            comm_hidden_ns: 70,
            ..Default::default()
        };
        assert_eq!(m.comm_exposed_ns + m.comm_hidden_ns, m.comm_ns);
        assert!((m.comm_hidden_frac() - 0.7).abs() < 1e-12);
    }
}
