//! Serving metrics: TTFT / per-token latency distributions (nearest-rank
//! percentiles), throughput, utilization counters, per-request span
//! records, cross-episode cache hit rates — and, for workload-driven runs
//! ([`crate::coordinator::workload`]), per-tenant-class percentile
//! breakdowns, SLO attainment fractions, goodput and a bounded
//! queue-depth timeline.
//!
//! All latency series are [`LatHist`] accumulators and the per-request
//! spans a [`Reservoir`], so memory stays bounded at million-request
//! episode sizes: exact (bit-identical to the historical `Vec`s) up to
//! `ServeConfig::metrics_sample_cap` samples, a ≤ 1 % relative-error
//! sketch / uniform sample beyond it.

use crate::util::stats::{LatHist, Reservoir};

/// One finished request's lifetime on the serving timeline (ns) — the
/// record behind the per-request Perfetto spans and the percentile
/// distributions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestSpan {
    pub id: u64,
    /// Submission instant.
    pub arrival_ns: u64,
    /// First token completion (TTFT = `first_token_ns - arrival_ns`).
    pub first_token_ns: u64,
    /// Last token completion.
    pub finish_ns: u64,
    /// Tokens generated.
    pub tokens: u64,
    /// Tenant class index (0 for single-class workloads).
    pub class: u8,
}

impl RequestSpan {
    /// Mean per-token latency over the decode phase (ns/token); `None`
    /// for single-token requests (no inter-token interval exists).
    pub fn tpot_ns(&self) -> Option<f64> {
        if self.tokens < 2 {
            return None;
        }
        Some((self.finish_ns - self.first_token_ns) as f64 / (self.tokens - 1) as f64)
    }

    /// Time-to-first-token (ns).
    pub fn ttft_ns(&self) -> u64 {
        self.first_token_ns - self.arrival_ns
    }
}

/// Per-tenant latency service-level objective. A finished request meets
/// its SLO when TTFT ≤ `ttft_ms` AND (when it produced ≥ 2 tokens) its
/// mean per-token latency ≤ `tpot_ms`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloTarget {
    pub ttft_ms: f64,
    pub tpot_ms: f64,
}

impl SloTarget {
    /// Does `span` meet this objective?
    pub fn met_by(&self, span: &RequestSpan) -> bool {
        span.ttft_ns() as f64 <= self.ttft_ms * 1e6
            && span
                .tpot_ns()
                .map_or(true, |t| t <= self.tpot_ms * 1e6)
    }
}

/// Per-tenant-class serving statistics (one entry per workload class).
#[derive(Debug, Clone, PartialEq)]
pub struct ClassStats {
    pub name: String,
    /// The class's latency objective; `None` = best-effort (every
    /// finished request counts as SLO-met).
    pub slo: Option<SloTarget>,
    pub finished: u64,
    pub tokens_out: u64,
    pub ttft_ns: LatHist,
    pub tpot_ns: LatHist,
    /// Finished requests that met the class SLO.
    pub slo_met: u64,
}

impl ClassStats {
    /// Fresh stats for a named class (default exact-sample cap).
    pub fn new(name: String, slo: Option<SloTarget>) -> Self {
        ClassStats {
            name,
            slo,
            finished: 0,
            tokens_out: 0,
            ttft_ns: LatHist::default(),
            tpot_ns: LatHist::default(),
            slo_met: 0,
        }
    }

    /// Fresh stats with an explicit exact-sample cap per latency series
    /// (`ServeConfig::metrics_sample_cap`).
    pub fn with_cap(name: String, slo: Option<SloTarget>, cap: usize) -> Self {
        ClassStats {
            ttft_ns: LatHist::with_cap(cap),
            tpot_ns: LatHist::with_cap(cap),
            ..ClassStats::new(name, slo)
        }
    }

    /// Nearest-rank TTFT percentile in ms.
    pub fn ttft_pct_ms(&self, p: f64) -> f64 {
        self.ttft_ns.percentile(p) / 1e6
    }

    /// Nearest-rank per-token latency percentile in ms/token.
    pub fn tpot_pct_ms(&self, p: f64) -> f64 {
        self.tpot_ns.percentile(p) / 1e6
    }

    /// Fraction of finished requests meeting the class SLO (NaN before
    /// anything finishes).
    pub fn attainment(&self) -> f64 {
        if self.finished == 0 {
            return f64::NAN;
        }
        self.slo_met as f64 / self.finished as f64
    }
}

/// Aggregated serving metrics (times in ns unless noted).
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    /// TTFT samples (ns) — exact up to the configured cap, sketched above.
    pub ttft_ns: LatHist,
    /// Per-request mean inter-token latency samples (ns/token), one per
    /// finished request that generated ≥ 2 tokens.
    pub tpot_ns: LatHist,
    /// Per-finished-request records in finish order; a bounded uniform
    /// sample past the cap (`Reservoir::len` still counts every finish).
    pub requests: Reservoir<RequestSpan>,
    /// Requests handed to the scheduler (arrival events ingested).
    pub submitted: u64,
    pub finished: u64,
    pub tokens_out: u64,
    pub wall_ns: u64,
    /// Host (scheduler thread) busy time.
    pub host_busy_ns: u64,
    /// GPU busy time (decode + prefill + kernel-fetch CU time).
    pub gpu_busy_ns: u64,
    /// Total cross-node collective (TP all-reduce) time; 0 on single-node
    /// deployments (folded into the perf model there). Always equals
    /// `comm_exposed_ns + comm_hidden_ns`.
    pub comm_ns: u64,
    /// Collective time actually charged on the decode/prefill critical
    /// path — the part no compute window covers (all of `comm_ns` when
    /// overlap is disabled).
    pub comm_exposed_ns: u64,
    /// Collective time hidden behind per-layer compute by the
    /// chunk-granular overlap model (`coordinator::comm::CommCost`).
    pub comm_hidden_ns: u64,
    /// Total fetch bytes moved CPU→GPU.
    pub fetch_bytes: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Flat plan-cache (hit, miss) delta over this run
    /// ([`crate::collectives::cache::stats`]).
    pub plan_cache: (u64, u64),
    /// Hierarchical rounds-cache (hit, miss) delta over this run
    /// ([`crate::cluster::rounds_cache_stats`]).
    pub rounds_cache: (u64, u64),
    /// Per-tenant-class breakdowns; empty unless the engine was driven by
    /// a multi-class workload (`VirtualEngine::configure_classes`).
    pub per_class: Vec<ClassStats>,
    /// `(virtual time ns, waiting + admitted-but-not-decoding)` samples;
    /// decimated to a bounded length (`ServeConfig::queue_sample_cap`).
    pub queue_depth: Vec<(u64, u64)>,
    /// Peak of the queue-depth signal over the whole run (exact — not
    /// subject to timeline decimation).
    pub queue_peak: u64,
    /// Collective retry attempts priced by the fault model
    /// (`crate::cluster::FaultStats`); 0 on healthy runs.
    pub retries: u64,
    /// Collective messages that exhausted their retry budget (still
    /// delivered, after the full backoff ladder).
    pub timeouts: u64,
    /// Best-effort arrivals refused by SLO-aware shedding.
    pub shed: u64,
    /// Running best-effort requests evicted for a queued SLO'd request.
    pub preemptions: u64,
    /// Nodes drained from the serving world by the degradation policy.
    pub drained_nodes: u64,
    /// Cross-node KV migrations (disaggregated prefill→decode handoffs);
    /// 0 on colocated deployments.
    pub migrations: u64,
    /// KV bytes moved across the NIC by migrations.
    pub migrated_bytes: u64,
    /// Total migration latency charged on request critical paths (NIC
    /// port wait + save/stream/fetch pipeline).
    pub migration_ns: u64,
    /// NIC port busy time consumed by migrations (occupancy, not
    /// end-to-end latency — the exclusive-track span time).
    pub migration_nic_busy_ns: u64,
}

impl ServeMetrics {
    /// Output tokens per second.
    pub fn tps(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.tokens_out as f64 / (self.wall_ns as f64 / 1e9)
    }

    /// Mean TTFT in ms.
    pub fn ttft_mean_ms(&self) -> f64 {
        self.ttft_ns.mean() / 1e6
    }

    /// Nearest-rank TTFT percentile in ms.
    pub fn ttft_pct_ms(&self, p: f64) -> f64 {
        self.ttft_ns.percentile(p) / 1e6
    }

    /// p50 TTFT in ms (nearest rank).
    pub fn ttft_p50_ms(&self) -> f64 {
        self.ttft_pct_ms(50.0)
    }

    /// p95 TTFT in ms (nearest rank).
    pub fn ttft_p95_ms(&self) -> f64 {
        self.ttft_pct_ms(95.0)
    }

    /// p99 TTFT in ms (nearest rank).
    pub fn ttft_p99_ms(&self) -> f64 {
        self.ttft_pct_ms(99.0)
    }

    /// Nearest-rank per-token latency percentile in ms/token.
    pub fn tpot_pct_ms(&self, p: f64) -> f64 {
        self.tpot_ns.percentile(p) / 1e6
    }

    /// Requests that met their class SLO (all finished requests for
    /// class-less runs and best-effort classes).
    pub fn slo_met(&self) -> u64 {
        if self.per_class.is_empty() {
            return self.finished;
        }
        self.per_class.iter().map(|c| c.slo_met).sum()
    }

    /// Overall SLO attainment fraction (NaN before anything finishes).
    pub fn slo_attainment(&self) -> f64 {
        if self.finished == 0 {
            return f64::NAN;
        }
        self.slo_met() as f64 / self.finished as f64
    }

    /// Goodput: SLO-meeting finished requests per second.
    pub fn goodput_rps(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.slo_met() as f64 / (self.wall_ns as f64 / 1e9)
    }

    /// Fraction of collective time hidden behind compute (0 when no
    /// collectives ran).
    pub fn comm_hidden_frac(&self) -> f64 {
        if self.comm_ns == 0 {
            return 0.0;
        }
        self.comm_hidden_ns as f64 / self.comm_ns as f64
    }

    /// GPU utilization over the run.
    pub fn gpu_util(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.gpu_busy_ns as f64 / self.wall_ns as f64
    }

    /// One-line summary.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} reqs, {} tok, {:.1} tok/s, ttft p50 {:.1}ms p95 {:.1}ms p99 {:.1}ms \
             (mean {:.1}ms), gpu util {:.0}%",
            self.finished,
            self.tokens_out,
            self.tps(),
            self.ttft_p50_ms(),
            self.ttft_p95_ms(),
            self.ttft_p99_ms(),
            self.ttft_mean_ms(),
            self.gpu_util() * 100.0
        );
        if !self.tpot_ns.is_empty() {
            s.push_str(&format!(
                ", tpot p50 {:.2}ms p99 {:.2}ms",
                self.tpot_pct_ms(50.0),
                self.tpot_pct_ms(99.0)
            ));
        }
        if !self.per_class.is_empty() {
            s.push_str(&format!(
                ", slo {:.1}% ({:.1} good req/s), queue peak {}",
                self.slo_attainment() * 100.0,
                self.goodput_rps(),
                self.queue_peak
            ));
        }
        let (ph, pm) = self.plan_cache;
        let (rh, rm) = self.rounds_cache;
        if ph + pm + rh + rm > 0 {
            s.push_str(&format!(
                ", plan cache {ph}h/{pm}m, rounds cache {rh}h/{rm}m"
            ));
        }
        if self.retries + self.timeouts + self.shed + self.preemptions + self.drained_nodes > 0 {
            s.push_str(&format!(
                ", faults: {} retries {} timeouts, shed {}, preempted {}, drained {}",
                self.retries, self.timeouts, self.shed, self.preemptions, self.drained_nodes
            ));
        }
        if self.migrations > 0 {
            s.push_str(&format!(
                ", migrations {} ({:.1} MiB, {:.1}ms total, nic busy {:.1}ms)",
                self.migrations,
                self.migrated_bytes as f64 / (1024.0 * 1024.0),
                self.migration_ns as f64 / 1e6,
                self.migration_nic_busy_ns as f64 / 1e6
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tps_and_ttft() {
        let m = ServeMetrics {
            ttft_ns: vec![1e6, 2e6, 3e6].into(),
            finished: 3,
            tokens_out: 300,
            wall_ns: 2_000_000_000,
            ..Default::default()
        };
        assert!((m.tps() - 150.0).abs() < 1e-9);
        assert!((m.ttft_mean_ms() - 2.0).abs() < 1e-9);
        // Nearest-rank on 3 samples: p50 → 2nd, p95/p99 → 3rd.
        assert!((m.ttft_p50_ms() - 2.0).abs() < 1e-9);
        assert!((m.ttft_p95_ms() - 3.0).abs() < 1e-9);
        assert!((m.ttft_p99_ms() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_is_zero() {
        let m = ServeMetrics::default();
        assert_eq!(m.tps(), 0.0);
        assert_eq!(m.gpu_util(), 0.0);
        assert_eq!(m.comm_hidden_frac(), 0.0);
        assert_eq!(m.goodput_rps(), 0.0);
        assert!(m.slo_attainment().is_nan());
        // Percentiles of an empty distribution are NaN, never a panic.
        assert!(m.ttft_p99_ms().is_nan());
    }

    #[test]
    fn comm_split_fraction() {
        let m = ServeMetrics {
            comm_ns: 100,
            comm_exposed_ns: 30,
            comm_hidden_ns: 70,
            ..Default::default()
        };
        assert_eq!(m.comm_exposed_ns + m.comm_hidden_ns, m.comm_ns);
        assert!((m.comm_hidden_frac() - 0.7).abs() < 1e-12);
    }

    #[test]
    fn request_span_tpot() {
        let r = RequestSpan {
            id: 0,
            arrival_ns: 100,
            first_token_ns: 1_100,
            finish_ns: 5_100,
            tokens: 5,
            class: 0,
        };
        assert_eq!(r.tpot_ns(), Some(1_000.0));
        assert_eq!(r.ttft_ns(), 1_000);
        let single = RequestSpan { tokens: 1, ..r };
        assert_eq!(single.tpot_ns(), None);
    }

    #[test]
    fn slo_target_gating() {
        let span = RequestSpan {
            id: 0,
            arrival_ns: 0,
            first_token_ns: 2_000_000, // TTFT 2ms
            finish_ns: 10_000_000,     // TPOT 2ms over 4 intervals
            tokens: 5,
            class: 0,
        };
        let ok = SloTarget {
            ttft_ms: 5.0,
            tpot_ms: 5.0,
        };
        let tight_ttft = SloTarget {
            ttft_ms: 1.0,
            tpot_ms: 5.0,
        };
        let tight_tpot = SloTarget {
            ttft_ms: 5.0,
            tpot_ms: 1.0,
        };
        assert!(ok.met_by(&span));
        assert!(!tight_ttft.met_by(&span));
        assert!(!tight_tpot.met_by(&span));
        // Single-token spans are gated by TTFT only.
        let single = RequestSpan { tokens: 1, ..span };
        assert!(tight_tpot.met_by(&single));
    }

    #[test]
    fn per_class_attainment_and_goodput() {
        let mut m = ServeMetrics {
            finished: 4,
            wall_ns: 2_000_000_000,
            ..Default::default()
        };
        let mut a = ClassStats::new(
            "chat".to_string(),
            Some(SloTarget {
                ttft_ms: 1.0,
                tpot_ms: 1.0,
            }),
        );
        a.finished = 2;
        a.slo_met = 1;
        let mut b = ClassStats::new("bulk".to_string(), None);
        b.finished = 2;
        b.slo_met = 2; // best-effort: every finish counts
        m.per_class = vec![a, b];
        assert_eq!(m.slo_met(), 3);
        assert!((m.slo_attainment() - 0.75).abs() < 1e-12);
        assert!((m.goodput_rps() - 1.5).abs() < 1e-12);
        assert!((m.per_class[0].attainment() - 0.5).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("slo 75.0%"));
        assert!(s.contains("queue peak"));
    }

    #[test]
    fn summary_includes_percentiles_and_caches() {
        let m = ServeMetrics {
            ttft_ns: vec![1e6; 4].into(),
            tpot_ns: vec![5e5; 4].into(),
            plan_cache: (3, 1),
            rounds_cache: (2, 2),
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("p50") && s.contains("p95") && s.contains("p99"));
        assert!(s.contains("tpot"));
        assert!(s.contains("plan cache 3h/1m"));
        assert!(s.contains("rounds cache 2h/2m"));
        // Fault counters stay out of healthy summaries entirely.
        assert!(!s.contains("faults:"));
    }

    /// Past the exact cap the series spill to the sketch but keep serving
    /// percentiles within the 1 % bound — no caller-visible change of
    /// shape, just bounded memory.
    #[test]
    fn bounded_metrics_survive_spill() {
        let mut m = ServeMetrics::default();
        let mut c = ClassStats::with_cap("chat".to_string(), None, 8);
        for i in 1..=100u64 {
            m.ttft_ns.push(i as f64 * 1e6);
            c.ttft_ns.push(i as f64 * 1e6);
        }
        assert!(c.ttft_ns.spilled(), "cap 8 must spill at 100 samples");
        assert!(!m.ttft_ns.spilled(), "default cap must hold 100 samples");
        assert!((c.ttft_pct_ms(50.0) - 50.0).abs() / 50.0 <= 0.01);
        assert_eq!(m.ttft_p99_ms(), 99.0);
        assert_eq!(m.ttft_ns.len(), 100);
    }

    #[test]
    fn summary_reports_migrations_only_when_disaggregated() {
        let quiet = ServeMetrics::default();
        assert!(!quiet.summary().contains("migrations"));
        let m = ServeMetrics {
            migrations: 4,
            migrated_bytes: 8 * 1024 * 1024,
            migration_ns: 3_000_000,
            migration_nic_busy_ns: 1_500_000,
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("migrations 4"));
        assert!(s.contains("8.0 MiB"));
        assert!(s.contains("nic busy 1.5ms"));
    }

    #[test]
    fn summary_reports_fault_counters_only_when_faulted() {
        let m = ServeMetrics {
            retries: 7,
            timeouts: 1,
            shed: 3,
            preemptions: 2,
            drained_nodes: 1,
            ..Default::default()
        };
        let s = m.summary();
        assert!(s.contains("faults: 7 retries 1 timeouts"));
        assert!(s.contains("shed 3"));
        assert!(s.contains("preempted 2"));
        assert!(s.contains("drained 1"));
    }
}
