//! Workload generation: deterministic, seeded arrival processes and
//! multi-tenant request classes for production-traffic serving runs.
//!
//! The generators produce a time-sorted stream of [`ArrivalEvent`]s that
//! the engine ingests on the virtual clock — the engine no longer assumes
//! every request is present at t=0. Two equivalent forms exist:
//! [`WorkloadSpec::generate`] materializes the whole sorted vector (kept
//! as the reference and legacy path), while [`WorkloadSpec::stream`]
//! yields the **same events in the same order lazily** through a k-way
//! heap merge keyed `(at_ns, session, turn)`, so
//! [`super::engine::VirtualEngine::submit_workload_stream`] holds only
//! O(active sessions) arrivals resident — the million-request path.
//! Three arrival shapes cover the usual production regimes:
//!
//! - **Poisson** — memoryless open-loop traffic at a fixed offered rate;
//! - **Bursty** — a Markov-modulated on/off process (exponential dwell
//!   times); arrivals only occur during on-dwells, at `rate_on_rps`;
//! - **Trace** — diurnal-trace replay: a non-homogeneous Poisson process
//!   thinned against a fixed 24-bin day profile ([`DIURNAL`]).
//!
//! Tenant classes ([`TenantClass`]) model prefill-heavy vs decode-heavy
//! mixes with per-class prompt/output length distributions, optional
//! per-class [`SloTarget`]s, and multi-turn conversation replays whose
//! follow-up turns share a per-session CPU-tier cache key — the
//! prefix-cache hit path of [`super::scheduler::Scheduler`].
//!
//! Everything is a pure function of `(spec, seed)`: the same spec always
//! yields the same event stream, byte for byte, on every platform
//! (pinned by `tests/prop_workload.rs` and `tests/determinism.rs`).

use super::config::ServeConfig;
use super::engine::VirtualEngine;
use super::metrics::{ServeMetrics, SloTarget};
use crate::util::rng::Rng;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Relative load per hour-of-day, normalized to a 1.0 peak (hour 13).
/// The shape follows the usual consumer-serving diurnal curve: a deep
/// overnight trough, a morning ramp, an early-afternoon peak and a slow
/// evening decay.
pub const DIURNAL: [f64; 24] = [
    0.35, 0.28, 0.22, 0.18, 0.16, 0.18, 0.25, 0.40, 0.55, 0.70, 0.82, 0.90, 0.95, 1.00, 0.98,
    0.92, 0.88, 0.85, 0.80, 0.75, 0.65, 0.55, 0.48, 0.40,
];

/// Mean of the [`DIURNAL`] profile (the average-to-peak rate ratio).
pub fn diurnal_mean() -> f64 {
    DIURNAL.iter().sum::<f64>() / DIURNAL.len() as f64
}

/// Seeded arrival process on the virtual-ns timeline.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson arrivals at `rate_rps` requests/second.
    Poisson { rate_rps: f64 },
    /// Markov-modulated on/off (interrupted Poisson) process: exponential
    /// on-dwells (mean `on_ms`) emitting arrivals at `rate_on_rps`,
    /// separated by silent exponential off-dwells (mean `off_ms`).
    Bursty {
        rate_on_rps: f64,
        on_ms: f64,
        off_ms: f64,
    },
    /// Diurnal-trace replay: Poisson candidates at `peak_rps` thinned by
    /// the [`DIURNAL`] profile over a (possibly compressed) day of
    /// `day_s` virtual seconds.
    Trace { peak_rps: f64, day_s: f64 },
}

impl ArrivalProcess {
    /// Long-run average arrival rate (requests/second).
    pub fn mean_rate_rps(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate_rps } => *rate_rps,
            ArrivalProcess::Bursty {
                rate_on_rps,
                on_ms,
                off_ms,
            } => rate_on_rps * on_ms / (on_ms + off_ms),
            ArrivalProcess::Trace { peak_rps, .. } => peak_rps * diurnal_mean(),
        }
    }

    /// The same process shape with the rate scaled by `factor`.
    pub fn scaled(&self, factor: f64) -> ArrivalProcess {
        match *self {
            ArrivalProcess::Poisson { rate_rps } => ArrivalProcess::Poisson {
                rate_rps: rate_rps * factor,
            },
            ArrivalProcess::Bursty {
                rate_on_rps,
                on_ms,
                off_ms,
            } => ArrivalProcess::Bursty {
                rate_on_rps: rate_on_rps * factor,
                on_ms,
                off_ms,
            },
            ArrivalProcess::Trace { peak_rps, day_s } => ArrivalProcess::Trace {
                peak_rps: peak_rps * factor,
                day_s,
            },
        }
    }

    /// Build the process named by the CLI `--workload` flag with a
    /// long-run average of `rate_rps`. For `trace`, the day profile is
    /// compressed into `horizon_s` virtual seconds so a finite run sweeps
    /// the full diurnal curve. Returns `None` for unknown kinds.
    pub fn for_kind(kind: &str, rate_rps: f64, horizon_s: f64) -> Option<ArrivalProcess> {
        match kind {
            "poisson" => Some(ArrivalProcess::Poisson { rate_rps }),
            // 25% duty cycle: 4× the average rate inside bursts.
            "bursty" => Some(ArrivalProcess::Bursty {
                rate_on_rps: rate_rps * 4.0,
                on_ms: 200.0,
                off_ms: 600.0,
            }),
            "trace" | "diurnal" => Some(ArrivalProcess::Trace {
                peak_rps: rate_rps / diurnal_mean(),
                day_s: horizon_s.max(1e-3),
            }),
            _ => None,
        }
    }
}

/// Token-length distribution for prompts/outputs/turn counts.
#[derive(Debug, Clone, PartialEq)]
pub enum LenDist {
    Fixed(u64),
    /// Uniform over `[lo, hi]` inclusive.
    Uniform { lo: u64, hi: u64 },
}

impl LenDist {
    /// Draw one value.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        match *self {
            LenDist::Fixed(v) => v,
            LenDist::Uniform { lo, hi } => {
                assert!(lo <= hi, "LenDist::Uniform lo > hi");
                lo + rng.below(hi - lo + 1)
            }
        }
    }

    /// Expected value.
    pub fn mean(&self) -> f64 {
        match *self {
            LenDist::Fixed(v) => v as f64,
            LenDist::Uniform { lo, hi } => (lo + hi) as f64 / 2.0,
        }
    }
}

/// One tenant request class: a slice of the traffic with its own length
/// distributions, cache affinity, conversation shape and (optionally) a
/// latency SLO.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantClass {
    pub name: String,
    /// Relative share of sessions (normalized over all classes).
    pub weight: f64,
    /// First-turn prompt length (tokens).
    pub prompt: LenDist,
    /// Output length per turn (tokens).
    pub output: LenDist,
    /// Fraction of first turns whose prefix is pre-resident in the CPU
    /// tier (follow-up turns are always warm — their prefix is the
    /// conversation so far).
    pub warm_frac: f64,
    /// Latency objective; `None` = best-effort.
    pub slo: Option<SloTarget>,
    /// Conversation turns per session (values < 1 are clamped to 1).
    pub turns: LenDist,
    /// Mean think time between turns (exponential, ms).
    pub think_ms: f64,
    /// New user tokens appended per follow-up turn.
    pub followup: LenDist,
}

impl TenantClass {
    /// A single-turn class with no SLO — the minimal useful tenant.
    pub fn simple(name: &str, weight: f64, prompt: LenDist, output: LenDist) -> Self {
        TenantClass {
            name: name.to_string(),
            weight,
            prompt,
            output,
            warm_frac: 1.0,
            slo: None,
            turns: LenDist::Fixed(1),
            think_ms: 0.0,
            followup: LenDist::Fixed(0),
        }
    }
}

/// The default two-tenant production mix: an interactive chat class
/// (decode-heavy, multi-turn, tight SLO) and a bulk ingestion class
/// (prefill-heavy, single-turn, best-effort).
pub fn default_tenants() -> Vec<TenantClass> {
    vec![
        TenantClass {
            name: "chat".to_string(),
            weight: 0.7,
            prompt: LenDist::Uniform { lo: 256, hi: 768 },
            output: LenDist::Uniform { lo: 32, hi: 128 },
            warm_frac: 0.8,
            slo: Some(SloTarget {
                ttft_ms: 250.0,
                tpot_ms: 50.0,
            }),
            turns: LenDist::Uniform { lo: 1, hi: 4 },
            think_ms: 500.0,
            followup: LenDist::Uniform { lo: 16, hi: 64 },
        },
        TenantClass {
            name: "bulk".to_string(),
            weight: 0.3,
            prompt: LenDist::Uniform { lo: 2048, hi: 6144 },
            output: LenDist::Uniform { lo: 128, hi: 384 },
            warm_frac: 0.2,
            slo: None,
            turns: LenDist::Fixed(1),
            think_ms: 0.0,
            followup: LenDist::Fixed(0),
        },
    ]
}

/// Parse the CLI `--tenants` spec: `default`, or a comma-separated list
/// of `name:weight:prompt:output[:ttft_ms[:tpot_ms]]` entries (fixed
/// lengths, single-turn; an SLO is attached when `ttft_ms` is present,
/// with `tpot_ms` defaulting to 50). Malformed input returns a
/// descriptive error naming the offending entry and field.
pub fn parse_tenants(spec: &str) -> Result<Vec<TenantClass>, String> {
    if spec == "default" {
        return Ok(default_tenants());
    }
    if spec.is_empty() {
        return Err("empty --tenants spec (try `default`)".to_string());
    }
    let mut classes = Vec::new();
    for entry in spec.split(',') {
        let f: Vec<&str> = entry.split(':').collect();
        if !(4..=6).contains(&f.len()) {
            return Err(format!(
                "tenant entry `{entry}`: want name:weight:prompt:output[:ttft_ms[:tpot_ms]], \
                 got {} field(s)",
                f.len()
            ));
        }
        let weight: f64 = f[1]
            .parse()
            .map_err(|_| format!("tenant `{}`: weight `{}` is not a number", f[0], f[1]))?;
        let prompt: u64 = f[2].parse().map_err(|_| {
            format!("tenant `{}`: prompt tokens `{}` is not an integer", f[0], f[2])
        })?;
        let output: u64 = f[3].parse().map_err(|_| {
            format!("tenant `{}`: output tokens `{}` is not an integer", f[0], f[3])
        })?;
        if weight <= 0.0 {
            return Err(format!(
                "tenant `{}`: weight must be > 0, got {weight}",
                f[0]
            ));
        }
        if prompt == 0 || output == 0 {
            return Err(format!(
                "tenant `{}`: prompt and output tokens must be >= 1",
                f[0]
            ));
        }
        let mut class = TenantClass::simple(
            f[0],
            weight,
            LenDist::Fixed(prompt),
            LenDist::Fixed(output),
        );
        if f.len() >= 5 {
            let ttft_ms: f64 = f[4].parse().map_err(|_| {
                format!("tenant `{}`: ttft_ms `{}` is not a number", f[0], f[4])
            })?;
            let tpot_ms: f64 = if f.len() == 6 {
                f[5].parse().map_err(|_| {
                    format!("tenant `{}`: tpot_ms `{}` is not a number", f[0], f[5])
                })?
            } else {
                50.0
            };
            class.slo = Some(SloTarget { ttft_ms, tpot_ms });
        }
        classes.push(class);
    }
    Ok(classes)
}

/// One generated arrival: a conversation turn of one session, timestamped
/// on the virtual clock.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrivalEvent {
    /// Arrival instant (virtual ns).
    pub at_ns: u64,
    /// Index into the spec's class table.
    pub class: u8,
    /// Session (conversation) id; turns of one session share it.
    pub session: u64,
    /// Turn number within the session (0-based, strictly ordered in time).
    pub turn: u32,
    pub prompt_tokens: u64,
    pub output_tokens: u64,
    /// Prefix resident in the CPU tier at arrival (always true for
    /// follow-up turns).
    pub warm: bool,
}

/// CPU-tier cache key for a session's conversation prefix. The high bit
/// keeps session keys disjoint from the per-request default keys
/// (`Request::cache_key = id`) when workload and direct submissions mix.
pub fn session_cache_key(session: u64) -> u64 {
    (1u64 << 63) | session
}

/// A complete workload: arrival process × tenant mix × size × seed.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    /// Offered-load shape. Its rate is the **request** (turn) rate; the
    /// generator divides by the mix's mean turns-per-session to get the
    /// session start rate.
    pub process: ArrivalProcess,
    pub classes: Vec<TenantClass>,
    /// Total arrival events to generate (conversation turns count
    /// individually).
    pub requests: u64,
    pub seed: u64,
}

impl WorkloadSpec {
    /// Poisson workload over the default tenant mix.
    pub fn poisson(rate_rps: f64, requests: u64, seed: u64) -> Self {
        WorkloadSpec {
            process: ArrivalProcess::Poisson { rate_rps },
            classes: default_tenants(),
            requests,
            seed,
        }
    }

    /// A closed-loop variant of `classes`: everything arrives (nearly) at
    /// once, conversations flattened to one turn — measures pure service
    /// capacity with no arrival-process or think-time slack.
    pub fn closed_loop(classes: &[TenantClass], requests: u64, seed: u64) -> Self {
        let flat = classes
            .iter()
            .map(|c| TenantClass {
                turns: LenDist::Fixed(1),
                think_ms: 0.0,
                ..c.clone()
            })
            .collect();
        WorkloadSpec {
            process: ArrivalProcess::Poisson { rate_rps: 1e9 },
            classes: flat,
            requests,
            seed,
        }
    }

    /// Mean conversation turns per session over the class mix.
    fn mean_turns(&self) -> f64 {
        let total_w: f64 = self.classes.iter().map(|c| c.weight).sum();
        let weighted: f64 = self
            .classes
            .iter()
            .map(|c| c.weight * c.turns.mean().max(1.0))
            .sum();
        weighted / total_w
    }

    /// Generate the arrival stream: `requests` events sorted by arrival
    /// time. Pure function of the spec (same spec ⇒ identical stream).
    pub fn generate(&self) -> Vec<ArrivalEvent> {
        assert!(!self.classes.is_empty(), "workload needs ≥ 1 class");
        let total_w: f64 = self.classes.iter().map(|c| c.weight).sum();
        assert!(total_w > 0.0, "class weights must sum > 0");
        // Session starts are the process thinned to the per-session rate.
        let session_process = self.process.scaled(1.0 / self.mean_turns());
        let mut gen = ArrivalGen::new(session_process, Rng::new(self.seed ^ ARRIVAL_STREAM));
        let mut rng = Rng::new(self.seed);
        let mut events: Vec<ArrivalEvent> = Vec::with_capacity(self.requests as usize);
        let mut session = 0u64;
        while events.len() < self.requests as usize {
            let t0 = gen.next_ns();
            let class = pick_weighted(&mut rng, &self.classes, total_w);
            let cl = &self.classes[class];
            let turns = cl.turns.sample(&mut rng).max(1);
            let mut at = t0;
            let mut context = 0u64;
            for turn in 0..turns {
                let (prompt, warm) = if turn == 0 {
                    (cl.prompt.sample(&mut rng).max(1), rng.chance(cl.warm_frac))
                } else {
                    // The follow-up prompt is the conversation so far plus
                    // the user's new tokens; its prefix is warm by
                    // construction (the previous turn's KV).
                    (context + cl.followup.sample(&mut rng).max(1), true)
                };
                let output = cl.output.sample(&mut rng).max(1);
                events.push(ArrivalEvent {
                    at_ns: at,
                    class: class as u8,
                    session,
                    turn: turn as u32,
                    prompt_tokens: prompt,
                    output_tokens: output,
                    warm,
                });
                context = prompt + output;
                at += 1 + exp_ns(&mut rng, cl.think_ms * 1e6) as u64;
            }
            session += 1;
        }
        // Time-sort across sessions. Within a session `at_ns` is strictly
        // increasing, so (at, session, turn) keeps turn order globally and
        // truncation only ever drops the latest turns.
        events.sort_by_key(|e| (e.at_ns, e.session, e.turn));
        events.truncate(self.requests as usize);
        events
    }

    /// Lazy equivalent of [`WorkloadSpec::generate`]: an iterator yielding
    /// the **byte-identical event sequence** while keeping only the
    /// undrained turns of already-started sessions resident (a k-way heap
    /// merge keyed `(at_ns, session, turn)` — O(active sessions) memory
    /// instead of O(requests)). Pinned against `generate` by
    /// `tests/prop_workload.rs`.
    pub fn stream(&self) -> ArrivalStream {
        assert!(!self.classes.is_empty(), "workload needs ≥ 1 class");
        let total_w: f64 = self.classes.iter().map(|c| c.weight).sum();
        assert!(total_w > 0.0, "class weights must sum > 0");
        let session_process = self.process.scaled(1.0 / self.mean_turns());
        let mut gen = ArrivalGen::new(session_process, Rng::new(self.seed ^ ARRIVAL_STREAM));
        // Lookahead session start. Arrival instants draw from their own
        // RNG stream (ARRIVAL_STREAM), so pre-drawing the next t0 never
        // perturbs any per-request draw; for `requests == 0` this draws
        // one instant `generate` would not, which is equally harmless.
        let next_t0 = gen.next_ns();
        ArrivalStream {
            classes: self.classes.clone(),
            total_w,
            requests: self.requests,
            gen,
            rng: Rng::new(self.seed),
            heap: BinaryHeap::new(),
            next_t0,
            next_session: 0,
            generated: 0,
            emitted: 0,
            peak_resident: 0,
        }
    }
}

/// Run `spec` through a fresh [`VirtualEngine`] for `cfg` and return the
/// serving metrics (per-class breakdowns included). Arrivals are pulled
/// lazily via [`WorkloadSpec::stream`], so episode memory is bounded by
/// active sessions, not by `spec.requests`.
pub fn drive(cfg: &ServeConfig, spec: &WorkloadSpec) -> ServeMetrics {
    let mut eng = VirtualEngine::new(cfg.clone());
    eng.configure_classes(&spec.classes);
    eng.submit_workload_stream(spec);
    eng.run_to_completion().clone()
}

/// Stream separator: arrival instants draw from their own RNG stream so
/// adding per-request draws never perturbs the timeline.
const ARRIVAL_STREAM: u64 = 0xA5A5_5A5A_0F0F_F0F0;

/// Cap on a single inter-arrival gap (~1 virtual day). A zero/NaN rate
/// sends the exponential mean to infinity; capping degrades that to
/// "very sparse" instead of hanging generation or overflowing the clock.
/// Real configs never get near it: at any practical rate the probability
/// of a 1e14 ns gap is ~e^{-10^5}, so healthy streams are bit-identical.
const GAP_CAP_NS: f64 = 1e14;

/// Exponential variate with the given mean (returns 0.0 mean as 0.0;
/// NaN means are treated as 0.0 too — `!(x > 0)` catches both).
fn exp_ns(rng: &mut Rng, mean_ns: f64) -> f64 {
    if !(mean_ns > 0.0) {
        return 0.0;
    }
    // f64() ∈ [0,1) ⇒ 1-u ∈ (0,1] ⇒ ln finite and ≤ 0.
    (-mean_ns * (1.0 - rng.f64()).ln()).min(GAP_CAP_NS)
}

/// Weighted class pick.
fn pick_weighted(rng: &mut Rng, classes: &[TenantClass], total_w: f64) -> usize {
    let mut x = rng.f64() * total_w;
    for (i, c) in classes.iter().enumerate() {
        x -= c.weight;
        if x < 0.0 {
            return i;
        }
    }
    classes.len() - 1
}

/// Stateful arrival-instant generator over the virtual-ns timeline. Owns
/// its process so [`ArrivalStream`] can carry one without a lifetime.
#[derive(Debug, Clone)]
struct ArrivalGen {
    process: ArrivalProcess,
    rng: Rng,
    /// Current time, kept in f64 ns so long streams accumulate precisely.
    t_ns: f64,
    /// Bursty only: end of the current on-dwell.
    on_until_ns: f64,
}

impl ArrivalGen {
    fn new(process: ArrivalProcess, mut rng: Rng) -> Self {
        let on_until_ns = match &process {
            ArrivalProcess::Bursty { on_ms, .. } => exp_ns(&mut rng, on_ms * 1e6),
            _ => 0.0,
        };
        ArrivalGen {
            process,
            rng,
            t_ns: 0.0,
            on_until_ns,
        }
    }

    /// Next arrival instant (ns); strictly non-decreasing.
    fn next_ns(&mut self) -> u64 {
        match self.process {
            ArrivalProcess::Poisson { rate_rps } => {
                self.t_ns += exp_ns(&mut self.rng, 1e9 / rate_rps);
                self.t_ns as u64
            }
            ArrivalProcess::Bursty {
                rate_on_rps,
                on_ms,
                off_ms,
            } => loop {
                let gap = exp_ns(&mut self.rng, 1e9 / rate_on_rps);
                // The capped-gap escape also ends the dwell loop for
                // zero/degenerate on-rates (gap can never reach the cap
                // at any real rate — see `GAP_CAP_NS`).
                if self.t_ns + gap <= self.on_until_ns || gap >= GAP_CAP_NS {
                    self.t_ns += gap;
                    return self.t_ns as u64;
                }
                // The on-dwell expires before the candidate arrival: the
                // memoryless property lets us jump through an off-dwell
                // into a fresh on-dwell and redraw.
                self.t_ns = self.on_until_ns + exp_ns(&mut self.rng, off_ms * 1e6);
                self.on_until_ns = self.t_ns + exp_ns(&mut self.rng, on_ms * 1e6);
            },
            ArrivalProcess::Trace { peak_rps, day_s } => loop {
                self.t_ns += exp_ns(&mut self.rng, 1e9 / peak_rps);
                if self.rng.f64() < diurnal_at(self.t_ns, day_s) {
                    return self.t_ns as u64;
                }
            },
        }
    }
}

/// The diurnal profile value at virtual instant `t_ns` for a day of
/// `day_s` seconds (cyclic).
fn diurnal_at(t_ns: f64, day_s: f64) -> f64 {
    let day_frac = (t_ns / (day_s * 1e9)).fract();
    let bin = ((day_frac * 24.0) as usize).min(23);
    DIURNAL[bin]
}

/// Heap entry ordering [`ArrivalEvent`]s by the global sort key
/// `(at_ns, session, turn)` — the exact comparator `generate` sorts by.
/// Keys are unique (one event per session × turn), so equality under this
/// order coincides with key equality.
#[derive(Debug, Clone)]
struct OrderedEvent(ArrivalEvent);

impl OrderedEvent {
    fn key(&self) -> (u64, u64, u32) {
        (self.0.at_ns, self.0.session, self.0.turn)
    }
}

impl PartialEq for OrderedEvent {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}
impl Eq for OrderedEvent {}
impl PartialOrd for OrderedEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrderedEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key().cmp(&other.key())
    }
}

/// Lazy, heap-merged arrival stream — the iterator behind
/// [`WorkloadSpec::stream`].
///
/// Sessions start in `t0` order (the arrival-instant RNG stream); starting
/// a session draws its **entire** conversation into a min-heap keyed
/// `(at_ns, session, turn)`, exactly the draws `generate` performs at the
/// same point of the per-request RNG stream. The heap min is emitted once
/// no unstarted session could precede it: future sessions start at
/// `>= next_t0` and carry larger session ids, so a resident key at or
/// before `(next_t0, ..)` is globally next. Emitting exactly `requests`
/// events therefore reproduces `generate`'s sort + truncate byte for
/// byte, while residency stays bounded by the turns of in-flight sessions
/// ([`ArrivalStream::peak_resident`]).
#[derive(Debug, Clone)]
pub struct ArrivalStream {
    classes: Vec<TenantClass>,
    total_w: f64,
    requests: u64,
    gen: ArrivalGen,
    rng: Rng,
    heap: BinaryHeap<Reverse<OrderedEvent>>,
    /// First-turn instant of the next (unstarted) session.
    next_t0: u64,
    next_session: u64,
    /// Events drawn into the heap so far (emitted + resident).
    generated: u64,
    emitted: u64,
    peak_resident: usize,
}

impl ArrivalStream {
    /// Draw the next session's every turn into the heap.
    fn start_session(&mut self) {
        let t0 = self.next_t0;
        let class = pick_weighted(&mut self.rng, &self.classes, self.total_w);
        let cl = &self.classes[class];
        let turns = cl.turns.sample(&mut self.rng).max(1);
        let mut at = t0;
        let mut context = 0u64;
        for turn in 0..turns {
            let (prompt, warm) = if turn == 0 {
                (
                    cl.prompt.sample(&mut self.rng).max(1),
                    self.rng.chance(cl.warm_frac),
                )
            } else {
                (context + cl.followup.sample(&mut self.rng).max(1), true)
            };
            let output = cl.output.sample(&mut self.rng).max(1);
            self.heap.push(Reverse(OrderedEvent(ArrivalEvent {
                at_ns: at,
                class: class as u8,
                session: self.next_session,
                turn: turn as u32,
                prompt_tokens: prompt,
                output_tokens: output,
                warm,
            })));
            context = prompt + output;
            at += 1 + exp_ns(&mut self.rng, cl.think_ms * 1e6) as u64;
        }
        self.generated += turns;
        self.next_session += 1;
        self.next_t0 = self.gen.next_ns();
        self.peak_resident = self.peak_resident.max(self.heap.len());
    }

    /// Arrivals currently resident (drawn but not yet emitted).
    pub fn resident(&self) -> usize {
        self.heap.len()
    }

    /// High-water mark of resident arrivals over the stream's lifetime —
    /// the O(active sessions) bound `BENCH_PR9.json` tracks.
    pub fn peak_resident(&self) -> usize {
        self.peak_resident
    }
}

impl Iterator for ArrivalStream {
    type Item = ArrivalEvent;

    fn next(&mut self) -> Option<ArrivalEvent> {
        if self.emitted == self.requests {
            return None;
        }
        loop {
            // Once `generate` would have stopped starting sessions, the
            // remaining output is purely the heap drained in key order.
            if self.generated >= self.requests {
                break;
            }
            if let Some(Reverse(min)) = self.heap.peek() {
                if min.0.at_ns <= self.next_t0 {
                    // Ties on at_ns break by session id; every resident
                    // session precedes every unstarted one.
                    break;
                }
            }
            self.start_session();
        }
        // Invariant: heap len == generated - emitted, and both break arms
        // guarantee generated > emitted here.
        let Reverse(OrderedEvent(e)) = self.heap.pop().expect("resident arrival");
        self.emitted += 1;
        Some(e)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = (self.requests - self.emitted) as usize;
        (left, Some(left))
    }
}

impl ExactSizeIterator for ArrivalStream {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn len_dist_bounds_and_mean() {
        let mut rng = Rng::new(1);
        let d = LenDist::Uniform { lo: 10, hi: 20 };
        for _ in 0..1000 {
            let v = d.sample(&mut rng);
            assert!((10..=20).contains(&v));
        }
        assert_eq!(d.mean(), 15.0);
        assert_eq!(LenDist::Fixed(7).sample(&mut rng), 7);
    }

    #[test]
    fn process_mean_rates() {
        let p = ArrivalProcess::Poisson { rate_rps: 100.0 };
        assert_eq!(p.mean_rate_rps(), 100.0);
        let b = ArrivalProcess::Bursty {
            rate_on_rps: 400.0,
            on_ms: 200.0,
            off_ms: 600.0,
        };
        assert!((b.mean_rate_rps() - 100.0).abs() < 1e-9);
        let t = ArrivalProcess::Trace {
            peak_rps: 100.0,
            day_s: 60.0,
        };
        assert!((t.mean_rate_rps() - 100.0 * diurnal_mean()).abs() < 1e-9);
        assert!((p.scaled(2.0).mean_rate_rps() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn for_kind_matches_requested_average() {
        for kind in ["poisson", "bursty", "trace"] {
            let p = ArrivalProcess::for_kind(kind, 150.0, 10.0).unwrap();
            assert!(
                (p.mean_rate_rps() - 150.0).abs() < 1e-6,
                "{kind}: {}",
                p.mean_rate_rps()
            );
        }
        assert!(ArrivalProcess::for_kind("nope", 1.0, 1.0).is_none());
    }

    #[test]
    fn generate_is_sorted_and_sized() {
        let spec = WorkloadSpec::poisson(500.0, 200, 42);
        let ev = spec.generate();
        assert_eq!(ev.len(), 200);
        assert!(ev.windows(2).all(|w| w[0].at_ns <= w[1].at_ns));
        // Both default classes show up in a 200-event stream.
        assert!(ev.iter().any(|e| e.class == 0));
        assert!(ev.iter().any(|e| e.class == 1));
    }

    #[test]
    fn closed_loop_arrives_at_once() {
        let spec = WorkloadSpec::closed_loop(&default_tenants(), 64, 3);
        let ev = spec.generate();
        assert_eq!(ev.len(), 64);
        // 64 draws at 1e9 req/s land within a few µs.
        assert!(ev.last().unwrap().at_ns < 1_000_000);
        assert!(ev.iter().all(|e| e.turn == 0));
    }

    #[test]
    fn parse_tenants_roundtrip() {
        let t = parse_tenants("default").unwrap();
        assert_eq!(t.len(), 2);
        let t = parse_tenants("chat:0.7:512:64:250:40,bulk:0.3:4096:256").unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].name, "chat");
        assert_eq!(
            t[0].slo,
            Some(SloTarget {
                ttft_ms: 250.0,
                tpot_ms: 40.0
            })
        );
        assert_eq!(t[0].prompt, LenDist::Fixed(512));
        assert!(t[1].slo.is_none());
    }

    /// Satellite fix: malformed `--tenants` specs explain what's wrong
    /// instead of a bare `None`.
    #[test]
    fn parse_tenants_errors_are_descriptive() {
        let e = parse_tenants("").unwrap_err();
        assert!(e.contains("empty"), "{e}");
        let e = parse_tenants("a:1:64").unwrap_err();
        assert!(e.contains("field") && e.contains("a:1:64"), "{e}");
        let e = parse_tenants("a:b:c:d").unwrap_err();
        assert!(e.contains("weight") && e.contains("`b`"), "{e}");
        let e = parse_tenants("a:1:x:8").unwrap_err();
        assert!(e.contains("prompt") && e.contains("`x`"), "{e}");
        let e = parse_tenants("a:1:0:8").unwrap_err();
        assert!(e.contains(">= 1"), "{e}");
        let e = parse_tenants("a:-2:64:8").unwrap_err();
        assert!(e.contains("> 0"), "{e}");
        let e = parse_tenants("a:1:64:8:fast").unwrap_err();
        assert!(e.contains("ttft_ms") && e.contains("`fast`"), "{e}");
        let e = parse_tenants("a:1:64:8:250:soon").unwrap_err();
        assert!(e.contains("tpot_ms") && e.contains("`soon`"), "{e}");
    }

    /// Satellite hardening: zero-rate processes degrade to very sparse
    /// streams (gaps capped at [`GAP_CAP_NS`]) — generation terminates,
    /// stays sorted, and never panics. The bursty dwell loop is the
    /// interesting one: with a zero on-rate no candidate ever lands
    /// inside a dwell.
    #[test]
    fn zero_rate_workloads_generate_without_hanging() {
        for process in [
            ArrivalProcess::Poisson { rate_rps: 0.0 },
            ArrivalProcess::Bursty {
                rate_on_rps: 0.0,
                on_ms: 1.0,
                off_ms: 1.0,
            },
            ArrivalProcess::Trace {
                peak_rps: 0.0,
                day_s: 1.0,
            },
        ] {
            let spec = WorkloadSpec {
                process,
                classes: default_tenants(),
                requests: 4,
                seed: 1,
            };
            let ev = spec.generate();
            assert_eq!(ev.len(), 4);
            assert!(ev.windows(2).all(|w| w[0].at_ns <= w[1].at_ns));
        }
    }

    /// Tentpole determinism pin: the lazy heap merge yields byte-for-byte
    /// the sorted vector, for every arrival shape (the property-test
    /// version over random specs lives in `tests/prop_workload.rs`).
    #[test]
    fn stream_is_byte_identical_to_generate() {
        for process in [
            ArrivalProcess::Poisson { rate_rps: 800.0 },
            ArrivalProcess::Bursty {
                rate_on_rps: 2000.0,
                on_ms: 20.0,
                off_ms: 30.0,
            },
            ArrivalProcess::Trace {
                peak_rps: 600.0,
                day_s: 0.5,
            },
        ] {
            let spec = WorkloadSpec {
                process,
                classes: default_tenants(),
                requests: 500,
                seed: 42,
            };
            let streamed: Vec<ArrivalEvent> = spec.stream().collect();
            assert_eq!(streamed, spec.generate());
        }
    }

    /// Satellite hardening: zero- and single-request streams terminate
    /// cleanly through the merge path.
    #[test]
    fn stream_degenerate_sizes() {
        let mut spec = WorkloadSpec::poisson(500.0, 0, 9);
        assert_eq!(spec.stream().next(), None);
        assert_eq!(spec.stream().len(), 0);
        spec.requests = 1;
        let one: Vec<ArrivalEvent> = spec.stream().collect();
        assert_eq!(one.len(), 1);
        assert_eq!(one, spec.generate());
    }

    /// The memory claim itself: residency tracks active sessions (turns
    /// in flight), not total requests. 4000 requests at a modest rate
    /// keeps well under a quarter of the stream resident.
    #[test]
    fn stream_residency_is_bounded_by_active_sessions() {
        let spec = WorkloadSpec::poisson(500.0, 4000, 11);
        let mut s = spec.stream();
        let mut n = 0u64;
        while s.next().is_some() {
            n += 1;
        }
        assert_eq!(n, 4000);
        assert!(
            s.peak_resident() < 1000,
            "peak resident {} for 4000 requests",
            s.peak_resident()
        );
    }

    #[test]
    fn session_keys_have_high_bit() {
        assert_ne!(session_cache_key(0), 0);
        assert_eq!(session_cache_key(5) & !(1u64 << 63), 5);
    }

    #[test]
    fn diurnal_profile_is_normalized() {
        assert!(DIURNAL.iter().all(|&v| v > 0.0 && v <= 1.0));
        assert_eq!(DIURNAL.iter().cloned().fold(0.0, f64::max), 1.0);
        assert!(diurnal_mean() > 0.3 && diurnal_mean() < 1.0);
        // Cyclic lookup: hour 13 of any day is the peak.
        let day_ns = 60.0 * 1e9;
        assert_eq!(diurnal_at(13.5 / 24.0 * day_ns, 60.0), 1.0);
        assert_eq!(diurnal_at(day_ns + 13.5 / 24.0 * day_ns, 60.0), 1.0);
    }
}
