//! Serving configuration.

use crate::cluster::FaultSpec;
use crate::kvcache::fetch::FetchImpl;
use crate::models::{ModelConfig, PerfModel};

/// How the serving engine reacts when a fault plan degrades the fleet
/// ([`ServeConfig::faults`]). Each lever is independent so the figures and
/// benches can compare the degradation-aware engine against a
/// degradation-blind baseline (and ablate the levers in between):
///
/// - `reselect` — re-pick collective variant/schedule against the
///   *derated* topology (`cluster::select_cluster_degraded`) instead of
///   the healthy belief.
/// - `drain` — drop badly degraded nodes from the serving world (NIC
///   below half speed, or compute ≥ 1.5× slower), shrinking the
///   collective world to the healthy survivors; compute throughput is
///   scaled by the lost capacity.
/// - `shed` — under SLO pressure, drop incoming best-effort (no-SLO)
///   arrivals instead of queuing them ahead of chat traffic.
/// - `preempt` — evict a running best-effort request when an SLO'd
///   request would otherwise wait behind a full batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DegradePolicy {
    pub reselect: bool,
    pub drain: bool,
    pub shed: bool,
    pub preempt: bool,
}

impl DegradePolicy {
    /// All levers on — the graceful-degradation engine.
    pub fn aware() -> Self {
        DegradePolicy {
            reselect: true,
            drain: true,
            shed: true,
            preempt: true,
        }
    }

    /// All levers off — the degradation-blind baseline: the engine keeps
    /// its healthy beliefs and policies while reality runs derated.
    pub fn blind() -> Self {
        DegradePolicy {
            reselect: false,
            drain: false,
            shed: false,
            preempt: false,
        }
    }
}

impl Default for DegradePolicy {
    fn default() -> Self {
        DegradePolicy::aware()
    }
}

/// Disaggregated deployment shape: `prefill_nodes` nodes run prefill
/// only, `decode_nodes` nodes run decode only, and every admitted request
/// migrates its KV cache prefill→decode over the NIC
/// ([`crate::kvcache::migrate`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DisaggSpec {
    /// Nodes dedicated to prefill (≥ 1).
    pub prefill_nodes: usize,
    /// Nodes dedicated to decode (≥ 1).
    pub decode_nodes: usize,
    /// KV migration schedule (layer-pipelined by default).
    pub schedule: crate::kvcache::MigrateSchedule,
}

impl DisaggSpec {
    /// `P` prefill + `D` decode nodes with the pipelined schedule.
    pub fn new(prefill_nodes: usize, decode_nodes: usize) -> Self {
        assert!(prefill_nodes >= 1 && decode_nodes >= 1);
        DisaggSpec {
            prefill_nodes,
            decode_nodes,
            schedule: crate::kvcache::MigrateSchedule::LayerPipelined,
        }
    }

    /// Use the blocking bulk-transfer schedule (the comparison baseline).
    pub fn blocking(mut self) -> Self {
        self.schedule = crate::kvcache::MigrateSchedule::Blocking;
        self
    }

    /// Total nodes in the deployment.
    pub fn total_nodes(&self) -> usize {
        self.prefill_nodes + self.decode_nodes
    }

    /// Parse a `P:D` ratio, e.g. `3:1` (the `--disagg` CLI syntax).
    pub fn parse(s: &str) -> Result<Self, String> {
        let (p, d) = s
            .split_once(':')
            .ok_or_else(|| format!("expected P:D (e.g. 3:1), got {s:?}"))?;
        let prefill: usize = p
            .trim()
            .parse()
            .map_err(|e| format!("bad prefill node count {p:?}: {e}"))?;
        let decode: usize = d
            .trim()
            .parse()
            .map_err(|e| format!("bad decode node count {d:?}: {e}"))?;
        if prefill == 0 || decode == 0 {
            return Err(format!(
                "need at least one node on each side, got {prefill}:{decode}"
            ));
        }
        Ok(DisaggSpec::new(prefill, decode))
    }
}

/// Configuration for one serving engine (virtual or real).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub model: &'static ModelConfig,
    /// KV fetch implementation (the experiment axis of §5.3).
    pub fetch: FetchImpl,
    /// Tokens per KV block.
    pub block_tokens: u32,
    /// GPU KV pool capacity in blocks.
    pub gpu_blocks: u64,
    /// CPU KV tier capacity in blocks.
    pub cpu_blocks: u64,
    /// Max concurrently running (decoding) requests.
    pub max_batch: usize,
    /// Fraction of requests whose prefix hits the CPU cache (paper sweeps
    /// 50/70/100%).
    pub hit_rate: f64,
    /// Per-request framework overhead (Python/vLLM scheduler + launch —
    /// the gap between TTFT_GPU and TTFT_total in Fig. 16).
    pub framework_overhead_ns: u64,
    /// MI300X timing model.
    pub perf: PerfModel,
    /// Workload seed.
    pub seed: u64,
    /// Nodes this deployment spans (8 GPUs each). 1 = the paper's
    /// single-node platform; >1 sizes collectives for the hierarchical
    /// cluster layer (`crate::cluster::ClusterTopology::mi300x(num_nodes)`
    /// is the matching topology).
    pub num_nodes: usize,
    /// Overlap per-layer TP all-reduces with the next block's compute
    /// (`coordinator::comm::CommCost` split): the critical path is charged
    /// only the exposed part. On by default — DMA/NIC offload is the
    /// paper's whole point; disable to model a strictly serialized engine
    /// (the pre-PR-4 accounting, kept as the overlap bench baseline).
    pub comm_overlap: bool,
    /// Max queue-depth timeline samples kept in `ServeMetrics::queue_depth`
    /// (the engine halves resolution once full — deterministic decimation);
    /// < 2 disables the timeline (the exact peak is still tracked).
    pub queue_sample_cap: usize,
    /// Exact-sample cap for the latency series and `RequestSpan` reservoir
    /// in `ServeMetrics`. Episodes at or below this many samples per
    /// series report bit-exact percentiles (same numbers as the historical
    /// unbounded vectors); beyond it the TTFT/TPOT series degrade to a
    /// log-bucketed sketch with a ≤ 1 % relative-error bound and the spans
    /// to a seeded uniform reservoir — memory stays O(cap) at any episode
    /// size ([`crate::util::stats::LatHist`]).
    pub metrics_sample_cap: usize,
    /// Fault injection: `None` (the default) is the healthy fleet and
    /// perturbs **nothing** — the engine never materializes a plan, so
    /// healthy runs stay bit-identical (`tests/determinism.rs`). `Some`
    /// materializes a [`crate::cluster::FaultPlan`] from [`ServeConfig::seed`].
    pub faults: Option<FaultSpec>,
    /// Reaction policy when `faults` is set (ignored when healthy).
    pub degrade: DegradePolicy,
    /// Disaggregated prefill/decode deployment: `None` (the default) is
    /// colocated serving and perturbs nothing — the engine takes the
    /// existing single-pool path bit-identically. `Some` routes prefill
    /// and decode to separate node pools and charges each request a KV
    /// migration over the NIC (`num_nodes` is overridden to P+D).
    pub disagg: Option<DisaggSpec>,
}

impl ServeConfig {
    /// Paper-style defaults for `model` with the given fetch impl.
    pub fn new(model: &'static ModelConfig, fetch: FetchImpl) -> Self {
        ServeConfig {
            model,
            fetch,
            block_tokens: crate::kvcache::DEFAULT_BLOCK_TOKENS,
            gpu_blocks: 8192,
            cpu_blocks: 1 << 20,
            max_batch: 64,
            hit_rate: 1.0,
            framework_overhead_ns: 1_800_000,
            perf: PerfModel::default(),
            seed: 0xC0FFEE,
            num_nodes: 1,
            comm_overlap: true,
            queue_sample_cap: 2048,
            metrics_sample_cap: crate::util::stats::LATHIST_DEFAULT_CAP,
            faults: None,
            degrade: DegradePolicy::aware(),
            disagg: None,
        }
    }

    /// Disaggregate into `prefill_nodes` + `decode_nodes` pools (also
    /// sizes `num_nodes` to the total).
    pub fn with_disagg(mut self, spec: DisaggSpec) -> Self {
        self.num_nodes = spec.total_nodes();
        self.disagg = Some(spec);
        self
    }

    /// Deploy across `num_nodes` 8-GPU nodes.
    pub fn with_nodes(mut self, num_nodes: usize) -> Self {
        assert!(num_nodes >= 1);
        self.num_nodes = num_nodes;
        self
    }

    /// Toggle collective/compute overlap (see [`ServeConfig::comm_overlap`]).
    pub fn with_comm_overlap(mut self, on: bool) -> Self {
        self.comm_overlap = on;
        self
    }

    /// Inject the given fault spec (materialized from [`ServeConfig::seed`]).
    pub fn with_faults(mut self, spec: FaultSpec) -> Self {
        self.faults = Some(spec);
        self
    }

    /// Set the degradation-reaction policy (see [`DegradePolicy`]).
    pub fn with_degrade(mut self, policy: DegradePolicy) -> Self {
        self.degrade = policy;
        self
    }

    /// Total GPU count across the deployment (8 GPUs per node, matching
    /// [`crate::sim::Topology::mi300x_platform`]).
    pub fn world_size(&self) -> usize {
        self.num_nodes * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::LLAMA31_8B;

    #[test]
    fn defaults_sane() {
        let c = ServeConfig::new(&LLAMA31_8B, FetchImpl::DmaB2b);
        assert_eq!(c.block_tokens, 16);
        assert!(c.hit_rate == 1.0);
        assert!(c.max_batch > 0);
        assert_eq!(c.num_nodes, 1);
        assert_eq!(c.world_size(), 8);
        assert!(c.comm_overlap);
        assert!(c.queue_sample_cap >= 2);
        // Existing tests/benches push far fewer samples than this, so the
        // exact phase covers them and no modeled number moves.
        assert!(c.metrics_sample_cap >= 4096);
        assert!(c.faults.is_none(), "default config must be fault-free");
        assert_eq!(c.degrade, DegradePolicy::aware());
        assert!(!c.with_comm_overlap(false).comm_overlap);
    }

    #[test]
    fn fault_builders_compose() {
        let spec = FaultSpec::parse("nic=1:0.25").unwrap();
        let c = ServeConfig::new(&LLAMA31_8B, FetchImpl::DmaB2b)
            .with_faults(spec.clone())
            .with_degrade(DegradePolicy::blind());
        assert_eq!(c.faults, Some(spec));
        assert!(!c.degrade.reselect && !c.degrade.shed);
        assert!(DegradePolicy::aware().preempt);
    }

    #[test]
    fn multi_node_world_size() {
        let c = ServeConfig::new(&LLAMA31_8B, FetchImpl::DmaB2b).with_nodes(4);
        assert_eq!(c.world_size(), 32);
    }

    #[test]
    fn disagg_parse_accepts_ratios() {
        let d = DisaggSpec::parse("3:1").unwrap();
        assert_eq!((d.prefill_nodes, d.decode_nodes), (3, 1));
        assert_eq!(d.total_nodes(), 4);
        assert_eq!(d.schedule, crate::kvcache::MigrateSchedule::LayerPipelined);
        assert_eq!(
            DisaggSpec::parse(" 1 : 2 ").unwrap().total_nodes(),
            3,
            "whitespace around the ratio is tolerated"
        );
        assert_eq!(
            DisaggSpec::parse("2:2").unwrap().blocking().schedule,
            crate::kvcache::MigrateSchedule::Blocking
        );
    }

    #[test]
    fn disagg_parse_rejects_garbage_with_reasons() {
        // PR 8 style: every rejection is a Result with a descriptive
        // message, never a panic — the CLI surfaces these verbatim.
        let e = DisaggSpec::parse("3").unwrap_err();
        assert!(e.contains("P:D"), "{e}");
        let e = DisaggSpec::parse("a:1").unwrap_err();
        assert!(e.contains("prefill"), "{e}");
        let e = DisaggSpec::parse("1:b").unwrap_err();
        assert!(e.contains("decode"), "{e}");
        let e = DisaggSpec::parse("0:2").unwrap_err();
        assert!(e.contains("at least one node"), "{e}");
        assert!(DisaggSpec::parse("1:0").is_err());
        assert!(DisaggSpec::parse("").is_err());
    }

    #[test]
    fn with_disagg_sizes_the_world() {
        let c = ServeConfig::new(&LLAMA31_8B, FetchImpl::DmaB2b)
            .with_disagg(DisaggSpec::parse("3:1").unwrap());
        assert_eq!(c.num_nodes, 4);
        assert_eq!(c.world_size(), 32);
        assert!(c.disagg.is_some());
        // Default stays colocated.
        assert!(ServeConfig::new(&LLAMA31_8B, FetchImpl::DmaB2b).disagg.is_none());
    }
}
